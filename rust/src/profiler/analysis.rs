//! The central Stage Analysis Service (§4.1): ingests stage events from all
//! nodes, pairs begin/end, and maintains the duration database the figures
//! query.

use crate::profiler::events::{EventKind, Stage, StageEvent, JOB_LEVEL};
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};

/// One computed stage duration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DurationRow {
    pub job: u64,
    pub attempt: u32,
    pub node: u32,
    pub stage: Stage,
    pub begin: f64,
    pub end: f64,
}

impl DurationRow {
    pub fn duration(&self) -> f64 {
        self.end - self.begin
    }
}

/// The duration database.
#[derive(Clone, Debug, Default)]
pub struct DurationDb {
    pub rows: Vec<DurationRow>,
    /// GPUs requested per job (attached metadata for per-scale queries).
    pub job_gpus: BTreeMap<u64, u32>,
}

impl DurationDb {
    /// All durations of `stage`, node-level (excludes job-level rows).
    pub fn node_durations(&self, stage: Stage) -> Vec<f64> {
        self.rows
            .iter()
            .filter(|r| r.stage == stage && r.node != JOB_LEVEL)
            .map(|r| r.duration())
            .collect()
    }

    /// Durations of `stage` for one job.
    pub fn job_stage_durations(&self, job: u64, stage: Stage) -> Vec<f64> {
        self.rows
            .iter()
            .filter(|r| r.job == job && r.stage == stage && r.node != JOB_LEVEL)
            .map(|r| r.duration())
            .collect()
    }

    /// Attempts recorded for a job.
    pub fn attempts(&self, job: u64) -> Vec<u32> {
        let mut v: Vec<u32> =
            self.rows.iter().filter(|r| r.job == job).map(|r| r.attempt).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Job-level stage span within one attempt: min(begin) → max(end)
    /// across nodes (or the job-level row for pre-worker stages).
    pub fn attempt_stage_span(&self, job: u64, attempt: u32, stage: Stage) -> Option<(f64, f64)> {
        let rows: Vec<&DurationRow> = self
            .rows
            .iter()
            .filter(|r| r.job == job && r.attempt == attempt && r.stage == stage)
            .collect();
        if rows.is_empty() {
            return None;
        }
        let b = rows.iter().map(|r| r.begin).fold(f64::INFINITY, f64::min);
        let e = rows.iter().map(|r| r.end).fold(f64::NEG_INFINITY, f64::max);
        Some((b, e))
    }

    /// Job-level stage span: min(begin) → max(end) across nodes (or the
    /// job-level row for pre-worker stages).
    pub fn job_stage_span(&self, job: u64, stage: Stage) -> Option<(f64, f64)> {
        let rows: Vec<&DurationRow> =
            self.rows.iter().filter(|r| r.job == job && r.stage == stage).collect();
        if rows.is_empty() {
            return None;
        }
        let b = rows.iter().map(|r| r.begin).fold(f64::INFINITY, f64::min);
        let e = rows.iter().map(|r| r.end).fold(f64::NEG_INFINITY, f64::max);
        Some((b, e))
    }

    /// Job-level startup overhead (§3.1): submission → training begins.
    pub fn job_startup_overhead(&self, job: u64) -> Option<f64> {
        self.job_stage_span(job, Stage::Training).map(|(b, _)| b)
    }

    /// Node-level startup overhead (§3.1) for one attempt: sum of the
    /// node's own stage durations (excluding waiting on other nodes), plus
    /// the attempt's queuing+allocation spans (node names are assigned at
    /// submission time, before resources exist).
    pub fn node_startup_overhead(&self, job: u64, attempt: u32, node: u32) -> Option<f64> {
        let own: f64 = self
            .rows
            .iter()
            .filter(|r| {
                r.job == job
                    && r.attempt == attempt
                    && r.node == node
                    && Stage::WORKER_PHASE.contains(&r.stage)
            })
            .map(|r| r.duration())
            .sum();
        let pre: f64 = [Stage::Queuing, Stage::Allocation]
            .iter()
            .filter_map(|&s| self.attempt_stage_span(job, attempt, s))
            .map(|(b, e)| e - b)
            .sum();
        if own == 0.0 {
            None
        } else {
            Some(own + pre)
        }
    }

    /// All node ids seen for a job (excluding job-level).
    pub fn job_nodes(&self, job: u64) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .rows
            .iter()
            .filter(|r| r.job == job && r.node != JOB_LEVEL)
            .map(|r| r.node)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn jobs(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.rows.iter().map(|r| r.job).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Export all rows as JSON (for offline plotting).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("job", r.job)
                    .set("attempt", r.attempt as u64)
                    .set("node", r.node as u64)
                    .set("stage", r.stage.name())
                    .set("begin", r.begin)
                    .set("end", r.end);
                o
            })
            .collect();
        let mut out = Json::obj();
        out.set("rows", Json::Arr(rows));
        out
    }
}

/// Pairs begin/end events into duration rows. `Clone` lets the batched
/// replay ([`crate::trace::batch_replay`]) hand duplicate candidates a copy
/// of their leader's fully-aggregated result instead of re-replaying.
#[derive(Clone, Debug, Default)]
pub struct StageAnalysisService {
    // detlint::allow(hash-container, "begin/end pairing scratch: keyed insert/remove only, never iterated, so hash order cannot reach a result")
    open: HashMap<(u64, u32, u32, Stage), f64>,
    pub db: DurationDb,
    /// Events that ended without a begin (or doubled begins) — surfaced so
    /// bugs in instrumentation are visible, as in the real service.
    pub anomalies: Vec<StageEvent>,
}

impl StageAnalysisService {
    pub fn new() -> StageAnalysisService {
        StageAnalysisService::default()
    }

    /// Record job metadata (gpus requested).
    pub fn register_job(&mut self, job: u64, gpus: u32) {
        self.db.job_gpus.insert(job, gpus);
    }

    pub fn ingest(&mut self, ev: StageEvent) {
        let key = (ev.job, ev.attempt, ev.node, ev.stage);
        match ev.kind {
            EventKind::Begin => {
                if self.open.insert(key, ev.ts).is_some() {
                    self.anomalies.push(ev);
                }
            }
            EventKind::End => match self.open.remove(&key) {
                Some(begin) if ev.ts >= begin => self.db.rows.push(DurationRow {
                    job: ev.job,
                    attempt: ev.attempt,
                    node: ev.node,
                    stage: ev.stage,
                    begin,
                    end: ev.ts,
                }),
                _ => self.anomalies.push(ev),
            },
        }
    }

    pub fn ingest_all(&mut self, evs: impl IntoIterator<Item = StageEvent>) {
        for e in evs {
            self.ingest(e);
        }
    }

    /// Stages still open (never ended) — startup hangs show up here.
    pub fn open_stages(&self) -> usize {
        self.open.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::events::JOB_LEVEL;
    use crate::profiler::parser::LogParser;

    fn ev(job: u64, node: u32, stage: Stage, kind: EventKind, ts: f64) -> StageEvent {
        StageEvent { job, attempt: 0, node, stage, kind, ts }
    }

    #[test]
    fn pairs_begin_end() {
        let mut svc = StageAnalysisService::new();
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::Begin, 10.0));
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::End, 25.0));
        assert_eq!(svc.db.rows.len(), 1);
        assert_eq!(svc.db.rows[0].duration(), 15.0);
        assert_eq!(svc.open_stages(), 0);
        assert!(svc.anomalies.is_empty());
    }

    #[test]
    fn flags_end_without_begin() {
        let mut svc = StageAnalysisService::new();
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::End, 25.0));
        assert!(svc.db.rows.is_empty());
        assert_eq!(svc.anomalies.len(), 1);
    }

    #[test]
    fn flags_negative_duration() {
        let mut svc = StageAnalysisService::new();
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::Begin, 30.0));
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::End, 25.0));
        assert!(svc.db.rows.is_empty());
        assert_eq!(svc.anomalies.len(), 1);
    }

    #[test]
    fn job_level_and_node_level_split() {
        let mut svc = StageAnalysisService::new();
        svc.ingest(ev(1, JOB_LEVEL, Stage::Queuing, EventKind::Begin, 0.0));
        svc.ingest(ev(1, JOB_LEVEL, Stage::Queuing, EventKind::End, 100.0));
        svc.ingest(ev(1, 0, Stage::ImageLoading, EventKind::Begin, 102.0));
        svc.ingest(ev(1, 0, Stage::ImageLoading, EventKind::End, 130.0));
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::Begin, 130.0));
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::End, 150.0));
        svc.ingest(ev(1, 0, Stage::ModelInit, EventKind::Begin, 150.0));
        svc.ingest(ev(1, 0, Stage::ModelInit, EventKind::End, 170.0));
        let node = svc.db.node_startup_overhead(1, 0, 0).unwrap();
        // 100 (queuing) + 28 + 20 + 20 (worker stages), allocation absent.
        assert!((node - 168.0).abs() < 1e-9, "node overhead {node}");
        assert_eq!(svc.db.node_durations(Stage::Queuing), Vec::<f64>::new());
    }

    #[test]
    fn job_startup_overhead_is_training_begin() {
        let mut svc = StageAnalysisService::new();
        svc.ingest(ev(3, 0, Stage::Training, EventKind::Begin, 412.0));
        svc.ingest(ev(3, 0, Stage::Training, EventKind::End, 1000.0));
        assert_eq!(svc.db.job_startup_overhead(3), Some(412.0));
    }

    #[test]
    fn full_loop_through_log_lines() {
        // The §4.1 pipeline: events → log text → parser → service → db.
        let events = vec![
            ev(9, 0, Stage::InstallScript, EventKind::Begin, 5.0),
            ev(9, 1, Stage::InstallScript, EventKind::Begin, 5.5),
            ev(9, 0, Stage::InstallScript, EventKind::End, 45.0),
            ev(9, 1, Stage::InstallScript, EventKind::End, 95.5),
        ];
        let log: String =
            events.iter().map(|e| e.log_line() + "\n").collect::<String>() + "noise\n";
        let mut svc = StageAnalysisService::new();
        svc.ingest_all(LogParser::parse_stream(&log));
        let durs = svc.db.job_stage_durations(9, Stage::InstallScript);
        assert_eq!(durs, vec![40.0, 90.0]);
        assert_eq!(svc.db.job_nodes(9), vec![0, 1]);
        assert_eq!(svc.db.jobs(), vec![9]);
    }

    #[test]
    fn json_export_parses() {
        let mut svc = StageAnalysisService::new();
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::Begin, 1.0));
        svc.ingest(ev(1, 0, Stage::EnvSetup, EventKind::End, 2.0));
        let j = svc.db.to_json();
        let text = j.to_pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }
}
