//! Stage-transition events and their log-line representation.

use std::fmt;

/// Startup stages (paper Figure 2). `InstallScript` is the sub-stage of
/// EnvSetup whose duration is the §3.3 straggler proxy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Stage {
    Queuing,
    Allocation,
    ImageLoading,
    EnvSetup,
    InstallScript,
    ModelInit,
    Training,
}

impl Stage {
    pub const ALL: [Stage; 7] = [
        Stage::Queuing,
        Stage::Allocation,
        Stage::ImageLoading,
        Stage::EnvSetup,
        Stage::InstallScript,
        Stage::ModelInit,
        Stage::Training,
    ];

    /// The GPU-consuming Worker Phase stages (§2.3) — the ones that waste
    /// GPU resources and that BootSeer optimizes.
    pub const WORKER_PHASE: [Stage; 3] =
        [Stage::ImageLoading, Stage::EnvSetup, Stage::ModelInit];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Queuing => "queuing",
            Stage::Allocation => "allocation",
            Stage::ImageLoading => "image_loading",
            Stage::EnvSetup => "env_setup",
            Stage::InstallScript => "install_script",
            Stage::ModelInit => "model_init",
            Stage::Training => "training",
        }
    }

    pub fn parse(s: &str) -> Option<Stage> {
        Stage::ALL.iter().copied().find(|st| st.name() == s)
    }
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Begin,
    End,
}

/// One stage transition on one node of one job.
#[derive(Clone, Debug, PartialEq)]
pub struct StageEvent {
    pub job: u64,
    /// Startup attempt number (restarts of one job are separate attempts).
    pub attempt: u32,
    /// Node index within the job; `u32::MAX` marks a job-level event
    /// (queuing/allocation happen before nodes exist).
    pub node: u32,
    pub stage: Stage,
    pub kind: EventKind,
    /// Timestamp, seconds since job submission.
    pub ts: f64,
}

/// Job-level pseudo-node id.
pub const JOB_LEVEL: u32 = u32::MAX;

impl StageEvent {
    /// Render as the log line the worker emits ('print'/'echo' style §4.1).
    pub fn log_line(&self) -> String {
        let kind = match self.kind {
            EventKind::Begin => "begin",
            EventKind::End => "end",
        };
        format!(
            "[bootseer] ts={:.6} job={} attempt={} node={} stage={} event={}",
            self.ts, self.job, self.attempt, self.node, self.stage, kind
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_name_roundtrip() {
        for s in Stage::ALL {
            assert_eq!(Stage::parse(s.name()), Some(s));
        }
        assert_eq!(Stage::parse("bogus"), None);
    }

    #[test]
    fn log_line_format() {
        let e = StageEvent {
            job: 7,
            attempt: 2,
            node: 3,
            stage: Stage::EnvSetup,
            kind: EventKind::Begin,
            ts: 12.5,
        };
        assert_eq!(
            e.log_line(),
            "[bootseer] ts=12.500000 job=7 attempt=2 node=3 stage=env_setup event=begin"
        );
    }

    #[test]
    fn worker_phase_subset() {
        for s in Stage::WORKER_PHASE {
            assert!(Stage::ALL.contains(&s));
        }
        assert!(!Stage::WORKER_PHASE.contains(&Stage::Queuing));
    }
}
