//! BootSeer's profiling system (§4.1, Figure 8).
//!
//! Worker nodes log stage transitions as plain text lines; a per-node Log
//! Parser extracts `StageEvent`s; the central Stage Analysis Service groups
//! begin/end pairs into durations and stores them in a queryable duration
//! DB. Every §3 figure in this repo is produced from this pipeline — the
//! startup simulator *prints log lines* and the analysis service computes
//! everything downstream, exactly like the production deployment.

pub mod analysis;
pub mod events;
pub mod parser;

pub use analysis::{DurationDb, StageAnalysisService};
pub use events::{EventKind, Stage, StageEvent};
pub use parser::LogParser;
