//! The per-node Log Parser (§4.1): extracts `StageEvent`s from raw worker
//! log streams, tolerating interleaved non-bootseer lines.

use crate::profiler::events::{EventKind, Stage, StageEvent};
use once_cell::sync::Lazy;
use regex::Regex;

static LINE_RE: Lazy<Regex> = Lazy::new(|| {
    Regex::new(
        r"^\[bootseer\] ts=([0-9]+(?:\.[0-9]+)?) job=([0-9]+) attempt=([0-9]+) node=([0-9]+) stage=([a-z_]+) event=(begin|end)$",
    )
    .expect("static regex")
});

/// Stateless log parser.
pub struct LogParser;

impl LogParser {
    /// Parse one line; `None` if it is not a bootseer stage line.
    pub fn parse_line(line: &str) -> Option<StageEvent> {
        let caps = LINE_RE.captures(line.trim())?;
        Some(StageEvent {
            ts: caps[1].parse().ok()?,
            job: caps[2].parse().ok()?,
            attempt: caps[3].parse().ok()?,
            node: caps[4].parse().ok()?,
            stage: Stage::parse(&caps[5])?,
            kind: if &caps[6] == "begin" { EventKind::Begin } else { EventKind::End },
        })
    }

    /// Parse a whole log stream, skipping foreign lines.
    pub fn parse_stream(text: &str) -> Vec<StageEvent> {
        text.lines().filter_map(Self::parse_line).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_log_line() {
        let e = StageEvent {
            job: 42,
            attempt: 1,
            node: 7,
            stage: Stage::ImageLoading,
            kind: EventKind::End,
            ts: 98.25,
        };
        assert_eq!(LogParser::parse_line(&e.log_line()), Some(e));
    }

    #[test]
    fn skips_foreign_lines() {
        let text = "\
random stderr noise
[bootseer] ts=1.000000 job=1 attempt=0 node=0 stage=env_setup event=begin
pip install torch... done
[bootseer] ts=9.000000 job=1 attempt=0 node=0 stage=env_setup event=end
";
        let evs = LogParser::parse_stream(text);
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, EventKind::Begin);
        assert_eq!(evs[1].ts, 9.0);
    }

    #[test]
    fn rejects_malformed() {
        assert!(LogParser::parse_line("[bootseer] ts=x job=1 attempt=0 node=0 stage=env_setup event=begin").is_none());
        assert!(LogParser::parse_line("[bootseer] ts=1 job=1 attempt=0 node=0 stage=nope event=begin").is_none());
        assert!(LogParser::parse_line("").is_none());
    }

    #[test]
    fn tolerates_whitespace() {
        let e = StageEvent {
            job: 1,
            attempt: 0,
            node: 2,
            stage: Stage::ModelInit,
            kind: EventKind::Begin,
            ts: 3.0,
        };
        assert_eq!(LogParser::parse_line(&format!("  {}  ", e.log_line())), Some(e));
    }
}
