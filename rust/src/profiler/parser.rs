//! The per-node Log Parser (§4.1): extracts `StageEvent`s from raw worker
//! log streams, tolerating interleaved non-bootseer lines. Hand-rolled
//! field parsing (the offline crate set has no `regex`): a line matches
//! exactly `[bootseer] ts=F job=N attempt=N node=N stage=S event=begin|end`.

use crate::profiler::events::{EventKind, Stage, StageEvent};

/// Strip `key=` from a token, leaving the value.
fn field<'a>(tok: &'a str, key: &str) -> Option<&'a str> {
    tok.strip_prefix(key)?.strip_prefix('=')
}

/// Parse a non-negative decimal with optional fraction (the regex accepted
/// `[0-9]+(\.[0-9]+)?` — notably not `1e5`, `inf`, or a leading sign).
fn parse_ts(s: &str) -> Option<f64> {
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_digit() || b == b'.') {
        return None;
    }
    let mut parts = s.split('.');
    let int = parts.next()?;
    if int.is_empty() {
        return None;
    }
    if let Some(frac) = parts.next() {
        if frac.is_empty() || parts.next().is_some() {
            return None;
        }
    }
    s.parse().ok()
}

/// Stateless log parser.
pub struct LogParser;

impl LogParser {
    /// Parse one line; `None` if it is not a bootseer stage line.
    pub fn parse_line(line: &str) -> Option<StageEvent> {
        let mut toks = line.trim().split(' ');
        if toks.next()? != "[bootseer]" {
            return None;
        }
        let ts = parse_ts(field(toks.next()?, "ts")?)?;
        let job = field(toks.next()?, "job")?.parse().ok()?;
        let attempt = field(toks.next()?, "attempt")?.parse().ok()?;
        let node = field(toks.next()?, "node")?.parse().ok()?;
        let stage = Stage::parse(field(toks.next()?, "stage")?)?;
        let kind = match field(toks.next()?, "event")? {
            "begin" => EventKind::Begin,
            "end" => EventKind::End,
            _ => return None,
        };
        if toks.next().is_some() {
            return None; // trailing junk → not one of our lines
        }
        Some(StageEvent { ts, job, attempt, node, stage, kind })
    }

    /// Parse a whole log stream, skipping foreign lines.
    pub fn parse_stream(text: &str) -> Vec<StageEvent> {
        text.lines().filter_map(Self::parse_line).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_log_line() {
        let e = StageEvent {
            job: 42,
            attempt: 1,
            node: 7,
            stage: Stage::ImageLoading,
            kind: EventKind::End,
            ts: 98.25,
        };
        assert_eq!(LogParser::parse_line(&e.log_line()), Some(e));
    }

    #[test]
    fn skips_foreign_lines() {
        let text = "\
random stderr noise
[bootseer] ts=1.000000 job=1 attempt=0 node=0 stage=env_setup event=begin
pip install torch... done
[bootseer] ts=9.000000 job=1 attempt=0 node=0 stage=env_setup event=end
";
        let evs = LogParser::parse_stream(text);
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, EventKind::Begin);
        assert_eq!(evs[1].ts, 9.0);
    }

    #[test]
    fn rejects_malformed() {
        let bad_ts = "[bootseer] ts=x job=1 attempt=0 node=0 stage=env_setup event=begin";
        assert!(LogParser::parse_line(bad_ts).is_none());
        let bad_stage = "[bootseer] ts=1 job=1 attempt=0 node=0 stage=nope event=begin";
        assert!(LogParser::parse_line(bad_stage).is_none());
        assert!(LogParser::parse_line("").is_none());
    }

    #[test]
    fn rejects_malformed_field_variants() {
        // Truncated after any field.
        for line in [
            "[bootseer]",
            "[bootseer] ts=1.0",
            "[bootseer] ts=1.0 job=1 attempt=0 node=0 stage=env_setup",
        ] {
            assert!(LogParser::parse_line(line).is_none(), "{line:?}");
        }
        // Fields out of order, duplicated, or with junk values.
        for line in [
            "[bootseer] job=1 ts=1.0 attempt=0 node=0 stage=env_setup event=begin",
            "[bootseer] ts=1.0 ts=2.0 job=1 attempt=0 node=0 stage=env_setup event=begin",
            "[bootseer] ts=1e5 job=1 attempt=0 node=0 stage=env_setup event=begin",
            "[bootseer] ts=-1.0 job=1 attempt=0 node=0 stage=env_setup event=begin",
            "[bootseer] ts=1..0 job=1 attempt=0 node=0 stage=env_setup event=begin",
            "[bootseer] ts=. job=1 attempt=0 node=0 stage=env_setup event=begin",
            "[bootseer] ts=1.0 job=-1 attempt=0 node=0 stage=env_setup event=begin",
            "[bootseer] ts=1.0 job=1 attempt=0 node=0 stage=env_setup event=done",
            "[bootseer] ts=1.0 job=1 attempt=0 node=0 stage=env_setup event=begin extra",
        ] {
            assert!(LogParser::parse_line(line).is_none(), "{line:?}");
        }
    }

    #[test]
    fn interleaved_and_partial_lines() {
        // A stream where bootseer lines are interleaved with partial copies
        // of themselves (a torn write, a pip progress bar, an empty line):
        // only the well-formed lines survive.
        let text = "\
[bootseer] ts=1.000000 job=3 attempt=0 node=0 stage=image_loading event=begin
[bootseer] ts=2.000000 job=3 attempt=0 node=0 stage=image_load
Collecting torch [bootseer] ts=9 job=3
[bootseer] ts=2.500000 job=3 attempt=0 node=0 stage=image_loading event=end

[bootseer] ts=3.000000 job=3 attempt=0 node=1 stage=env_setup event=begin
";
        let evs = LogParser::parse_stream(text);
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].stage, Stage::ImageLoading);
        assert_eq!(evs[1].ts, 2.5);
        assert_eq!(evs[2].node, 1);
    }

    #[test]
    fn tolerates_whitespace() {
        let e = StageEvent {
            job: 1,
            attempt: 0,
            node: 2,
            stage: Stage::ModelInit,
            kind: EventKind::Begin,
            ts: 3.0,
        };
        assert_eq!(LogParser::parse_line(&format!("  {}  ", e.log_line())), Some(e));
    }
}
