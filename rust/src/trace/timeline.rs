//! Epoch-sharded replay timeline: time partitioning, cross-epoch handoff,
//! contention-integral subranges, and warm-cache carry for
//! [`super::replay_cluster`]'s phase 1.5/2.
//!
//! The unit list is partitioned into `E` equal-width time epochs by start
//! time. Everything a unit observes from *other* units — shared image /
//! env availability ([`super::SharedWorld`]) and the fleet contention
//! integral — is folded per epoch and merged at epoch boundaries by pure,
//! order-independent min-folds, so the partitioned replay is byte-identical
//! to the unpartitioned one at any epoch count:
//!
//! * **Availability** ([`EpochHandoff`]): an identity's availability is the
//!   min estimated end over the startups producing it. A contributor with
//!   `end ≤ t` necessarily *started* before `t` (estimates are positive),
//!   and epoch assignment is monotone in start time — so the prefix fold of
//!   epochs `0..=e` answers every query from epoch `e` exactly as the
//!   global map would. Min-merge is commutative, associative and
//!   idempotent, so the fold is order-independent.
//! * **Contention** ([`ContentionTimeline`]): the step-function integral is
//!   queried only at `t ≥` the querying unit's start, so each epoch's
//!   queries can skip the strictly-earlier prefix of the breakpoint array.
//!   The skip is anchored on the epoch's *actual* minimum unit start (not
//!   the nominal boundary), which makes it exact under any floating-point
//!   quirk of the epoch division.
//! * **Warm carry** ([`WarmCarry`] / [`seed_warm_cache`]): the per-job
//!   constants a warm restart seeds its bounded [`CacheState`] from,
//!   hoisted out of the per-unit hot path. Insert order (hot set → pin →
//!   env snapshot → delta shard → churn) and the churn arithmetic are
//!   preserved exactly — the eviction-order goldens in `super::tests` pin
//!   them.

use crate::artifact::cache::CacheState;
use crate::config::defaults as d;
use crate::config::BootseerConfig;
use crate::util::cast::bytes_from_f64;
use crate::util::rng::mix64;
use crate::util::salts::SALT_CHURN;
use std::collections::BTreeMap;
use std::sync::Arc;

use super::{SharedEnv, SharedImage, SharedWorld};

/// Equal-width partition of `[0, horizon]` into `epochs` time slices.
pub(crate) struct EpochTimeline {
    pub epochs: usize,
    width_s: f64,
}

impl EpochTimeline {
    pub fn new(horizon_s: f64, epochs: usize) -> EpochTimeline {
        let epochs = epochs.max(1);
        EpochTimeline {
            epochs,
            width_s: (horizon_s / epochs as f64).max(f64::MIN_POSITIVE),
        }
    }

    /// Epoch index of a start time — monotone in `start_s` (this is what
    /// the handoff-prefix argument above relies on), clamped into range so
    /// schedule overrun past the nominal horizon stays total.
    pub fn epoch_of(&self, start_s: f64) -> usize {
        (((start_s / self.width_s).floor()) as usize).min(self.epochs - 1)
    }
}

/// One epoch's contribution to shared warm-state availability: earliest
/// estimated end per image digest / env signature among the epoch's units.
///
/// [`EpochHandoff::absorb`] is a min-merge — commutative, associative,
/// idempotent — so folding contributions in any order (or more than once)
/// yields the same map; the replay folds them as a prefix over epochs.
#[derive(Default, Clone)]
pub(crate) struct EpochHandoff {
    img_avail: BTreeMap<u64, f64>,
    env_avail: BTreeMap<u64, f64>,
}

impl EpochHandoff {
    /// Record a full startup of image `digest` estimated to end at `end_s`.
    pub fn note_image(&mut self, digest: u64, end_s: f64) {
        let e = self.img_avail.entry(digest).or_insert(f64::INFINITY);
        *e = e.min(end_s);
    }

    /// Record a startup of env signature `sig` estimated to end at `end_s`.
    pub fn note_env(&mut self, sig: u64, end_s: f64) {
        let e = self.env_avail.entry(sig).or_insert(f64::INFINITY);
        *e = e.min(end_s);
    }

    /// Min-merge another epoch's contribution into this one.
    pub fn absorb(&mut self, other: &EpochHandoff) {
        for (&k, &v) in &other.img_avail {
            let e = self.img_avail.entry(k).or_insert(f64::INFINITY);
            *e = e.min(v);
        }
        for (&k, &v) in &other.env_avail {
            let e = self.env_avail.entry(k).or_insert(f64::INFINITY);
            *e = e.min(v);
        }
    }
}

/// Fold per-epoch handoffs into one [`SharedWorld`] per epoch: epoch `e`'s
/// world is the merge of contributions from epochs `0..=e`. Hot-block lists
/// are shared by [`Arc`], so `E` worlds cost `E` map clones, not `E` copies
/// of every image's block list.
pub(crate) fn fold_worlds(
    handoffs: &[EpochHandoff],
    img_blocks: &BTreeMap<u64, Arc<Vec<u32>>>,
    env_bytes: &BTreeMap<u64, u64>,
) -> Vec<SharedWorld> {
    let mut acc = EpochHandoff::default();
    handoffs
        .iter()
        .map(|h| {
            acc.absorb(h);
            let images = acc
                .img_avail
                .iter()
                .filter_map(|(&digest, &avail)| {
                    img_blocks.get(&digest).map(|blocks| {
                        let img =
                            SharedImage { hot_blocks: Arc::clone(blocks), available_s: avail };
                        (digest, img)
                    })
                })
                .collect();
            let envs = acc
                .env_avail
                .iter()
                .filter_map(|(&sig, &avail)| {
                    env_bytes
                        .get(&sig)
                        .map(|&cb| (sig, SharedEnv { cache_bytes: cb, available_s: avail }))
                })
                .collect();
            SharedWorld { images, envs }
        })
        .collect()
}

/// The fleet contention step function `A(t)` (concurrently-starting nodes)
/// as breakpoint arrays with a prefix integral, supporting exact subrange
/// queries so per-epoch scans skip the strictly-earlier breakpoints.
pub(crate) struct ContentionTimeline {
    times: Vec<f64>,
    level: Vec<f64>,
    pref: Vec<f64>,
}

impl ContentionTimeline {
    /// Build from `(time, node-delta)` events. Sorting and the prefix
    /// accumulation reproduce the pre-sharding sweep exactly (stable sort,
    /// same accumulation order).
    pub fn build(mut pts: Vec<(f64, f64)>) -> ContentionTimeline {
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut times: Vec<f64> = Vec::with_capacity(pts.len());
        let mut level: Vec<f64> = Vec::with_capacity(pts.len());
        let mut pref: Vec<f64> = Vec::with_capacity(pts.len());
        let mut cur = 0.0f64;
        let mut acc = 0.0f64;
        for &(t, dl) in &pts {
            if let Some(&lt) = times.last() {
                acc += cur * (t - lt);
            }
            times.push(t);
            pref.push(acc);
            cur += dl;
            level.push(cur);
        }
        ContentionTimeline { times, level, pref }
    }

    /// Index of the first breakpoint `≥ t_lo`: every query at `x ≥ t_lo`
    /// may start its search here instead of at 0.
    pub fn lower_bound(&self, t_lo: f64) -> usize {
        self.times.partition_point(|&t| t < t_lo)
    }

    /// `∫₀ˣ A(t) dt`, searching only breakpoints `≥ lo` (from
    /// [`Self::lower_bound`]). Bit-identical to the full-range query for
    /// every `x` at or above the bound's time: the skipped prefix is
    /// strictly below it, so the located interval — and hence the float
    /// arithmetic — is the same.
    pub fn integral_at_from(&self, lo: usize, x: f64) -> f64 {
        debug_assert!(lo == 0 || self.times[lo - 1] <= x, "query below subrange anchor");
        let i = lo + self.times[lo..].partition_point(|&t| t <= x);
        if i == 0 {
            0.0
        } else {
            self.pref[i - 1] + self.level[i - 1] * (x - self.times[i - 1])
        }
    }

    /// Full-range `∫₀ˣ A(t) dt`.
    #[cfg(test)]
    pub fn integral_at(&self, x: f64) -> f64 {
        self.integral_at_from(0, x)
    }
}

/// Per-job constants a warm local restart seeds its node cache from,
/// computed once per job instead of once per unit. The delta-shard bytes
/// (`retained_resume_bytes_per_node`) depend only on the job's parallelism
/// and the cluster's `gpus_per_node` — which `effective_cluster` never
/// changes — so hoisting them from the per-unit effective cluster to the
/// per-job seed cluster is bit-identical.
#[derive(Debug)]
pub(crate) struct WarmCarry {
    /// Image hot-set artifact: (manifest id, bytes).
    pub hot_id: u64,
    pub hot_bytes: u64,
    /// Env snapshot artifact: (manifest id, bytes).
    pub env_id: u64,
    pub env_bytes: u64,
    /// Retained checkpoint shard `(manifest id, bytes)`. Computed
    /// unconditionally by the prefix build (it is a pure function of the
    /// job config and cluster, both config-invariant), so one
    /// [`super::batch::ReplayPrefix`] serves candidates on either side of
    /// the `delta_resume` knob; [`seed_warm_cache`] applies the gate.
    pub delta: Option<(u64, u64)>,
}

/// Build the [`CacheState`] a warm local restart starts from. Preserves the
/// pre-sharding insert order exactly — hot set, optional pin, env snapshot,
/// optional delta shard, then (bounded only) the log-uniform churn other
/// tenants wrote to the node's disk, inserted *last* so the eviction policy
/// must defend the warm artifacts against it.
pub(crate) fn seed_warm_cache(
    cfg: &BootseerConfig,
    carry: &WarmCarry,
    seed: u64,
    job_id: u64,
    attempt: u32,
) -> CacheState {
    let bounded = cfg.cache_capacity_bytes != u64::MAX;
    let mut cache = if bounded {
        CacheState::with_capacity(cfg.cache_capacity_bytes, cfg.cache_policy)
    } else {
        CacheState::new()
    };
    cache.insert_shared_artifact(carry.hot_id, carry.hot_bytes);
    if bounded && cfg.cache_policy.pins_hot_set() {
        cache.pin_shared_artifact(carry.hot_id);
    }
    cache.insert_shared_artifact(carry.env_id, carry.env_bytes);
    if let Some((id, bytes)) = carry.delta.filter(|_| cfg.delta_resume) {
        cache.insert_shared_artifact(id, bytes);
    }
    if bounded {
        // Log-uniform churn in [min, min·2^doublings), a pure function of
        // (seed, job, attempt).
        let h = mix64(
            seed ^ SALT_CHURN
                ^ job_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64).wrapping_mul(0xA5A5_5A5A_A5A5_5A5A),
        );
        let uf = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let churn =
            bytes_from_f64(d::CACHE_CHURN_MIN_BYTES as f64 * (d::CACHE_CHURN_DOUBLINGS * uf).exp2());
        cache.insert_shared_artifact(mix64(h ^ SALT_CHURN), churn);
    }
    cache
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CachePolicy;

    #[test]
    fn epoch_of_is_monotone_and_clamped() {
        let tl = EpochTimeline::new(10.0, 5);
        assert_eq!(tl.epoch_of(0.0), 0);
        assert_eq!(tl.epoch_of(1.999), 0);
        assert_eq!(tl.epoch_of(2.0), 1);
        assert_eq!(tl.epoch_of(9.999), 4);
        // Clamped: the nominal horizon boundary and schedule overrun land
        // in the last epoch instead of indexing out of range.
        assert_eq!(tl.epoch_of(10.0), 4);
        assert_eq!(tl.epoch_of(1.0e9), 4);
        // Monotone in start time (the handoff-prefix invariant).
        let mut last = 0;
        for i in 0..1000 {
            let e = tl.epoch_of(i as f64 * 0.0123);
            assert!(e >= last, "epoch_of not monotone at {i}");
            last = e;
        }
        // Degenerate inputs stay total.
        assert_eq!(EpochTimeline::new(0.0, 4).epoch_of(0.0), 0);
        assert_eq!(EpochTimeline::new(100.0, 0).epochs, 1);
    }

    #[test]
    fn handoff_fold_is_order_independent() {
        let mut a = EpochHandoff::default();
        a.note_image(1, 50.0);
        a.note_image(2, 70.0);
        a.note_env(9, 40.0);
        let mut b = EpochHandoff::default();
        b.note_image(1, 30.0);
        b.note_env(9, 90.0);
        b.note_env(8, 15.0);
        let mut c = EpochHandoff::default();
        c.note_image(2, 65.0);
        c.note_image(3, 5.0);

        let fold = |order: &[&EpochHandoff]| {
            let mut acc = EpochHandoff::default();
            for h in order {
                acc.absorb(h);
            }
            let mut img: Vec<(u64, u64)> =
                acc.img_avail.iter().map(|(&k, &v)| (k, v.to_bits())).collect();
            let mut env: Vec<(u64, u64)> =
                acc.env_avail.iter().map(|(&k, &v)| (k, v.to_bits())).collect();
            img.sort_unstable();
            env.sort_unstable();
            (img, env)
        };
        let abc = fold(&[&a, &b, &c]);
        assert_eq!(abc, fold(&[&c, &b, &a]), "commutative");
        assert_eq!(abc, fold(&[&b, &a, &c]));
        assert_eq!(abc, fold(&[&a, &a, &b, &c, &b]), "idempotent");
        assert_eq!(abc.0.iter().find(|&&(k, _)| k == 1).unwrap().1, 30.0f64.to_bits());
        assert_eq!(abc.1.iter().find(|&&(k, _)| k == 9).unwrap().1, 40.0f64.to_bits());
    }

    #[test]
    fn subrange_integral_matches_full_scan_bitwise() {
        // Irregular steps, including duplicate breakpoint times.
        let mut pts = Vec::new();
        for i in 0..200u64 {
            let t = (mix64(i) % 10_000) as f64 * 0.37;
            let n = (1 + mix64(i ^ 0xABCD) % 64) as f64;
            pts.push((t, n));
            pts.push((t + 150.0 + (i % 7) as f64 * 33.3, -n));
        }
        let tl = ContentionTimeline::build(pts);
        // For several anchors, every query at or above the anchor must be
        // bit-identical through the subrange search.
        for &t0 in &[0.0, 11.1, 370.0, 1234.5, 3600.0, 9999.0] {
            let lo = tl.lower_bound(t0);
            for k in 0..50 {
                let x = t0 + k as f64 * 77.7;
                assert_eq!(
                    tl.integral_at_from(lo, x).to_bits(),
                    tl.integral_at(x).to_bits(),
                    "t0={t0} x={x}"
                );
            }
        }
    }

    #[test]
    fn warm_seed_insert_order_feeds_churn_last() {
        let carry = WarmCarry {
            hot_id: 0xAA,
            hot_bytes: 600_000_000,
            env_id: 0xBB,
            env_bytes: 250_000_000,
            delta: None,
        };
        // Capacity exactly hot + env with the pinning policy: churn (≥1 GB,
        // inserted last) must evict exactly the env snapshot — the pinned
        // hot set survives. This pins the insert order; the trace goldens
        // pin the downstream bytes.
        let cfg = BootseerConfig {
            cache_capacity_bytes: carry.hot_bytes + carry.env_bytes,
            cache_policy: CachePolicy::PinHotSet,
            ..BootseerConfig::bootseer()
        };
        let cache = seed_warm_cache(&cfg, &carry, 7, 1, 1);
        assert_eq!(cache.evicted_bytes(), carry.env_bytes);
        // A capacity that never fills evicts nothing, and the same
        // (seed, job, attempt) reproduces the same cache bit-for-bit.
        let huge = BootseerConfig {
            cache_capacity_bytes: 1 << 60,
            ..cfg.clone()
        };
        let a = seed_warm_cache(&huge, &carry, 7, 1, 1);
        let b = seed_warm_cache(&huge, &carry, 7, 1, 1);
        assert_eq!(a.evicted_bytes(), 0);
        assert_eq!(a.used_bytes(0), b.used_bytes(0));
        // The unbounded default carries no churn at all.
        let unbounded =
            BootseerConfig { cache_capacity_bytes: u64::MAX, ..BootseerConfig::bootseer() };
        let u = seed_warm_cache(&unbounded, &carry, 7, 1, 1);
        assert_eq!(u.used_bytes(0), carry.hot_bytes + carry.env_bytes);
    }
}
