//! Production-trace workload generator + replay (§3).
//!
//! The paper's characterization runs over a week of cluster data: 28,000+
//! jobs, 700,000+ requested GPUs, with the distributions reported in §3
//! (most jobs small; large jobs restart 2–8 times, sometimes 20+; queue
//! waits ~100 s median with hour-long tails). `gen_trace` synthesizes a
//! trace with those marginals; `replay` runs every startup of every job
//! through the full pipeline simulator and feeds the profiler, producing
//! the duration DB behind Figures 1 and 3–7.

use crate::config::{BootseerConfig, ClusterConfig, JobConfig};
use crate::profiler::StageAnalysisService;
use crate::startup::{run_startup, StartupKind, StartupOutcome, World};
use crate::util::rng::Rng;

/// One job in the synthetic week.
#[derive(Clone, Debug)]
pub struct TraceJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// Full startups over the job's lifetime (≥1; §3.1: restarts from
    /// debugging, failures, reconfiguration).
    pub full_startups: u32,
    /// Hot updates (partial startups).
    pub hot_updates: u32,
    /// Productive training time between startups, hours.
    pub train_hours: f64,
    pub priority: u32,
}

/// Job-scale buckets used by the §3 figures.
pub const SCALE_BUCKETS: [(u32, u32, &str); 6] = [
    (1, 8, "1-8"),
    (9, 64, "9-64"),
    (65, 128, "65-128"),
    (129, 512, "129-512"),
    (513, 2048, "513-2048"),
    (2049, 11520, ">2048"),
];

/// Bucket index for a GPU count.
pub fn bucket_of(gpus: u32) -> usize {
    SCALE_BUCKETS
        .iter()
        .position(|&(lo, hi, _)| gpus >= lo && gpus <= hi)
        .unwrap_or(SCALE_BUCKETS.len() - 1)
}

fn poisson(rng: &mut Rng, lambda: f64) -> u32 {
    // Knuth's method; fine for the small lambdas used here.
    let l = (-lambda).exp();
    let mut k = 0u32;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
        if k > 1000 {
            return k;
        }
    }
}

/// Synthesize `n_jobs` over a `horizon_s`-second window.
pub fn gen_trace(seed: u64, n_jobs: usize, horizon_s: f64) -> Vec<TraceJob> {
    let mut rng = Rng::seeded(seed ^ 0x7124CE);
    // Scale-bucket weights: most jobs are small (§3.1 Fig 4 right axis).
    let weights = [0.55, 0.20, 0.12, 0.09, 0.035, 0.005];
    (0..n_jobs)
        .map(|i| {
            let b = rng.weighted(&weights);
            let (lo, hi, _) = SCALE_BUCKETS[b];
            let mut gpus = rng.range(lo as u64, hi as u64) as u32;
            if gpus > 8 {
                gpus = (gpus / 8).max(1) * 8; // whole 8-GPU nodes
            }
            // Flagship jobs hold their GPUs for weeks: training time grows
            // with scale (the cluster's GPU-hours are dominated by a few
            // huge long-running jobs, as in any production fleet).
            let train_hours = (rng.lognormal(4f64.ln(), 1.2)
                * (1.0 + gpus as f64 / 256.0))
                .clamp(0.1, 1000.0);
            // Startups per job: failures scale with GPU-hour exposure
            // (hardware faults, loss spikes), plus a debugging component on
            // large jobs; small jobs are mostly single-startup (§3.1).
            let lambda = 2.5e-5 * gpus as f64 * train_hours
                + if gpus >= 100 { 1.0 } else { 0.05 };
            // Debug-storm tail: a few big jobs restart many times (§3.1
            // "20 or more startups ... due to debugging").
            let storm = if gpus >= 100 && train_hours > 4.0 && rng.chance(0.03) {
                rng.range(8, 20) as u32
            } else {
                0
            };
            let full_startups = 1 + poisson(&mut rng, lambda.min(20.0)) + storm;
            let hot_updates = poisson(&mut rng, 0.2 + lambda.min(6.0) / 3.0);
            TraceJob {
                id: i as u64 + 1,
                submit_s: rng.f64() * horizon_s,
                gpus,
                full_startups,
                hot_updates,
                train_hours,
                priority: rng.weighted(&[0.1, 0.7, 0.2]) as u32,
            }
        })
        .collect()
}

/// Summary of one replayed job.
#[derive(Clone, Debug)]
pub struct JobReplay {
    pub job: TraceJob,
    /// Worker-phase seconds of every full startup + hot update.
    pub startup_worker_s: Vec<f64>,
    /// Job-level total (incl. queuing) of the first startup.
    pub first_total_s: f64,
    /// Install-script durations of the last startup (straggler proxy).
    pub install_durations: Vec<f64>,
    /// Per-stage durations (job-level) of the last FULL startup.
    pub last_full: Option<StartupOutcome>,
}

/// Replay output: the profiler DB plus per-job summaries and the Fig-1
/// GPU-hour split.
pub struct ReplayResult {
    pub svc: StageAnalysisService,
    pub jobs: Vec<JobReplay>,
    pub train_gpu_hours: f64,
    pub startup_gpu_hours: f64,
}

impl ReplayResult {
    pub fn startup_fraction(&self) -> f64 {
        self.startup_gpu_hours / (self.startup_gpu_hours + self.train_gpu_hours)
    }
}

/// Replay every startup of every job through the pipeline simulator.
pub fn replay(
    trace: &[TraceJob],
    cluster: &ClusterConfig,
    cfg: &BootseerConfig,
    seed: u64,
) -> ReplayResult {
    let mut svc = StageAnalysisService::new();
    let mut jobs = Vec::with_capacity(trace.len());
    let mut train_gpu_hours = 0.0;
    let mut startup_gpu_hours = 0.0;
    for tj in trace {
        // Smaller jobs run smaller models: image and checkpoint scale with
        // job size (§3.1: "smaller jobs tend to start more quickly, as they
        // typically involve smaller container images and smaller model
        // checkpoints"), and shared services (HDFS, cache, registry) are
        // fleet-sized, not fixed at the 16-node testbed configuration.
        let size_f = (tj.gpus as f64 / 128.0).clamp(0.05, 4.0);
        let img_f = 0.3 + 0.7 * (tj.gpus as f64 / 128.0).min(1.0);
        let base_job = JobConfig::paper_moe(tj.gpus.max(16));
        // Bigger models are sharded wider: scale PP with node count so the
        // per-node resume share stays in the production-realistic range
        // (the paper's fleet-level Fig 5 shows model-init at 100-200 s
        // across all scales).
        let nodes_est = (tj.gpus.max(16) + 7) / 8;
        let job = JobConfig {
            gpus: tj.gpus,
            image_bytes: (base_job.image_bytes as f64 * img_f) as u64,
            ckpt_bytes: (base_job.ckpt_bytes as f64 * size_f) as u64,
            pp: base_job.pp.max(nodes_est / 4),
            ..base_job
        };
        let nodes = job.nodes(cluster).max(1);
        let cluster = ClusterConfig {
            hdfs_datanodes: cluster.hdfs_datanodes.max(nodes * 8),
            cluster_cache_egress_bps: cluster
                .cluster_cache_egress_bps
                .max(nodes as f64 * 1.0e9),
            registry_egress_bps: cluster.registry_egress_bps.max(nodes as f64 * 0.5e9),
            ..cluster.clone()
        };
        let cluster = &cluster;
        let mut world = World::new();
        let mut startup_worker_s = Vec::new();
        let mut first_total = 0.0;
        let mut installs = Vec::new();
        let mut last_full = None;
        svc.register_job(tj.id, tj.gpus);
        for s in 0..tj.full_startups {
            let o = run_startup(
                tj.id,
                s,
                cluster,
                &job,
                cfg,
                &mut world,
                StartupKind::Full,
                seed ^ (s as u64).wrapping_mul(0xA5A5_5A5A),
            );
            if s == 0 {
                first_total = o.total_s;
            }
            startup_worker_s.push(o.worker_phase_s);
            startup_gpu_hours += o.gpu_seconds_wasted() / 3600.0;
            installs = o.install_durations.clone();
            svc.ingest_all(o.events.iter().cloned());
            last_full = Some(o);
        }
        for h in 0..tj.hot_updates {
            let o = run_startup(
                tj.id,
                tj.full_startups + h,
                cluster,
                &job,
                cfg,
                &mut world,
                StartupKind::HotUpdate,
                seed ^ 0xB00F ^ ((h as u64) << 17),
            );
            startup_worker_s.push(o.worker_phase_s);
            startup_gpu_hours += o.gpu_seconds_wasted() / 3600.0;
        }
        train_gpu_hours += tj.gpus as f64 * tj.train_hours;
        jobs.push(JobReplay {
            job: tj.clone(),
            startup_worker_s,
            first_total_s: first_total,
            install_durations: installs,
            last_full,
        });
    }
    ReplayResult { svc, jobs, train_gpu_hours, startup_gpu_hours }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn trace_marginals() {
        let t = gen_trace(1, 4000, 7.0 * 86400.0);
        assert_eq!(t.len(), 4000);
        let small = t.iter().filter(|j| j.gpus < 100).count() as f64 / 4000.0;
        assert!(small > 0.7, "small fraction {small}");
        // Small jobs mostly single-startup.
        let small_single = t
            .iter()
            .filter(|j| j.gpus < 100)
            .filter(|j| j.full_startups == 1)
            .count() as f64
            / t.iter().filter(|j| j.gpus < 100).count() as f64;
        assert!(small_single > 0.75, "single-startup small {small_single}");
        // Large jobs restart more.
        let large: Vec<f64> = t
            .iter()
            .filter(|j| j.gpus >= 1000)
            .map(|j| j.full_startups as f64)
            .collect();
        assert!(!large.is_empty());
        assert!(stats::mean(&large) > 2.0, "large-job startups {}", stats::mean(&large));
        // Total requested GPUs scale like the paper (~700k for 28k jobs →
        // ~25 GPUs/job average... our mixture averages above 8).
        let total: u64 = t.iter().map(|j| j.gpus as u64).sum();
        assert!(total > 100_000, "total gpus {total}");
    }

    #[test]
    fn bucket_of_covers_everything() {
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(8), 0);
        assert_eq!(bucket_of(100), 2);
        assert_eq!(bucket_of(11520), 5);
    }

    #[test]
    fn trace_deterministic() {
        let a = gen_trace(9, 100, 86400.0);
        let b = gen_trace(9, 100, 86400.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.gpus, y.gpus);
            assert_eq!(x.full_startups, y.full_startups);
        }
    }

    #[test]
    fn replay_small_trace() {
        let t = gen_trace(2, 150, 86400.0);
        let r = replay(&t, &ClusterConfig::default(), &BootseerConfig::baseline(), 7);
        assert_eq!(r.jobs.len(), 150);
        assert!(r.train_gpu_hours > 0.0);
        assert!(r.startup_gpu_hours > 0.0);
        let frac = r.startup_fraction();
        // Fig 1 band: startup is a few percent of cluster GPU hours.
        assert!((0.005..0.15).contains(&frac), "startup fraction {frac}");
        // Profiler got events for every job.
        assert_eq!(r.svc.db.jobs().len(), 150);
        assert!(r.svc.anomalies.is_empty());
    }

    #[test]
    fn replay_bootseer_reduces_startup_hours() {
        let t = gen_trace(3, 25, 86400.0);
        let base = replay(&t, &ClusterConfig::default(), &BootseerConfig::baseline(), 7);
        let boot = replay(&t, &ClusterConfig::default(), &BootseerConfig::bootseer(), 7);
        assert!(
            boot.startup_gpu_hours < base.startup_gpu_hours,
            "bootseer {} vs baseline {}",
            boot.startup_gpu_hours,
            base.startup_gpu_hours
        );
    }
}
