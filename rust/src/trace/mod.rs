//! Production-trace workload generator + contention-aware cluster replay
//! (§3).
//!
//! The paper's characterization runs over a week of cluster data: 28,000+
//! jobs, 700,000+ requested GPUs, with the distributions reported in §3
//! (most jobs small; large jobs restart 2–8 times, sometimes 20+; queue
//! waits ~100 s median with hour-long tails). [`gen_trace`] synthesizes a
//! trace with those marginals; [`replay_cluster`] replays every startup of
//! every job and feeds the profiler, producing the duration DB behind
//! Figures 1 and 3–7.
//!
//! The replay is a two-phase engine (design note: `docs/replay.md`):
//!
//! 1. **Schedule** — [`schedule_trace`] turns every job into a
//!    [`crate::scheduler::ChainJob`] (one segment per full startup;
//!    restarts release their GPUs and re-enter the queue, hot updates keep
//!    their allocation) and runs [`crate::scheduler::schedule_chains`] over
//!    a finite GPU pool. Queue waits are *derived from contention*, not
//!    sampled.
//! 2. **Replay** — every startup becomes an independent simulation unit
//!    with a deterministic per-unit seed, replayed in parallel across
//!    threads through the startup stage-graph ([`crate::startup::graph`];
//!    the [`crate::config::OverlapMode`] on the replayed `BootseerConfig`
//!    selects sequential / overlapped / speculative gating). Shared-service
//!    bandwidth (registry, cluster cache, HDFS) is charged against the set
//!    of *concurrently starting* jobs from phase 1, and warm-cache state
//!    (image hot-set records, environment caches) is served from a
//!    [`SharedWorld`] registry keyed by image digest with virtual-time
//!    visibility — so results are byte-identical regardless of thread
//!    count. The unit list is sharded into time **epochs**
//!    ([`ReplayOptions::epochs`], CLI `--epochs`; 0 auto-shards daily):
//!    per-unit prep amortizes per epoch and workers drain the units in
//!    epoch-major order, while a pure, order-independent min-fold carries
//!    warm-state availability across epoch boundaries (`timeline.rs`) —
//!    so the epoch count is a pure performance knob, byte-identical at
//!    every value.
//!
//! A third, optional axis layers **generated faults** over the replay
//! ([`ReplayOptions::faults`], CLI `--faults`, config `[faults]`): the
//! seeded crash hazard of [`crate::faults`] interrupts scheduled segments
//! at their failure instants (phase 1, via
//! [`crate::scheduler::schedule_chains_with`]), rolls training back to the
//! last resume point, and re-queues the restart — warm or cold depending
//! on whether it lands on its previous nodes — while brownout windows and
//! injected stragglers degrade phase 2's effective services. All fault
//! decisions are pure functions of `(seed, identity)`, computed before the
//! parallel phase, so the replay stays byte-identical at any `--threads`;
//! a zero fault rate is byte-identical to the fault-free replay.
//! [`ReplayResult::wasted_fraction`] is the paper's headline metric
//! (">3.5% of GPU time is wasted"), reproduced by
//! [`crate::figures::wasted_gpu_time_sweep`].
//!
//! A fourth axis — **fleet cache economics**
//! (`bootseer.cache_capacity_bytes` / `bootseer.cache_policy`) — bounds
//! every warm restart's node cache: seeded log-uniform disk churn is
//! inserted behind the warm artifacts and the eviction policy decides
//! what survives, while finite registry / cluster-cache slots (the
//! `storm` fault preset) shed and retry the re-fetch wave through
//! [`crate::artifact::Admission`]. Both default off and are then
//! byte-identical to the plain replay; [`ReplayResult::hit_rate`] and
//! [`ReplayResult::shed_rate`] summarize the economics, and
//! [`crate::figures::cache_economics_sweep`] sweeps the capacity knee.
//!
//! A fifth axis — the **hierarchical topology** (`cluster.racks` /
//! `cluster.spines` / `cluster.spine_oversub`; CLI `--racks`,
//! `--spine-oversub`) — places every scheduled gang onto the rack tree
//! with a chronological [`crate::scheduler::RackPool`] walk over phase 1's segments:
//! best-fit single rack, greedy spill across the spine otherwise. Warm
//! restarts re-pin their previous racks; relocated restarts pay
//! `cluster.relocation_cost_s` scaled by how many nodes moved; and
//! rack-scoped brownout windows (`faults.brownout_rack_frac`) only brown
//! out the racks a gang actually spans. The flat default (`racks = 1`)
//! takes none of these paths and replays byte-identically to the
//! pre-topology engine; `docs/topology.md` has the design note.
//!
//! [`replay`] is the convenience wrapper with auto-sized pool and
//! auto-detected threads; `bootseer trace --pool-gpus N --threads T`
//! exposes both knobs.

use crate::config::defaults as d;
use crate::config::{
    BootseerConfig, CachePolicy, ClusterConfig, JobConfig, OverlapMode, RunConfig,
};
use crate::faults::{FaultConfig, FaultEngine};
use crate::profiler::StageAnalysisService;
use crate::scheduler::{schedule_chains_with, ChainJob, ChainOutcome, FaultOracle};
use crate::startup::{StartupKind, StartupOutcome, World};
use crate::util::cast::{bytes_from_f64, u32_from_f64};
use crate::util::rng::{mix64, Rng};
use std::collections::BTreeMap;
use std::sync::Arc;

mod batch;
mod timeline;

pub use batch::{
    batch_replay, build_prefix, evaluate_prefix, BatchOutcome, EvalKey, PrefixKey, ReplayPrefix,
};

/// One job in the synthetic week.
#[derive(Clone, Debug)]
pub struct TraceJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// Full startups over the job's lifetime (≥1; §3.1: restarts from
    /// debugging, failures, reconfiguration).
    pub full_startups: u32,
    /// Hot updates (partial startups).
    pub hot_updates: u32,
    /// Productive training time between startups, hours.
    pub train_hours: f64,
    pub priority: u32,
    /// Container image identity. Many jobs share a platform image, so one
    /// job's hot-set record and environment cache warm every later job on
    /// the same image — as in production (§4.2/§4.3).
    pub image_id: u64,
}

/// Job-scale buckets used by the §3 figures.
pub const SCALE_BUCKETS: [(u32, u32, &str); 6] = [
    (1, 8, "1-8"),
    (9, 64, "9-64"),
    (65, 128, "65-128"),
    (129, 512, "129-512"),
    (513, 2048, "513-2048"),
    (2049, 11520, ">2048"),
];

/// Bucket index for a GPU count.
pub fn bucket_of(gpus: u32) -> usize {
    SCALE_BUCKETS
        .iter()
        .position(|&(lo, hi, _)| gpus >= lo && gpus <= hi)
        .unwrap_or(SCALE_BUCKETS.len() - 1)
}

/// Shared container-image pool sizes per job size class (small / medium /
/// large). Small is a zoo of team images; the few flagship-scale images are
/// heavily shared.
const IMAGE_POOL: [u64; 3] = [12, 6, 4];
const IMAGE_CLASS_BASE: [u64; 3] = [0, 1000, 2000];

fn image_class(gpus: u32) -> usize {
    if gpus <= 64 {
        0
    } else if gpus <= 512 {
        1
    } else {
        2
    }
}

/// Deterministic per-image size factor (fraction of the paper's 28.62 GB
/// image). Images used by bigger job classes are bigger, preserving §3.1's
/// "smaller jobs tend to involve smaller container images".
pub fn image_size_factor(image_id: u64) -> f64 {
    const BANDS: [(f64, f64); 3] = [(0.30, 0.60), (0.55, 0.90), (0.85, 1.10)];
    let cls = ((image_id / 1000) as usize).min(2);
    let h = mix64(image_id.wrapping_mul(0x9E3779B97F4A7C15));
    let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    let (lo, hi) = BANDS[cls];
    lo + u * (hi - lo)
}

fn poisson(rng: &mut Rng, lambda: f64) -> u32 {
    // Knuth's method; fine for the small lambdas used here.
    let l = (-lambda).exp();
    let mut k = 0u32;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
        if k > 1000 {
            return k;
        }
    }
}

/// Synthesize `n_jobs` over a `horizon_s`-second window.
pub fn gen_trace(seed: u64, n_jobs: usize, horizon_s: f64) -> Vec<TraceJob> {
    let mut rng = Rng::seeded(seed ^ 0x7124CE);
    // Scale-bucket weights: most jobs are small (§3.1 Fig 4 right axis).
    let weights = [0.55, 0.20, 0.12, 0.09, 0.035, 0.005];
    (0..n_jobs)
        .map(|i| {
            let b = rng.weighted(&weights);
            let (lo, hi, _) = SCALE_BUCKETS[b];
            let mut gpus = rng.range(lo as u64, hi as u64) as u32;
            if gpus > 8 {
                gpus = (gpus / 8).max(1) * 8; // whole 8-GPU nodes
            }
            // Flagship jobs hold their GPUs for weeks: training time grows
            // with scale (the cluster's GPU-hours are dominated by a few
            // huge long-running jobs, as in any production fleet).
            let train_hours = (rng.lognormal(4f64.ln(), 1.2)
                * (1.0 + gpus as f64 / 256.0))
                .clamp(0.1, 1000.0);
            // Startups per job: failures scale with GPU-hour exposure
            // (hardware faults, loss spikes), plus a debugging component on
            // large jobs; small jobs are mostly single-startup (§3.1).
            let lambda = 2.5e-5 * gpus as f64 * train_hours
                + if gpus >= 100 { 1.0 } else { 0.05 };
            // Debug-storm tail: a few big jobs restart many times (§3.1
            // "20 or more startups ... due to debugging").
            let storm = if gpus >= 100 && train_hours > 4.0 && rng.chance(0.03) {
                rng.range(8, 20) as u32
            } else {
                0
            };
            let full_startups = 1 + poisson(&mut rng, lambda.min(20.0)) + storm;
            let hot_updates = poisson(&mut rng, 0.2 + lambda.min(6.0) / 3.0);
            let submit_s = rng.f64() * horizon_s;
            let priority = rng.weighted(&[0.1, 0.7, 0.2]) as u32;
            let cls = image_class(gpus);
            let image_id = IMAGE_CLASS_BASE[cls] + rng.below(IMAGE_POOL[cls]);
            TraceJob {
                id: i as u64 + 1,
                submit_s,
                gpus,
                full_startups,
                hot_updates,
                train_hours,
                priority,
                image_id,
            }
        })
        .collect()
}

/// The startup-relevant job configuration the replay derives for a trace
/// job: image size follows the shared image, checkpoint size follows job
/// scale, PP widens with node count so the per-node resume share stays in
/// the production-realistic range (Fig 5's 100–200 s model-init band).
pub fn trace_job_config(tj: &TraceJob) -> JobConfig {
    let img_f = image_size_factor(tj.image_id);
    let size_f = (tj.gpus as f64 / 128.0).clamp(0.05, 4.0);
    let base = JobConfig::paper_moe(tj.gpus.max(16));
    let nodes_est = (tj.gpus.max(16) + 7) / 8;
    JobConfig {
        gpus: tj.gpus,
        image_bytes: bytes_from_f64(base.image_bytes as f64 * img_f),
        ckpt_bytes: bytes_from_f64(base.ckpt_bytes as f64 * size_f),
        pp: base.pp.max(nodes_est / 4),
        image_seed: Some(0x1AA6E ^ tj.image_id.wrapping_mul(0x9E3779B97F4A7C15)),
        env_seed: Some(0x9AC5 ^ tj.image_id.wrapping_mul(0xA24BAED4963EE407)),
        ..base
    }
}

/// Closed-form startup-duration estimate (seconds) used by phase 1 to size
/// scheduler segments and by the contention sweep to bound each startup's
/// interval. Deliberately coarse — the replay measures the real duration —
/// but in the right band (a few hundred seconds for typical jobs).
pub fn estimate_startup_s(job: &JobConfig, cluster: &ClusterConfig) -> f64 {
    let n = job.nodes(cluster).max(1) as f64;
    let alloc = d::ALLOC_BASE_S + 0.02 * n;
    let hot_bytes = job.image_bytes as f64 * job.image_hot_fraction;
    let hot_blocks = (hot_bytes / job.image_block_bytes as f64).max(1.0);
    let contention = 1.0 + d::LAZY_CONTENTION_PENALTY * (n - 1.0).min(31.0);
    let image = d::CONTAINER_START_S
        + hot_blocks * d::LAZY_MISS_LATENCY_S * contention
        + hot_bytes / d::NODE_NIC_BPS;
    let env = job.env_packages as f64
        * (d::SCM_ADMIT_BASE_S + job.env_install_cpu_mean_s + 0.02)
        + d::ENV_DAEMON_BASE_S
        + d::env_daemon_sync_s(n as usize);
    let resume = (job.ckpt_bytes as f64 / job.pp.max(1) as f64) / d::HDFS_STREAM_BPS;
    let init = d::MODEL_INIT_BASE_S + d::model_init_sync_s(n as usize) + resume;
    alloc + image + env + init
}

/// Demand-based GPU-pool sizing: total GPU-seconds the trace wants, spread
/// over the submission horizon, at the target utilization — then clamped so
/// the largest job fits at all.
pub fn default_pool_gpus(trace: &[TraceJob], cluster: &ClusterConfig) -> u32 {
    let ests: Vec<f64> = trace
        .iter()
        .map(|tj| estimate_startup_s(&trace_job_config(tj), cluster))
        .collect();
    pool_from_demand(trace, &ests)
}

/// Pool sizing from precomputed per-job startup estimates.
fn pool_from_demand(trace: &[TraceJob], ests: &[f64]) -> u32 {
    let horizon = trace
        .iter()
        .map(|t| t.submit_s)
        .fold(0.0f64, f64::max)
        .max(3600.0);
    let mut demand = 0.0;
    for (tj, est) in trace.iter().zip(ests) {
        demand += tj.gpus as f64 * (tj.train_hours * 3600.0 + tj.full_startups as f64 * est);
    }
    let pool = ((demand / horizon / d::POOL_TARGET_UTILIZATION / 8.0).ceil() as u32).max(1) * 8;
    pool.max(trace.iter().map(|t| t.gpus).max().unwrap_or(8))
}

/// Phase-1 output: the pool and every job's scheduled segments.
pub struct TraceSchedule {
    pub pool_gpus: u32,
    /// One outcome per trace job, in trace order; segment `k` is the job's
    /// `k`-th full startup.
    pub outcomes: Vec<ChainOutcome>,
    /// Per-job startup-duration estimate (seconds).
    pub ests: Vec<f64>,
}

/// Phase 1: run the event-driven chain scheduler over the whole trace.
/// Every full startup of every job gets a contention-derived start time and
/// queue wait; restarts re-enter the queue, hot updates keep their
/// allocation and never appear here.
pub fn schedule_trace(
    trace: &[TraceJob],
    cluster: &ClusterConfig,
    pool_gpus: Option<u32>,
) -> TraceSchedule {
    let jobs_cfg: Vec<JobConfig> = trace.iter().map(trace_job_config).collect();
    schedule_trace_with(trace, cluster, pool_gpus, &jobs_cfg, &FaultConfig::off(), 0)
}

/// [`schedule_trace`] over already-derived job configs — the replay calls
/// this so phase 1 and phase 2 share one derivation and can never
/// desynchronize. With an active [`FaultConfig`] the seeded crash hazard
/// ([`FaultEngine`]) interrupts in-flight segments: the outcome then
/// contains extra (interrupted + retry) segment runs beyond the scripted
/// chain; [`FaultConfig::off`] is bit-identical to the fault-free schedule.
fn schedule_trace_with(
    trace: &[TraceJob],
    cluster: &ClusterConfig,
    pool_gpus: Option<u32>,
    jobs_cfg: &[JobConfig],
    faults: &FaultConfig,
    seed: u64,
) -> TraceSchedule {
    let ests: Vec<f64> =
        jobs_cfg.iter().map(|job| estimate_startup_s(job, cluster)).collect();
    let max_gpus = trace.iter().map(|t| t.gpus).max().unwrap_or(8);
    let pool = pool_gpus
        .unwrap_or_else(|| pool_from_demand(trace, &ests))
        .max(max_gpus);
    let chains: Vec<ChainJob> = trace
        .iter()
        .zip(&ests)
        .map(|(tj, &est)| {
            let slice = tj.train_hours * 3600.0 / tj.full_startups.max(1) as f64;
            ChainJob {
                id: tj.id,
                submit_s: tj.submit_s,
                gpus: tj.gpus,
                priority: tj.priority,
                segments: vec![est + slice; tj.full_startups.max(1) as usize],
            }
        })
        .collect();
    let id_ests: Vec<(u64, f64)> =
        trace.iter().zip(&ests).map(|(tj, &e)| (tj.id, e)).collect();
    let engine = FaultEngine::new(faults.clone(), seed, &id_ests);
    let oracle: Option<&dyn FaultOracle> =
        if faults.hazard_per_gpu_hour > 0.0 { Some(&engine) } else { None };
    let outcomes = schedule_chains_with(pool, &chains, d::SCHED_ROUND_S, oracle);
    TraceSchedule { pool_gpus: pool, outcomes, ests }
}

/// Cluster-wide warm-state registry, keyed by image digest (hot-set
/// records) and environment signature (env caches). Built once from the
/// phase-1 schedule: an artifact becomes *available* at the estimated end
/// of the chronologically first startup that would have produced it, and a
/// startup at virtual time `t` sees exactly the artifacts with
/// `available_s <= t`. Visibility is a pure function of the schedule, never
/// of thread interleaving — this is what makes the parallel replay
/// byte-identical at any `--threads`. The replay instantiates one per
/// timeline epoch by prefix-folding per-epoch contributions
/// (`timeline::fold_worlds`) — every producer visible to a query lives in
/// an earlier-or-equal epoch, so each epoch's world answers its own units
/// exactly like the global one would.
#[derive(Debug)]
pub struct SharedWorld {
    images: BTreeMap<u64, SharedImage>,
    envs: BTreeMap<u64, SharedEnv>,
}

#[derive(Debug)]
struct SharedImage {
    /// Shared via [`Arc`]: per-epoch worlds clone the map entry, not the
    /// block list.
    hot_blocks: Arc<Vec<u32>>,
    available_s: f64,
}

#[derive(Debug)]
struct SharedEnv {
    cache_bytes: u64,
    available_s: f64,
}

impl SharedWorld {
    /// Materialize the [`World`] a startup beginning at virtual time `t`
    /// observes: warm iff some earlier-ending startup shared its image /
    /// environment signature.
    pub fn world_at(&self, digest: u64, env_sig: u64, t: f64) -> World {
        let mut w = World::new();
        if let Some(si) = self.images.get(&digest) {
            if si.available_s <= t {
                w.hotset.seed_record(digest, si.hot_blocks.iter().copied());
            }
        }
        if let Some(se) = self.envs.get(&env_sig) {
            if se.available_s <= t {
                w.envcache.store(env_sig, se.cache_bytes);
            }
        }
        w
    }
}

/// Summary of one replayed job.
#[derive(Clone, Debug)]
pub struct JobReplay {
    pub job: TraceJob,
    /// Worker-phase seconds of every full startup + hot update.
    pub startup_worker_s: Vec<f64>,
    /// Foreground bytes each of those startups fetched over the network
    /// (same order): the cross-segment cache-carry observable — under
    /// Sequential/Overlapped gating a warm restart re-fetches strictly
    /// less than its cold start. (Speculative mode's Allocation-time
    /// stager still moves its budget-bounded prefix regardless of
    /// residency, mirroring the pre-refactor pipeline.)
    pub startup_fetched_bytes: Vec<u64>,
    /// Job-level total (incl. queuing) of the first startup.
    pub first_total_s: f64,
    /// Install-script durations of the last startup (straggler proxy).
    pub install_durations: Vec<f64>,
    /// Per-stage durations (job-level) of the last FULL startup.
    pub last_full: Option<StartupOutcome>,
    /// Scheduler-derived queue wait of each full startup.
    pub queue_waits: Vec<f64>,
    /// Cluster-clock start time of each full startup's allocation.
    pub starts_s: Vec<f64>,
    /// GPU-seconds this job wasted: startup time (capped at the failure
    /// instant for interrupted attempts) plus checkpoint-rollback losses.
    pub wasted_gpu_s: f64,
    /// Fault-generated restarts this job suffered (0 without faults).
    pub fault_restarts: u32,
}

/// Replay output: the profiler DB plus per-job summaries and the Fig-1
/// GPU-hour split. `Clone` serves the batched replay's duplicate-candidate
/// path ([`batch_replay`]): followers receive a copy of their leader's
/// result instead of re-running phase 2.
#[derive(Clone, Debug)]
pub struct ReplayResult {
    pub svc: StageAnalysisService,
    pub jobs: Vec<JobReplay>,
    pub train_gpu_hours: f64,
    pub startup_gpu_hours: f64,
    /// GPU-hours of training rolled back at fault instants (work since the
    /// last resume point, lost and re-done). Zero without faults.
    pub lost_train_gpu_hours: f64,
    /// Fault-generated restarts across the whole trace.
    pub fault_restarts: u64,
    /// GPU pool the scheduler ran over.
    pub pool_gpus: u32,
    /// Scheduler-derived queue wait of every full startup (job order, then
    /// attempt order) — the §3.2 distribution.
    pub queue_waits: Vec<f64>,
    /// Bytes credited from cache residency against stage demand, summed
    /// over every startup.
    pub credited_bytes: u64,
    /// Total bytes the startups' stages demanded (hit-rate denominator).
    pub demanded_bytes: u64,
    /// Governed registry / cluster-cache fetches shed at least once.
    pub shed_events: u64,
    /// Governed fetches evaluated against the admission limits.
    pub shed_checks: u64,
    /// Bytes evicted from bounded warm caches under capacity pressure
    /// (0 with the unbounded default).
    pub evicted_bytes: u64,
}

impl ReplayResult {
    pub fn startup_fraction(&self) -> f64 {
        self.startup_gpu_hours / (self.startup_gpu_hours + self.train_gpu_hours)
    }

    /// Total wasted GPU-hours: startup overhead plus rollback losses —
    /// the paper's "more than 3.5% of GPU time is wasted" quantity.
    pub fn wasted_gpu_hours(&self) -> f64 {
        self.startup_gpu_hours + self.lost_train_gpu_hours
    }

    /// Wasted share of all GPU time spent (training + waste).
    pub fn wasted_fraction(&self) -> f64 {
        self.wasted_gpu_hours() / (self.wasted_gpu_hours() + self.train_gpu_hours)
    }

    /// Cache hit rate: share of demanded bytes served from residency.
    pub fn hit_rate(&self) -> f64 {
        if self.demanded_bytes == 0 {
            0.0
        } else {
            self.credited_bytes as f64 / self.demanded_bytes as f64
        }
    }

    /// Shed rate: share of governed fetches shed at least once.
    pub fn shed_rate(&self) -> f64 {
        if self.shed_checks == 0 {
            0.0
        } else {
            self.shed_events as f64 / self.shed_checks as f64
        }
    }
}

/// Knobs of the cluster replay.
#[derive(Clone, Debug, Default)]
pub struct ReplayOptions {
    /// GPU pool the scheduler allocates from; `None` → demand-based sizing
    /// via [`default_pool_gpus`].
    pub pool_gpus: Option<u32>,
    /// Worker threads for the parallel startup replay; 0 → one per
    /// available core. The result is identical for every value.
    pub threads: usize,
    /// Fault-injection processes layered over the replay
    /// ([`FaultConfig::off`] by default — byte-identical to the fault-free
    /// replay).
    pub faults: FaultConfig,
    /// Phase-2 timeline epochs (time partitions with deterministic
    /// cross-epoch handoff; see `timeline.rs`). 0 (the default)
    /// auto-shards at one epoch per
    /// [`crate::config::defaults::REPLAY_EPOCH_SPAN_S`] of schedule
    /// horizon, capped at
    /// [`crate::config::defaults::REPLAY_MAX_EPOCHS`]. Purely a
    /// performance knob: the replay is byte-identical at every value.
    pub epochs: usize,
    /// Override the replayed [`BootseerConfig`]'s overlap mode; `None`
    /// keeps the config's value. Applied once by
    /// [`ReplayOptions::resolve`] at the top of [`replay_cluster`].
    pub overlap: Option<OverlapMode>,
    /// Override `bootseer.cache_capacity_bytes`; `None` keeps the config.
    pub cache_capacity: Option<u64>,
    /// Override `bootseer.cache_policy`; `None` keeps the config.
    pub cache_policy: Option<CachePolicy>,
    /// Override `bootseer.artifact_dedup`; `None` keeps the config.
    pub dedup: Option<bool>,
    /// Override `bootseer.delta_resume`; `None` keeps the config.
    pub delta_resume: Option<bool>,
    /// Override `bootseer.spec_prefetch_budget_bytes`; `None` keeps the
    /// config.
    pub spec_prefetch_budget: Option<u64>,
    /// Override `cluster.racks` — the topology tree's rack count; `None`
    /// keeps the config. Clamped to ≥ 1.
    pub racks: Option<u32>,
    /// Override `cluster.spine_oversub`; `None` keeps the config. Clamped
    /// to ≥ 1.
    pub spine_oversub: Option<f64>,
}

impl ReplayOptions {
    /// The defaults (auto pool, auto threads, faults off, auto epochs, no
    /// config overrides); chain the `with_*` setters from here.
    pub fn new() -> ReplayOptions {
        ReplayOptions::default()
    }

    /// Seed the options from a resolved [`RunConfig`]: the `[faults]`
    /// table becomes the replayed fault processes, everything else keeps
    /// its default. This is the single config → replay path; CLI flags
    /// layer on top through the `with_*` setters, so an explicit flag
    /// always beats the file.
    pub fn from_config(rc: &RunConfig) -> ReplayOptions {
        ReplayOptions { faults: rc.faults.clone(), ..ReplayOptions::default() }
    }

    /// GPU pool the scheduler allocates from (`None` → demand-sized).
    pub fn with_pool_gpus(mut self, pool_gpus: Option<u32>) -> ReplayOptions {
        self.pool_gpus = pool_gpus;
        self
    }

    /// Worker threads for the parallel replay (0 → one per core).
    pub fn with_threads(mut self, threads: usize) -> ReplayOptions {
        self.threads = threads;
        self
    }

    /// Fault-injection processes layered over the replay.
    pub fn with_faults(mut self, faults: FaultConfig) -> ReplayOptions {
        self.faults = faults;
        self
    }

    /// Phase-2 timeline epochs (0 → auto-shard daily).
    pub fn with_epochs(mut self, epochs: usize) -> ReplayOptions {
        self.epochs = epochs;
        self
    }

    /// Override the replayed overlap mode.
    pub fn with_overlap(mut self, overlap: OverlapMode) -> ReplayOptions {
        self.overlap = Some(overlap);
        self
    }

    /// Override the bounded-cache economics knobs.
    pub fn with_cache(mut self, capacity_bytes: u64, policy: CachePolicy) -> ReplayOptions {
        self.cache_capacity = Some(capacity_bytes);
        self.cache_policy = Some(policy);
        self
    }

    /// Override cross-artifact chunk dedup (`bootseer.artifact_dedup`).
    pub fn with_dedup(mut self, dedup: bool) -> ReplayOptions {
        self.dedup = Some(dedup);
        self
    }

    /// Override delta resume (`bootseer.delta_resume`).
    pub fn with_delta_resume(mut self, delta_resume: bool) -> ReplayOptions {
        self.delta_resume = Some(delta_resume);
        self
    }

    /// Override the speculative-prefetch byte budget
    /// (`bootseer.spec_prefetch_budget_bytes`).
    pub fn with_spec_prefetch_budget(mut self, budget_bytes: u64) -> ReplayOptions {
        self.spec_prefetch_budget = Some(budget_bytes);
        self
    }

    /// Override the topology's rack count (CLI `--racks`).
    pub fn with_racks(mut self, racks: u32) -> ReplayOptions {
        self.racks = Some(racks);
        self
    }

    /// Override the spine oversubscription factor (CLI `--spine-oversub`).
    pub fn with_spine_oversub(mut self, oversub: f64) -> ReplayOptions {
        self.spine_oversub = Some(oversub);
        self
    }

    /// Apply the overrides to the configs the replay was handed and
    /// return the effective pair. All-`None` options return bit-equal
    /// clones and the application is idempotent; [`replay_cluster`] calls
    /// this once at its top, so callers never need to.
    pub fn resolve(
        &self,
        cluster: &ClusterConfig,
        cfg: &BootseerConfig,
    ) -> (ClusterConfig, BootseerConfig) {
        let mut bc = cfg.clone();
        if let Some(m) = self.overlap {
            bc.overlap = m;
        }
        if let Some(c) = self.cache_capacity {
            bc.cache_capacity_bytes = c;
        }
        if let Some(p) = self.cache_policy {
            bc.cache_policy = p;
        }
        if let Some(x) = self.dedup {
            bc.artifact_dedup = x;
        }
        if let Some(x) = self.delta_resume {
            bc.delta_resume = x;
        }
        if let Some(b) = self.spec_prefetch_budget {
            bc.spec_prefetch_budget_bytes = b;
        }
        (self.resolve_cluster(cluster), bc)
    }

    /// The cluster half of [`ReplayOptions::resolve`]: apply the topology
    /// overrides (racks, spine oversubscription) and nothing else. Split
    /// out so [`PrefixKey::derive`] and the prefix build share the exact
    /// clamping arithmetic with the full resolve path.
    pub fn resolve_cluster(&self, cluster: &ClusterConfig) -> ClusterConfig {
        let mut cl = cluster.clone();
        if let Some(r) = self.racks {
            cl.racks = r.max(1);
        }
        if let Some(o) = self.spine_oversub {
            cl.spine_oversub = o.max(1.0);
        }
        cl
    }
}

/// One independent simulation unit of phase 2. `Debug` feeds the
/// [`ReplayPrefix::fingerprint`] content dump — every field below is part
/// of the prefix's identity.
#[derive(Debug)]
struct Unit {
    job_idx: usize,
    attempt: u32,
    kind: StartupKind,
    start_s: f64,
    est_s: f64,
    queue_s: f64,
    digest: u64,
    env_sig: u64,
    eff_cluster: ClusterConfig,
    /// Fault bookkeeping (all inert without faults): which scripted
    /// segment + retry this run is, whether it was interrupted mid-hold,
    /// its scheduler-assigned length, the training rolled back at its
    /// failure, and whether a restart landed warm on its previous nodes.
    retry: u32,
    interrupted: bool,
    seg_len_s: f64,
    lost_train_s: f64,
    warm_local: bool,
    /// Fleet-wide concurrently-starting node count over this unit's
    /// interval (ceil of the phase-1 contention average) — the demand the
    /// registry / cluster-cache admission limits are measured against.
    demand: u32,
    /// Timeline epoch this unit's start falls in: selects the prefix-folded
    /// [`SharedWorld`] it observes and its slot in the epoch-major issue
    /// order.
    epoch: usize,
    /// Rack of each node of this startup's gang, assigned by the
    /// chronological [`crate::scheduler::RackPool`] walk over phase 1's segments. `None` on
    /// a flat topology — the placement-free (pre-topology) pipeline.
    placement: Option<Arc<Vec<u32>>>,
    /// Relocation cost a rescheduled restart pays
    /// (`cluster.relocation_cost_s` × moved-node fraction), folded into
    /// its allocation phase. 0 on flat topologies, on cold first starts,
    /// and on warm restarts that kept their racks.
    relocation_s: f64,
}

/// Per-startup effective service capacities: the seed per-job entitlement,
/// degraded by the fleet share when the concurrently-starting node count
/// exceeds the fleet service capacity.
fn effective_cluster(cluster: &ClusterConfig, nodes: u32, avg_active_nodes: f64) -> ClusterConfig {
    let n = nodes as f64;
    let f = (cluster.fleet_service_nodes as f64 / avg_active_nodes.max(1.0)).min(1.0);
    ClusterConfig {
        hdfs_datanodes: u32_from_f64((cluster.hdfs_datanodes.max(nodes * 8) as f64 * f).round())
            .max(1),
        cluster_cache_egress_bps: cluster.cluster_cache_egress_bps.max(n * 1.0e9) * f,
        registry_egress_bps: cluster.registry_egress_bps.max(n * 0.5e9) * f,
        ..cluster.clone()
    }
}

/// Replay every startup of every job through the pipeline simulator, with
/// scheduler-derived queue waits (phase 1) and shared-service contention
/// across concurrently starting jobs (phase 2). See the module docs and
/// `docs/replay.md`.
///
/// Since the batched-evaluation split this is a thin wrapper: the
/// config-invariant phases (scheduling, placement, fault decisions, epoch
/// worlds, warm carries) build a [`ReplayPrefix`] via [`build_prefix`], and
/// [`evaluate_prefix`] runs phase 2 against it. [`batch_replay`] drives the
/// same two calls for N candidate configs at once, sharing prefixes across
/// candidates whose [`PrefixKey`]s coincide — byte-identical to calling
/// this function once per candidate.
pub fn replay_cluster(
    trace: &[TraceJob],
    cluster: &ClusterConfig,
    cfg: &BootseerConfig,
    seed: u64,
    opts: &ReplayOptions,
) -> ReplayResult {
    if trace.is_empty() {
        return batch::empty_result();
    }
    // Single config -> replay override path: builder / CLI overrides fold
    // into the effective configs exactly once, here (the prefix build
    // applies the same resolution to the cluster half internally).
    let (_, cfg) = opts.resolve(cluster, cfg);
    let prefix = build_prefix(trace, cluster, seed, opts);
    evaluate_prefix(&prefix, trace, &cfg, opts.threads)
}

/// Replay with default options: auto-sized pool, one worker per core.
pub fn replay(
    trace: &[TraceJob],
    cluster: &ClusterConfig,
    cfg: &BootseerConfig,
    seed: u64,
) -> ReplayResult {
    replay_cluster(trace, cluster, cfg, seed, &ReplayOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::spec::ImageSpec;
    use crate::util::stats;

    /// [`ReplayOptions`] with explicit pool/threads/faults and the default
    /// (auto) epoch count.
    fn opts(pool_gpus: Option<u32>, threads: usize, faults: FaultConfig) -> ReplayOptions {
        ReplayOptions::new().with_pool_gpus(pool_gpus).with_threads(threads).with_faults(faults)
    }

    #[test]
    fn trace_marginals() {
        let t = gen_trace(1, 4000, 7.0 * 86400.0);
        assert_eq!(t.len(), 4000);
        let small = t.iter().filter(|j| j.gpus < 100).count() as f64 / 4000.0;
        assert!(small > 0.7, "small fraction {small}");
        // Small jobs mostly single-startup.
        let small_single = t
            .iter()
            .filter(|j| j.gpus < 100)
            .filter(|j| j.full_startups == 1)
            .count() as f64
            / t.iter().filter(|j| j.gpus < 100).count() as f64;
        assert!(small_single > 0.75, "single-startup small {small_single}");
        // Large jobs restart more.
        let large: Vec<f64> = t
            .iter()
            .filter(|j| j.gpus >= 1000)
            .map(|j| j.full_startups as f64)
            .collect();
        assert!(!large.is_empty());
        assert!(stats::mean(&large) > 2.0, "large-job startups {}", stats::mean(&large));
        // Total requested GPUs scale like the paper (~700k for 28k jobs →
        // ~25 GPUs/job average... our mixture averages above 8).
        let total: u64 = t.iter().map(|j| j.gpus as u64).sum();
        assert!(total > 100_000, "total gpus {total}");
        // Images are shared: the whole week runs on a small image pool.
        let images: std::collections::BTreeSet<u64> = t.iter().map(|j| j.image_id).collect();
        assert!(images.len() <= 22, "distinct images {}", images.len());
        assert!(images.len() >= 10);
    }

    #[test]
    fn bucket_of_covers_everything() {
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(8), 0);
        assert_eq!(bucket_of(100), 2);
        assert_eq!(bucket_of(11520), 5);
    }

    #[test]
    fn trace_deterministic() {
        let a = gen_trace(9, 100, 86400.0);
        let b = gen_trace(9, 100, 86400.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.gpus, y.gpus);
            assert_eq!(x.full_startups, y.full_startups);
            assert_eq!(x.image_id, y.image_id);
        }
    }

    #[test]
    fn replay_small_trace() {
        let t = gen_trace(2, 150, 86400.0);
        let r = replay(&t, &ClusterConfig::default(), &BootseerConfig::baseline(), 7);
        assert_eq!(r.jobs.len(), 150);
        assert!(r.train_gpu_hours > 0.0);
        assert!(r.startup_gpu_hours > 0.0);
        let frac = r.startup_fraction();
        // Fig 1 band: startup is a few percent of cluster GPU hours.
        assert!((0.004..0.18).contains(&frac), "startup fraction {frac}");
        // Profiler got events for every job.
        assert_eq!(r.svc.db.jobs().len(), 150);
        assert!(r.svc.anomalies.is_empty());
        // Queue waits come from the scheduler, one per full startup.
        let n_fulls: usize = t.iter().map(|j| j.full_startups as usize).sum();
        assert_eq!(r.queue_waits.len(), n_fulls);
        assert!(r.queue_waits.iter().all(|&w| w >= 0.0));
        assert!(r.pool_gpus >= t.iter().map(|j| j.gpus).max().unwrap());
    }

    #[test]
    fn replay_bootseer_reduces_startup_hours() {
        let t = gen_trace(3, 25, 86400.0);
        let base = replay(&t, &ClusterConfig::default(), &BootseerConfig::baseline(), 7);
        let boot = replay(&t, &ClusterConfig::default(), &BootseerConfig::bootseer(), 7);
        assert!(
            boot.startup_gpu_hours < base.startup_gpu_hours,
            "bootseer {} vs baseline {}",
            boot.startup_gpu_hours,
            base.startup_gpu_hours
        );
    }

    #[test]
    fn replay_overlap_modes_reduce_startup_hours_and_stay_deterministic() {
        use crate::config::OverlapMode;
        let t = gen_trace(4, 40, 86400.0);
        let cluster = ClusterConfig::default();
        let run_mode = |mode: OverlapMode, threads: usize| {
            replay_cluster(
                &t,
                &cluster,
                &BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() },
                7,
                &ReplayOptions { pool_gpus: None, threads, ..ReplayOptions::default() },
            )
        };
        let seq = run_mode(OverlapMode::Sequential, 1);
        let ovl = run_mode(OverlapMode::Overlapped, 1);
        let spec = run_mode(OverlapMode::Speculative, 1);
        assert!(
            ovl.startup_gpu_hours < seq.startup_gpu_hours,
            "overlapped {} vs sequential {}",
            ovl.startup_gpu_hours,
            seq.startup_gpu_hours
        );
        assert!(
            spec.startup_gpu_hours < ovl.startup_gpu_hours,
            "speculative {} vs overlapped {}",
            spec.startup_gpu_hours,
            ovl.startup_gpu_hours
        );
        // Thread-count determinism holds through the graph in every mode.
        let spec8 = run_mode(OverlapMode::Speculative, 8);
        assert_eq!(
            spec.startup_gpu_hours.to_bits(),
            spec8.startup_gpu_hours.to_bits(),
            "overlap replay must stay byte-identical across thread counts"
        );
    }

    /// Golden-schedule determinism for the cluster replay: the full
    /// per-job `(worker_phase_s, total_s)` streams — the replay-level
    /// `(finished_at, tag)` capture — must be bit-identical across thread
    /// counts AND epoch counts for every overlap mode, with faults off,
    /// the `paper` preset, and the shedding `storm` preset. The
    /// `(threads: 1, epochs: 1)` baseline is structurally the pre-sharding
    /// replay (one partition, original issue order, fully folded world),
    /// so this also pins byte-identity to the pre-epoch engine; any
    /// nondeterminism in the handoff fold, the per-epoch prep memos, or
    /// the epoch-major claim order lands here as a bit flip.
    #[test]
    fn golden_week_replay_bit_identical_across_threads_modes_and_faults() {
        use crate::config::OverlapMode;
        let t = gen_trace(6, 30, 86400.0);
        let cluster = ClusterConfig::default();
        let capture = |mode: OverlapMode, faults: FaultConfig, threads: usize, epochs| {
            let r = replay_cluster(
                &t,
                &cluster,
                &BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() },
                11,
                &ReplayOptions::new().with_threads(threads).with_faults(faults).with_epochs(epochs),
            );
            let mut stream: Vec<u64> = vec![
                r.startup_gpu_hours.to_bits(),
                r.lost_train_gpu_hours.to_bits(),
                r.fault_restarts,
            ];
            for j in &r.jobs {
                for w in &j.startup_worker_s {
                    stream.push(w.to_bits());
                }
                stream.push(j.first_total_s.to_bits());
            }
            stream
        };
        for mode in OverlapMode::ALL {
            for faults in [FaultConfig::off(), FaultConfig::paper(), hot_storm()] {
                let baseline = capture(mode, faults.clone(), 1, 1);
                // threads × epochs, including the auto-derived count (0).
                for (threads, epochs) in [(4, 1), (1, 4), (8, 13), (4, 0)] {
                    let other = capture(mode, faults.clone(), threads, epochs);
                    assert_eq!(
                        baseline, other,
                        "replay diverged: mode={mode:?} hazard={} threads={threads} \
                         epochs={epochs}",
                        faults.hazard_per_gpu_hour
                    );
                }
            }
        }
    }

    #[test]
    fn queue_waits_match_paper_distribution() {
        // Phase 1 only (cheap): the §3.2 shape — ~100 s median from the
        // scheduling-round cadence, hour-long tails from pool contention.
        let t = gen_trace(1, 250, 7.0 * 86400.0);
        let s = schedule_trace(&t, &ClusterConfig::default(), None);
        let waits: Vec<f64> = s
            .outcomes
            .iter()
            .flat_map(|o| o.segments.iter().map(|g| g.queue_wait_s))
            .collect();
        let n_fulls: usize = t.iter().map(|j| j.full_startups as usize).sum();
        assert_eq!(waits.len(), n_fulls, "every full startup scheduled");
        let med = stats::median(&waits);
        assert!((30.0..300.0).contains(&med), "median queue wait {med}");
        assert!(stats::max(&waits) > 3600.0, "tail {}", stats::max(&waits));
    }

    #[test]
    fn schedule_never_overallocates_pool() {
        let t = gen_trace(1, 250, 7.0 * 86400.0);
        let s = schedule_trace(&t, &ClusterConfig::default(), None);
        let mut evs: Vec<(f64, i64)> = Vec::new();
        for (tj, o) in t.iter().zip(&s.outcomes) {
            for seg in &o.segments {
                evs.push((seg.start_s, tj.gpus as i64));
                evs.push((seg.end_s, -(tj.gpus as i64)));
            }
        }
        evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let mut used = 0i64;
        for (_, dl) in evs {
            used += dl;
            assert!(used <= s.pool_gpus as i64, "pool over-allocated: {used}");
        }
    }

    /// Phase 1 on a real seeded week (trace → chains → scheduler) must
    /// match the preserved pre-rewrite round-grid scheduler bit-for-bit,
    /// fault oracle off and on — the workload-level complement of the
    /// synthetic equivalence suite in `scheduler::tests`.
    #[test]
    fn week_schedule_matches_reference_scheduler() {
        use crate::scheduler::reference::schedule_chains_reference;
        let t = gen_trace(1, 150, 7.0 * 86400.0);
        let cluster = ClusterConfig::default();
        let jobs_cfg: Vec<JobConfig> = t.iter().map(trace_job_config).collect();
        let ests: Vec<f64> =
            jobs_cfg.iter().map(|j| estimate_startup_s(j, &cluster)).collect();
        let chains: Vec<ChainJob> = t
            .iter()
            .zip(&ests)
            .map(|(tj, &est)| {
                let slice = tj.train_hours * 3600.0 / tj.full_startups.max(1) as f64;
                ChainJob {
                    id: tj.id,
                    submit_s: tj.submit_s,
                    gpus: tj.gpus,
                    priority: tj.priority,
                    segments: vec![est + slice; tj.full_startups.max(1) as usize],
                }
            })
            .collect();
        let pool = pool_from_demand(&t, &ests);
        let id_ests: Vec<(u64, f64)> =
            t.iter().zip(&ests).map(|(tj, &e)| (tj.id, e)).collect();
        for faults in [FaultConfig::off(), hot_faults()] {
            let engine = FaultEngine::new(faults.clone(), 5, &id_ests);
            let oracle: Option<&dyn FaultOracle> =
                if faults.hazard_per_gpu_hour > 0.0 { Some(&engine) } else { None };
            let new = schedule_chains_with(pool, &chains, d::SCHED_ROUND_S, oracle);
            let old = schedule_chains_reference(pool, &chains, d::SCHED_ROUND_S, oracle);
            assert_eq!(new.len(), old.len());
            for (a, b) in new.iter().zip(&old) {
                assert_eq!(a.segments.len(), b.segments.len(), "job {}", a.id);
                for (x, y) in a.segments.iter().zip(&b.segments) {
                    assert_eq!(x.start_s.to_bits(), y.start_s.to_bits(), "job {}", a.id);
                    assert_eq!(x.end_s.to_bits(), y.end_s.to_bits(), "job {}", a.id);
                    assert_eq!(
                        x.queue_wait_s.to_bits(),
                        y.queue_wait_s.to_bits(),
                        "job {}",
                        a.id
                    );
                    assert_eq!(x.interrupted, y.interrupted, "job {}", a.id);
                    assert_eq!(
                        x.lost_train_s.to_bits(),
                        y.lost_train_s.to_bits(),
                        "job {}",
                        a.id
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_replay_identical_across_thread_counts() {
        let t = gen_trace(11, 60, 86400.0);
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::baseline();
        let one = replay_cluster(
            &t,
            &cluster,
            &cfg,
            5,
            &ReplayOptions { pool_gpus: None, threads: 1, ..ReplayOptions::default() },
        );
        let many = replay_cluster(
            &t,
            &cluster,
            &cfg,
            5,
            &ReplayOptions { pool_gpus: None, threads: 8, ..ReplayOptions::default() },
        );
        assert_eq!(one.pool_gpus, many.pool_gpus);
        assert_eq!(one.queue_waits, many.queue_waits);
        assert_eq!(
            one.startup_gpu_hours.to_bits(),
            many.startup_gpu_hours.to_bits(),
            "gpu-hour accumulation must be byte-identical"
        );
        for (a, b) in one.jobs.iter().zip(&many.jobs) {
            assert_eq!(a.startup_worker_s, b.startup_worker_s);
            assert_eq!(a.first_total_s.to_bits(), b.first_total_s.to_bits());
        }
        // And the whole thing is deterministic given the seed.
        let again = replay_cluster(
            &t,
            &cluster,
            &cfg,
            5,
            &ReplayOptions { pool_gpus: None, threads: 8, ..ReplayOptions::default() },
        );
        assert_eq!(again.startup_gpu_hours.to_bits(), many.startup_gpu_hours.to_bits());
    }

    #[test]
    fn shared_image_warms_later_jobs() {
        // Two jobs on the same image, far apart in time: the second one's
        // first-ever startup already sees the hot-set record + env cache the
        // first job produced (cross-job sharing, as in production).
        let mk = |id: u64, submit: f64| TraceJob {
            id,
            submit_s: submit,
            gpus: 64,
            full_startups: 1,
            hot_updates: 0,
            train_hours: 0.2,
            priority: 1,
            image_id: 7,
        };
        let t = vec![mk(1, 0.0), mk(2, 20_000.0)];
        let r = replay_cluster(
            &t,
            &ClusterConfig::default(),
            &BootseerConfig::bootseer(),
            9,
            &ReplayOptions { pool_gpus: Some(256), threads: 1, ..ReplayOptions::default() },
        );
        let cold = r.jobs[0].startup_worker_s[0];
        let warm = r.jobs[1].startup_worker_s[0];
        assert!(
            warm < cold * 0.8,
            "second job on a shared image should start warm: {cold} vs {warm}"
        );
        // Different image → no warm benefit.
        let mut t2 = t.clone();
        t2[1].image_id = 8;
        let r2 = replay_cluster(
            &t2,
            &ClusterConfig::default(),
            &BootseerConfig::bootseer(),
            9,
            &ReplayOptions { pool_gpus: Some(256), threads: 1, ..ReplayOptions::default() },
        );
        assert!(r2.jobs[1].startup_worker_s[0] > warm * 1.2);
    }

    // ---- fault injection ----

    /// A fault spec hot enough to actually fire on a small trace.
    fn hot_faults() -> FaultConfig {
        FaultConfig {
            hazard_per_gpu_hour: 5.0e-4,
            ..FaultConfig::paper()
        }
    }

    #[test]
    fn zero_fault_rate_is_byte_identical() {
        // `faults: off` must take the exact same code paths as the
        // fault-free replay: every number bit-equal.
        let t = gen_trace(6, 50, 86400.0);
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::baseline();
        let plain = replay_cluster(
            &t,
            &cluster,
            &cfg,
            5,
            &ReplayOptions { pool_gpus: None, threads: 2, ..ReplayOptions::default() },
        );
        let off = replay_cluster(
            &t,
            &cluster,
            &cfg,
            5,
            &opts(None, 2, FaultConfig::off()),
        );
        assert_eq!(plain.startup_gpu_hours.to_bits(), off.startup_gpu_hours.to_bits());
        assert_eq!(plain.queue_waits, off.queue_waits);
        assert_eq!(off.lost_train_gpu_hours, 0.0);
        assert_eq!(off.fault_restarts, 0);
        assert_eq!(
            plain.wasted_gpu_hours().to_bits(),
            plain.startup_gpu_hours.to_bits(),
            "without faults, wasted == startup overhead"
        );
        for (a, b) in plain.jobs.iter().zip(&off.jobs) {
            assert_eq!(a.startup_worker_s, b.startup_worker_s);
            assert_eq!(b.fault_restarts, 0);
        }
    }

    #[test]
    fn faults_generate_restarts_and_increase_waste() {
        let t = gen_trace(6, 50, 86400.0);
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::baseline();
        let off = replay_cluster(&t, &cluster, &cfg, 5, &ReplayOptions::default());
        let on = replay_cluster(
            &t,
            &cluster,
            &cfg,
            5,
            &ReplayOptions { faults: hot_faults(), ..ReplayOptions::default() },
        );
        assert!(on.fault_restarts > 0, "hot hazard must fire on a 50-job trace");
        assert!(on.lost_train_gpu_hours > 0.0, "training failures roll work back");
        assert!(
            on.wasted_gpu_hours() > off.wasted_gpu_hours(),
            "faults add waste: {} vs {}",
            on.wasted_gpu_hours(),
            off.wasted_gpu_hours()
        );
        // Per-job waste sums to the cluster totals.
        let per_job: f64 = on.jobs.iter().map(|j| j.wasted_gpu_s).sum();
        let total = on.wasted_gpu_hours();
        assert!(
            (per_job / 3600.0 - total).abs() < 1e-6 * total.max(1.0),
            "per-job wasted {} vs total {total}",
            per_job / 3600.0
        );
        let per_job_restarts: u64 = on.jobs.iter().map(|j| j.fault_restarts as u64).sum();
        assert_eq!(per_job_restarts, on.fault_restarts);
        // Training itself is unaffected: the lost work is re-done.
        assert_eq!(on.train_gpu_hours.to_bits(), off.train_gpu_hours.to_bits());
    }

    #[test]
    fn fault_replay_deterministic_across_threads_and_modes() {
        use crate::config::OverlapMode;
        let t = gen_trace(4, 40, 86400.0);
        let cluster = ClusterConfig::default();
        for mode in OverlapMode::ALL {
            let cfg = BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() };
            let run = |threads: usize| {
                replay_cluster(
                    &t,
                    &cluster,
                    &cfg,
                    7,
                    &opts(None, threads, hot_faults()),
                )
            };
            let one = run(1);
            let four = run(4);
            assert!(one.fault_restarts > 0, "{mode:?}: hazard fired");
            assert_eq!(one.fault_restarts, four.fault_restarts, "{mode:?}");
            assert_eq!(
                one.startup_gpu_hours.to_bits(),
                four.startup_gpu_hours.to_bits(),
                "{mode:?}: startup hours bit-equal across threads"
            );
            assert_eq!(
                one.lost_train_gpu_hours.to_bits(),
                four.lost_train_gpu_hours.to_bits(),
                "{mode:?}: lost hours bit-equal across threads"
            );
            assert_eq!(one.queue_waits, four.queue_waits, "{mode:?}");
            for (a, b) in one.jobs.iter().zip(&four.jobs) {
                assert_eq!(a.startup_worker_s, b.startup_worker_s, "{mode:?}");
                assert_eq!(a.wasted_gpu_s.to_bits(), b.wasted_gpu_s.to_bits(), "{mode:?}");
            }
            // And reruns with the same seed reproduce the same bits.
            let again = run(4);
            assert_eq!(
                again.wasted_gpu_hours().to_bits(),
                four.wasted_gpu_hours().to_bits(),
                "{mode:?}: rerun bit-equal"
            );
        }
    }

    #[test]
    fn warm_restart_beats_cold_restart() {
        // One job, hazard hot enough to force restarts: with relocate=0
        // every restart lands back on its nodes (local hot set + env
        // archive still on disk); with relocate=1 every restart is
        // rescheduled cold. The warm restart startups must be faster.
        let t = vec![TraceJob {
            id: 1,
            submit_s: 0.0,
            gpus: 128,
            full_startups: 1,
            hot_updates: 0,
            train_hours: 40.0,
            priority: 1,
            image_id: 7,
        }];
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::bootseer();
        let run = |relocate: f64| {
            let faults = FaultConfig {
                hazard_per_gpu_hour: 2.0e-3,
                relocate_prob: relocate,
                straggler_prob: 0.0,
                brownouts_per_week: 0.0,
                ..FaultConfig::paper()
            };
            replay_cluster(
                &t,
                &cluster,
                &cfg,
                11,
                &opts(Some(256), 1, faults),
            )
        };
        let warm = run(0.0);
        let cold = run(1.0);
        assert!(warm.fault_restarts >= 1, "restarts fired: {}", warm.fault_restarts);
        assert_eq!(warm.fault_restarts, cold.fault_restarts, "same crash schedule");
        // Compare the restart attempts only (index ≥ 1 in worker series).
        let mean_tail = |r: &ReplayResult| {
            let w = &r.jobs[0].startup_worker_s[1..];
            w.iter().sum::<f64>() / w.len() as f64
        };
        let wm = mean_tail(&warm);
        let cm = mean_tail(&cold);
        assert!(wm < cm, "warm restarts {wm} should beat cold {cm}");
    }

    /// Cross-segment cache carry: a faulted job's warm restart fetches
    /// strictly fewer bytes than its cold start, and — since nothing was
    /// evicted — exactly zero extra bytes beyond the unavoidable resume
    /// read: the image and env stages fetch nothing at all.
    #[test]
    fn warm_restart_carries_cache_across_segments() {
        let t = vec![TraceJob {
            id: 1,
            submit_s: 0.0,
            gpus: 128,
            full_startups: 1,
            hot_updates: 0,
            train_hours: 40.0,
            priority: 1,
            image_id: 7,
        }];
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::bootseer();
        let run = |relocate: f64| {
            let faults = FaultConfig {
                hazard_per_gpu_hour: 2.0e-3,
                relocate_prob: relocate,
                straggler_prob: 0.0,
                brownouts_per_week: 0.0,
                ..FaultConfig::paper()
            };
            replay_cluster(
                &t,
                &cluster,
                &cfg,
                11,
                &opts(Some(256), 1, faults),
            )
        };
        let warm = run(0.0);
        assert!(warm.fault_restarts >= 1, "restarts fired: {}", warm.fault_restarts);
        let fetched = &warm.jobs[0].startup_fetched_bytes;
        let cold_start = fetched[0];
        for (k, &restart) in fetched.iter().enumerate().skip(1) {
            assert!(
                restart < cold_start,
                "warm restart {k} fetched {restart} >= cold start {cold_start}"
            );
        }
        // Nothing was evicted, so the last warm restart's image and env
        // stages fetched zero bytes — the resume read is all that remains.
        let last = warm.jobs[0].last_full.as_ref().expect("job replayed");
        use crate::profiler::Stage;
        assert_eq!(last.fetched(Stage::ImageLoading), 0, "hot set fully resident");
        assert_eq!(last.fetched(Stage::EnvSetup), 0, "env archive fully resident");
        assert_eq!(last.fetched_bytes, last.fetched(Stage::ModelInit));

        // A relocated (cold) restart re-fetches the hot set + archive the
        // warm one kept — same crash schedule, strictly more bytes.
        let cold = run(1.0);
        assert_eq!(warm.fault_restarts, cold.fault_restarts, "same crash schedule");
        for (w, c) in warm.jobs[0]
            .startup_fetched_bytes
            .iter()
            .zip(&cold.jobs[0].startup_fetched_bytes)
            .skip(1)
        {
            assert!(w < c, "warm restart bytes {w} vs cold {c}");
        }
    }

    /// Delta resume re-fetches only the rewritten shard chunks on a warm
    /// restart: strictly fewer bytes and no slower than the plain warm
    /// restart; with the feature off the replay is untouched.
    #[test]
    fn delta_resume_shrinks_warm_restart_fetches() {
        let t = vec![TraceJob {
            id: 1,
            submit_s: 0.0,
            gpus: 128,
            full_startups: 1,
            hot_updates: 0,
            train_hours: 40.0,
            priority: 1,
            image_id: 7,
        }];
        let cluster = ClusterConfig::default();
        let faults = FaultConfig {
            hazard_per_gpu_hour: 2.0e-3,
            relocate_prob: 0.0,
            straggler_prob: 0.0,
            brownouts_per_week: 0.0,
            ..FaultConfig::paper()
        };
        let run = |delta: bool| {
            let cfg = BootseerConfig { delta_resume: delta, ..BootseerConfig::bootseer() };
            replay_cluster(
                &t,
                &cluster,
                &cfg,
                11,
                &opts(Some(256), 1, faults.clone()),
            )
        };
        let plain = run(false);
        let delta = run(true);
        assert!(plain.fault_restarts >= 1);
        // Cold first starts identical; warm restarts strictly smaller.
        assert_eq!(
            plain.jobs[0].startup_fetched_bytes[0],
            delta.jobs[0].startup_fetched_bytes[0]
        );
        for (p, q) in plain.jobs[0]
            .startup_fetched_bytes
            .iter()
            .zip(&delta.jobs[0].startup_fetched_bytes)
            .skip(1)
        {
            assert!(q < p, "delta restart bytes {q} vs plain {p}");
        }
        assert!(
            delta.startup_gpu_hours < plain.startup_gpu_hours,
            "delta {} vs plain {}",
            delta.startup_gpu_hours,
            plain.startup_gpu_hours
        );
    }

    #[test]
    fn brownouts_slow_overlapping_startups() {
        // A constant brownout covering the whole horizon with harsh
        // degradation must slow the replayed startups.
        let t = gen_trace(8, 20, 43200.0);
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::baseline();
        let calm = replay_cluster(&t, &cluster, &cfg, 3, &ReplayOptions::default());
        let browned = replay_cluster(
            &t,
            &cluster,
            &cfg,
            3,
            &ReplayOptions {
                faults: FaultConfig {
                    brownouts_per_week: 2000.0,
                    brownout_duration_s: 7200.0,
                    brownout_capacity_factor: 0.15,
                    hazard_per_gpu_hour: 0.0,
                    straggler_prob: 0.0,
                    ..FaultConfig::paper()
                },
                ..ReplayOptions::default()
            },
        );
        assert!(
            browned.startup_gpu_hours > calm.startup_gpu_hours * 1.02,
            "brownouts degrade startups: {} vs {}",
            browned.startup_gpu_hours,
            calm.startup_gpu_hours
        );
        // No crashes configured: schedule identical, no restarts.
        assert_eq!(browned.fault_restarts, 0);
        assert_eq!(browned.queue_waits, calm.queue_waits);
    }

    #[test]
    fn contention_degrades_concurrent_bursts() {
        // The same 128-GPU job replayed alone vs inside a burst of large
        // concurrent starters: the burst copy must not start faster, and
        // the fleet share math must bite once active nodes exceed the
        // fleet service capacity.
        let cluster = ClusterConfig::default();
        let solo = effective_cluster(&cluster, 16, 16.0);
        let burst = effective_cluster(&cluster, 16, 4.0 * cluster.fleet_service_nodes as f64);
        assert!(solo.registry_egress_bps > burst.registry_egress_bps * 3.0);
        assert!(solo.cluster_cache_egress_bps > burst.cluster_cache_egress_bps * 3.0);
        assert!(burst.hdfs_datanodes < solo.hdfs_datanodes);
        // Solo equals the per-job entitlement (seed behaviour).
        assert_eq!(solo.registry_egress_bps, cluster.registry_egress_bps.max(16.0 * 0.5e9));
    }

    // ---- bounded caches & load shedding ----

    /// The storm preset, scaled to a 30–60 job test trace: the production
    /// hazard would barely fire a restart wave this small, so crashes are
    /// hotter and most restarts land warm (where cache economics bite).
    fn hot_storm() -> FaultConfig {
        FaultConfig {
            hazard_per_gpu_hour: 1.0e-3,
            relocate_prob: 0.2,
            ..FaultConfig::storm()
        }
    }

    /// Satellite determinism pin: a bounded-cache replay under a restart
    /// storm — evictions, churn, shedding and retry backoff all active —
    /// stays bit-identical across thread counts in every overlap mode.
    #[test]
    fn bounded_storm_replay_bit_identical_across_threads_and_modes() {
        use crate::config::{CachePolicy, OverlapMode};
        let t = gen_trace(6, 30, 86400.0);
        let cluster = ClusterConfig::default();
        for mode in OverlapMode::ALL {
            let cfg = BootseerConfig {
                overlap: mode,
                cache_capacity_bytes: 1_000_000_000,
                cache_policy: CachePolicy::Lru,
                ..BootseerConfig::bootseer()
            };
            let run = |threads: usize, epochs: usize| {
                replay_cluster(
                    &t,
                    &cluster,
                    &cfg,
                    11,
                    &ReplayOptions::new()
                        .with_threads(threads)
                        .with_faults(hot_storm())
                        .with_epochs(epochs),
                )
            };
            // Eviction/churn/shedding state crossed with epoch sharding:
            // (1 thread, 1 epoch) is the pre-sharding baseline.
            let one = run(1, 1);
            let four = run(4, 13);
            assert!(one.fault_restarts > 0, "{mode:?}: storm fired");
            assert!(one.evicted_bytes > 0, "{mode:?}: churn evicted warm bytes");
            assert!(one.shed_checks > 0, "{mode:?}: finite slots governed fetches");
            assert!(one.demanded_bytes > 0, "{mode:?}");
            assert_eq!(
                one.startup_gpu_hours.to_bits(),
                four.startup_gpu_hours.to_bits(),
                "{mode:?}: startup hours bit-equal across threads"
            );
            assert_eq!(
                one.lost_train_gpu_hours.to_bits(),
                four.lost_train_gpu_hours.to_bits(),
                "{mode:?}"
            );
            assert_eq!(one.credited_bytes, four.credited_bytes, "{mode:?}");
            assert_eq!(one.demanded_bytes, four.demanded_bytes, "{mode:?}");
            assert_eq!(one.shed_events, four.shed_events, "{mode:?}");
            assert_eq!(one.shed_checks, four.shed_checks, "{mode:?}");
            assert_eq!(one.evicted_bytes, four.evicted_bytes, "{mode:?}");
            for (a, b) in one.jobs.iter().zip(&four.jobs) {
                assert_eq!(a.startup_worker_s, b.startup_worker_s, "{mode:?}");
                assert_eq!(a.startup_fetched_bytes, b.startup_fetched_bytes, "{mode:?}");
            }
            // And reruns with the same seed reproduce the same bits.
            let again = run(4, 13);
            assert_eq!(
                again.wasted_gpu_hours().to_bits(),
                four.wasted_gpu_hours().to_bits(),
                "{mode:?}: rerun bit-equal"
            );
        }
    }

    /// The unbounded default takes exactly the legacy code paths, and a
    /// finite capacity that never fills behaves identically: no churn
    /// artifact is demanded by any stage, nothing is evicted, no peer is
    /// dropped — every replayed number is bit-equal.
    #[test]
    fn unfilled_capacity_is_byte_identical_to_unbounded() {
        use crate::config::CachePolicy;
        let t = gen_trace(6, 30, 86400.0);
        let cluster = ClusterConfig::default();
        let run = |capacity: u64, policy: CachePolicy| {
            let cfg = BootseerConfig {
                cache_capacity_bytes: capacity,
                cache_policy: policy,
                ..BootseerConfig::bootseer()
            };
            replay_cluster(
                &t,
                &cluster,
                &cfg,
                11,
                &opts(None, 2, hot_storm()),
            )
        };
        let default = run(u64::MAX, CachePolicy::Lru);
        // Policy is irrelevant while capacity is unbounded.
        let unbounded_pin = run(u64::MAX, CachePolicy::PinHotSet);
        // 10 TB never fills: warm set + churn tops out below 50 GB.
        let huge = run(10_000_000_000_000, CachePolicy::Lru);
        assert!(default.fault_restarts > 0);
        assert_eq!(default.evicted_bytes, 0);
        assert_eq!(huge.evicted_bytes, 0);
        for other in [&unbounded_pin, &huge] {
            assert_eq!(
                default.startup_gpu_hours.to_bits(),
                other.startup_gpu_hours.to_bits()
            );
            assert_eq!(
                default.wasted_gpu_hours().to_bits(),
                other.wasted_gpu_hours().to_bits()
            );
            assert_eq!(default.credited_bytes, other.credited_bytes);
            assert_eq!(default.demanded_bytes, other.demanded_bytes);
            assert_eq!(default.shed_events, other.shed_events);
            assert_eq!(default.shed_checks, other.shed_checks);
            for (a, b) in default.jobs.iter().zip(&other.jobs) {
                assert_eq!(a.startup_worker_s, b.startup_worker_s);
                assert_eq!(a.startup_fetched_bytes, b.startup_fetched_bytes);
            }
        }
    }

    /// Cross-segment eviction accounting (satellite): with the pin-hot-set
    /// policy and a capacity of exactly hot set + env archive, every warm
    /// restart's churn evicts the env archive (churn ≥ 1 GB > 270 MB) and
    /// nothing else — the pinned hot set survives. The bounded replay must
    /// therefore re-fetch *exactly* the evicted bytes on every restart:
    /// strictly more than the unbounded warm replay, strictly less than a
    /// cold (relocated) one.
    #[test]
    fn eviction_refetches_exactly_the_evicted_bytes_across_segments() {
        use crate::config::CachePolicy;
        let t = vec![TraceJob {
            id: 1,
            submit_s: 0.0,
            gpus: 128,
            full_startups: 1,
            hot_updates: 0,
            train_hours: 40.0,
            priority: 1,
            image_id: 7,
        }];
        let cluster = ClusterConfig::default();
        let job = trace_job_config(&t[0]);
        let img = ImageSpec::synth(
            job.image_identity_seed(1),
            job.image_bytes,
            job.image_block_bytes,
            job.image_hot_fraction,
        );
        let nodes = job.nodes(&cluster) as u64;
        let run = |capacity: u64, policy: CachePolicy, relocate: f64| {
            let faults = FaultConfig {
                hazard_per_gpu_hour: 2.0e-3,
                relocate_prob: relocate,
                straggler_prob: 0.0,
                brownouts_per_week: 0.0,
                ..FaultConfig::paper()
            };
            let cfg = BootseerConfig {
                cache_capacity_bytes: capacity,
                cache_policy: policy,
                ..BootseerConfig::bootseer()
            };
            replay_cluster(
                &t,
                &cluster,
                &cfg,
                11,
                &opts(Some(256), 1, faults),
            )
        };
        let cap = img.hot_bytes() + job.env_cache_bytes;
        let unbounded = run(u64::MAX, CachePolicy::PinHotSet, 0.0);
        let bounded = run(cap, CachePolicy::PinHotSet, 0.0);
        let cold = run(u64::MAX, CachePolicy::PinHotSet, 1.0);
        assert!(unbounded.fault_restarts >= 1, "restarts fired");
        // Capacity never reaches phase 1: identical crash schedules.
        assert_eq!(unbounded.fault_restarts, bounded.fault_restarts);
        assert_eq!(unbounded.fault_restarts, cold.fault_restarts);
        let ub = &unbounded.jobs[0].startup_fetched_bytes;
        let bd = &bounded.jobs[0].startup_fetched_bytes;
        let cd = &cold.jobs[0].startup_fetched_bytes;
        // Identical cold first start; every restart strictly between the
        // fully-warm and fully-cold replays.
        assert_eq!(ub[0], bd[0]);
        for k in 1..ub.len() {
            assert!(bd[k] > ub[k], "restart {k}: bounded {} vs warm {}", bd[k], ub[k]);
            assert!(bd[k] < cd[k], "restart {k}: bounded {} vs cold {}", bd[k], cd[k]);
        }
        // Exactness: the extra bytes are the evicted env archive on every
        // node, nothing more — the pinned hot set never fell out.
        let extra: u64 = bd.iter().sum::<u64>() - ub.iter().sum::<u64>();
        assert_eq!(
            bounded.evicted_bytes,
            unbounded.fault_restarts * job.env_cache_bytes,
            "each warm restart evicted exactly the env archive"
        );
        assert_eq!(extra, nodes * bounded.evicted_bytes);
        assert_eq!(unbounded.evicted_bytes, 0);
    }

    // ---- hierarchical topology ----

    /// Flat-topology byte-identity golden: a cluster that *sets* every
    /// tree knob but keeps `racks = 1` replays bit-identically to the
    /// default flat cluster across every overlap mode and fault preset —
    /// the knobs must be completely inert until a second rack exists.
    #[test]
    fn flat_topology_replay_is_byte_identical() {
        use crate::config::OverlapMode;
        let t = gen_trace(6, 30, 86400.0);
        let plain = ClusterConfig::default();
        let knobbed = ClusterConfig {
            racks: 1,
            spines: 1,
            rack_uplink_bps: 40.0e9 / 8.0,
            spine_oversub: 8.0,
            relocation_cost_s: 99.0,
            ..ClusterConfig::default()
        };
        for mode in OverlapMode::ALL {
            let cfg = BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() };
            for faults in [FaultConfig::off(), hot_faults(), hot_storm()] {
                let a = replay_cluster(&t, &plain, &cfg, 11, &opts(None, 2, faults.clone()));
                let b = replay_cluster(&t, &knobbed, &cfg, 11, &opts(None, 2, faults.clone()));
                assert_eq!(
                    a.startup_gpu_hours.to_bits(),
                    b.startup_gpu_hours.to_bits(),
                    "{mode:?}: flat tree knobs must be inert"
                );
                assert_eq!(
                    a.wasted_gpu_hours().to_bits(),
                    b.wasted_gpu_hours().to_bits(),
                    "{mode:?}"
                );
                for (x, y) in a.jobs.iter().zip(&b.jobs) {
                    assert_eq!(x.startup_worker_s, y.startup_worker_s, "{mode:?}");
                    assert_eq!(x.startup_fetched_bytes, y.startup_fetched_bytes, "{mode:?}");
                }
            }
        }
        // The builder's override path resolves to the same bits as the
        // config it overrides.
        let cfg = BootseerConfig::bootseer();
        let via_opts = replay_cluster(
            &t,
            &knobbed,
            &cfg,
            11,
            &ReplayOptions::new().with_racks(1).with_spine_oversub(8.0).with_threads(2),
        );
        let direct = replay_cluster(&t, &knobbed, &cfg, 11, &opts(None, 2, FaultConfig::off()));
        assert_eq!(
            via_opts.startup_gpu_hours.to_bits(),
            direct.startup_gpu_hours.to_bits(),
            "ReplayOptions overrides must equal the same values set in the config"
        );
    }

    /// Thread / epoch / rerun determinism of the topology-aware replay:
    /// placements, relocation costs and rack-scoped brownout scales are
    /// all computed before the parallel phase, so a 4-rack replay stays
    /// bit-identical at every (threads, epochs) and across reruns.
    #[test]
    fn topology_replay_deterministic_across_threads_and_epochs() {
        let t = gen_trace(6, 30, 86400.0);
        let cluster =
            ClusterConfig { racks: 4, spines: 2, spine_oversub: 4.0, ..ClusterConfig::default() };
        let cfg = BootseerConfig::bootseer();
        let faults = FaultConfig { brownout_rack_frac: 0.5, ..hot_storm() };
        let run = |threads: usize, epochs: usize| {
            replay_cluster(
                &t,
                &cluster,
                &cfg,
                11,
                &ReplayOptions::new()
                    .with_threads(threads)
                    .with_faults(faults.clone())
                    .with_epochs(epochs),
            )
        };
        let one = run(1, 1);
        let four = run(4, 13);
        assert!(one.fault_restarts > 0, "storm fired");
        assert_eq!(one.startup_gpu_hours.to_bits(), four.startup_gpu_hours.to_bits());
        assert_eq!(one.lost_train_gpu_hours.to_bits(), four.lost_train_gpu_hours.to_bits());
        assert_eq!(one.queue_waits, four.queue_waits);
        for (a, b) in one.jobs.iter().zip(&four.jobs) {
            assert_eq!(a.startup_worker_s, b.startup_worker_s);
            assert_eq!(a.startup_fetched_bytes, b.startup_fetched_bytes);
        }
        let again = run(4, 13);
        assert_eq!(again.wasted_gpu_hours().to_bits(), four.wasted_gpu_hours().to_bits());
    }

    /// Rack-scoped brownouts are strictly gentler than fleet-wide ones on
    /// a multi-rack cluster (each window only browns out a subset of the
    /// racks a gang spans) and never cheaper than no brownouts at all.
    #[test]
    fn rack_scoped_brownouts_are_gentler_than_fleet_wide() {
        let t = gen_trace(8, 20, 43200.0);
        let cluster = ClusterConfig { racks: 8, ..ClusterConfig::default() };
        let cfg = BootseerConfig::baseline();
        let brown = |rack_frac: f64| FaultConfig {
            brownouts_per_week: 2000.0,
            brownout_duration_s: 7200.0,
            brownout_capacity_factor: 0.15,
            brownout_rack_frac: rack_frac,
            hazard_per_gpu_hour: 0.0,
            straggler_prob: 0.0,
            ..FaultConfig::paper()
        };
        let calm = replay_cluster(&t, &cluster, &cfg, 3, &opts(None, 2, FaultConfig::off()));
        let fleet = replay_cluster(&t, &cluster, &cfg, 3, &opts(None, 2, brown(0.0)));
        let scoped = replay_cluster(&t, &cluster, &cfg, 3, &opts(None, 2, brown(0.3)));
        assert!(
            scoped.startup_gpu_hours < fleet.startup_gpu_hours,
            "scoping to 30% of racks must soften the brownout: {} vs {}",
            scoped.startup_gpu_hours,
            fleet.startup_gpu_hours
        );
        assert!(
            scoped.startup_gpu_hours >= calm.startup_gpu_hours,
            "scoped brownouts still cost something: {} vs {}",
            scoped.startup_gpu_hours,
            calm.startup_gpu_hours
        );
        // Identical schedules throughout: brownouts never crash jobs.
        assert_eq!(scoped.queue_waits, fleet.queue_waits);
        assert_eq!(scoped.fault_restarts, 0);
    }

    /// On a multi-rack cluster, forcing every restart to relocate (cold
    /// caches + placement-distance cost) wastes strictly more GPU-time
    /// than letting every restart land warm on its previous racks, under
    /// the same crash schedule.
    #[test]
    fn relocated_restarts_waste_more_on_a_multi_rack_cluster() {
        let t = vec![TraceJob {
            id: 1,
            submit_s: 0.0,
            gpus: 128,
            full_startups: 1,
            hot_updates: 0,
            train_hours: 40.0,
            priority: 1,
            image_id: 7,
        }];
        let cluster = ClusterConfig { racks: 4, ..ClusterConfig::default() };
        let run = |relocate: f64| {
            let faults = FaultConfig {
                hazard_per_gpu_hour: 2.0e-3,
                relocate_prob: relocate,
                straggler_prob: 0.0,
                brownouts_per_week: 0.0,
                ..FaultConfig::paper()
            };
            let cfg = BootseerConfig::bootseer();
            replay_cluster(&t, &cluster, &cfg, 11, &opts(Some(256), 1, faults))
        };
        let warm = run(0.0);
        let cold = run(1.0);
        assert!(warm.fault_restarts >= 1, "restarts fired: {}", warm.fault_restarts);
        assert_eq!(warm.fault_restarts, cold.fault_restarts, "same crash schedule");
        assert!(
            cold.startup_gpu_hours > warm.startup_gpu_hours,
            "relocation must cost: {} vs {}",
            cold.startup_gpu_hours,
            warm.startup_gpu_hours
        );
    }

    // ---- ReplayOptions builder ----

    #[test]
    fn resolve_applies_overrides_and_is_idempotent() {
        use crate::config::{CachePolicy, OverlapMode};
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::baseline();
        let o = ReplayOptions::new()
            .with_racks(8)
            .with_spine_oversub(4.0)
            .with_overlap(OverlapMode::Speculative)
            .with_cache(1_000_000_000, CachePolicy::PinHotSet);
        let (cl, bc) = o.resolve(&cluster, &cfg);
        assert_eq!(cl.racks, 8);
        assert_eq!(cl.spine_oversub, 4.0);
        assert_eq!(bc.overlap, OverlapMode::Speculative);
        assert_eq!(bc.cache_capacity_bytes, 1_000_000_000);
        assert_eq!(bc.cache_policy, CachePolicy::PinHotSet);
        let (cl2, bc2) = o.resolve(&cl, &bc);
        assert_eq!(cl2.racks, cl.racks);
        assert_eq!(cl2.spine_oversub.to_bits(), cl.spine_oversub.to_bits());
        assert_eq!(bc2.cache_capacity_bytes, bc.cache_capacity_bytes);
        // No overrides → bit-equal clones of the inputs.
        let (cl3, bc3) = ReplayOptions::new().resolve(&cluster, &cfg);
        assert_eq!(cl3.racks, cluster.racks);
        assert_eq!(cl3.spine_core_bps.to_bits(), cluster.spine_core_bps.to_bits());
        assert_eq!(bc3.cache_capacity_bytes, cfg.cache_capacity_bytes);
        assert_eq!(bc3.overlap, cfg.overlap);
        // The artifact-knob overrides resolve onto the config the same way.
        let (_, bc4) = ReplayOptions::new()
            .with_dedup(true)
            .with_delta_resume(true)
            .with_spec_prefetch_budget(3_000_000_000)
            .resolve(&cluster, &cfg);
        assert!(bc4.artifact_dedup && bc4.delta_resume);
        assert_eq!(bc4.spec_prefetch_budget_bytes, 3_000_000_000);
    }
}
