//! Batched what-if evaluation: one immutable, `Arc`-shared replay prefix
//! per distinct [`PrefixKey`], phase-2-only evaluation per candidate
//! config, and candidate-level dedup through [`EvalKey`].
//!
//! [`super::replay_cluster`] does strictly more work than a what-if sweep
//! needs: most knob changes (overlap mode, cache economics, prefetch
//! budgets) touch only the phase-2 startup pipeline, yet every standalone
//! call re-runs phase-1 scheduling, the [`RackPool`] placement walk, the
//! fault-oracle decisions, and the epoch [`SharedWorld`] fold. This
//! module factors the engine:
//!
//!  1. [`build_prefix`] computes everything config-invariant once into a
//!     [`ReplayPrefix`]: the scheduled unit list, placements, per-unit
//!     effective clusters, epoch worlds, and warm-restart carries.
//!  2. [`evaluate_prefix`] replays only phase 2 against a shared prefix.
//!  3. [`batch_replay`] evaluates K candidates at once: prefixes are
//!     memoized by [`PrefixKey`], and candidates whose *effective*
//!     phase-2 config is provably identical ([`EvalKey`]) share a single
//!     evaluation — each follower clones its leader's [`ReplayResult`].
//!
//! Everything here preserves the replay's bit-exactness contract: a
//! batched candidate's result is byte-identical to its standalone
//! [`super::replay_cluster`] run at any thread or epoch count (pinned by
//! the tests below and the golden tests in the parent module).

use crate::artifact::cache::CacheState;
use crate::artifact::manifest::ArtifactManifest;
use crate::artifact::Admission;
use crate::ckpt::resume::retained_resume_bytes_per_node;
use crate::config::defaults as d;
use crate::config::{BootseerConfig, CachePolicy, ClusterConfig, ImageMode, JobConfig, OverlapMode};
use crate::env::packages::PackageSet;
use crate::faults::{BrownoutWindows, FaultConfig, FaultEngine};
use crate::image::spec::ImageSpec;
use crate::profiler::StageAnalysisService;
use crate::scheduler::{placement_distance, RackPool};
use crate::startup::{run_startup_with, StartupContext, StartupKind, StartupOutcome};
use crate::util::rng::mix64;
use crate::util::salts::SALT_ADMISSION;
use crate::util::sha256::sha256;
use std::cmp::Ordering;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::Arc;

use super::timeline;
use super::{
    effective_cluster, schedule_trace_with, trace_job_config, JobReplay, ReplayOptions,
    ReplayResult, SharedWorld, TraceJob, Unit,
};

/// Bit-captured [`FaultConfig`]: every float is keyed by its exact bit
/// pattern, so two fault configs compare equal here iff the replay could
/// not tell them apart. Comparisons are hand-written (not derived) so the
/// key fields are read by real code and the ordering is explicit.
#[derive(Clone, Debug)]
struct FaultKey {
    hazard_per_gpu_hour: u64,
    relocate_prob: u64,
    straggler_prob: u64,
    straggler_severity: u64,
    brownouts_per_week: u64,
    brownout_duration_s: u64,
    brownout_capacity_factor: u64,
    ckpt_interval_s: u64,
    max_retries: u32,
    registry_slots: u32,
    cache_slots: u32,
    shed_backoff_s: u64,
    shed_retries: u32,
    brownout_rack_frac: u64,
}

impl FaultKey {
    /// Mechanical bit-capture of every fault field. The by-value
    /// destructure is exhaustive on purpose: adding a [`FaultConfig`]
    /// field fails compilation here until it is keyed (every fault
    /// process shapes phase 1 or the admission plane, so the safe default
    /// is prefix-relevant).
    fn derive(faults: &FaultConfig) -> FaultKey {
        let &FaultConfig {
            hazard_per_gpu_hour,
            relocate_prob,
            straggler_prob,
            straggler_severity,
            brownouts_per_week,
            brownout_duration_s,
            brownout_capacity_factor,
            ckpt_interval_s,
            max_retries,
            registry_slots,
            cache_slots,
            shed_backoff_s,
            shed_retries,
            brownout_rack_frac,
        } = faults;
        FaultKey {
            hazard_per_gpu_hour: hazard_per_gpu_hour.to_bits(),
            relocate_prob: relocate_prob.to_bits(),
            straggler_prob: straggler_prob.to_bits(),
            straggler_severity: straggler_severity.to_bits(),
            brownouts_per_week: brownouts_per_week.to_bits(),
            brownout_duration_s: brownout_duration_s.to_bits(),
            brownout_capacity_factor: brownout_capacity_factor.to_bits(),
            ckpt_interval_s: ckpt_interval_s.to_bits(),
            max_retries,
            registry_slots,
            cache_slots,
            shed_backoff_s: shed_backoff_s.to_bits(),
            shed_retries,
            brownout_rack_frac: brownout_rack_frac.to_bits(),
        }
    }
}

impl Ord for FaultKey {
    fn cmp(&self, o: &FaultKey) -> Ordering {
        self.hazard_per_gpu_hour
            .cmp(&o.hazard_per_gpu_hour)
            .then(self.relocate_prob.cmp(&o.relocate_prob))
            .then(self.straggler_prob.cmp(&o.straggler_prob))
            .then(self.straggler_severity.cmp(&o.straggler_severity))
            .then(self.brownouts_per_week.cmp(&o.brownouts_per_week))
            .then(self.brownout_duration_s.cmp(&o.brownout_duration_s))
            .then(self.brownout_capacity_factor.cmp(&o.brownout_capacity_factor))
            .then(self.ckpt_interval_s.cmp(&o.ckpt_interval_s))
            .then(self.max_retries.cmp(&o.max_retries))
            .then(self.registry_slots.cmp(&o.registry_slots))
            .then(self.cache_slots.cmp(&o.cache_slots))
            .then(self.shed_backoff_s.cmp(&o.shed_backoff_s))
            .then(self.shed_retries.cmp(&o.shed_retries))
            .then(self.brownout_rack_frac.cmp(&o.brownout_rack_frac))
    }
}

impl PartialOrd for FaultKey {
    fn partial_cmp(&self, o: &FaultKey) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl PartialEq for FaultKey {
    fn eq(&self, o: &FaultKey) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}

impl Eq for FaultKey {}

/// The prefix-relevant subset of a replay's inputs: two `(seed, cluster,
/// options)` triples with equal keys produce bit-identical
/// [`ReplayPrefix`]es (the property test below pins this with
/// [`ReplayPrefix::fingerprint`]). Derived mechanically by
/// [`PrefixKey::derive`]; used as the memo key in [`batch_replay`].
#[derive(Clone, Debug)]
pub struct PrefixKey {
    seed: u64,
    pool_gpus: Option<u32>,
    epochs: usize,
    racks: u32,
    spine_oversub_bits: u64,
    faults: FaultKey,
}

impl PrefixKey {
    /// Classify every [`ReplayOptions`] field as prefix-relevant (folded
    /// into the key) or phase-2-only (ignored, with the reason on the
    /// ignore arm). The destructure is exhaustive, so adding an option
    /// forces the classification decision here at compile time. The
    /// topology overrides are keyed through
    /// [`ReplayOptions::resolve_cluster`] so the key shares the clamping
    /// arithmetic with the build itself.
    pub fn derive(seed: u64, cluster: &ClusterConfig, opts: &ReplayOptions) -> PrefixKey {
        let ReplayOptions {
            pool_gpus,
            threads: _,              // execution knob: never touches the bits
            faults,
            epochs,
            overlap: _,              // phase-2 stage-graph knob
            cache_capacity: _,       // phase-2 cache-economics knob
            cache_policy: _,         // phase-2 cache-economics knob
            dedup: _,                // phase-2 transfer-plane knob
            delta_resume: _,         // phase-2 knob: carries are built unconditionally
            spec_prefetch_budget: _, // phase-2 staging knob
            racks: _,                // folded into the resolved cluster below
            spine_oversub: _,        // folded into the resolved cluster below
        } = opts;
        let resolved = opts.resolve_cluster(cluster);
        PrefixKey {
            seed,
            pool_gpus: *pool_gpus,
            epochs: *epochs,
            racks: resolved.racks,
            spine_oversub_bits: resolved.spine_oversub.to_bits(),
            faults: FaultKey::derive(faults),
        }
    }
}

impl Ord for PrefixKey {
    fn cmp(&self, o: &PrefixKey) -> Ordering {
        self.seed
            .cmp(&o.seed)
            .then(self.pool_gpus.cmp(&o.pool_gpus))
            .then(self.epochs.cmp(&o.epochs))
            .then(self.racks.cmp(&o.racks))
            .then(self.spine_oversub_bits.cmp(&o.spine_oversub_bits))
            .then_with(|| self.faults.cmp(&o.faults))
    }
}

impl PartialOrd for PrefixKey {
    fn partial_cmp(&self, o: &PrefixKey) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl PartialEq for PrefixKey {
    fn eq(&self, o: &PrefixKey) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}

impl Eq for PrefixKey {}

fn image_mode_tag(m: ImageMode) -> u8 {
    match m {
        ImageMode::OciFull => 0,
        ImageMode::Lazy => 1,
        ImageMode::RecordPrefetch => 2,
    }
}

fn overlap_tag(m: OverlapMode) -> u8 {
    match m {
        OverlapMode::Sequential => 0,
        OverlapMode::Overlapped => 1,
        OverlapMode::Speculative => 2,
    }
}

fn cache_policy_tag(p: CachePolicy) -> u8 {
    match p {
        CachePolicy::Lru => 0,
        CachePolicy::Gdsf => 1,
        CachePolicy::PinHotSet => 2,
    }
}

/// The phase-2-effective identity of a resolved [`BootseerConfig`] against
/// one prefix: two candidates with equal keys replay to byte-identical
/// [`ReplayResult`]s, so [`batch_replay`] evaluates one of them and clones.
///
/// Beyond the verbatim field capture, two *provably dead* knobs are
/// normalized away so candidate grids collapse:
///
/// - `spec_prefetch_budget_bytes` is only read inside the
///   `OverlapMode::Speculative` branch of the stage graph, so under any
///   other overlap mode it is keyed as 0.
/// - The per-node cache capacity/policy can only reach the bits through
///   (a) the warm-restart seed ([`timeline::seed_warm_cache`], warm units
///   only) or (b) the dedup shared-chunk layer (`startup::graph` only
///   mutates its run cache under `artifact_dedup`; the pipeline's
///   `evicted_bytes` reads the context cache, which stays empty for cold
///   units). With an unbounded cache, or with no warm unit in the prefix
///   and dedup off, both paths are inert — the pair is keyed as
///   `(u64::MAX, Lru)`, the unbounded default.
#[derive(Clone, Debug)]
pub struct EvalKey {
    image_mode: u8,
    p2p: bool,
    env_cache: bool,
    ckpt_striped: bool,
    record_window_bits: u64,
    prefetch_threads: u32,
    stripe_chunk_bytes: u64,
    stripe_width: u32,
    overlap: u8,
    spec_prefetch_budget_bytes: u64,
    artifact_dedup: bool,
    delta_resume: bool,
    cache_capacity_bytes: u64,
    cache_policy: u8,
}

impl EvalKey {
    /// Key a *resolved* config (builder/CLI overrides already folded by
    /// [`ReplayOptions::resolve`]) against a prefix with
    /// `has_warm_units` warm restarts ([`ReplayPrefix::has_warm_units`]).
    /// The destructure is exhaustive: a new [`BootseerConfig`] field
    /// fails compilation here until it is keyed (phase-2 configs default
    /// to eval-relevant; only provably dead combinations may normalize).
    pub fn derive(cfg: &BootseerConfig, has_warm_units: bool) -> EvalKey {
        let &BootseerConfig {
            image_mode,
            p2p,
            env_cache,
            ckpt_striped,
            record_window_s,
            prefetch_threads,
            stripe_chunk_bytes,
            stripe_width,
            overlap,
            spec_prefetch_budget_bytes,
            artifact_dedup,
            delta_resume,
            cache_capacity_bytes,
            cache_policy,
        } = cfg;
        let budget =
            if overlap == OverlapMode::Speculative { spec_prefetch_budget_bytes } else { 0 };
        let unbounded = cache_capacity_bytes == u64::MAX;
        let cache_live = !unbounded && (has_warm_units || artifact_dedup);
        let (capacity, policy) = if cache_live {
            (cache_capacity_bytes, cache_policy)
        } else {
            (u64::MAX, CachePolicy::Lru)
        };
        EvalKey {
            image_mode: image_mode_tag(image_mode),
            p2p,
            env_cache,
            ckpt_striped,
            record_window_bits: record_window_s.to_bits(),
            prefetch_threads,
            stripe_chunk_bytes,
            stripe_width,
            overlap: overlap_tag(overlap),
            spec_prefetch_budget_bytes: budget,
            artifact_dedup,
            delta_resume,
            cache_capacity_bytes: capacity,
            cache_policy: cache_policy_tag(policy),
        }
    }
}

impl Ord for EvalKey {
    fn cmp(&self, o: &EvalKey) -> Ordering {
        self.image_mode
            .cmp(&o.image_mode)
            .then(self.p2p.cmp(&o.p2p))
            .then(self.env_cache.cmp(&o.env_cache))
            .then(self.ckpt_striped.cmp(&o.ckpt_striped))
            .then(self.record_window_bits.cmp(&o.record_window_bits))
            .then(self.prefetch_threads.cmp(&o.prefetch_threads))
            .then(self.stripe_chunk_bytes.cmp(&o.stripe_chunk_bytes))
            .then(self.stripe_width.cmp(&o.stripe_width))
            .then(self.overlap.cmp(&o.overlap))
            .then(self.spec_prefetch_budget_bytes.cmp(&o.spec_prefetch_budget_bytes))
            .then(self.artifact_dedup.cmp(&o.artifact_dedup))
            .then(self.delta_resume.cmp(&o.delta_resume))
            .then(self.cache_capacity_bytes.cmp(&o.cache_capacity_bytes))
            .then(self.cache_policy.cmp(&o.cache_policy))
    }
}

impl PartialOrd for EvalKey {
    fn partial_cmp(&self, o: &EvalKey) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl PartialEq for EvalKey {
    fn eq(&self, o: &EvalKey) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}

impl Eq for EvalKey {}

/// Everything [`super::replay_cluster`] computes before the parallel
/// phase-2 startup replay, frozen: phase-1 schedule and unit list,
/// placements, per-unit effective clusters (brownouts and injected
/// stragglers folded in), epoch-folded [`SharedWorld`]s, and per-job
/// warm-restart carries. Immutable after [`build_prefix`], so any number
/// of candidate evaluations can share one instance behind an [`Arc`].
#[derive(Debug)]
pub struct ReplayPrefix {
    key: PrefixKey,
    /// The resolved cluster (topology overrides applied).
    cluster: ClusterConfig,
    /// The fault processes the prefix was built under; phase 2 draws its
    /// admission limits from here so prefix and evaluation can never
    /// disagree about the fault model.
    faults: FaultConfig,
    seed: u64,
    jobs_cfg: Vec<JobConfig>,
    nodes_of: Vec<u32>,
    pool_gpus: u32,
    units: Vec<Unit>,
    job_units: Vec<Vec<usize>>,
    /// Epoch-major issue order (see the phase-2 comment in the parent
    /// module): claim order never touches the bits.
    order: Vec<usize>,
    worlds: Vec<SharedWorld>,
    carries: Vec<timeline::WarmCarry>,
    img_blocks: BTreeMap<u64, Arc<Vec<u32>>>,
    has_warm_units: bool,
}

impl ReplayPrefix {
    /// The key this prefix was built under.
    pub fn key(&self) -> &PrefixKey {
        &self.key
    }

    /// The resolved cluster the prefix scheduled against.
    pub fn cluster(&self) -> &ClusterConfig {
        &self.cluster
    }

    /// Phase-2 units the prefix carries (full startups + hot updates).
    pub fn n_units(&self) -> usize {
        self.units.len()
    }

    /// Whether any unit is a warm local restart. Feeds the
    /// [`EvalKey::derive`] cache-liveness normalization: with no warm
    /// unit (and dedup off) the cache knobs cannot reach the bits.
    pub fn has_warm_units(&self) -> bool {
        self.has_warm_units
    }

    /// Content fingerprint: SHA-256 over the full debug dump of every
    /// frozen field, truncated to 64 bits. Two prefixes with equal
    /// fingerprints are bit-identical in everything phase 2 can observe;
    /// the property test uses this to prove [`PrefixKey`]-equal options
    /// share one prefix.
    pub fn fingerprint(&self) -> u64 {
        let dump = format!("{self:?}");
        let h = sha256(dump.as_bytes());
        u64::from_be_bytes([h[0], h[1], h[2], h[3], h[4], h[5], h[6], h[7]])
    }
}

/// The all-zero result an empty trace replays to.
pub(super) fn empty_result() -> ReplayResult {
    ReplayResult {
        svc: StageAnalysisService::new(),
        jobs: Vec::new(),
        train_gpu_hours: 0.0,
        startup_gpu_hours: 0.0,
        lost_train_gpu_hours: 0.0,
        fault_restarts: 0,
        pool_gpus: 0,
        queue_waits: Vec::new(),
        credited_bytes: 0,
        demanded_bytes: 0,
        shed_events: 0,
        shed_checks: 0,
        evicted_bytes: 0,
    }
}

/// Build the config-invariant replay prefix for `trace` under `opts`:
/// phase 1 scheduling, the placement walk, the contention sweep, epoch
/// partitioning and world folds, per-unit effective clusters, and the
/// per-job warm carries. `trace` must be non-empty (callers handle the
/// empty case with [`super::replay_cluster`]'s zero result).
///
/// The body is the former first half of `replay_cluster`, verbatim — the
/// parent module's golden tests pin that the factored engine reproduces
/// the monolithic one bit-for-bit.
pub fn build_prefix(
    trace: &[TraceJob],
    cluster: &ClusterConfig,
    seed: u64,
    opts: &ReplayOptions,
) -> ReplayPrefix {
    debug_assert!(!trace.is_empty(), "empty traces have no prefix");
    let key = PrefixKey::derive(seed, cluster, opts);
    let resolved = opts.resolve_cluster(cluster);
    let cluster = &resolved;

    // ---- Phase 0: per-job configs ----
    let jobs_cfg: Vec<JobConfig> = trace.iter().map(trace_job_config).collect();
    let nodes_of: Vec<u32> = jobs_cfg.iter().map(|j| j.nodes(cluster).max(1)).collect();

    // ---- Phase 1: schedule every full startup over the finite pool ----
    // The fault engine's crash hazard interrupts segments in here; the
    // same engine re-derives per-restart decisions (relocation, injected
    // stragglers) below, keyed purely by identity — no shared state.
    let sched = schedule_trace_with(trace, cluster, opts.pool_gpus, &jobs_cfg, &opts.faults, seed);
    let fengine = FaultEngine::new(opts.faults.clone(), seed, &[]);

    // ---- Image / environment identities (shared across jobs) ----
    // digest + hot set + hot bytes per distinct image seed; signature per
    // distinct env seed. Both are pure functions of the job config,
    // computed once.
    let mut img_idents: BTreeMap<u64, (u64, Arc<Vec<u32>>, u64)> = BTreeMap::new();
    let mut env_idents: BTreeMap<u64, u64> = BTreeMap::new();
    let mut job_digest = Vec::with_capacity(trace.len());
    let mut job_hot_bytes = Vec::with_capacity(trace.len());
    let mut job_env_sig = Vec::with_capacity(trace.len());
    for (j, tj) in trace.iter().enumerate() {
        let job = &jobs_cfg[j];
        let img_seed = job.image_identity_seed(tj.id);
        let (digest, _, hot_bytes) = img_idents.entry(img_seed).or_insert_with(|| {
            let img = ImageSpec::synth(
                img_seed,
                job.image_bytes,
                job.image_block_bytes,
                job.image_hot_fraction,
            );
            let hot = img.hot_bytes();
            (img.digest, Arc::new(img.startup_access), hot)
        });
        job_digest.push(*digest);
        job_hot_bytes.push(*hot_bytes);
        let env_seed = job.env_identity_seed(tj.id);
        let sig = *env_idents
            .entry(env_seed)
            .or_insert_with(|| PackageSet::synth(job, env_seed).signature());
        job_env_sig.push(sig);
    }

    // ---- Build the unit list: every full startup + every hot update ----
    let mut units: Vec<Unit> = Vec::new();
    let mut job_units: Vec<Vec<usize>> = vec![Vec::new(); trace.len()];
    for (j, tj) in trace.iter().enumerate() {
        let est = sched.ests[j];
        let segs = &sched.outcomes[j].segments;
        if segs.is_empty() {
            // Cannot happen with the pool clamp, but stay total: replay the
            // job uncontended at its submit time.
            job_units[j].push(units.len());
            units.push(Unit {
                job_idx: j,
                attempt: 0,
                kind: StartupKind::Full,
                start_s: tj.submit_s,
                est_s: est,
                queue_s: 0.0,
                digest: job_digest[j],
                env_sig: job_env_sig[j],
                eff_cluster: cluster.clone(),
                retry: 0,
                interrupted: false,
                seg_len_s: est,
                lost_train_s: 0.0,
                warm_local: false,
                demand: 0,
                epoch: 0,
                placement: None,
                relocation_s: 0.0,
            });
            continue;
        }
        // Walk the outcome runs reconstructing (scripted segment, retry):
        // an interrupted run is followed by its retry of the same segment.
        let mut seg_idx = 0u64;
        let mut retry = 0u32;
        for (k, s) in segs.iter().enumerate() {
            let warm_local = retry > 0 && !fengine.relocated(tj.id, seg_idx, retry);
            job_units[j].push(units.len());
            units.push(Unit {
                job_idx: j,
                attempt: k as u32,
                kind: StartupKind::Full,
                start_s: s.start_s,
                est_s: est,
                queue_s: s.queue_wait_s,
                digest: job_digest[j],
                env_sig: job_env_sig[j],
                eff_cluster: cluster.clone(),
                retry,
                interrupted: s.interrupted,
                seg_len_s: s.end_s - s.start_s,
                lost_train_s: s.lost_train_s,
                warm_local,
                demand: 0,
                epoch: 0,
                placement: None,
                relocation_s: 0.0,
            });
            if s.interrupted {
                retry += 1;
            } else {
                seg_idx += 1;
                retry = 0;
            }
        }
        // Hot updates happen while the last segment trains; they keep the
        // allocation (no queue) and re-run env setup + model init.
        let last = segs[segs.len() - 1];
        let window = (last.end_s - last.start_s - est).max(0.0);
        for h in 0..tj.hot_updates {
            let t = last.start_s + est + window * (h + 1) as f64 / (tj.hot_updates + 1) as f64;
            job_units[j].push(units.len());
            units.push(Unit {
                job_idx: j,
                attempt: segs.len() as u32 + h,
                kind: StartupKind::HotUpdate,
                start_s: t,
                est_s: est,
                queue_s: 0.0,
                digest: job_digest[j],
                env_sig: job_env_sig[j],
                eff_cluster: cluster.clone(),
                retry: 0,
                interrupted: false,
                seg_len_s: 0.0,
                lost_train_s: 0.0,
                warm_local: false,
                demand: 0,
                epoch: 0,
                placement: None,
                relocation_s: 0.0,
            });
        }
    }

    // ---- Topology-aware gang placement over the rack tree ----
    // Phase 1 fixed every full startup's interval; a chronological walk
    // over those segments assigns each gang racks from a shared
    // [`RackPool`] (best-fit single rack, greedy spill across the spine
    // otherwise). Warm restarts re-pin their previous racks; relocated
    // restarts pay `cluster.relocation_cost_s` scaled by how many nodes
    // moved; hot updates inherit the job's allocation. On a flat topology
    // (`racks <= 1`) none of this runs and every placement stays `None` —
    // byte-identical to the placement-free replay.
    if cluster.racks > 1 {
        let mut pool = RackPool::new(sched.pool_gpus, cluster.racks);
        let mut full: Vec<usize> =
            (0..units.len()).filter(|&i| units[i].kind == StartupKind::Full).collect();
        full.sort_by(|&a, &b| {
            units[a]
                .start_s
                .total_cmp(&units[b].start_s)
                .then(units[a].job_idx.cmp(&units[b].job_idx))
                .then(units[a].attempt.cmp(&units[b].attempt))
        });
        // Gangs currently holding racks, keyed by segment end.
        let mut active: Vec<(f64, usize)> = Vec::new();
        let mut prev_of: Vec<Option<Arc<Vec<u32>>>> = vec![None; trace.len()];
        for &i in &full {
            let now = units[i].start_s;
            // Return every gang whose segment ended by `now`.
            let mut still = Vec::with_capacity(active.len());
            for (end, ui) in active.drain(..) {
                if end <= now {
                    if let Some(p) = &units[ui].placement {
                        pool.release(p, trace[units[ui].job_idx].gpus, cluster.gpus_per_node);
                    }
                } else {
                    still.push((end, ui));
                }
            }
            active = still;
            let j = units[i].job_idx;
            let gpus = trace[j].gpus;
            let placement = match (&prev_of[j], units[i].warm_local) {
                (Some(prev), true) => {
                    // The fault oracle already ruled this restart lands
                    // back on its nodes: re-pin the previous racks.
                    let prev = Arc::clone(prev);
                    pool.take(&prev, gpus, cluster.gpus_per_node);
                    prev
                }
                (prev, _) => {
                    let placed = Arc::new(pool.place(gpus, cluster.gpus_per_node));
                    if units[i].retry > 0 {
                        if let Some(prev) = prev {
                            let moved = placement_distance(prev, &placed) as f64;
                            units[i].relocation_s =
                                cluster.relocation_cost_s * moved / placed.len().max(1) as f64;
                        }
                    }
                    placed
                }
            };
            prev_of[j] = Some(Arc::clone(&placement));
            units[i].placement = Some(placement);
            active.push((units[i].start_s + units[i].seg_len_s, i));
        }
        for u in units.iter_mut() {
            if u.kind == StartupKind::HotUpdate {
                u.placement = prev_of[u.job_idx].clone();
            }
        }
    }

    // ---- Contention sweep: A(t) = Σ nodes of startups in flight at t ----
    let mut pts: Vec<(f64, f64)> = Vec::with_capacity(units.len() * 2);
    for u in &units {
        let n = nodes_of[u.job_idx] as f64;
        pts.push((u.start_s, n));
        pts.push((u.start_s + u.est_s, -n));
    }
    let contention = timeline::ContentionTimeline::build(pts);

    // ---- Epoch partition of the unit list ----
    // Equal-width time slices over the schedule horizon; 0 auto-shards one
    // epoch per REPLAY_EPOCH_SPAN_S (capped). Everything below folds per
    // epoch and merges at the boundaries, so the count is a pure
    // performance knob — the goldens pin byte-identity across epoch
    // counts. `epochs: 1` *is* the pre-sharding replay: one partition,
    // the original issue order, a fully folded world.
    let horizon = units.iter().map(|u| u.start_s + u.est_s).fold(0.0f64, f64::max);
    let n_epochs = if opts.epochs == 0 {
        ((horizon / d::REPLAY_EPOCH_SPAN_S).ceil() as usize).clamp(1, d::REPLAY_MAX_EPOCHS)
    } else {
        opts.epochs
    };
    let tl = timeline::EpochTimeline::new(horizon, n_epochs);
    let mut epoch_units: Vec<Vec<usize>> = vec![Vec::new(); tl.epochs];
    for (i, u) in units.iter_mut().enumerate() {
        u.epoch = tl.epoch_of(u.start_s);
        epoch_units[u.epoch].push(i);
    }

    // ---- Warm-state availability: per-epoch handoff, prefix-folded ----
    // Earliest estimated end per identity, noted in the producing unit's
    // epoch and min-merged across epochs 0..=e into epoch e's
    // [`SharedWorld`]. A producer whose end is visible to a query started
    // strictly earlier (estimates are positive), so it lives in an
    // earlier-or-equal epoch and the prefix fold answers exactly like the
    // old global map (see timeline.rs for the argument).
    let mut handoffs: Vec<timeline::EpochHandoff> =
        vec![timeline::EpochHandoff::default(); tl.epochs];
    for u in &units {
        let end = u.start_s + u.est_s;
        if u.kind == StartupKind::Full {
            handoffs[u.epoch].note_image(u.digest, end);
        }
        handoffs[u.epoch].note_env(u.env_sig, end);
    }
    let img_blocks: BTreeMap<u64, Arc<Vec<u32>>> =
        img_idents.values().map(|(dg, b, _)| (*dg, Arc::clone(b))).collect();
    // First job in trace order defines an env signature's cache bytes —
    // same tie-break as the old single-world build.
    let mut env_bytes_of: BTreeMap<u64, u64> = BTreeMap::new();
    for j in 0..trace.len() {
        env_bytes_of.entry(job_env_sig[j]).or_insert(jobs_cfg[j].env_cache_bytes);
    }
    let worlds: Vec<SharedWorld> = timeline::fold_worlds(&handoffs, &img_blocks, &env_bytes_of);

    // ---- Per-unit effective services + fault-injected degradation ----
    // Brownout windows are generated once from the seed over the whole
    // horizon; injected stragglers are keyed by (job, attempt). All of it
    // is computed here, in the prefix, so neither thread interleaving nor
    // the candidate config can ever observe it differently. Per-unit work
    // amortizes per epoch: the contention-integral search skips
    // breakpoints strictly before the epoch's earliest unit
    // (bit-identical — see timeline.rs), and the `effective_cluster` /
    // brownout lookups are memoized on exact-bit keys, so the round-grid's
    // batches of identical (nodes, interval) units hit instead of
    // recomputing.
    let brownouts = BrownoutWindows::generate(&opts.faults, seed, horizon);
    for idxs in &epoch_units {
        if idxs.is_empty() {
            continue;
        }
        let min_start = idxs.iter().map(|&i| units[i].start_s).fold(f64::INFINITY, f64::min);
        let lo = contention.lower_bound(min_start);
        let mut eff_memo: BTreeMap<(u32, u64), ClusterConfig> = BTreeMap::new();
        let mut brown_memo: BTreeMap<(u64, u64), f64> = BTreeMap::new();
        for &i in idxs {
            let u = &mut units[i];
            let end = u.start_s + u.est_s;
            let avg_active = (contention.integral_at_from(lo, end)
                - contention.integral_at_from(lo, u.start_s))
                / u.est_s.max(1e-9);
            u.demand = avg_active.ceil().max(0.0) as u32;
            let nodes = nodes_of[u.job_idx];
            u.eff_cluster = eff_memo
                .entry((nodes, avg_active.to_bits()))
                .or_insert_with(|| effective_cluster(cluster, nodes, avg_active))
                .clone();
            if !brownouts.is_empty() {
                let f = if let (true, Some(p)) = (brownouts.scoped(), &u.placement) {
                    // Rack-scoped windows weigh in by the racks this gang
                    // actually spans; the key is per-placement, so skip
                    // the interval memo and compute directly.
                    let mut racks: Vec<u32> = p.iter().copied().collect();
                    racks.sort_unstable();
                    racks.dedup();
                    brownouts.capacity_scale_racks(u.start_s, end, &racks)
                } else {
                    *brown_memo
                        .entry((u.start_s.to_bits(), end.to_bits()))
                        .or_insert_with(|| brownouts.capacity_scale(u.start_s, end))
                };
                if f < 1.0 {
                    u.eff_cluster.registry_egress_bps *= f;
                    u.eff_cluster.cluster_cache_egress_bps *= f;
                    u.eff_cluster.hdfs_datanode_egress_bps *= f;
                }
            }
            if u.kind == StartupKind::Full && fengine.straggler(trace[u.job_idx].id, u.attempt) {
                let tail = u.eff_cluster.straggler_tail_prob;
                u.eff_cluster.straggler_tail_prob =
                    (tail * opts.faults.straggler_severity).min(0.9);
            }
        }
    }

    // ---- Per-job warm-restart carry, hoisted out of the unit hot path ----
    // The delta-shard bytes use the seed cluster: `effective_cluster`
    // never changes `gpus_per_node`, the only cluster field the resume
    // share depends on, so this is bit-identical to the old per-unit
    // derivation from `eff_cluster`. The delta pair is computed
    // unconditionally (it is a pure function of job + cluster);
    // [`timeline::seed_warm_cache`] gates it on the *candidate's*
    // `delta_resume`, so one prefix serves both sides of that knob.
    let carries: Vec<timeline::WarmCarry> = (0..trace.len())
        .map(|j| timeline::WarmCarry {
            hot_id: ArtifactManifest::image_hot_id(job_digest[j]),
            hot_bytes: job_hot_bytes[j],
            env_id: ArtifactManifest::env_snapshot_id(job_env_sig[j]),
            env_bytes: jobs_cfg[j].env_cache_bytes,
            delta: Some((
                ArtifactManifest::ckpt_shard_id(&jobs_cfg[j]),
                retained_resume_bytes_per_node(&jobs_cfg[j], cluster),
            )),
        })
        .collect();

    // Epoch-major issue order: workers drain epoch 0's units first, then
    // epoch 1's, and so on. Epochs *pipeline* across threads — no barrier
    // at the boundary (the handoff fold already ran), but consecutive
    // pulls share an epoch's world and prep locality. Each unit is still
    // an independent pure function, so the claim order never touches the
    // bits.
    let order: Vec<usize> = epoch_units.iter().flatten().copied().collect();
    let has_warm_units = units.iter().any(|u| u.warm_local);
    ReplayPrefix {
        key,
        cluster: resolved,
        faults: opts.faults.clone(),
        seed,
        jobs_cfg,
        nodes_of,
        pool_gpus: sched.pool_gpus,
        units,
        job_units,
        order,
        worlds,
        carries,
        img_blocks,
        has_warm_units,
    }
}

/// Replay one unit against the shared prefix — the phase-2 inner loop,
/// verbatim from the monolithic engine. Pure: reads the prefix, builds a
/// private [`crate::startup::World`] view, and returns the outcome.
fn run_unit(
    prefix: &ReplayPrefix,
    trace: &[TraceJob],
    cfg: &BootseerConfig,
    u: &Unit,
) -> StartupOutcome {
    let tj = &trace[u.job_idx];
    let job = &prefix.jobs_cfg[u.job_idx];
    let mut world = prefix.worlds[u.epoch].world_at(u.digest, u.env_sig, u.start_s);
    if u.warm_local {
        // Restart on its previous nodes: the job's own prior attempt
        // guarantees a record + cache regardless of cluster-level
        // availability timing.
        if !world.hotset.has_record(u.digest) {
            if let Some(blocks) = prefix.img_blocks.get(&u.digest) {
                world.hotset.seed_record(u.digest, blocks.iter().copied());
            }
        }
        if world.envcache.lookup(u.env_sig).is_none() {
            world.envcache.store(u.env_sig, job.env_cache_bytes);
        }
    }
    let unit_seed = prefix.seed
        ^ tj.id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (u.attempt as u64).wrapping_mul(0xA5A5_5A5A_A5A5_5A5A);
    let (queue_s, alloc_s) = if u.kind == StartupKind::Full {
        // A relocated restart pays its placement-distance cost in the
        // allocation phase; `relocation_s` is 0.0 everywhere else, so
        // the flat replay stays bit-identical.
        (u.queue_s, d::ALLOC_BASE_S + 0.02 * prefix.nodes_of[u.job_idx] as f64 + u.relocation_s)
    } else {
        (0.0, 0.0)
    };
    // Warm restart on its previous nodes: the artifacts the failed
    // attempt materialized are still resident — expressed as cache
    // state, not per-subsystem byte fields, seeded from the per-job
    // [`timeline::WarmCarry`] (hot set → pin → env snapshot → delta
    // shard → churn, the exact pre-sharding insert order and churn
    // arithmetic). The unbounded default with a cold start skips all
    // of this and is byte-identical to the plain replay.
    let bounded = cfg.cache_capacity_bytes != u64::MAX;
    let cache = if u.warm_local {
        timeline::seed_warm_cache(cfg, &prefix.carries[u.job_idx], prefix.seed, tj.id, u.attempt)
    } else if bounded {
        CacheState::with_capacity(cfg.cache_capacity_bytes, cfg.cache_policy)
    } else {
        CacheState::new()
    };
    let admission = Admission::from_faults(
        &prefix.faults,
        u.demand,
        mix64(
            prefix.seed
                ^ SALT_ADMISSION
                ^ tj.id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (u.attempt as u64).wrapping_mul(0xA5A5_5A5A_A5A5_5A5A),
        ),
    );
    run_startup_with(
        tj.id,
        u.attempt,
        &u.eff_cluster,
        job,
        cfg,
        &mut world,
        u.kind,
        unit_seed,
        StartupContext { queue_s, alloc_s, cache, admission, placement: u.placement.clone() },
    )
}

/// Replay every unit once per candidate config, all candidates
/// interleaved over one thread pool: the flattened work list is
/// candidate-major over the prefix's epoch-major unit order, workers pull
/// with a single atomic cursor into per-worker reusable scratch vectors,
/// and outcomes scatter back to `slots[candidate][unit]`. Each
/// (candidate, unit) cell is an independent pure function of the shared
/// prefix, so claim order never touches the bits — the same argument as
/// the single-config engine, per candidate.
fn run_units_batch(
    prefix: &ReplayPrefix,
    trace: &[TraceJob],
    cfgs: &[BootseerConfig],
    threads: usize,
) -> Vec<Vec<Option<StartupOutcome>>> {
    let n_threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    let n_units = prefix.order.len();
    let total = cfgs.len() * n_units;
    let mut slots: Vec<Vec<Option<StartupOutcome>>> =
        cfgs.iter().map(|_| (0..n_units).map(|_| None).collect()).collect();
    if n_threads <= 1 || total <= 1 {
        for (li, cfg) in cfgs.iter().enumerate() {
            for &i in &prefix.order {
                slots[li][i] = Some(run_unit(prefix, trace, cfg, &prefix.units[i]));
            }
        }
        return slots;
    }
    let next = AtomicUsize::new(0);
    let collected: Vec<Vec<(usize, usize, StartupOutcome)>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n_threads);
        for _ in 0..n_threads {
            let next = &next;
            handles.push(scope.spawn(move || {
                // Per-worker scratch arena: one growing vector collects
                // every outcome this worker produces, across candidates.
                let mut local: Vec<(usize, usize, StartupOutcome)> = Vec::new();
                loop {
                    let k = next.fetch_add(1, AtomicOrdering::Relaxed);
                    if k >= total {
                        break;
                    }
                    let li = k / n_units;
                    let i = prefix.order[k % n_units];
                    local.push((li, i, run_unit(prefix, trace, &cfgs[li], &prefix.units[i])));
                }
                local
            }));
        }
        handles.into_iter().map(|h| h.join().expect("batch replay worker panicked")).collect()
    });
    for (li, i, o) in collected.into_iter().flatten() {
        slots[li][i] = Some(o);
    }
    slots
}

/// Fold one candidate's unit outcomes into a [`ReplayResult`] in
/// deterministic (job, attempt) order — the former aggregation tail of
/// `replay_cluster`, verbatim.
fn aggregate(
    prefix: &ReplayPrefix,
    trace: &[TraceJob],
    mut slots: Vec<Option<StartupOutcome>>,
) -> ReplayResult {
    let mut svc = StageAnalysisService::new();
    let mut jobs = Vec::with_capacity(trace.len());
    let mut train_gpu_hours = 0.0;
    let mut startup_gpu_hours = 0.0;
    let mut lost_train_gpu_hours = 0.0;
    let mut fault_restarts = 0u64;
    let mut queue_waits = Vec::new();
    let mut credited_bytes = 0u64;
    let mut demanded_bytes = 0u64;
    let mut shed_events = 0u64;
    let mut shed_checks = 0u64;
    let mut evicted_bytes = 0u64;
    for (j, tj) in trace.iter().enumerate() {
        svc.register_job(tj.id, tj.gpus);
        let alloc_s = d::ALLOC_BASE_S + 0.02 * prefix.nodes_of[j] as f64;
        let mut startup_worker_s = Vec::new();
        let mut startup_fetched_bytes = Vec::new();
        let mut first_total = 0.0;
        let mut installs = Vec::new();
        let mut last_full: Option<StartupOutcome> = None;
        let mut job_queue_waits = Vec::new();
        let mut starts_s = Vec::new();
        let mut wasted_gpu_s = 0.0;
        let mut job_fault_restarts = 0u32;
        for &ui in &prefix.job_units[j] {
            let u = &prefix.units[ui];
            let o = slots[ui].take().expect("unit replayed");
            startup_worker_s.push(o.worker_phase_s);
            startup_fetched_bytes.push(o.fetched_bytes);
            credited_bytes += o.credited_bytes;
            demanded_bytes += o.demanded_bytes;
            shed_events += o.shed_events;
            shed_checks += o.shed_checks;
            evicted_bytes += o.evicted_bytes;
            if u.interrupted {
                // The run ended at the failure instant: only the startup
                // time actually spent before it counts as waste.
                let charged = o.worker_phase_s.min((u.seg_len_s - alloc_s).max(0.0));
                startup_gpu_hours += charged * tj.gpus as f64 / 3600.0;
                wasted_gpu_s += charged * tj.gpus as f64;
            } else {
                startup_gpu_hours += o.gpu_seconds_wasted() / 3600.0;
                wasted_gpu_s += o.gpu_seconds_wasted();
            }
            if u.lost_train_s > 0.0 {
                lost_train_gpu_hours += u.lost_train_s * tj.gpus as f64 / 3600.0;
                wasted_gpu_s += u.lost_train_s * tj.gpus as f64;
            }
            if u.kind == StartupKind::Full {
                if u.retry > 0 {
                    fault_restarts += 1;
                    job_fault_restarts += 1;
                }
                if u.attempt == 0 {
                    first_total = o.total_s;
                }
                installs = o.install_durations.clone();
                job_queue_waits.push(u.queue_s);
                starts_s.push(u.start_s);
                svc.ingest_all(o.events.iter().cloned());
                last_full = Some(o);
            }
        }
        queue_waits.extend(job_queue_waits.iter().copied());
        train_gpu_hours += tj.gpus as f64 * tj.train_hours;
        jobs.push(JobReplay {
            job: tj.clone(),
            startup_worker_s,
            startup_fetched_bytes,
            first_total_s: first_total,
            install_durations: installs,
            last_full,
            queue_waits: job_queue_waits,
            starts_s,
            wasted_gpu_s,
            fault_restarts: job_fault_restarts,
        });
    }
    ReplayResult {
        svc,
        jobs,
        train_gpu_hours,
        startup_gpu_hours,
        lost_train_gpu_hours,
        fault_restarts,
        pool_gpus: prefix.pool_gpus,
        queue_waits,
        credited_bytes,
        demanded_bytes,
        shed_events,
        shed_checks,
        evicted_bytes,
    }
}

/// Phase-2-only evaluation of one *resolved* [`BootseerConfig`] against a
/// shared prefix. `cfg` must already have any builder/CLI overrides
/// folded in ([`ReplayOptions::resolve`]); [`super::replay_cluster`] is
/// exactly [`build_prefix`] + this call.
pub fn evaluate_prefix(
    prefix: &ReplayPrefix,
    trace: &[TraceJob],
    cfg: &BootseerConfig,
    threads: usize,
) -> ReplayResult {
    let slots = run_units_batch(prefix, trace, std::slice::from_ref(cfg), threads)
        .pop()
        .expect("one slot vector per config");
    aggregate(prefix, trace, slots)
}

/// What [`batch_replay`] returns: one result per candidate (same order),
/// plus the sharing telemetry the bench gate and the optimizer report.
#[derive(Debug)]
pub struct BatchOutcome {
    /// `results[i]` is byte-identical to
    /// `replay_cluster(trace, cluster, cfg, seed, &candidates[i])`.
    pub results: Vec<ReplayResult>,
    /// Distinct [`ReplayPrefix`]es built (phase-1 schedules run).
    pub prefix_builds: usize,
    /// Distinct phase-2 evaluations run; `candidates.len() - eval_groups`
    /// results were served as clones of an [`EvalKey`]-equal leader.
    pub eval_groups: usize,
}

/// Evaluate every candidate [`ReplayOptions`] over one trace, sharing all
/// config-invariant work:
///
/// - prefixes are memoized by [`PrefixKey`] — candidates that differ only
///   in phase-2 knobs share one phase-1 schedule/placement/world build;
/// - candidates with equal `(PrefixKey, EvalKey)` share one phase-2
///   evaluation — followers clone the leader's [`ReplayResult`];
/// - each prefix's distinct evaluations run interleaved over a single
///   worker pool ([`run_units_batch`]), so `threads` bounds the whole
///   batch rather than each candidate.
///
/// A candidate's own `threads` field is ignored — the `threads` parameter
/// governs the batch (results are byte-identical either way).
pub fn batch_replay(
    trace: &[TraceJob],
    cluster: &ClusterConfig,
    cfg: &BootseerConfig,
    seed: u64,
    candidates: &[ReplayOptions],
    threads: usize,
) -> BatchOutcome {
    if trace.is_empty() || candidates.is_empty() {
        return BatchOutcome {
            results: candidates.iter().map(|_| empty_result()).collect(),
            prefix_builds: 0,
            eval_groups: 0,
        };
    }
    let mut prefixes: BTreeMap<PrefixKey, Arc<ReplayPrefix>> = BTreeMap::new();
    let mut groups: BTreeMap<(PrefixKey, EvalKey), usize> = BTreeMap::new();
    let mut leaders: Vec<(PrefixKey, BootseerConfig)> = Vec::new();
    let mut member_of: Vec<usize> = Vec::with_capacity(candidates.len());
    for opts in candidates {
        let key = PrefixKey::derive(seed, cluster, opts);
        let prefix = prefixes
            .entry(key.clone())
            .or_insert_with(|| Arc::new(build_prefix(trace, cluster, seed, opts)));
        let (_, bc) = opts.resolve(cluster, cfg);
        let ekey = EvalKey::derive(&bc, prefix.has_warm_units);
        let slot = *groups.entry((key.clone(), ekey)).or_insert_with(|| {
            leaders.push((key.clone(), bc));
            leaders.len() - 1
        });
        member_of.push(slot);
    }
    // One interleaved phase-2 batch per prefix, covering all its leaders.
    let mut by_prefix: BTreeMap<PrefixKey, Vec<usize>> = BTreeMap::new();
    for (slot, (key, _)) in leaders.iter().enumerate() {
        by_prefix.entry(key.clone()).or_default().push(slot);
    }
    let mut leader_results: Vec<Option<ReplayResult>> = leaders.iter().map(|_| None).collect();
    for (key, slots) in &by_prefix {
        let prefix = &prefixes[key];
        let cfgs: Vec<BootseerConfig> = slots.iter().map(|&s| leaders[s].1.clone()).collect();
        let outs = run_units_batch(prefix, trace, &cfgs, threads);
        for (&slot, slot_outs) in slots.iter().zip(outs) {
            leader_results[slot] = Some(aggregate(prefix, trace, slot_outs));
        }
    }
    let results = member_of
        .iter()
        .map(|&s| leader_results[s].clone().expect("leader evaluated"))
        .collect();
    BatchOutcome { results, prefix_builds: prefixes.len(), eval_groups: leaders.len() }
}

#[cfg(test)]
mod tests {
    use super::super::{gen_trace, replay_cluster};
    use super::*;

    /// Hot enough that the week actually sees warm restarts, relocations,
    /// and shedding (mirrors the cache-economics sweep preset).
    fn hot() -> FaultConfig {
        FaultConfig { hazard_per_gpu_hour: 2.0e-3, relocate_prob: 0.2, ..FaultConfig::storm() }
    }

    /// Full bit-capture of a [`ReplayResult`]: every scalar, every
    /// per-job stream. Two equal captures mean byte-identical results for
    /// everything downstream consumers can observe.
    fn capture(r: &ReplayResult) -> Vec<u64> {
        let mut s = vec![
            r.startup_gpu_hours.to_bits(),
            r.train_gpu_hours.to_bits(),
            r.lost_train_gpu_hours.to_bits(),
            r.fault_restarts,
            u64::from(r.pool_gpus),
            r.credited_bytes,
            r.demanded_bytes,
            r.shed_events,
            r.shed_checks,
            r.evicted_bytes,
        ];
        for w in &r.queue_waits {
            s.push(w.to_bits());
        }
        for j in &r.jobs {
            for w in &j.startup_worker_s {
                s.push(w.to_bits());
            }
            for &b in &j.startup_fetched_bytes {
                s.push(b);
            }
            s.push(j.first_total_s.to_bits());
            s.push(j.wasted_gpu_s.to_bits());
            s.push(u64::from(j.fault_restarts));
        }
        s
    }

    #[test]
    fn prefix_key_partitions_options_and_key_equal_prefixes_are_bit_identical() {
        let t = gen_trace(11, 18, 2.0 * 86400.0);
        let cluster = ClusterConfig::default();
        let base = ReplayOptions::new();
        let k0 = PrefixKey::derive(5, &cluster, &base);
        let f0 = build_prefix(&t, &cluster, 5, &base).fingerprint();
        let irrelevant: Vec<(&str, ReplayOptions)> = vec![
            ("overlap", ReplayOptions::new().with_overlap(OverlapMode::Speculative)),
            ("dedup", ReplayOptions::new().with_dedup(true)),
            ("delta_resume", ReplayOptions::new().with_delta_resume(true)),
            ("cache", ReplayOptions::new().with_cache(8_000_000_000, CachePolicy::Gdsf)),
            ("budget", ReplayOptions::new().with_spec_prefetch_budget(1_000_000_000)),
            ("threads", ReplayOptions::new().with_threads(7)),
        ];
        for (what, o) in &irrelevant {
            assert_eq!(PrefixKey::derive(5, &cluster, o), k0, "{what} changed the key");
            assert_eq!(
                build_prefix(&t, &cluster, 5, o).fingerprint(),
                f0,
                "{what} changed the prefix bits"
            );
        }
        let relevant: Vec<(&str, ReplayOptions)> = vec![
            ("pool_gpus", ReplayOptions::new().with_pool_gpus(Some(4096))),
            ("faults", ReplayOptions::new().with_faults(FaultConfig::paper())),
            ("racks", ReplayOptions::new().with_racks(4)),
            ("epochs", ReplayOptions::new().with_epochs(3)),
            ("spine_oversub", ReplayOptions::new().with_spine_oversub(9.0)),
        ];
        for (what, o) in &relevant {
            assert_ne!(PrefixKey::derive(5, &cluster, o), k0, "{what} must change the key");
        }
        assert_ne!(PrefixKey::derive(6, &cluster, &base), k0, "seed must change the key");
    }

    #[test]
    fn eval_key_normalizes_provably_dead_knobs() {
        let base = BootseerConfig::bootseer();
        let with_budget = |m: OverlapMode, b: u64| BootseerConfig {
            overlap: m,
            spec_prefetch_budget_bytes: b,
            ..base.clone()
        };
        // The budget only reaches the bits under Speculative overlap.
        assert_eq!(
            EvalKey::derive(&with_budget(OverlapMode::Sequential, 1), false),
            EvalKey::derive(&with_budget(OverlapMode::Sequential, 9), false)
        );
        assert_ne!(
            EvalKey::derive(&with_budget(OverlapMode::Speculative, 1), false),
            EvalKey::derive(&with_budget(OverlapMode::Speculative, 9), false)
        );
        let with_cache = |cap: u64, p: CachePolicy, dedup: bool| BootseerConfig {
            cache_capacity_bytes: cap,
            cache_policy: p,
            artifact_dedup: dedup,
            ..base.clone()
        };
        // Cold fleet, dedup off: capacity and policy collapse to the
        // unbounded default...
        assert_eq!(
            EvalKey::derive(&with_cache(3_000_000_000, CachePolicy::Gdsf, false), false),
            EvalKey::derive(&with_cache(u64::MAX, CachePolicy::Lru, false), false)
        );
        // ...warm units revive them...
        assert_ne!(
            EvalKey::derive(&with_cache(3_000_000_000, CachePolicy::Gdsf, false), true),
            EvalKey::derive(&with_cache(u64::MAX, CachePolicy::Lru, false), true)
        );
        // ...and so does dedup on its own.
        assert_ne!(
            EvalKey::derive(&with_cache(3_000_000_000, CachePolicy::Gdsf, true), false),
            EvalKey::derive(&with_cache(u64::MAX, CachePolicy::Lru, true), false)
        );
        // An unbounded cache never keys on policy.
        assert_eq!(
            EvalKey::derive(&with_cache(u64::MAX, CachePolicy::Gdsf, true), true),
            EvalKey::derive(&with_cache(u64::MAX, CachePolicy::Lru, true), true)
        );
    }

    /// The acceptance pin: every batched candidate's result is
    /// byte-identical to its standalone [`replay_cluster`] run, across
    /// thread and epoch counts, over candidates chosen to exercise every
    /// dangerous [`EvalKey`] normalization (dead budget, dead cache
    /// knobs, warm-unit revival, dedup, topology).
    #[test]
    fn batched_results_byte_identical_to_standalone_across_threads_and_epochs() {
        let t = gen_trace(9, 20, 7.0 * 86400.0);
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::bootseer();
        let base: Vec<ReplayOptions> = vec![
            ReplayOptions::new(),
            ReplayOptions::new()
                .with_overlap(OverlapMode::Speculative)
                .with_spec_prefetch_budget(2_000_000_000),
            ReplayOptions::new()
                .with_overlap(OverlapMode::Sequential)
                .with_spec_prefetch_budget(2_000_000_000),
            ReplayOptions::new().with_faults(hot()).with_cache(3_000_000_000, CachePolicy::Lru),
            ReplayOptions::new().with_faults(hot()).with_cache(3_000_000_000, CachePolicy::Gdsf),
            ReplayOptions::new().with_faults(hot()).with_delta_resume(true),
            ReplayOptions::new().with_dedup(true).with_cache(8_000_000_000, CachePolicy::Lru),
            ReplayOptions::new().with_racks(4),
        ];
        // The cache-liveness normalization must actually be exercised:
        // the hot-faults prefix carries warm units, the fault-free one
        // none.
        assert!(build_prefix(&t, &cluster, 7, &base[3]).has_warm_units());
        assert!(!build_prefix(&t, &cluster, 7, &base[0]).has_warm_units());
        for threads in [1usize, 4] {
            for epochs in [1usize, 3] {
                let cands: Vec<ReplayOptions> =
                    base.iter().map(|o| o.clone().with_epochs(epochs)).collect();
                let out = batch_replay(&t, &cluster, &cfg, 7, &cands, threads);
                assert_eq!(out.results.len(), cands.len());
                for (i, o) in cands.iter().enumerate() {
                    let solo =
                        replay_cluster(&t, &cluster, &cfg, 7, &o.clone().with_threads(threads));
                    assert_eq!(
                        capture(&out.results[i]),
                        capture(&solo),
                        "candidate {i} diverged (threads={threads} epochs={epochs})"
                    );
                }
            }
        }
    }

    #[test]
    fn duplicate_candidates_share_one_evaluation() {
        let t = gen_trace(3, 16, 86400.0);
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::bootseer();
        // Fault-free and dedup-free, so every cache knob and every
        // Sequential-mode budget is provably dead: eight candidates
        // collapse to two live groups over one shared prefix.
        let cands = vec![
            ReplayOptions::new().with_cache(8_000_000_000, CachePolicy::Lru),
            ReplayOptions::new().with_cache(8_000_000_000, CachePolicy::Gdsf),
            ReplayOptions::new().with_cache(24_000_000_000, CachePolicy::Lru),
            ReplayOptions::new().with_spec_prefetch_budget(1_000_000_000),
            ReplayOptions::new().with_spec_prefetch_budget(9_000_000_000),
            ReplayOptions::new(),
            ReplayOptions::new().with_overlap(OverlapMode::Overlapped),
            ReplayOptions::new()
                .with_overlap(OverlapMode::Overlapped)
                .with_cache(3_000_000_000, CachePolicy::Gdsf),
        ];
        let out = batch_replay(&t, &cluster, &cfg, 3, &cands, 2);
        assert_eq!(out.prefix_builds, 1, "one shared prefix");
        assert_eq!(out.eval_groups, 2, "two live eval groups");
        let first = capture(&out.results[0]);
        for i in 1..6 {
            assert_eq!(first, capture(&out.results[i]), "follower {i} != leader");
        }
        assert_eq!(capture(&out.results[6]), capture(&out.results[7]));
        assert_ne!(first, capture(&out.results[6]), "overlap modes must differ");
    }

    #[test]
    fn empty_trace_and_empty_candidates_are_total() {
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::bootseer();
        let out = batch_replay(&[], &cluster, &cfg, 1, &[ReplayOptions::new()], 2);
        assert_eq!(out.results.len(), 1);
        assert_eq!(out.prefix_builds, 0);
        assert_eq!(out.eval_groups, 0);
        assert_eq!(out.results[0].pool_gpus, 0);
        assert!(out.results[0].jobs.is_empty());
        let t = gen_trace(1, 4, 86400.0);
        let none = batch_replay(&t, &cluster, &cfg, 1, &[], 2);
        assert!(none.results.is_empty());
        assert_eq!(none.eval_groups, 0);
    }
}
