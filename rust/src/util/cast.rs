//! Checked numeric conversions for accounting arithmetic.
//!
//! The replay's headline quantities — bytes moved, GPU-hours wasted,
//! service-op counts — cross between `f64` (fluid-sim arithmetic), `u64`
//! (byte ledgers) and `u32`/`usize` (counts and indexing). A bare `as`
//! cast at those joints truncates or wraps silently, which is exactly how
//! accounting drift ships unnoticed; detlint rule `unchecked-cast` (R5)
//! flags bare casts in accounting statements and points here.
//!
//! Every helper is **bit-identical to the `as` cast it replaces** in
//! release builds: the precondition is a `debug_assert!`, checked by
//! `cargo test` (dev profile) and compiled out of the release replay. The
//! raw casts below are the one blessed site — R5 skips this module.

/// Byte quantity from float arithmetic, truncating toward zero exactly
/// like `as`. Checked: finite, non-negative, and below 2^53 — the range
/// where `f64` still resolves individual bytes (9 PB, far above any
/// modeled artifact), so the truncation drops only the sub-byte fraction.
#[inline]
pub fn bytes_from_f64(x: f64) -> u64 {
    debug_assert!(x.is_finite(), "byte quantity not finite: {x}");
    debug_assert!(x >= 0.0, "negative byte quantity: {x}");
    debug_assert!(x < 9_007_199_254_740_992.0, "byte quantity above f64 integer range: {x}");
    x as u64
}

/// Count (node/op/capacity) from float arithmetic, truncating like `as`.
/// Checked: finite, non-negative, and within `u32`.
#[inline]
pub fn u32_from_f64(x: f64) -> u32 {
    debug_assert!(x.is_finite(), "count not finite: {x}");
    debug_assert!(x >= 0.0, "negative count: {x}");
    debug_assert!(x <= u32::MAX as f64, "count overflows u32: {x}");
    x as u32
}

/// Widen a length/index to a `u64` ledger quantity. Lossless on every
/// target Rust supports (`usize` ≤ 64 bits); spelled as a named helper so
/// accounting statements carry no bare `as`.
#[inline]
pub fn u64_from_usize(x: usize) -> u64 {
    x as u64
}

/// Narrow a `u64` ledger quantity to an in-memory size/index. Checked:
/// must fit `usize` — a real guard on 32-bit targets, where a 5 GB wire
/// length must fail loudly rather than wrap into a short allocation.
#[inline]
pub fn usize_from_u64(x: u64) -> usize {
    debug_assert!(
        u128::from(x) <= usize::MAX as u128,
        "u64 {x} does not fit usize on this target"
    );
    x as usize
}

/// Narrow a `u64` count to `u32`. Checked: must fit.
#[inline]
pub fn u32_from_u64(x: u64) -> u32 {
    debug_assert!(x <= u64::from(u32::MAX), "count overflows u32: {x}");
    x as u32
}

/// Narrow a collection length to a `u32` count. Checked: must fit.
#[inline]
pub fn u32_from_usize(x: usize) -> u32 {
    debug_assert!(x <= u32::MAX as usize, "length overflows u32: {x}");
    x as u32
}

/// Widen a `u32` id/index for slice indexing. Lossless on every target
/// Rust supports (`usize` ≥ 32 bits on all tier-1/2 platforms this
/// builds for).
#[inline]
pub fn usize_from_u32(x: u32) -> usize {
    x as usize
}

/// Config-file integer (TOML `i64`) to a byte/size quantity: negatives
/// clamp to 0 — a negative byte count must never wrap into an effectively
/// unlimited quantity (the `cache_capacity_bytes` bug class).
#[inline]
pub fn u64_from_i64_clamped(x: i64) -> u64 {
    x.max(0) as u64
}

/// Config-file integer (TOML `i64`) to a `u32` count: clamped into
/// `0..=u32::MAX` instead of bit-truncated.
#[inline]
pub fn u32_from_i64_clamped(x: i64) -> u32 {
    x.clamp(0, i64::from(u32::MAX)) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn release_equivalence_on_happy_path() {
        // Each helper must truncate exactly like the cast it replaces.
        assert_eq!(bytes_from_f64(28_620_000_000.9), 28_620_000_000);
        assert_eq!(bytes_from_f64(0.0), 0);
        assert_eq!(u32_from_f64(65_535.7), 65_535);
        assert_eq!(u64_from_usize(123_456), 123_456);
        assert_eq!(usize_from_u64(1 << 40), 1usize << 40);
        assert_eq!(u32_from_u64(4_294_967_295), u32::MAX);
        assert_eq!(u32_from_usize(8), 8);
        assert_eq!(usize_from_u32(u32::MAX), 4_294_967_295);
    }

    #[test]
    fn clamped_config_conversions() {
        assert_eq!(u64_from_i64_clamped(-1), 0);
        assert_eq!(u64_from_i64_clamped(i64::MAX), i64::MAX as u64);
        assert_eq!(u32_from_i64_clamped(-7), 0);
        assert_eq!(u32_from_i64_clamped(1 << 40), u32::MAX);
        assert_eq!(u32_from_i64_clamped(12), 12);
    }

    #[test]
    #[should_panic(expected = "negative byte quantity")]
    #[cfg(debug_assertions)]
    fn negative_bytes_caught_in_debug() {
        bytes_from_f64(-1.0);
    }

    #[test]
    #[should_panic(expected = "count overflows u32")]
    #[cfg(debug_assertions)]
    fn u32_overflow_caught_in_debug() {
        u32_from_u64(1 << 33);
    }
}
