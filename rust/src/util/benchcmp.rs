//! Bench-regression comparison: diff a fresh `BENCH_*.json` against the
//! committed baseline under `benches/baselines/` with a relative
//! tolerance. Backs the `bench-gate` binary CI runs after the bench
//! sweeps, turning the artifacts from "uploaded and forgotten" into a
//! blocking regression gate.
//!
//! All tracked metrics are **lower-is-better** (simulated seconds, wasted
//! fractions, GPU-hours of overhead), so only `fresh > baseline * (1 +
//! tol)` counts as a regression; improvements just pass (refresh the
//! baseline to ratchet them in). A metric present in one file but not the
//! other is a schema drift and fails too — intentional changes must update
//! the committed baseline in the same PR.

use crate::util::json::Json;

/// Is this leaf a tracked lower-is-better metric? Keys ending in `_s`
/// (simulated seconds) or `_fraction`, every `wasted*` quantity (incl.
/// sliced variants like `wasted_fraction_ge128`), plus the GPU-hour
/// overhead counters. Identity/metadata fields (gpus, seed, n_jobs,
/// train_gpu_hours, ...) are compared for presence only.
pub fn is_metric_key(key: &str) -> bool {
    key.ends_with("_s")
        || key.ends_with("_fraction")
        || key.starts_with("wasted")
        || key == "startup_gpu_hours"
        || key == "lost_gpu_hours"
}

/// One comparison violation, human-readable.
#[derive(Clone, Debug, PartialEq)]
pub struct Violation {
    pub path: String,
    pub detail: String,
}

impl Violation {
    fn new(path: &str, detail: String) -> Violation {
        Violation { path: path.to_string(), detail }
    }
}

/// Compare `fresh` against `baseline`; returns every violation (empty =
/// gate passes). `tol` is the allowed relative regression on each metric
/// (0.35 = fail when fresh exceeds baseline by more than 35%).
pub fn compare(baseline: &Json, fresh: &Json, tol: f64) -> Vec<Violation> {
    let mut out = Vec::new();
    walk(baseline, fresh, "", tol, &mut out);
    out
}

fn walk(base: &Json, fresh: &Json, path: &str, tol: f64, out: &mut Vec<Violation>) {
    match (base, fresh) {
        (Json::Obj(b), Json::Obj(f)) => {
            for (k, bv) in b {
                let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                match f.get(k) {
                    Some(fv) => walk(bv, fv, &sub, tol, out),
                    None => out.push(Violation::new(
                        &sub,
                        "missing from fresh run (schema drift — update the baseline)"
                            .to_string(),
                    )),
                }
            }
            for k in f.keys() {
                if !b.contains_key(k) {
                    let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                    out.push(Violation::new(
                        &sub,
                        "missing from baseline (schema drift — update the baseline)"
                            .to_string(),
                    ));
                }
            }
        }
        (Json::Arr(b), Json::Arr(f)) => {
            if b.len() != f.len() {
                out.push(Violation::new(
                    path,
                    format!("array length {} vs baseline {}", f.len(), b.len()),
                ));
                return;
            }
            for (i, (bv, fv)) in b.iter().zip(f).enumerate() {
                walk(bv, fv, &format!("{path}[{i}]"), tol, out);
            }
        }
        (Json::Num(b), Json::Num(f)) => {
            let key = path.rsplit('.').next().unwrap_or(path);
            let key = key.split('[').next().unwrap_or(key);
            if is_metric_key(key) && *f > b * (1.0 + tol) + 1e-12 {
                out.push(Violation::new(
                    path,
                    format!(
                        "regressed: {f:.6} vs baseline {b:.6} (+{:.1}%, tolerance {:.0}%)",
                        100.0 * (f / b.max(1e-12) - 1.0),
                        100.0 * tol
                    ),
                ));
            }
        }
        // Non-numeric leaves (mode names, configs): presence is enough.
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn j(s: &str) -> Json {
        parse(s).unwrap()
    }

    #[test]
    fn metric_key_classification() {
        assert!(is_metric_key("sequential_s"));
        assert!(is_metric_key("wasted_fraction"));
        assert!(is_metric_key("wasted_fraction_ge128"));
        assert!(is_metric_key("wasted_gpu_hours"));
        assert!(is_metric_key("startup_gpu_hours"));
        assert!(!is_metric_key("gpus"));
        assert!(!is_metric_key("train_gpu_hours"));
        assert!(!is_metric_key("seed"));
        assert!(!is_metric_key("fault_restarts"));
    }

    #[test]
    fn sliced_headline_metric_is_gated() {
        let base = j(r#"{"modes": [{"wasted_fraction_ge128": 0.033}]}"#);
        let fresh = j(r#"{"modes": [{"wasted_fraction_ge128": 0.30}]}"#);
        let v = compare(&base, &fresh, 0.35);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].path, "modes[0].wasted_fraction_ge128");
    }

    #[test]
    fn within_tolerance_passes() {
        let base = j(r#"{"points": [{"gpus": 128, "sequential_s": 100.0}]}"#);
        let fresh = j(r#"{"points": [{"gpus": 128, "sequential_s": 120.0}]}"#);
        assert!(compare(&base, &fresh, 0.35).is_empty());
    }

    #[test]
    fn regression_fails() {
        let base = j(r#"{"points": [{"gpus": 128, "sequential_s": 100.0}]}"#);
        let fresh = j(r#"{"points": [{"gpus": 128, "sequential_s": 140.0}]}"#);
        let v = compare(&base, &fresh, 0.35);
        assert_eq!(v.len(), 1);
        assert!(v[0].path.contains("sequential_s"), "{:?}", v[0]);
        assert!(v[0].detail.contains("regressed"));
    }

    #[test]
    fn improvement_and_metadata_drift_pass() {
        // Faster is fine; a *bigger* gpus identity field is not a metric.
        let base = j(r#"{"points": [{"gpus": 128, "sequential_s": 100.0}]}"#);
        let fresh = j(r#"{"points": [{"gpus": 999, "sequential_s": 10.0}]}"#);
        assert!(compare(&base, &fresh, 0.1).is_empty());
    }

    #[test]
    fn schema_drift_fails_both_ways() {
        let base = j(r#"{"a_s": 1.0, "b_s": 1.0}"#);
        let fresh = j(r#"{"a_s": 1.0, "c_s": 1.0}"#);
        let v = compare(&base, &fresh, 0.5);
        assert_eq!(v.len(), 2);
        assert!(v.iter().any(|x| x.path == "b_s"));
        assert!(v.iter().any(|x| x.path == "c_s"));
    }

    #[test]
    fn array_length_mismatch_fails() {
        let base = j(r#"{"points": [1, 2]}"#);
        let fresh = j(r#"{"points": [1]}"#);
        let v = compare(&base, &fresh, 0.5);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("array length"));
    }

    #[test]
    fn nested_paths_reported() {
        let base = j(r#"{"modes": [{"mode": "seq", "wasted_fraction": 0.03}]}"#);
        let fresh = j(r#"{"modes": [{"mode": "seq", "wasted_fraction": 0.08}]}"#);
        let v = compare(&base, &fresh, 0.35);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].path, "modes[0].wasted_fraction");
    }
}
