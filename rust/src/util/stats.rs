//! Summary statistics used throughout the figure generators: quantiles,
//! box-plot summaries (the paper's plots are boxplots with whiskers at two
//! standard deviations), and the paper's Max/Median straggler ratio (§3.3).

/// Five-number-plus summary of a sample, matching the paper's plotting
/// convention: whiskers extend to two standard deviations around the mean
/// (clamped to the observed min/max), "in order to exclude outliers".
#[derive(Clone, Debug, PartialEq)]
pub struct BoxSummary {
    pub n: usize,
    pub min: f64,
    pub whisker_lo: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub whisker_hi: f64,
    pub max: f64,
    pub mean: f64,
    pub std: f64,
}

/// Linear-interpolation quantile (type 7, numpy default) of an unsorted slice.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty sample");
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Quantile of an already-sorted slice.
pub fn quantile_sorted(v: &[f64], q: f64) -> f64 {
    assert!(!v.is_empty());
    if v.len() == 1 {
        return v[0];
    }
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// The paper's straggler metric (§3.3): slowest node / median node.
pub fn max_median_ratio(xs: &[f64]) -> f64 {
    let med = median(xs);
    assert!(med > 0.0, "max/median ratio needs positive median");
    max(xs) / med
}

impl BoxSummary {
    pub fn of(xs: &[f64]) -> BoxSummary {
        assert!(!xs.is_empty());
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = mean(&v);
        let s = std_dev(&v);
        let lo = v[0];
        let hi = v[v.len() - 1];
        BoxSummary {
            n: v.len(),
            min: lo,
            whisker_lo: (m - 2.0 * s).max(lo),
            q1: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.5),
            q3: quantile_sorted(&v, 0.75),
            whisker_hi: (m + 2.0 * s).min(hi),
            max: hi,
            mean: m,
            std: s,
        }
    }

    /// Compact single-line rendering for bench output.
    pub fn line(&self) -> String {
        format!(
            "n={:<6} min={:8.1} q1={:8.1} med={:8.1} q3={:8.1} max={:8.1} mean={:8.1} std={:7.1}",
            self.n, self.min, self.q1, self.median, self.q3, self.max, self.mean, self.std
        )
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; values outside
/// the range are clamped into the edge buckets (used for Fig 7 / Fig 14
/// distribution plots).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn build(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(bins > 0 && hi > lo);
        let mut counts = vec![0u64; bins];
        let w = (hi - lo) / bins as f64;
        for &x in xs {
            let idx = (((x - lo) / w).floor() as i64).clamp(0, bins as i64 - 1) as usize;
            counts[idx] += 1;
        }
        Histogram { lo, hi, counts }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// ASCII rendering, one bucket per line, bars scaled to `width` chars.
    pub fn render(&self, width: usize) -> String {
        let maxc = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar_len = ((c as f64 / maxc as f64) * width as f64).round() as usize;
            let lo = self.lo + i as f64 * w;
            out.push_str(&format!(
                "[{:8.1},{:8.1}) {:>7} |{}\n",
                lo,
                lo + w,
                c,
                "#".repeat(bar_len)
            ));
        }
        out
    }
}

/// Cumulative fraction of samples <= x (for long-tail reporting).
pub fn fraction_le(xs: &[f64], x: f64) -> f64 {
    xs.iter().filter(|&&v| v <= x).count() as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_simple() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.75) - 7.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.5), 3.0);
    }

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn max_median() {
        let xs = [10.0, 10.0, 10.0, 10.0, 40.0];
        assert!((max_median_ratio(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn box_summary_ordering() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let b = BoxSummary::of(&xs);
        assert!(b.min <= b.whisker_lo);
        assert!(b.whisker_lo <= b.q1);
        assert!(b.q1 <= b.median);
        assert!(b.median <= b.q3);
        assert!(b.q3 <= b.whisker_hi);
        assert!(b.whisker_hi <= b.max);
        assert_eq!(b.n, 100);
    }

    #[test]
    fn box_summary_whiskers_clamped() {
        let xs = [5.0, 5.0, 5.0];
        let b = BoxSummary::of(&xs);
        assert_eq!(b.whisker_lo, 5.0);
        assert_eq!(b.whisker_hi, 5.0);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.5, 1.5, 1.6, 9.9, -3.0, 100.0];
        let h = Histogram::build(&xs, 0.0, 10.0, 10);
        assert_eq!(h.total(), 6);
        assert_eq!(h.counts[0], 2); // 0.5 and clamped -3.0
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 2); // 9.9 and clamped 100.0
    }

    #[test]
    fn fraction_le_works() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((fraction_le(&xs, 2.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_render_nonempty() {
        let xs = [1.0, 1.0, 2.0];
        let h = Histogram::build(&xs, 0.0, 4.0, 4);
        let r = h.render(20);
        assert!(r.contains('#'));
        assert_eq!(r.lines().count(), 4);
    }
}
