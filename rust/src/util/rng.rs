//! Deterministic pseudo-random number generation and the distribution
//! samplers the cluster simulator needs.
//!
//! The offline crate set has no `rand`/`rand_distr`, so we carry our own
//! PCG64-class generator (xoshiro256**, seeded via SplitMix64) plus inverse-
//! transform / Box-Muller samplers. Everything is deterministic given a seed,
//! which the simulator relies on for reproducible figures.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless SplitMix64 finalizer: one high-quality 64-bit mix of `x`.
/// The identity hash behind every "pure function of (seed, id, ...)"
/// derivation in the simulator (`trace` image identities, `faults`
/// decision streams) — one definition so the streams can never diverge.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent child generator (for per-node streams).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::seeded(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire-style rejection-free enough for sim use.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply method (tiny modulo bias is irrelevant here, but
        // use widening multiply for uniformity anyway).
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (caches the second variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Pareto with scale `xm` and shape `alpha` (heavy tail for stragglers).
    #[inline]
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        xm / u.powf(1.0 / alpha)
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// A node-slowness model: most nodes are ~nominal, a small fraction sit in a
/// heavy Pareto tail. This is the generator behind every straggler phenomenon
/// in the simulator (paper §3.3–3.4: most nodes finish in ~6 s, <1% take up
/// to 90 s under SCM throttling).
#[derive(Clone, Copy, Debug)]
pub struct TailedSlowdown {
    /// Probability a node is a straggler.
    pub tail_prob: f64,
    /// Normal body: multiplier ~ N(1, body_std), clamped to >= 0.7.
    pub body_std: f64,
    /// Tail: multiplier ~ Pareto(tail_scale, tail_alpha).
    pub tail_scale: f64,
    pub tail_alpha: f64,
    /// Hard cap on the multiplier (paper's observed extremes ~15x).
    pub cap: f64,
}

impl Default for TailedSlowdown {
    fn default() -> Self {
        TailedSlowdown {
            tail_prob: 0.01,
            body_std: 0.05,
            tail_scale: 1.5,
            tail_alpha: 1.2,
            cap: 16.0,
        }
    }
}

impl TailedSlowdown {
    /// Sample one node's slowdown multiplier (>= 0.7).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let m = if rng.chance(self.tail_prob) {
            rng.pareto(self.tail_scale, self.tail_alpha)
        } else {
            rng.normal_ms(1.0, self.body_std)
        };
        m.clamp(0.7, self.cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_stateless_and_mixing() {
        assert_eq!(mix64(42), mix64(42));
        assert_ne!(mix64(42), mix64(43));
        // Matches the seeder's finalizer: mixing 0 equals the first
        // splitmix64 output from state 0.
        let mut s = 0u64;
        assert_eq!(mix64(0), splitmix64(&mut s));
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seeded(13);
        let mut xs: Vec<f64> = (0..50_001).map(|_| r.lognormal(2.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        // Median of lognormal is exp(mu).
        assert!((median - 2.0f64.exp()).abs() / 2.0f64.exp() < 0.05);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seeded(15);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn pareto_min_is_scale() {
        let mut r = Rng::seeded(17);
        for _ in 0..10_000 {
            assert!(r.pareto(3.0, 2.0) >= 3.0);
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::seeded(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(21);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn tailed_slowdown_shape() {
        let mut r = Rng::seeded(23);
        let model = TailedSlowdown::default();
        let xs: Vec<f64> = (0..100_000).map(|_| model.sample(&mut r)).collect();
        let body = xs.iter().filter(|&&x| x < 1.3).count() as f64 / xs.len() as f64;
        let tail = xs.iter().filter(|&&x| x > 2.0).count() as f64 / xs.len() as f64;
        assert!(body > 0.97, "body fraction {body}");
        assert!(tail > 0.001 && tail < 0.02, "tail fraction {tail}");
        assert!(xs.iter().all(|&x| (0.7..=16.0).contains(&x)));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::seeded(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
