//! Pure-Rust SHA-256 (FIPS 180-4).
//!
//! The offline crate set has no `sha2`, so the content-addressed block
//! store and the env-cache packer hash with this. Streaming API mirroring
//! the `sha2` crate's: `Sha256::new()` → `update(..)` → `finalize()`.
//! Verified against the NIST vectors in the tests below.

const K: [u32; 64] = [
    0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5,
    0x3956C25B, 0x59F111F1, 0x923F82A4, 0xAB1C5ED5,
    0xD807AA98, 0x12835B01, 0x243185BE, 0x550C7DC3,
    0x72BE5D74, 0x80DEB1FE, 0x9BDC06A7, 0xC19BF174,
    0xE49B69C1, 0xEFBE4786, 0x0FC19DC6, 0x240CA1CC,
    0x2DE92C6F, 0x4A7484AA, 0x5CB0A9DC, 0x76F988DA,
    0x983E5152, 0xA831C66D, 0xB00327C8, 0xBF597FC7,
    0xC6E00BF3, 0xD5A79147, 0x06CA6351, 0x14292967,
    0x27B70A85, 0x2E1B2138, 0x4D2C6DFC, 0x53380D13,
    0x650A7354, 0x766A0ABB, 0x81C2C92E, 0x92722C85,
    0xA2BFE8A1, 0xA81A664B, 0xC24B8B70, 0xC76C51A3,
    0xD192E819, 0xD6990624, 0xF40E3585, 0x106AA070,
    0x19A4C116, 0x1E376C08, 0x2748774C, 0x34B0BCB5,
    0x391C0CB3, 0x4ED8AA4A, 0x5B9CCA4F, 0x682E6FF3,
    0x748F82EE, 0x78A5636F, 0x84C87814, 0x8CC70208,
    0x90BEFFFA, 0xA4506CEB, 0xBEF9A3F7, 0xC67178F2,
];

const H0: [u32; 8] = [
    0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
    0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19,
];

/// Incremental SHA-256 hasher.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_bytes: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

fn compress(state: &mut [u32; 8], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut w = [0u32; 64];
    for (i, wi) in w.iter_mut().take(16).enumerate() {
        *wi = u32::from_be_bytes([
            block[i * 4],
            block[i * 4 + 1],
            block[i * 4 + 2],
            block[i * 4 + 3],
        ]);
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
    state[5] = state[5].wrapping_add(f);
    state[6] = state[6].wrapping_add(g);
    state[7] = state[7].wrapping_add(h);
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total_bytes: 0 }
    }

    /// Absorb more input (callable any number of times).
    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total_bytes =
            self.total_bytes.wrapping_add(crate::util::cast::u64_from_usize(data.len()));
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            compress(&mut self.state, &data[..64]);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Pad, finish, and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_bytes.wrapping_mul(8);
        let mut tail = Vec::with_capacity(128);
        tail.extend_from_slice(&self.buf[..self.buf_len]);
        tail.push(0x80);
        while tail.len() % 64 != 56 {
            tail.push(0);
        }
        tail.extend_from_slice(&bit_len.to_be_bytes());
        for block in tail.chunks(64) {
            compress(&mut self.state, block);
        }
        let mut out = [0u8; 32];
        for (i, s) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&s.to_be_bytes());
        }
        out
    }
}

/// One-shot convenience.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(d: &[u8; 32]) -> String {
        d.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn nist_vectors() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a() {
        let mut h = Sha256::new();
        for _ in 0..1000 {
            h.update([b'a'; 1000]);
        }
        assert_eq!(
            hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_matches_oneshot_at_block_edges() {
        for n in [1usize, 55, 56, 63, 64, 65, 127, 128, 1000] {
            let data: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
            let mut h = Sha256::new();
            // Feed in awkward pieces.
            for chunk in data.chunks(7) {
                h.update(chunk);
            }
            assert_eq!(h.finalize(), sha256(&data), "length {n}");
        }
    }

    #[test]
    fn distinct_inputs_distinct_digests() {
        assert_ne!(sha256(b"a"), sha256(b"b"));
    }
}
