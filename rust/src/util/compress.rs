//! Byte-oriented run-length codec (PackBits-style).
//!
//! The offline crate set has no `zstd`, so the env-cache archive compresses
//! with this instead: a literal-run / repeat-run scheme that crushes the
//! padded, zero-heavy, repetitive content the real-bytes tests exercise and
//! costs at most ~0.8% expansion on incompressible data. Framed with a
//! magic plus the decompressed length so corrupt input is rejected instead
//! of mis-decoded.
//!
//! Opcodes: `0x00..=0x7F` — copy the next `op+1` bytes verbatim;
//! `0x80..=0xFF` — repeat the next byte `op-0x80+3` times (3..=130).

use crate::util::error::Result;
use crate::{bail, ensure};

const MAGIC: &[u8; 6] = b"BSRL1\0";
const MAX_LITERAL: usize = 128;
const MIN_RUN: usize = 3;
const MAX_RUN: usize = 130;

/// Compress `data`. `level` is accepted for zstd API compatibility and
/// ignored — the codec has a single operating point.
pub fn compress(data: &[u8], _level: i32) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 8 + 32);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&crate::util::cast::u64_from_usize(data.len()).to_le_bytes());
    let n = data.len();
    let run_at = |i: usize| -> usize {
        let b = data[i];
        let mut j = i + 1;
        while j < n && j - i < MAX_RUN && data[j] == b {
            j += 1;
        }
        j - i
    };
    let mut i = 0;
    while i < n {
        let r = run_at(i);
        if r >= MIN_RUN {
            out.push(0x80 + (r - MIN_RUN) as u8);
            out.push(data[i]);
            i += r;
        } else {
            // Literal run: until the next compressible run or the cap.
            let mut j = i + r;
            while j < n && j - i < MAX_LITERAL {
                let r2 = run_at(j);
                if r2 >= MIN_RUN {
                    break;
                }
                j += r2;
            }
            let j = j.min(i + MAX_LITERAL);
            out.push((j - i - 1) as u8);
            out.extend_from_slice(&data[i..j]);
            i = j;
        }
    }
    out
}

/// Decompress a [`compress`]-framed buffer, validating framing and length.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    ensure!(data.len() >= MAGIC.len() + 8, "compressed buffer too short");
    ensure!(&data[..MAGIC.len()] == MAGIC, "bad compression magic");
    let want = crate::util::cast::usize_from_u64(u64::from_le_bytes(
        data[MAGIC.len()..MAGIC.len() + 8].try_into().unwrap(),
    ));
    // A malformed header must not drive allocation: each payload byte can
    // decode to at most MAX_RUN output bytes, so anything past that bound
    // is guaranteed to fail the final length check anyway.
    ensure!(
        want <= data.len().saturating_mul(MAX_RUN),
        "declared length {want} impossible for {} payload bytes",
        data.len()
    );
    let mut out = Vec::with_capacity(want);
    let mut i = MAGIC.len() + 8;
    while i < data.len() {
        let op = data[i] as usize;
        i += 1;
        if op < 0x80 {
            let len = op + 1;
            if i + len > data.len() {
                bail!("truncated literal run");
            }
            out.extend_from_slice(&data[i..i + len]);
            i += len;
        } else {
            if i >= data.len() {
                bail!("truncated repeat run");
            }
            let len = op - 0x80 + MIN_RUN;
            let b = data[i];
            i += 1;
            out.resize(out.len() + len, b);
        }
        if out.len() > want {
            bail!("decompressed length exceeds header");
        }
    }
    ensure!(out.len() == want, "decompressed length mismatch: {} != {want}", out.len());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8]) {
        let c = compress(data, 3);
        assert_eq!(decompress(&c).unwrap(), data, "len {}", data.len());
    }

    #[test]
    fn roundtrips() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"ab");
        roundtrip(b"aaa");
        roundtrip(b"aabbaabbcc");
        roundtrip(&[7u8; 1000]);
        roundtrip(&(0..=255u8).collect::<Vec<_>>());
        let mut rng = Rng::seeded(3);
        for n in [1usize, 127, 128, 129, 130, 131, 1000, 100_000] {
            let random: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            roundtrip(&random);
            // Mixed compressible/incompressible.
            let mixed: Vec<u8> = random
                .iter()
                .flat_map(|&b| if b < 100 { vec![b; 5] } else { vec![b] })
                .collect();
            roundtrip(&mixed);
        }
    }

    #[test]
    fn repetitive_content_compresses_hard() {
        let data = vec![42u8; 100_000];
        let c = compress(&data, 3);
        assert!(c.len() < 2500, "rle of constant run: {} bytes", c.len());
    }

    #[test]
    fn random_content_expands_bounded() {
        let mut rng = Rng::seeded(5);
        let data: Vec<u8> = (0..100_000).map(|_| rng.next_u64() as u8).collect();
        let c = compress(&data, 3);
        assert!(c.len() < data.len() + data.len() / 64 + 64, "expansion {}", c.len());
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        assert!(decompress(b"not-an-archive").is_err());
        assert!(decompress(b"").is_err());
        let mut c = compress(&[1, 2, 3, 4, 5, 6, 7, 8], 3);
        c.truncate(c.len() - 2);
        assert!(decompress(&c).is_err());
        // Flip the declared length.
        let mut c = compress(b"hello world", 3);
        c[6] ^= 0xFF;
        assert!(decompress(&c).is_err());
    }
}
