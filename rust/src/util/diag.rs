//! Shared plumbing for the in-tree CI gate binaries (`bench-gate`,
//! `detlint`).
//!
//! Both gates follow the same contract: exit 0 when clean, 1 when the gate
//! trips (a real violation the change author must address), 2 on usage or
//! I/O errors (the gate itself could not run). Keeping the codes and the
//! file plumbing here means the CI workflow can treat every gate binary
//! identically.

use crate::util::json;

/// The gate ran and found nothing.
pub const EXIT_OK: i32 = 0;
/// The gate tripped: violations/findings were reported.
pub const EXIT_VIOLATIONS: i32 = 1;
/// The gate could not run: bad usage or unreadable inputs.
pub const EXIT_USAGE: i32 = 2;

/// Read and parse a JSON file, tagging errors with the path.
pub fn load_json(path: &str) -> Result<json::Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    json::parse(&text).map_err(|e| format!("{path}: {e}"))
}

/// Print `tool: msg` to stderr and exit with [`EXIT_USAGE`].
pub fn usage_error(tool: &str, msg: &str) -> ! {
    eprintln!("{tool}: {msg}");
    std::process::exit(EXIT_USAGE)
}

/// Write `text` to `path`, exiting with a usage diagnostic on failure.
pub fn write_or_exit(tool: &str, path: &str, text: &str) {
    if let Err(e) = std::fs::write(path, text) {
        usage_error(tool, &format!("{path}: {e}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_codes_are_distinct_and_stable() {
        assert_eq!(EXIT_OK, 0);
        assert_eq!(EXIT_VIOLATIONS, 1);
        assert_eq!(EXIT_USAGE, 2);
    }

    #[test]
    fn load_json_tags_errors_with_path() {
        let err = load_json("/nonexistent/gate.json").unwrap_err();
        assert!(err.starts_with("/nonexistent/gate.json: "));
    }
}
