//! Minimal JSON emitter (no serde in the offline crate set).
//!
//! Figure generators and the profiler export their series as JSON so the
//! data behind every figure is machine-readable. Only *writing* needs to be
//! general; the one place we parse JSON (artifact metadata from aot.py) uses
//! the small recursive-descent parser at the bottom.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. `Num` is stored as f64 (adequate for our metadata/series).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s).unwrap();
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0).unwrap();
        s
    }

    fn write(&self, out: &mut String) -> fmt::Result {
        use fmt::Write;
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x)?,
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out)?;
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out)?;
                }
                out.push('}');
            }
        }
        let _ = write!(out, "");
        Ok(())
    }

    fn write_pretty(&self, out: &mut String, indent: usize) -> fmt::Result {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    x.write_pretty(out, indent + 1)?;
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push(']');
                Ok(())
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write_pretty(out, indent + 1)?;
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
                Ok(())
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) -> fmt::Result {
    use fmt::Write;
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            write!(out, "{}", x as i64)
        } else {
            write!(out, "{x}")
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
        Ok(())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Parse a JSON document. Supports the full grammar minus `\uXXXX` surrogate
/// pairs (sufficient for aot.py's ASCII metadata).
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.i += 1;
                let mut v = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    v.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    m.insert(k, self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u".to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".to_string()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "bad utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
                None => return Err("unterminated string".to_string()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "bootseer").set("n", 42usize).set("ok", true);
        j.set("xs", vec![1.0, 2.5, 3.0]);
        let s = j.to_string();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        let s = j.to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x"], "c": -2.5e1}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -25.0);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_rejects_trailing() {
        assert!(parse("{} garbage").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn pretty_is_parseable() {
        let mut j = Json::obj();
        j.set("xs", vec![1usize, 2, 3]);
        j.set("nested", {
            let mut o = Json::obj();
            o.set("k", "v");
            o
        });
        assert_eq!(parse(&j.to_pretty()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".to_string()));
    }
}
