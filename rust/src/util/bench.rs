//! Tiny benchmark harness (criterion is not in the offline crate set).
//!
//! Every file under `benches/` is a `harness = false` binary built on this:
//! `Bench::new("name")` then `b.iter("case", || work())` measures warmed-up
//! wall time, reporting mean ± std over the sample and ops/s. Figure benches
//! additionally print paper-vs-measured series tables; the harness keeps the
//! timing discipline consistent across all of them.

use std::time::{Duration, Instant};

pub struct Bench {
    pub name: String,
    /// Minimum measurement time per case.
    pub min_time: Duration,
    /// Minimum number of measured iterations per case.
    pub min_iters: u32,
    results: Vec<CaseResult>,
}

#[derive(Clone, Debug)]
pub struct CaseResult {
    pub case: String,
    pub iters: u32,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        // Honor BOOTSEER_BENCH_FAST=1 for quick smoke runs.
        let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
        Bench {
            name: name.to_string(),
            min_time: if fast { Duration::from_millis(50) } else { Duration::from_millis(500) },
            min_iters: if fast { 2 } else { 5 },
            results: Vec::new(),
        }
    }

    /// Measure `f`, discarding one warmup run, until both `min_time` and
    /// `min_iters` are satisfied. Returns the mean seconds per iteration.
    pub fn iter<R>(&mut self, case: &str, mut f: impl FnMut() -> R) -> f64 {
        // Warmup (also primes caches / lazy inits).
        let _ = f();
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            let r = f();
            let dt = t0.elapsed().as_secs_f64();
            std::hint::black_box(&r);
            samples.push(dt);
            if samples.len() as u32 >= self.min_iters && start.elapsed() >= self.min_time {
                break;
            }
            // Safety valve: a single iteration longer than 30s is enough.
            if samples.len() >= 1 && start.elapsed() > Duration::from_secs(30) {
                break;
            }
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let res = CaseResult {
            case: case.to_string(),
            iters: samples.len() as u32,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        };
        println!(
            "bench {} / {:<40} {:>12} ± {:>10}  ({} iters)",
            self.name,
            res.case,
            fmt_time(res.mean_s),
            fmt_time(res.std_s),
            res.iters
        );
        self.results.push(res);
        mean
    }

    /// Measure one un-warmed end-to-end run (for expensive whole-cluster
    /// simulations where a single deterministic run IS the experiment).
    pub fn once<R>(&mut self, case: &str, mut f: impl FnMut() -> R) -> f64 {
        let t0 = Instant::now();
        let r = f();
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(&r);
        let res = CaseResult {
            case: case.to_string(),
            iters: 1,
            mean_s: dt,
            std_s: 0.0,
            min_s: dt,
            max_s: dt,
        };
        println!(
            "bench {} / {:<40} {:>12}  (1 iter)",
            self.name,
            res.case,
            fmt_time(res.mean_s)
        );
        self.results.push(res);
        dt
    }

    /// All results so far.
    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }

    /// Print a closing summary block.
    pub fn finish(&self) {
        println!("\n== {} summary ==", self.name);
        for r in &self.results {
            println!(
                "  {:<40} mean {:>12}  min {:>12}  max {:>12}",
                r.case,
                fmt_time(r.mean_s),
                fmt_time(r.min_s),
                fmt_time(r.max_s)
            );
        }
    }
}

/// Format a seconds value with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Standard header every figure bench prints: identifies the figure, the
/// paper's claim, and the workload.
pub fn figure_header(fig: &str, claim: &str) {
    println!("==========================================================");
    println!("{fig}");
    println!("paper: {claim}");
    println!("==========================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iter_returns_positive_mean() {
        let mut b = Bench::new("test");
        b.min_time = Duration::from_millis(1);
        b.min_iters = 3;
        let mean = b.iter("noop", || 1 + 1);
        assert!(mean >= 0.0);
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 3);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(3e-9).ends_with("ns"));
        assert!(fmt_time(3e-6).ends_with("µs"));
        assert!(fmt_time(3e-3).ends_with("ms"));
        assert!(fmt_time(3.0).ends_with('s'));
    }
}
