//! Central registry of RNG domain-separation salts.
//!
//! Every seeded decision in the replay is a pure function of
//! `(seed, identity, salt)`: the salt separates *domains*, so two different
//! decision kinds keyed by the same identity (say, the crash draw and the
//! relocation draw of the same `(job, seg, retry)`) never consume the same
//! stream. A duplicated salt silently couples two domains — the decisions
//! stay deterministic but stop being independent, which skews every
//! statistic built on them. All salts therefore live here, in one table:
//!
//! | family | constants | domain |
//! |---|---|---|
//! | `0xFA0x` | `SALT_CRASH`, `SALT_RELOCATE`, `SALT_STRAGGLER`, `SALT_BROWNOUT` | fault engine draws ([`crate::faults`]) |
//! | `0xA271_xxxx` | `SALT_IMG_HOT`, `SALT_IMG_COLD`, `SALT_ENV`, `SALT_ENV_CHUNK`, `SALT_CKPT`, `SALT_CKPT_CHUNK` | artifact ids and synthesized chunk digests ([`crate::artifact::manifest`]) |
//! | `0xA272_0001..=3` | `SALT_SHED`, `SALT_BACKOFF`, `SALT_PEER` | transfer admission ([`crate::artifact::transfer`]) |
//! | `0xA272_0004..=5` | `SALT_CHURN`, `SALT_ADMISSION` | trace-level cache economics ([`crate::trace`]) |
//!
//! Enforced twice: the [`ALL`] table's uniqueness unit test at runtime, and
//! detlint rule `salt-registry` (R2) statically — a `SALT_*` constant
//! declared outside this module, a salt-family literal used inline, a
//! duplicate value, or an undocumented entry all fail the lint gate. To add
//! a salt: pick the next free value in its family (or open a new family
//! prefix), add a `/// doc` line naming the decision stream, and import it
//! from here. The values are load-bearing for replay byte-identity — never
//! renumber an existing salt.

macro_rules! salt_registry {
    ($($(#[$doc:meta])* $name:ident = $value:literal;)*) => {
        $($(#[$doc])* pub const $name: u64 = $value;)*

        /// Every registered salt as `(name, value)` — the runtime twin of
        /// detlint rule R2: the uniqueness test below iterates this table,
        /// and the macro keeps it in lockstep with the constants by
        /// construction.
        pub const ALL: &[(&str, u64)] = &[$((stringify!($name), $name)),*];
    };
}

salt_registry! {
    /// Fault engine: crash-hazard draw per `(job, seg, retry)`.
    SALT_CRASH = 0xFA01;
    /// Fault engine: warm-vs-relocated restart placement per `(job, seg, retry)`.
    SALT_RELOCATE = 0xFA02;
    /// Fault engine: injected-straggler draw per `(job, attempt)`.
    SALT_STRAGGLER = 0xFA03;
    /// Fault engine: brownout window Poisson process and per-window rack subsets.
    SALT_BROWNOUT = 0xFA04;
    /// Artifact id of an image's startup-hot block set.
    SALT_IMG_HOT = 0xA271_0001;
    /// Artifact id of an image's background cold tail.
    SALT_IMG_COLD = 0xA271_0002;
    /// Artifact id of an environment snapshot archive.
    SALT_ENV = 0xA271_0003;
    /// Synthesized chunk digests of an environment snapshot.
    SALT_ENV_CHUNK = 0xA271_0004;
    /// Artifact id of a checkpoint resume shard.
    SALT_CKPT = 0xA271_0005;
    /// Synthesized chunk digests of a checkpoint resume shard.
    SALT_CKPT_CHUNK = 0xA271_0006;
    /// Transfer admission: shed draw per `(tier, artifact, node, attempt)`.
    SALT_SHED = 0xA272_0001;
    /// Transfer admission: backoff jitter per `(artifact, node, attempt)`.
    SALT_BACKOFF = 0xA272_0002;
    /// Swarm peer admission under cache-eviction pressure, per peer index.
    SALT_PEER = 0xA272_0003;
    /// Bounded-cache churn bytes a warm restart finds on its node's disk,
    /// per `(job, attempt)`.
    SALT_CHURN = 0xA272_0004;
    /// Trace-level per-`(job, attempt)` admission stream seed.
    SALT_ADMISSION = 0xA272_0005;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The runtime twin of detlint rule R2: no two salts may share a
    /// value, ever — a collision couples two decision domains.
    #[test]
    fn salts_globally_unique() {
        for (i, &(na, va)) in ALL.iter().enumerate() {
            for &(nb, vb) in &ALL[i + 1..] {
                assert_ne!(va, vb, "salt collision: {na} and {nb} both {va:#x}");
            }
        }
    }

    /// The values are part of the replay's byte-identity contract (they
    /// feed every seeded stream); pin the full table so a renumbering
    /// can't slip through as a refactor.
    #[test]
    fn salt_values_pinned() {
        let expect: &[(&str, u64)] = &[
            ("SALT_CRASH", 0xFA01),
            ("SALT_RELOCATE", 0xFA02),
            ("SALT_STRAGGLER", 0xFA03),
            ("SALT_BROWNOUT", 0xFA04),
            ("SALT_IMG_HOT", 0xA271_0001),
            ("SALT_IMG_COLD", 0xA271_0002),
            ("SALT_ENV", 0xA271_0003),
            ("SALT_ENV_CHUNK", 0xA271_0004),
            ("SALT_CKPT", 0xA271_0005),
            ("SALT_CKPT_CHUNK", 0xA271_0006),
            ("SALT_SHED", 0xA272_0001),
            ("SALT_BACKOFF", 0xA272_0002),
            ("SALT_PEER", 0xA272_0003),
            ("SALT_CHURN", 0xA272_0004),
            ("SALT_ADMISSION", 0xA272_0005),
        ];
        assert_eq!(ALL, expect);
    }
}
