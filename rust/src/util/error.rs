//! Minimal error + context plumbing, anyhow-shaped.
//!
//! The offline crate set has no `anyhow`, so the real-bytes engines
//! (env-cache packer, striped local store, checkpoint format, PJRT
//! runtime) use this: a string-message error with an optional cause chain,
//! a `Result` alias, the `anyhow!` / `bail!` / `ensure!` macros, and a
//! `Context` extension trait for `Result` and `Option`. Only the subset
//! this crate actually needs is implemented.

use std::fmt;

/// A string-message error with an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error as the cause of a higher-level message.
    pub fn wrap(self, m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), source: Some(Box::new(self)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(s) = &self.source {
            write!(f, ": {s}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

// Anything that is a std error converts via `?`. (Our own `Error` must not
// implement `std::error::Error`, or this impl would overlap the reflexive
// `From<T> for T`.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `.context("...")` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(msg))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{anyhow, bail, ensure};

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/bootseer")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_format() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        assert_eq!(format!("{}", anyhow!("n={}", 4)), "n=4");
    }
}
