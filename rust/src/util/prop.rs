//! Miniature property-based testing framework (proptest is not in the
//! offline crate set).
//!
//! Usage:
//! ```ignore
//! prop_check(256, |g| {
//!     let xs = g.vec(1..100, |g| g.f64_in(0.0, 1e6));
//!     let b = BoxSummary::of(&xs);
//!     prop_assert!(b.q1 <= b.median);
//!     Ok(())
//! });
//! ```
//! Each case gets a fresh deterministic generator; on failure the case seed
//! is printed so the exact input can be replayed with
//! `BOOTSEER_PROP_SEED=<seed>`.

use super::rng::Rng;

/// Input generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector of random length in `len` with elements from `f`.
    pub fn vec<T>(
        &mut self,
        len: std::ops::Range<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize_in(len.start, len.end.saturating_sub(1).max(len.start));
        (0..n).map(|_| f(self)).collect()
    }

    /// Random bytes of length n.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.rng.next_u64() as u8).collect()
    }

    /// Random ASCII identifier.
    pub fn ident(&mut self, max_len: usize) -> String {
        let n = self.usize_in(1, max_len.max(2));
        (0..n)
            .map(|_| {
                let c = b"abcdefghijklmnopqrstuvwxyz0123456789_"
                    [self.rng.below(37) as usize];
                c as char
            })
            .collect()
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing seed on error.
pub fn prop_check(cases: u32, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    // Replay mode: run only the given seed.
    if let Ok(s) = std::env::var("BOOTSEER_PROP_SEED") {
        let seed: u64 = s.parse().expect("BOOTSEER_PROP_SEED must be u64");
        let mut g = Gen { rng: Rng::seeded(seed), seed };
        if let Err(msg) = prop(&mut g) {
            panic!("property failed (replay seed {seed}): {msg}");
        }
        return;
    }
    let base = 0xB007_5EE3u64;
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::seeded(seed), seed };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed on case {i} (replay with BOOTSEER_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert inside a property, producing an Err instead of panicking so the
/// harness can attach the seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!("assertion failed: {}: {}", stringify!($cond), format!($($fmt)+)));
        }
    };
}

/// Approximate float equality helper for properties.
pub fn close(a: f64, b: f64, rel: f64) -> bool {
    let scale = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() / scale <= rel
}

/// Distance in ulps between two finite f64s: how many representable
/// doubles lie between them on the total-order line (±0.0 coincide).
/// The one definition of ulp distance in the tree — `close_ulps` and the
/// engine golden tests (`sim::golden`) both build on it.
pub fn ulps_between(a: f64, b: f64) -> u64 {
    if a == b {
        return 0;
    }
    // Map the IEEE-754 bit pattern to a monotone i64 line.
    let to_ordered = |x: f64| {
        let i = x.to_bits() as i64;
        if i < 0 {
            i64::MIN.wrapping_sub(i)
        } else {
            i
        }
    };
    to_ordered(a).abs_diff(to_ordered(b))
}

/// Ulp-level float equality: true when `a` and `b` are within `max_ulps`
/// representable doubles of each other (NaN never compares close). This is
/// the "exact up to accumulated rounding" comparison — vastly tighter than
/// any epsilon a relative test would use.
pub fn close_ulps(a: f64, b: f64, max_ulps: u64) -> bool {
    if a.is_nan() || b.is_nan() {
        return false;
    }
    ulps_between(a, b) <= max_ulps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check(64, |g| {
            let x = g.f64_in(0.0, 10.0);
            prop_assert!((0.0..=10.0).contains(&x));
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failing_seed() {
        prop_check(64, |g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x < 90, "x was {x}");
            Ok(())
        });
    }

    #[test]
    fn vec_length_in_range() {
        prop_check(64, |g| {
            let v = g.vec(2..10, |g| g.bool());
            prop_assert!(v.len() >= 2 && v.len() < 10, "len {}", v.len());
            Ok(())
        });
    }

    #[test]
    fn ident_is_ascii() {
        prop_check(64, |g| {
            let s = g.ident(16);
            prop_assert!(!s.is_empty() && s.is_ascii());
            Ok(())
        });
    }

    #[test]
    fn close_tolerates() {
        assert!(close(1.0, 1.0000001, 1e-5));
        assert!(!close(1.0, 1.1, 1e-5));
    }

    #[test]
    fn close_ulps_is_tight() {
        assert!(close_ulps(1.0, 1.0, 0));
        assert!(close_ulps(0.0, -0.0, 0));
        let next = f64::from_bits(1.0f64.to_bits() + 1);
        assert!(close_ulps(1.0, next, 1));
        assert!(!close_ulps(1.0, next, 0));
        assert!(!close_ulps(1.0, 1.0 + 1e-9, 256));
        assert!(!close_ulps(1.0, -1.0, 1 << 20));
        assert!(!close_ulps(f64::NAN, 1.0, 1 << 20));
    }

    #[test]
    fn ulps_between_basics() {
        assert_eq!(ulps_between(1.0, 1.0), 0);
        assert_eq!(ulps_between(1.0, f64::from_bits(1.0f64.to_bits() + 1)), 1);
        assert!(ulps_between(1.0, 1.0 + 1e-9) > 1000);
        // Crossing zero walks the total-order line, monotonically.
        assert_eq!(ulps_between(0.0, f64::from_bits(1)), 1);
        assert_eq!(ulps_between(-f64::from_bits(1), f64::from_bits(1)), 2);
    }
}
