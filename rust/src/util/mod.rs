//! Shared substrate: deterministic RNG + distributions, statistics,
//! JSON, humanized formatting, the bench harness, and the mini
//! property-testing framework. None of this is BootSeer-specific; it exists
//! because the offline crate universe lacks rand/serde/criterion/proptest.

pub mod bench;
pub mod human;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
