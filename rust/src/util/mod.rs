//! Shared substrate: deterministic RNG + distributions, statistics,
//! JSON, humanized formatting, the bench harness, the mini
//! property-testing framework, and the anyhow/sha2/zstd stand-ins
//! (`error`, `sha256`, `compress`). None of this is BootSeer-specific; it
//! exists because the offline crate universe lacks
//! rand/serde/criterion/proptest/anyhow/sha2/zstd — the default build has
//! zero external dependencies.

pub mod bench;
pub mod benchcmp;
pub mod cast;
pub mod compress;
pub mod diag;
pub mod error;
pub mod human;
pub mod json;
pub mod prop;
pub mod rng;
pub mod salts;
pub mod sha256;
pub mod stats;
