//! Human-readable formatting of byte sizes and durations for logs, tables,
//! and bench output.

/// Format a byte count, e.g. `28.62 GB` (decimal units, matching the paper).
pub fn bytes(n: u64) -> String {
    const UNITS: [(&str, f64); 5] = [
        ("TB", 1e12),
        ("GB", 1e9),
        ("MB", 1e6),
        ("KB", 1e3),
        ("B", 1.0),
    ];
    for &(unit, scale) in &UNITS {
        if n as f64 >= scale || unit == "B" {
            let v = n as f64 / scale;
            return if v >= 100.0 || unit == "B" {
                format!("{v:.0} {unit}")
            } else {
                format!("{v:.2} {unit}")
            };
        }
    }
    unreachable!()
}

/// Format a duration given in seconds, e.g. `2m 13s`, `45.2s`, `380ms`.
pub fn secs(s: f64) -> String {
    if s < 0.0 {
        return format!("-{}", secs(-s));
    }
    if s < 1.0 {
        format!("{:.0}ms", s * 1e3)
    } else if s < 60.0 {
        format!("{s:.1}s")
    } else if s < 3600.0 {
        format!("{}m {:02.0}s", (s / 60.0) as u64, s % 60.0)
    } else {
        format!("{}h {:02}m", (s / 3600.0) as u64, ((s % 3600.0) / 60.0) as u64)
    }
}

/// Render a ratio like `2.1x`.
pub fn ratio(r: f64) -> String {
    format!("{r:.2}x")
}

/// Fixed-width table rendering: rows of cells, first row is the header.
pub fn table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    for (ri, row) in rows.iter().enumerate() {
        for (i, cell) in row.iter().enumerate() {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(&format!("{:<width$}", cell, width = widths[i]));
        }
        out.push('\n');
        if ri == 0 {
            for (i, w) in widths.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&"-".repeat(*w));
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(0), "0 B");
        assert_eq!(bytes(999), "999 B");
        assert_eq!(bytes(28_620_000_000), "28.62 GB");
        assert_eq!(bytes(413_000_000_000), "413 GB");
        assert_eq!(bytes(1_500), "1.50 KB");
    }

    #[test]
    fn secs_scales() {
        assert_eq!(secs(0.38), "380ms");
        assert_eq!(secs(45.23), "45.2s");
        assert_eq!(secs(133.0), "2m 13s");
        assert_eq!(secs(7260.0), "2h 01m");
    }

    #[test]
    fn table_aligns() {
        let t = table(&[
            vec!["a".into(), "long-header".into()],
            vec!["xx".into(), "1".into()],
        ]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].starts_with('-'));
        // All data lines have same prefix width for col 0.
        assert_eq!(lines[0].find("long-header"), lines[2].find('1'));
    }
}
