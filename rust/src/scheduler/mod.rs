//! Scheduler Phase substrate (§2.2): a priority + FIFO GPU allocator over
//! a finite pool, producing the Resource Queuing / Resource Allocation
//! behaviour of the trace replay — jobs wait "until their resource
//! requirements are met and no higher-priority jobs are pending".
//!
//! Two entry points share one event-driven core:
//!
//! * [`schedule`] — one allocation per job (submit → wait → hold →
//!   release), the §3.2 single-shot model.
//! * [`schedule_chains`] — the cluster-replay engine: every job is a
//!   *chain* of segments (one per full startup). When a segment ends (the
//!   job failed or was reconfigured, §3.1), its GPUs return to the pool and
//!   the next segment re-enters the queue at that instant, competing again
//!   under the same priority. Hot updates never appear here — they keep
//!   their allocation, so they consume no scheduler events.
//!
//! Allocation decisions are batched into periodic scheduling rounds
//! (`round_s`; see `defaults::SCHED_ROUND_S`): even an uncontended job
//! waits ~U[0, round] for the next pass, which is the structural source of
//! the paper's ~100 s median queue wait. Contention — a hot pool, a huge
//! job parked at the head of the queue with no backfill allowed — produces
//! the hour-long tail. `round_s == 0` degenerates to continuous,
//! allocate-immediately semantics (what [`schedule`] uses, and what the
//! scheduler unit tests pin down).
//!
//! **Interruption path** ([`schedule_chains_with`]): an optional
//! [`FaultOracle`] is consulted at every segment allocation and may declare
//! the segment [`SegmentFate::Interrupt`]ed mid-hold — the failure instant
//! ends the segment early, its GPUs return to the pool right there, and a
//! *retry* of the same scripted segment re-enters the queue at that instant
//! with the oracle-provided remaining hold, competing again under the
//! chain's original priority. [`crate::faults`] provides the seeded
//! hazard-based oracle the cluster replay drives this with; `None`
//! reproduces the uninterrupted schedule bit-for-bit.
//!
//! Consumed by [`crate::trace`]'s contention-aware replay (phase 1 of the
//! two-phase design described in `docs/replay.md`); the queue waits it
//! assigns flow into the profiler via [`crate::startup`]'s stage events.

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

/// A job submitted to the scheduler.
#[derive(Clone, Debug)]
pub struct SchedJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// How long the job holds its GPUs once started (training + startups).
    pub hold_s: f64,
    /// Smaller = more important.
    pub priority: u32,
}

/// Scheduling outcome for one job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchedOutcome {
    pub id: u64,
    pub start_s: f64,
    pub end_s: f64,
    pub queue_wait_s: f64,
}

/// A multi-segment job: each segment is one full startup plus its training
/// slice; segment `k+1` is submitted the instant segment `k` ends.
#[derive(Clone, Debug)]
pub struct ChainJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// Smaller = more important; restarts keep the job's priority.
    pub priority: u32,
    /// Hold duration of each segment, in order.
    pub segments: Vec<f64>,
}

/// One scheduled segment of a chain. With a [`FaultOracle`] in play a
/// scripted segment may appear several times: each interrupted run is
/// recorded (with `interrupted == true`) followed by its retries, until one
/// run completes or the oracle gives up.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SegmentOutcome {
    pub start_s: f64,
    pub end_s: f64,
    /// Time between (re-)submission and allocation.
    pub queue_wait_s: f64,
    /// The segment ended early at a failure instant (`end_s` is the
    /// failure time, not the planned hold end) and a retry re-entered the
    /// queue at `end_s`.
    pub interrupted: bool,
    /// Training progress rolled back at the interruption (seconds of work
    /// since the last resume point, lost and re-done by the retry). Zero
    /// for completed segments.
    pub lost_train_s: f64,
}

/// What a [`FaultOracle`] decides for one segment at allocation time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SegmentFate {
    /// The segment runs its full hold.
    Complete,
    /// The segment fails `after_s` seconds into its hold (`0 < after_s <
    /// hold`): its GPUs are released then and a retry with hold
    /// `retry_hold_s` re-enters the queue at the failure instant.
    /// `lost_train_s` is the training progress rolled back (recorded on
    /// the interrupted [`SegmentOutcome`]).
    Interrupt { after_s: f64, lost_train_s: f64, retry_hold_s: f64 },
}

/// Decides, deterministically, whether a segment run fails mid-hold.
/// Queried exactly once per (chain, scripted segment, retry) at the
/// allocation instant; implementations must be pure functions of those
/// identities (plus their own seed) so the schedule is reproducible. The
/// oracle is responsible for termination: it must return
/// [`SegmentFate::Complete`] once `retry` reaches its cap.
pub trait FaultOracle {
    fn fate(
        &self,
        chain: &ChainJob,
        seg: usize,
        retry: u32,
        start_s: f64,
        hold_s: f64,
    ) -> SegmentFate;
}

/// Scheduling outcome for a whole chain. `segments` is empty when the job
/// can never fit the pool (`gpus > pool_gpus`).
#[derive(Clone, Debug)]
pub struct ChainOutcome {
    pub id: u64,
    pub gpus: u32,
    pub segments: Vec<SegmentOutcome>,
}

/// Totally ordered f64 wrapper (times are finite and non-negative here).
#[derive(Clone, Copy, PartialEq)]
struct F64Ord(f64);
impl Eq for F64Ord {}
impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

/// Queue key: strict priority, then FIFO by (re-)submission time, then id.
/// `submit_bits` is the IEEE bit pattern of the non-negative submit time,
/// which orders identically to the float itself. `retry`/`hold_bits` ride
/// along so a retry keeps its chain's priority but carries its own
/// (shrunken) hold.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendKey {
    prio: u32,
    submit_bits: u64,
    id: u64,
    chain: usize,
    seg: usize,
    retry: u32,
    hold_bits: u64,
}

/// A timed scheduler event (arrival or completion), min-ordered by
/// `(t, id, chain, seg, retry)` — the same tie-break order the
/// pre-interruption tuples used, so the `None`-oracle schedule is
/// bit-identical to the historical one.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Ev {
    t: F64Ord,
    id: u64,
    chain: usize,
    seg: usize,
    retry: u32,
    /// Arrivals: the hold to queue with. Completions: the retry's hold
    /// when `is_retry` (unused otherwise).
    hold: F64Ord,
    /// Completions only: this completion is a failure instant and the same
    /// scripted segment re-enters the queue as `retry + 1`.
    is_retry: bool,
}

/// Event-driven scheduler over a pool of `pool_gpus` (single-segment form).
pub fn schedule(pool_gpus: u32, jobs: &[SchedJob]) -> Vec<SchedOutcome> {
    let chains: Vec<ChainJob> = jobs
        .iter()
        .map(|j| ChainJob {
            id: j.id,
            submit_s: j.submit_s,
            gpus: j.gpus,
            priority: j.priority,
            segments: vec![j.hold_s],
        })
        .collect();
    let mut out: Vec<SchedOutcome> = schedule_chains(pool_gpus, &chains, 0.0)
        .into_iter()
        .filter(|c| !c.segments.is_empty())
        .map(|c| SchedOutcome {
            id: c.id,
            start_s: c.segments[0].start_s,
            end_s: c.segments[0].end_s,
            queue_wait_s: c.segments[0].queue_wait_s,
        })
        .collect();
    out.sort_by_key(|o| o.id);
    out
}

/// Event-driven scheduler over a pool of `pool_gpus`, chain form: every
/// completed segment releases its GPUs and re-submits the chain's next
/// segment at the completion instant. Allocation passes run at multiples of
/// `round_s` (0 = continuous). Strict priority order; within priority,
/// FIFO; a job that does not fit blocks same-or-lower-priority jobs behind
/// it (no backfill — conservative, like the paper's quota scheduler).
///
/// Returns one [`ChainOutcome`] per input chain, in input order.
pub fn schedule_chains(pool_gpus: u32, chains: &[ChainJob], round_s: f64) -> Vec<ChainOutcome> {
    schedule_chains_with(pool_gpus, chains, round_s, None)
}

/// [`schedule_chains`] with an optional fault oracle: at every segment
/// allocation the oracle may declare the run interrupted mid-hold, in which
/// case the segment ends (and releases its GPUs) at the failure instant and
/// a retry with the oracle's remaining hold re-enters the queue right
/// there, keeping the chain's priority. `None` is bit-identical to
/// [`schedule_chains`].
pub fn schedule_chains_with(
    pool_gpus: u32,
    chains: &[ChainJob],
    round_s: f64,
    oracle: Option<&dyn FaultOracle>,
) -> Vec<ChainOutcome> {
    // Next allocation pass no earlier than `t`, quantized to the round grid.
    let quantize_up = |t: f64| -> f64 {
        if round_s <= 0.0 {
            t
        } else {
            (t / round_s - 1e-9).ceil() * round_s
        }
    };

    let mut out: Vec<ChainOutcome> = chains
        .iter()
        .map(|c| ChainOutcome { id: c.id, gpus: c.gpus, segments: Vec::new() })
        .collect();

    let mut arrivals: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    for (ci, c) in chains.iter().enumerate() {
        if c.gpus > pool_gpus || c.segments.is_empty() {
            continue; // can never run; outcome stays empty
        }
        arrivals.push(Reverse(Ev {
            t: F64Ord(c.submit_s.max(0.0)),
            id: c.id,
            chain: ci,
            seg: 0,
            retry: 0,
            hold: F64Ord(c.segments[0]),
            is_retry: false,
        }));
    }
    let mut completions: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut pending: BTreeSet<PendKey> = BTreeSet::new();
    let mut free = pool_gpus;
    let mut next_pass: Option<f64> = None;

    loop {
        // Advance to the next event: arrival, completion, or scheduled pass.
        let mut now = f64::INFINITY;
        if let Some(Reverse(ev)) = arrivals.peek() {
            now = now.min(ev.t.0);
        }
        if let Some(Reverse(ev)) = completions.peek() {
            now = now.min(ev.t.0);
        }
        if let Some(p) = next_pass {
            now = now.min(p);
        }
        if !now.is_finite() {
            break;
        }

        let mut changed = false;
        // Completions free GPUs and re-submit the chain's next run: the
        // retry of an interrupted segment, or the next scripted segment.
        while let Some(Reverse(ev)) = completions.peek() {
            if ev.t.0 > now + 1e-12 {
                break;
            }
            let Reverse(ev) = completions.pop().unwrap();
            free += chains[ev.chain].gpus;
            changed = true;
            if ev.is_retry {
                arrivals.push(Reverse(Ev {
                    t: F64Ord(now),
                    retry: ev.retry + 1,
                    is_retry: false,
                    ..ev
                }));
            } else if ev.seg + 1 < chains[ev.chain].segments.len() {
                arrivals.push(Reverse(Ev {
                    t: F64Ord(now),
                    seg: ev.seg + 1,
                    retry: 0,
                    hold: F64Ord(chains[ev.chain].segments[ev.seg + 1]),
                    is_retry: false,
                    ..ev
                }));
            }
        }
        // Arrivals enter the pending queue.
        while let Some(Reverse(ev)) = arrivals.peek() {
            if ev.t.0 > now + 1e-12 {
                break;
            }
            let Reverse(ev) = arrivals.pop().unwrap();
            pending.insert(PendKey {
                prio: chains[ev.chain].priority,
                submit_bits: ev.t.0.to_bits(),
                id: ev.id,
                chain: ev.chain,
                seg: ev.seg,
                retry: ev.retry,
                hold_bits: ev.hold.0.to_bits(),
            });
            changed = true;
        }
        // Any state change (re-)arms an allocation pass on the round grid.
        if changed && !pending.is_empty() {
            let p = quantize_up(now);
            next_pass = Some(match next_pass {
                Some(q) => q.min(p),
                None => p,
            });
        }

        // Allocation pass. Iteration is (priority, submit, id)-ordered, so
        // the first job that does not fit blocks everything behind it.
        if let Some(p) = next_pass {
            if p <= now + 1e-12 {
                let mut to_start: Vec<PendKey> = Vec::new();
                let mut trial_free = free;
                for &key in pending.iter() {
                    let c = &chains[key.chain];
                    if c.gpus <= trial_free {
                        trial_free -= c.gpus;
                        to_start.push(key);
                    } else {
                        break; // head-of-line: no backfill past a blocked job
                    }
                }
                for key in to_start {
                    pending.remove(&key);
                    let c = &chains[key.chain];
                    free -= c.gpus;
                    let hold = f64::from_bits(key.hold_bits);
                    let submit = f64::from_bits(key.submit_bits);
                    let fate = match oracle {
                        Some(o) => o.fate(c, key.seg, key.retry, now, hold),
                        None => SegmentFate::Complete,
                    };
                    match fate {
                        SegmentFate::Complete => {
                            out[key.chain].segments.push(SegmentOutcome {
                                start_s: now,
                                end_s: now + hold,
                                queue_wait_s: now - submit,
                                interrupted: false,
                                lost_train_s: 0.0,
                            });
                            completions.push(Reverse(Ev {
                                t: F64Ord(now + hold),
                                id: key.id,
                                chain: key.chain,
                                seg: key.seg,
                                retry: key.retry,
                                hold: F64Ord(0.0),
                                is_retry: false,
                            }));
                        }
                        SegmentFate::Interrupt { after_s, lost_train_s, retry_hold_s } => {
                            let after = after_s.clamp(0.0, hold);
                            out[key.chain].segments.push(SegmentOutcome {
                                start_s: now,
                                end_s: now + after,
                                queue_wait_s: now - submit,
                                interrupted: true,
                                lost_train_s,
                            });
                            completions.push(Reverse(Ev {
                                t: F64Ord(now + after),
                                id: key.id,
                                chain: key.chain,
                                seg: key.seg,
                                retry: key.retry,
                                hold: F64Ord(retry_hold_s.max(0.0)),
                                is_retry: true,
                            }));
                        }
                    }
                }
                next_pass = None;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn job(id: u64, submit: f64, gpus: u32, hold: f64, prio: u32) -> SchedJob {
        SchedJob { id, submit_s: submit, gpus, hold_s: hold, priority: prio }
    }

    #[test]
    fn immediate_start_when_free() {
        let out = schedule(100, &[job(1, 5.0, 50, 10.0, 1)]);
        assert_eq!(out[0].start_s, 5.0);
        assert_eq!(out[0].queue_wait_s, 0.0);
    }

    #[test]
    fn queues_when_full() {
        let out = schedule(100, &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 50, 5.0, 1)]);
        assert_eq!(out[1].start_s, 10.0);
        assert_eq!(out[1].queue_wait_s, 9.0);
    }

    #[test]
    fn priority_preempts_queue_order() {
        // Low-prio (2) submitted first, high-prio (0) second; pool fits one.
        let out = schedule(
            100,
            &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 100, 10.0, 2), job(3, 2.0, 100, 10.0, 0)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j3.start_s < j2.start_s, "high priority should run first");
    }

    #[test]
    fn fifo_within_priority() {
        let out = schedule(
            100,
            &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 60, 5.0, 1), job(3, 2.0, 60, 5.0, 1)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j2.start_s <= j3.start_s);
    }

    #[test]
    fn head_of_line_blocks_same_priority() {
        // Big job waits; a small same-priority job behind it must not jump
        // the queue (no backfill).
        let out = schedule(
            100,
            &[job(1, 0.0, 80, 10.0, 1), job(2, 1.0, 80, 10.0, 1), job(3, 2.0, 10, 1.0, 1)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j3.start_s >= j2.start_s, "no backfill past a blocked head");
    }

    #[test]
    fn prop_no_oversubscription_and_all_scheduled() {
        prop_check(32, |g| {
            let pool = g.u64_in(8, 512) as u32;
            let n = g.usize_in(1, 40);
            let jobs: Vec<SchedJob> = (0..n)
                .map(|i| SchedJob {
                    id: i as u64,
                    submit_s: g.f64_in(0.0, 100.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    hold_s: g.f64_in(1.0, 50.0),
                    priority: g.u64_in(0, 3) as u32,
                })
                .collect();
            let out = schedule(pool, &jobs);
            prop_assert!(out.len() == n, "all jobs scheduled");
            // Check instantaneous usage at every start event.
            for probe in &out {
                let t = probe.start_s + 1e-9;
                let used: u32 = out
                    .iter()
                    .zip(jobs.iter())
                    .filter(|(o, _)| o.start_s <= t && t < o.end_s)
                    .map(|(_, j)| j.gpus)
                    .sum();
                prop_assert!(used <= pool, "oversubscribed: {used} > {pool}");
            }
            // No job starts before submission.
            for (o, j) in out.iter().zip(jobs.iter()) {
                prop_assert!(o.start_s >= j.submit_s - 1e-9);
                prop_assert!((o.end_s - o.start_s - j.hold_s).abs() < 1e-9);
            }
            Ok(())
        });
    }

    // ---- chain engine ----

    #[test]
    fn chain_restarts_requeue_in_order() {
        // One 3-segment chain, empty pool: segments run back to back.
        let chains = [ChainJob {
            id: 1,
            submit_s: 4.0,
            gpus: 10,
            priority: 1,
            segments: vec![5.0, 7.0, 3.0],
        }];
        let out = schedule_chains(100, &chains, 0.0);
        assert_eq!(out[0].segments.len(), 3);
        assert_eq!(out[0].segments[0].start_s, 4.0);
        assert_eq!(out[0].segments[0].end_s, 9.0);
        assert_eq!(out[0].segments[1].start_s, 9.0);
        assert_eq!(out[0].segments[2].start_s, 16.0);
        for s in &out[0].segments {
            assert_eq!(s.queue_wait_s, 0.0);
        }
    }

    #[test]
    fn chain_restart_competes_with_queue() {
        // Chain A releases at t=10; a full-pool job B (submitted earlier,
        // same priority) is already queued, so A's restart waits behind B.
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 100, priority: 1, segments: vec![10.0, 5.0] },
            ChainJob { id: 2, submit_s: 1.0, gpus: 100, priority: 1, segments: vec![20.0] },
        ];
        let out = schedule_chains(100, &chains, 0.0);
        assert_eq!(out[0].segments[0].start_s, 0.0);
        assert_eq!(out[1].segments[0].start_s, 10.0, "B runs when A's first segment ends");
        assert_eq!(out[0].segments[1].start_s, 30.0, "A's restart waits behind B");
        assert_eq!(out[0].segments[1].queue_wait_s, 20.0);
    }

    #[test]
    fn oversized_chain_never_runs() {
        let chains =
            [ChainJob { id: 7, submit_s: 0.0, gpus: 200, priority: 0, segments: vec![1.0] }];
        let out = schedule_chains(100, &chains, 0.0);
        assert!(out[0].segments.is_empty());
    }

    #[test]
    fn rounds_quantize_start_times() {
        // With 30 s rounds, a job submitted at t=5 starts at the next pass.
        let chains =
            [ChainJob { id: 1, submit_s: 5.0, gpus: 10, priority: 1, segments: vec![4.0] }];
        let out = schedule_chains(100, &chains, 30.0);
        assert_eq!(out[0].segments[0].start_s, 30.0);
        assert_eq!(out[0].segments[0].queue_wait_s, 25.0);
        // A submission exactly on the grid is served at that pass.
        let chains =
            [ChainJob { id: 1, submit_s: 60.0, gpus: 10, priority: 1, segments: vec![4.0] }];
        let out = schedule_chains(100, &chains, 30.0);
        assert_eq!(out[0].segments[0].start_s, 60.0);
    }

    // ---- interruption path ----

    /// Scripted oracle: fails the first `fails` runs of every segment at
    /// `after_s` into the hold, losing `lost` and requeuing the full hold.
    struct ScriptedFaults {
        fails: u32,
        after_s: f64,
        lost: f64,
    }

    impl FaultOracle for ScriptedFaults {
        fn fate(
            &self,
            _chain: &ChainJob,
            _seg: usize,
            retry: u32,
            _start_s: f64,
            hold_s: f64,
        ) -> SegmentFate {
            if retry < self.fails {
                SegmentFate::Interrupt {
                    after_s: self.after_s.min(hold_s),
                    lost_train_s: self.lost,
                    retry_hold_s: hold_s,
                }
            } else {
                SegmentFate::Complete
            }
        }
    }

    #[test]
    fn none_oracle_is_bit_identical() {
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 60, priority: 1, segments: vec![10.0, 5.0] },
            ChainJob { id: 2, submit_s: 1.0, gpus: 60, priority: 0, segments: vec![20.0] },
        ];
        let a = schedule_chains(100, &chains, 30.0);
        let b = schedule_chains_with(100, &chains, 30.0, None);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.segments, y.segments);
        }
    }

    #[test]
    fn interrupted_segment_requeues_and_completes() {
        // One chain, empty pool, continuous rounds: the first run of the
        // only segment fails at t=3, the retry starts immediately at the
        // failure instant and runs the full hold.
        let chains =
            [ChainJob { id: 1, submit_s: 0.0, gpus: 10, priority: 1, segments: vec![10.0] }];
        let oracle = ScriptedFaults { fails: 1, after_s: 3.0, lost: 2.0 };
        let out = schedule_chains_with(100, &chains, 0.0, Some(&oracle));
        assert_eq!(out[0].segments.len(), 2);
        let failed = out[0].segments[0];
        let retry = out[0].segments[1];
        assert!(failed.interrupted);
        assert_eq!(failed.start_s, 0.0);
        assert_eq!(failed.end_s, 3.0, "segment ends at the failure instant");
        assert_eq!(failed.lost_train_s, 2.0);
        assert!(!retry.interrupted);
        assert_eq!(retry.start_s, 3.0, "retry re-enters at the failure instant");
        assert_eq!(retry.end_s, 13.0);
        assert_eq!(retry.lost_train_s, 0.0);
    }

    #[test]
    fn interruption_releases_gpus_at_failure_instant() {
        // A full-pool chain fails at t=2; a queued job must be able to
        // start right then, not at the planned hold end (t=100).
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 100, priority: 1, segments: vec![100.0] },
            ChainJob { id: 2, submit_s: 0.5, gpus: 100, priority: 0, segments: vec![5.0] },
        ];
        let oracle = ScriptedFaults { fails: 1, after_s: 2.0, lost: 0.0 };
        let out = schedule_chains_with(100, &chains, 0.0, Some(&oracle));
        let b = out[1].segments[0];
        assert_eq!(b.start_s, 2.0, "failure instant frees the pool for the queued job");
        // The retry (same priority 1) waits behind the higher-priority B.
        let retry = out[0].segments[1];
        assert!(retry.start_s >= 7.0, "retry waits for B: {}", retry.start_s);
    }

    #[test]
    fn restart_keeps_chain_priority() {
        // High-priority chain A fails; its retry must beat a lower-priority
        // job B that queued earlier at the same failure instant.
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 100, priority: 0, segments: vec![50.0] },
            ChainJob { id: 2, submit_s: 0.1, gpus: 100, priority: 2, segments: vec![50.0] },
        ];
        let oracle = ScriptedFaults { fails: 1, after_s: 5.0, lost: 0.0 };
        let out = schedule_chains_with(100, &chains, 0.0, Some(&oracle));
        let retry = out[0].segments[1];
        let b = out[1].segments[0];
        assert!(!retry.interrupted && retry.start_s == 5.0, "retry preempts the queue");
        assert!(b.start_s >= retry.end_s, "low-priority job waits for the retry");
    }

    #[test]
    fn restart_storm_never_deadlocks() {
        // Many jobs all failing repeatedly inside one window: every chain
        // still finishes every scripted segment (each with its retries),
        // and the pool is never over-allocated.
        let chains: Vec<ChainJob> = (0..40)
            .map(|i| ChainJob {
                id: i + 1,
                submit_s: (i as f64) * 0.5,
                gpus: 20 + (i as u32 % 5) * 16,
                priority: (i % 3) as u32,
                segments: vec![30.0, 20.0],
            })
            .collect();
        let oracle = ScriptedFaults { fails: 3, after_s: 1.0, lost: 0.5 };
        let out = schedule_chains_with(256, &chains, 15.0, Some(&oracle));
        let mut evs: Vec<(f64, i64)> = Vec::new();
        for (c, o) in chains.iter().zip(&out) {
            // 2 scripted segments x (3 failures + 1 completion) each.
            assert_eq!(o.segments.len(), 8, "chain {} fully scheduled", c.id);
            assert_eq!(o.segments.iter().filter(|s| !s.interrupted).count(), 2);
            for s in &o.segments {
                assert!(s.end_s > s.start_s - 1e-9);
                evs.push((s.start_s, c.gpus as i64));
                evs.push((s.end_s, -(c.gpus as i64)));
            }
        }
        evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let mut used = 0i64;
        for (_, d) in evs {
            used += d;
            assert!(used <= 256, "pool over-allocated under the storm: {used}");
        }
    }

    #[test]
    fn prop_interrupted_chains_conserve_pool() {
        prop_check(16, |g| {
            let pool = g.u64_in(32, 256) as u32;
            let n = g.usize_in(1, 15);
            let chains: Vec<ChainJob> = (0..n)
                .map(|i| ChainJob {
                    id: i as u64 + 1,
                    submit_s: g.f64_in(0.0, 100.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    priority: g.u64_in(0, 3) as u32,
                    segments: (0..g.usize_in(1, 3)).map(|_| g.f64_in(5.0, 40.0)).collect(),
                })
                .collect();
            let fails = g.u64_in(0, 3) as u32;
            let oracle = ScriptedFaults { fails, after_s: g.f64_in(0.5, 10.0), lost: 1.0 };
            let out = schedule_chains_with(pool, &chains, 10.0, Some(&oracle));
            let mut evs: Vec<(f64, i64)> = Vec::new();
            for (c, o) in chains.iter().zip(&out) {
                let completed = o.segments.iter().filter(|s| !s.interrupted).count();
                prop_assert!(completed == c.segments.len(), "every scripted segment completes");
                for s in &o.segments {
                    prop_assert!(s.queue_wait_s >= -1e-9);
                    evs.push((s.start_s, c.gpus as i64));
                    evs.push((s.end_s, -(c.gpus as i64)));
                }
            }
            evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let mut used = 0i64;
            for (_, d) in evs {
                used += d;
                prop_assert!(used <= pool as i64, "pool over-allocated: {used} > {pool}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_chains_conserve_pool_and_order() {
        prop_check(24, |g| {
            let pool = g.u64_in(16, 256) as u32;
            let n = g.usize_in(1, 20);
            let round = if g.rng.chance(0.5) { 0.0 } else { g.f64_in(1.0, 60.0) };
            let chains: Vec<ChainJob> = (0..n)
                .map(|i| ChainJob {
                    id: i as u64,
                    submit_s: g.f64_in(0.0, 200.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    priority: g.u64_in(0, 3) as u32,
                    segments: (0..g.usize_in(1, 4)).map(|_| g.f64_in(1.0, 40.0)).collect(),
                })
                .collect();
            let out = schedule_chains(pool, &chains, round);
            // Every segment of every fitting chain is scheduled.
            for (c, o) in chains.iter().zip(&out) {
                prop_assert!(o.segments.len() == c.segments.len(), "chain fully scheduled");
                // Segments are ordered; restarts re-enter the queue at the
                // previous segment's end, so waits are non-negative.
                let mut prev_end = c.submit_s;
                for (k, s) in o.segments.iter().enumerate() {
                    prop_assert!(s.start_s >= prev_end - 1e-9, "segment starts after re-submit");
                    prop_assert!(s.queue_wait_s >= -1e-9);
                    prop_assert!((s.end_s - s.start_s - c.segments[k]).abs() < 1e-9);
                    prev_end = s.end_s;
                }
            }
            // Pool conservation at every segment start.
            let mut evs: Vec<(f64, i64)> = Vec::new();
            for (c, o) in chains.iter().zip(&out) {
                for s in &o.segments {
                    evs.push((s.start_s, c.gpus as i64));
                    evs.push((s.end_s, -(c.gpus as i64)));
                }
            }
            // Process releases before acquisitions at equal times.
            evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let mut used = 0i64;
            for (_, d) in evs {
                used += d;
                prop_assert!(used <= pool as i64, "pool over-allocated: {used} > {pool}");
            }
            Ok(())
        });
    }
}
