//! Scheduler Phase substrate (§2.2): a priority + FIFO GPU allocator over
//! a finite pool. Produces the Resource Queuing / Resource Allocation
//! behaviour of the trace replay (jobs wait "until their resource
//! requirements are met and no higher-priority jobs are pending").

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A job submitted to the scheduler.
#[derive(Clone, Debug)]
pub struct SchedJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// How long the job holds its GPUs once started (training + startups).
    pub hold_s: f64,
    /// Smaller = more important.
    pub priority: u32,
}

/// Scheduling outcome for one job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchedOutcome {
    pub id: u64,
    pub start_s: f64,
    pub end_s: f64,
    pub queue_wait_s: f64,
}

/// Event-driven scheduler over a pool of `pool_gpus`.
pub fn schedule(pool_gpus: u32, jobs: &[SchedJob]) -> Vec<SchedOutcome> {
    #[derive(PartialEq)]
    struct F64Ord(f64);
    impl Eq for F64Ord {}
    impl PartialOrd for F64Ord {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for F64Ord {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.0.partial_cmp(&other.0).unwrap()
        }
    }

    let mut by_submit: Vec<&SchedJob> = jobs.iter().collect();
    by_submit.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap().then(a.id.cmp(&b.id)));

    // Pending queue ordered by (priority, submit, id).
    let mut pending: Vec<&SchedJob> = Vec::new();
    // Completion events.
    let mut completions: BinaryHeap<Reverse<(F64Ord, u64, u32)>> = BinaryHeap::new();
    let mut free = pool_gpus;
    let mut out = Vec::with_capacity(jobs.len());
    let mut next_arrival = 0usize;
    let mut now = 0.0f64;

    loop {
        // Advance to the next event: arrival or completion.
        let na = by_submit.get(next_arrival).map(|j| j.submit_s);
        let nc = completions.peek().map(|Reverse((t, _, _))| t.0);
        let t = match (na, nc) {
            (Some(a), Some(c)) => a.min(c),
            (Some(a), None) => a,
            (None, Some(c)) => c,
            (None, None) => break,
        };
        now = now.max(t);
        // Process completions at `now`.
        while let Some(Reverse((ft, _, g))) = completions.peek() {
            if ft.0 <= now + 1e-12 {
                free += *g;
                completions.pop();
            } else {
                break;
            }
        }
        // Admit arrivals at `now`.
        while next_arrival < by_submit.len() && by_submit[next_arrival].submit_s <= now + 1e-12 {
            pending.push(by_submit[next_arrival]);
            next_arrival += 1;
        }
        // Allocate: strict priority order; within priority, FIFO. A job that
        // does not fit blocks lower-priority jobs of the same or larger size
        // (no backfill — conservative, like the paper's quota scheduler).
        pending.sort_by(|a, b| {
            a.priority
                .cmp(&b.priority)
                .then(a.submit_s.partial_cmp(&b.submit_s).unwrap())
                .then(a.id.cmp(&b.id))
        });
        let mut blocked_priority: Option<u32> = None;
        let mut i = 0;
        while i < pending.len() {
            let j = pending[i];
            if let Some(bp) = blocked_priority {
                if j.priority >= bp {
                    break;
                }
            }
            if j.gpus <= free {
                free -= j.gpus;
                out.push(SchedOutcome {
                    id: j.id,
                    start_s: now,
                    end_s: now + j.hold_s,
                    queue_wait_s: now - j.submit_s,
                });
                completions.push(Reverse((F64Ord(now + j.hold_s), j.id, j.gpus)));
                pending.remove(i);
            } else {
                blocked_priority = Some(j.priority);
                i += 1;
            }
        }
    }
    out.sort_by_key(|o| o.id);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn job(id: u64, submit: f64, gpus: u32, hold: f64, prio: u32) -> SchedJob {
        SchedJob { id, submit_s: submit, gpus, hold_s: hold, priority: prio }
    }

    #[test]
    fn immediate_start_when_free() {
        let out = schedule(100, &[job(1, 5.0, 50, 10.0, 1)]);
        assert_eq!(out[0].start_s, 5.0);
        assert_eq!(out[0].queue_wait_s, 0.0);
    }

    #[test]
    fn queues_when_full() {
        let out = schedule(100, &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 50, 5.0, 1)]);
        assert_eq!(out[1].start_s, 10.0);
        assert_eq!(out[1].queue_wait_s, 9.0);
    }

    #[test]
    fn priority_preempts_queue_order() {
        // Low-prio (2) submitted first, high-prio (0) second; pool fits one.
        let out = schedule(
            100,
            &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 100, 10.0, 2), job(3, 2.0, 100, 10.0, 0)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j3.start_s < j2.start_s, "high priority should run first");
    }

    #[test]
    fn fifo_within_priority() {
        let out = schedule(
            100,
            &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 60, 5.0, 1), job(3, 2.0, 60, 5.0, 1)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j2.start_s <= j3.start_s);
    }

    #[test]
    fn head_of_line_blocks_same_priority() {
        // Big job waits; a small same-priority job behind it must not jump
        // the queue (no backfill).
        let out = schedule(
            100,
            &[job(1, 0.0, 80, 10.0, 1), job(2, 1.0, 80, 10.0, 1), job(3, 2.0, 10, 1.0, 1)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j3.start_s >= j2.start_s, "no backfill past a blocked head");
    }

    #[test]
    fn prop_no_oversubscription_and_all_scheduled() {
        prop_check(32, |g| {
            let pool = g.u64_in(8, 512) as u32;
            let n = g.usize_in(1, 40);
            let jobs: Vec<SchedJob> = (0..n)
                .map(|i| SchedJob {
                    id: i as u64,
                    submit_s: g.f64_in(0.0, 100.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    hold_s: g.f64_in(1.0, 50.0),
                    priority: g.u64_in(0, 3) as u32,
                })
                .collect();
            let out = schedule(pool, &jobs);
            prop_assert!(out.len() == n, "all jobs scheduled");
            // Check instantaneous usage at every start event.
            for probe in &out {
                let t = probe.start_s + 1e-9;
                let used: u32 = out
                    .iter()
                    .zip(jobs.iter())
                    .filter(|(o, _)| o.start_s <= t && t < o.end_s)
                    .map(|(_, j)| j.gpus)
                    .sum();
                prop_assert!(used <= pool, "oversubscribed: {used} > {pool}");
            }
            // No job starts before submission.
            for (o, j) in out.iter().zip(jobs.iter()) {
                prop_assert!(o.start_s >= j.submit_s - 1e-9);
                prop_assert!((o.end_s - o.start_s - j.hold_s).abs() < 1e-9);
            }
            Ok(())
        });
    }
}
