//! Scheduler Phase substrate (§2.2): a priority + FIFO GPU allocator over
//! a finite pool, producing the Resource Queuing / Resource Allocation
//! behaviour of the trace replay — jobs wait "until their resource
//! requirements are met and no higher-priority jobs are pending".
//!
//! Two entry points share one event-driven core:
//!
//! * [`schedule`] — one allocation per job (submit → wait → hold →
//!   release), the §3.2 single-shot model.
//! * [`schedule_chains`] — the cluster-replay engine: every job is a
//!   *chain* of segments (one per full startup). When a segment ends (the
//!   job failed or was reconfigured, §3.1), its GPUs return to the pool and
//!   the next segment re-enters the queue at that instant, competing again
//!   under the same priority. Hot updates never appear here — they keep
//!   their allocation, so they consume no scheduler events.
//!
//! Allocation decisions are batched into periodic scheduling rounds
//! (`round_s`; see `defaults::SCHED_ROUND_S`): even an uncontended job
//! waits ~U[0, round] for the next pass, which is the structural source of
//! the paper's ~100 s median queue wait. Contention — a hot pool, a huge
//! job parked at the head of the queue with no backfill allowed — produces
//! the hour-long tail. `round_s == 0` degenerates to continuous,
//! allocate-immediately semantics (what [`schedule`] uses, and what the
//! scheduler unit tests pin down).
//!
//! Consumed by [`crate::trace`]'s contention-aware replay (phase 1 of the
//! two-phase design described in `docs/replay.md`); the queue waits it
//! assigns flow into the profiler via [`crate::startup`]'s stage events.

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

/// A job submitted to the scheduler.
#[derive(Clone, Debug)]
pub struct SchedJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// How long the job holds its GPUs once started (training + startups).
    pub hold_s: f64,
    /// Smaller = more important.
    pub priority: u32,
}

/// Scheduling outcome for one job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchedOutcome {
    pub id: u64,
    pub start_s: f64,
    pub end_s: f64,
    pub queue_wait_s: f64,
}

/// A multi-segment job: each segment is one full startup plus its training
/// slice; segment `k+1` is submitted the instant segment `k` ends.
#[derive(Clone, Debug)]
pub struct ChainJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// Smaller = more important; restarts keep the job's priority.
    pub priority: u32,
    /// Hold duration of each segment, in order.
    pub segments: Vec<f64>,
}

/// One scheduled segment of a chain.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SegmentOutcome {
    pub start_s: f64,
    pub end_s: f64,
    /// Time between (re-)submission and allocation.
    pub queue_wait_s: f64,
}

/// Scheduling outcome for a whole chain. `segments` is empty when the job
/// can never fit the pool (`gpus > pool_gpus`).
#[derive(Clone, Debug)]
pub struct ChainOutcome {
    pub id: u64,
    pub gpus: u32,
    pub segments: Vec<SegmentOutcome>,
}

/// Totally ordered f64 wrapper (times are finite and non-negative here).
#[derive(PartialEq)]
struct F64Ord(f64);
impl Eq for F64Ord {}
impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

/// Queue key: strict priority, then FIFO by (re-)submission time, then id.
/// `submit_bits` is the IEEE bit pattern of the non-negative submit time,
/// which orders identically to the float itself.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendKey {
    prio: u32,
    submit_bits: u64,
    id: u64,
    chain: usize,
    seg: usize,
}

/// Event-driven scheduler over a pool of `pool_gpus` (single-segment form).
pub fn schedule(pool_gpus: u32, jobs: &[SchedJob]) -> Vec<SchedOutcome> {
    let chains: Vec<ChainJob> = jobs
        .iter()
        .map(|j| ChainJob {
            id: j.id,
            submit_s: j.submit_s,
            gpus: j.gpus,
            priority: j.priority,
            segments: vec![j.hold_s],
        })
        .collect();
    let mut out: Vec<SchedOutcome> = schedule_chains(pool_gpus, &chains, 0.0)
        .into_iter()
        .filter(|c| !c.segments.is_empty())
        .map(|c| SchedOutcome {
            id: c.id,
            start_s: c.segments[0].start_s,
            end_s: c.segments[0].end_s,
            queue_wait_s: c.segments[0].queue_wait_s,
        })
        .collect();
    out.sort_by_key(|o| o.id);
    out
}

/// Event-driven scheduler over a pool of `pool_gpus`, chain form: every
/// completed segment releases its GPUs and re-submits the chain's next
/// segment at the completion instant. Allocation passes run at multiples of
/// `round_s` (0 = continuous). Strict priority order; within priority,
/// FIFO; a job that does not fit blocks same-or-lower-priority jobs behind
/// it (no backfill — conservative, like the paper's quota scheduler).
///
/// Returns one [`ChainOutcome`] per input chain, in input order.
pub fn schedule_chains(pool_gpus: u32, chains: &[ChainJob], round_s: f64) -> Vec<ChainOutcome> {
    // Next allocation pass no earlier than `t`, quantized to the round grid.
    let quantize_up = |t: f64| -> f64 {
        if round_s <= 0.0 {
            t
        } else {
            (t / round_s - 1e-9).ceil() * round_s
        }
    };

    let mut out: Vec<ChainOutcome> = chains
        .iter()
        .map(|c| ChainOutcome { id: c.id, gpus: c.gpus, segments: Vec::new() })
        .collect();

    // (time, id, chain index, segment index), min-ordered by time.
    let mut arrivals: BinaryHeap<Reverse<(F64Ord, u64, usize, usize)>> = BinaryHeap::new();
    for (ci, c) in chains.iter().enumerate() {
        if c.gpus > pool_gpus || c.segments.is_empty() {
            continue; // can never run; outcome stays empty
        }
        arrivals.push(Reverse((F64Ord(c.submit_s.max(0.0)), c.id, ci, 0)));
    }
    let mut completions: BinaryHeap<Reverse<(F64Ord, u64, usize, usize)>> = BinaryHeap::new();
    let mut pending: BTreeSet<PendKey> = BTreeSet::new();
    let mut free = pool_gpus;
    let mut next_pass: Option<f64> = None;

    loop {
        // Advance to the next event: arrival, completion, or scheduled pass.
        let mut now = f64::INFINITY;
        if let Some(Reverse((t, _, _, _))) = arrivals.peek() {
            now = now.min(t.0);
        }
        if let Some(Reverse((t, _, _, _))) = completions.peek() {
            now = now.min(t.0);
        }
        if let Some(p) = next_pass {
            now = now.min(p);
        }
        if !now.is_finite() {
            break;
        }

        let mut changed = false;
        // Completions free GPUs and re-submit the chain's next segment.
        while let Some(Reverse((t, _, _, _))) = completions.peek() {
            if t.0 > now + 1e-12 {
                break;
            }
            let Reverse((_, id, ci, si)) = completions.pop().unwrap();
            free += chains[ci].gpus;
            changed = true;
            if si + 1 < chains[ci].segments.len() {
                arrivals.push(Reverse((F64Ord(now), id, ci, si + 1)));
            }
        }
        // Arrivals enter the pending queue.
        while let Some(Reverse((t, _, _, _))) = arrivals.peek() {
            if t.0 > now + 1e-12 {
                break;
            }
            let Reverse((t, id, ci, si)) = arrivals.pop().unwrap();
            pending.insert(PendKey {
                prio: chains[ci].priority,
                submit_bits: t.0.to_bits(),
                id,
                chain: ci,
                seg: si,
            });
            changed = true;
        }
        // Any state change (re-)arms an allocation pass on the round grid.
        if changed && !pending.is_empty() {
            let p = quantize_up(now);
            next_pass = Some(match next_pass {
                Some(q) => q.min(p),
                None => p,
            });
        }

        // Allocation pass. Iteration is (priority, submit, id)-ordered, so
        // the first job that does not fit blocks everything behind it.
        if let Some(p) = next_pass {
            if p <= now + 1e-12 {
                let mut to_start: Vec<PendKey> = Vec::new();
                let mut trial_free = free;
                for &key in pending.iter() {
                    let c = &chains[key.chain];
                    if c.gpus <= trial_free {
                        trial_free -= c.gpus;
                        to_start.push(key);
                    } else {
                        break; // head-of-line: no backfill past a blocked job
                    }
                }
                for key in to_start {
                    pending.remove(&key);
                    let c = &chains[key.chain];
                    free -= c.gpus;
                    let hold = c.segments[key.seg];
                    let submit = f64::from_bits(key.submit_bits);
                    out[key.chain].segments.push(SegmentOutcome {
                        start_s: now,
                        end_s: now + hold,
                        queue_wait_s: now - submit,
                    });
                    completions.push(Reverse((F64Ord(now + hold), key.id, key.chain, key.seg)));
                }
                next_pass = None;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn job(id: u64, submit: f64, gpus: u32, hold: f64, prio: u32) -> SchedJob {
        SchedJob { id, submit_s: submit, gpus, hold_s: hold, priority: prio }
    }

    #[test]
    fn immediate_start_when_free() {
        let out = schedule(100, &[job(1, 5.0, 50, 10.0, 1)]);
        assert_eq!(out[0].start_s, 5.0);
        assert_eq!(out[0].queue_wait_s, 0.0);
    }

    #[test]
    fn queues_when_full() {
        let out = schedule(100, &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 50, 5.0, 1)]);
        assert_eq!(out[1].start_s, 10.0);
        assert_eq!(out[1].queue_wait_s, 9.0);
    }

    #[test]
    fn priority_preempts_queue_order() {
        // Low-prio (2) submitted first, high-prio (0) second; pool fits one.
        let out = schedule(
            100,
            &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 100, 10.0, 2), job(3, 2.0, 100, 10.0, 0)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j3.start_s < j2.start_s, "high priority should run first");
    }

    #[test]
    fn fifo_within_priority() {
        let out = schedule(
            100,
            &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 60, 5.0, 1), job(3, 2.0, 60, 5.0, 1)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j2.start_s <= j3.start_s);
    }

    #[test]
    fn head_of_line_blocks_same_priority() {
        // Big job waits; a small same-priority job behind it must not jump
        // the queue (no backfill).
        let out = schedule(
            100,
            &[job(1, 0.0, 80, 10.0, 1), job(2, 1.0, 80, 10.0, 1), job(3, 2.0, 10, 1.0, 1)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j3.start_s >= j2.start_s, "no backfill past a blocked head");
    }

    #[test]
    fn prop_no_oversubscription_and_all_scheduled() {
        prop_check(32, |g| {
            let pool = g.u64_in(8, 512) as u32;
            let n = g.usize_in(1, 40);
            let jobs: Vec<SchedJob> = (0..n)
                .map(|i| SchedJob {
                    id: i as u64,
                    submit_s: g.f64_in(0.0, 100.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    hold_s: g.f64_in(1.0, 50.0),
                    priority: g.u64_in(0, 3) as u32,
                })
                .collect();
            let out = schedule(pool, &jobs);
            prop_assert!(out.len() == n, "all jobs scheduled");
            // Check instantaneous usage at every start event.
            for probe in &out {
                let t = probe.start_s + 1e-9;
                let used: u32 = out
                    .iter()
                    .zip(jobs.iter())
                    .filter(|(o, _)| o.start_s <= t && t < o.end_s)
                    .map(|(_, j)| j.gpus)
                    .sum();
                prop_assert!(used <= pool, "oversubscribed: {used} > {pool}");
            }
            // No job starts before submission.
            for (o, j) in out.iter().zip(jobs.iter()) {
                prop_assert!(o.start_s >= j.submit_s - 1e-9);
                prop_assert!((o.end_s - o.start_s - j.hold_s).abs() < 1e-9);
            }
            Ok(())
        });
    }

    // ---- chain engine ----

    #[test]
    fn chain_restarts_requeue_in_order() {
        // One 3-segment chain, empty pool: segments run back to back.
        let chains = [ChainJob {
            id: 1,
            submit_s: 4.0,
            gpus: 10,
            priority: 1,
            segments: vec![5.0, 7.0, 3.0],
        }];
        let out = schedule_chains(100, &chains, 0.0);
        assert_eq!(out[0].segments.len(), 3);
        assert_eq!(out[0].segments[0].start_s, 4.0);
        assert_eq!(out[0].segments[0].end_s, 9.0);
        assert_eq!(out[0].segments[1].start_s, 9.0);
        assert_eq!(out[0].segments[2].start_s, 16.0);
        for s in &out[0].segments {
            assert_eq!(s.queue_wait_s, 0.0);
        }
    }

    #[test]
    fn chain_restart_competes_with_queue() {
        // Chain A releases at t=10; a full-pool job B (submitted earlier,
        // same priority) is already queued, so A's restart waits behind B.
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 100, priority: 1, segments: vec![10.0, 5.0] },
            ChainJob { id: 2, submit_s: 1.0, gpus: 100, priority: 1, segments: vec![20.0] },
        ];
        let out = schedule_chains(100, &chains, 0.0);
        assert_eq!(out[0].segments[0].start_s, 0.0);
        assert_eq!(out[1].segments[0].start_s, 10.0, "B runs when A's first segment ends");
        assert_eq!(out[0].segments[1].start_s, 30.0, "A's restart waits behind B");
        assert_eq!(out[0].segments[1].queue_wait_s, 20.0);
    }

    #[test]
    fn oversized_chain_never_runs() {
        let chains = [ChainJob { id: 7, submit_s: 0.0, gpus: 200, priority: 0, segments: vec![1.0] }];
        let out = schedule_chains(100, &chains, 0.0);
        assert!(out[0].segments.is_empty());
    }

    #[test]
    fn rounds_quantize_start_times() {
        // With 30 s rounds, a job submitted at t=5 starts at the next pass.
        let chains = [ChainJob { id: 1, submit_s: 5.0, gpus: 10, priority: 1, segments: vec![4.0] }];
        let out = schedule_chains(100, &chains, 30.0);
        assert_eq!(out[0].segments[0].start_s, 30.0);
        assert_eq!(out[0].segments[0].queue_wait_s, 25.0);
        // A submission exactly on the grid is served at that pass.
        let chains = [ChainJob { id: 1, submit_s: 60.0, gpus: 10, priority: 1, segments: vec![4.0] }];
        let out = schedule_chains(100, &chains, 30.0);
        assert_eq!(out[0].segments[0].start_s, 60.0);
    }

    #[test]
    fn prop_chains_conserve_pool_and_order() {
        prop_check(24, |g| {
            let pool = g.u64_in(16, 256) as u32;
            let n = g.usize_in(1, 20);
            let round = if g.rng.chance(0.5) { 0.0 } else { g.f64_in(1.0, 60.0) };
            let chains: Vec<ChainJob> = (0..n)
                .map(|i| ChainJob {
                    id: i as u64,
                    submit_s: g.f64_in(0.0, 200.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    priority: g.u64_in(0, 3) as u32,
                    segments: (0..g.usize_in(1, 4)).map(|_| g.f64_in(1.0, 40.0)).collect(),
                })
                .collect();
            let out = schedule_chains(pool, &chains, round);
            // Every segment of every fitting chain is scheduled.
            for (c, o) in chains.iter().zip(&out) {
                prop_assert!(o.segments.len() == c.segments.len(), "chain fully scheduled");
                // Segments are ordered; restarts re-enter the queue at the
                // previous segment's end, so waits are non-negative.
                let mut prev_end = c.submit_s;
                for (k, s) in o.segments.iter().enumerate() {
                    prop_assert!(s.start_s >= prev_end - 1e-9, "segment starts after re-submit");
                    prop_assert!(s.queue_wait_s >= -1e-9);
                    prop_assert!((s.end_s - s.start_s - c.segments[k]).abs() < 1e-9);
                    prev_end = s.end_s;
                }
            }
            // Pool conservation at every segment start.
            let mut evs: Vec<(f64, i64)> = Vec::new();
            for (c, o) in chains.iter().zip(&out) {
                for s in &o.segments {
                    evs.push((s.start_s, c.gpus as i64));
                    evs.push((s.end_s, -(c.gpus as i64)));
                }
            }
            // Process releases before acquisitions at equal times.
            evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let mut used = 0i64;
            for (_, d) in evs {
                used += d;
                prop_assert!(used <= pool as i64, "pool over-allocated: {used} > {pool}");
            }
            Ok(())
        });
    }
}
