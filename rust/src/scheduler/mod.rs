//! Scheduler Phase substrate (§2.2): a priority + FIFO GPU allocator over
//! a finite pool, producing the Resource Queuing / Resource Allocation
//! behaviour of the trace replay — jobs wait "until their resource
//! requirements are met and no higher-priority jobs are pending".
//!
//! Two entry points share one event-driven core:
//!
//! * [`schedule`] — one allocation per job (submit → wait → hold →
//!   release), the §3.2 single-shot model.
//! * [`schedule_chains`] — the cluster-replay engine: every job is a
//!   *chain* of segments (one per full startup). When a segment ends (the
//!   job failed or was reconfigured, §3.1), its GPUs return to the pool and
//!   the next segment re-enters the queue at that instant, competing again
//!   under the same priority. Hot updates never appear here — they keep
//!   their allocation, so they consume no scheduler events.
//!
//! # The event core
//!
//! The core processes exactly three first-class event kinds:
//!
//! * **Arrival** — a (chain, segment, retry) run enters the
//!   pending queue, a priority structure ordered (priority, FIFO
//!   submit, id).
//! * **Release** — a running segment returns its GPUs to the indexed
//!   free-pool. A *completion* release re-submits the chain's next
//!   scripted segment at that instant; a *preemption* release (the failure
//!   instant of an interrupted run) re-enqueues the **same** scripted
//!   segment as `retry + 1` at the chain's retained priority, carrying the
//!   oracle-assigned remaining hold.
//! * **Gang admission** — armed (at most one in flight) whenever a release
//!   or arrival makes the queue head admissible, quantized up to the round
//!   grid. One admission event atomically starts the maximal multi-segment
//!   front: it pops queue heads while they fit the free pool at trial
//!   capacity, so admission does no rescanning — each pop is one ordered
//!   lookup, and the first head that does not fit ends the gang (no
//!   backfill past a blocked job, like the paper's quota scheduler).
//!
//! The pre-rewrite core — re-armed allocation passes that rescanned the
//! pending set head-of-line — survives verbatim in [`reference`]; the
//! tests pin the two bit-identical (oracle on and off) and
//! `micro_replay_parallel` gates the speedup ratio through
//! `BENCH_replay.json`.
//!
//! Allocation decisions are batched into periodic scheduling rounds
//! (`round_s`; see `defaults::SCHED_ROUND_S`): even an uncontended job
//! waits ~U[0, round] for the next admission, which is the structural
//! source of the paper's ~100 s median queue wait. Contention — a hot
//! pool, a huge job parked at the head of the queue with no backfill
//! allowed — produces the hour-long tail. `round_s == 0` degenerates to
//! continuous, allocate-immediately semantics (what [`schedule`] uses, and
//! what the scheduler unit tests pin down). Time comparisons share two
//! named constants: [`EVENT_COALESCE_S`] (event coalescing) and
//! [`ROUND_GRID_REL`] (grid snapping slack in [`quantize_up`]).
//!
//! **Interruption path** ([`schedule_chains_with`]): an optional
//! [`FaultOracle`] is consulted at every segment admission and may declare
//! the segment [`SegmentFate::Interrupt`]ed mid-hold — the failure instant
//! becomes a preemption release: the segment ends early, its GPUs return
//! to the pool right there, and a *retry* of the same scripted segment
//! re-enters the queue at that instant with the oracle-provided remaining
//! hold, competing again under the chain's original priority.
//! [`crate::faults`] provides the seeded hazard-based oracle the cluster
//! replay drives this with; `None` reproduces the uninterrupted schedule
//! bit-for-bit.
//!
//! Consumed by [`crate::trace`]'s contention-aware replay (phase 1 of the
//! two-phase design described in `docs/replay.md`); the queue waits it
//! assigns flow into the profiler via [`crate::startup`]'s stage events.

pub mod reference;

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

/// Two timed events closer than this coalesce into one instant: releases
/// and arrivals within the window are drained together, and an armed gang
/// admission whose due time is within the window of `now` fires at `now`.
/// Absolute, in seconds — replay times are O(weeks) ≈ 6e5 s, so this sits
/// ~7 decimal orders below one ulp of a typical timestamp and only ever
/// coalesces genuinely identical instants that differ by fp noise.
pub const EVENT_COALESCE_S: f64 = 1e-12;

/// Relative slack used by [`quantize_up`] when snapping a time up to the
/// allocation-round grid: a time within `ROUND_GRID_REL` rounds *below* a
/// grid point (i.e. `t/round_s` within 1e-9 of an integer from above) is
/// treated as exactly on-grid rather than pushed a full round later.
pub const ROUND_GRID_REL: f64 = 1e-9;

/// Snaps `t` up to the next allocation-round grid point (`k * round_s`,
/// minimal `k` such that the grid point is not more than [`ROUND_GRID_REL`]
/// rounds below `t`). `round_s <= 0` is the continuous degenerate: `t`
/// itself. Shared by the event core and its preserved [`reference`]
/// implementation; `quantize_up_pins_round_grid_boundaries` is the
/// regression test for the boundary behaviour.
pub fn quantize_up(t: f64, round_s: f64) -> f64 {
    if round_s <= 0.0 {
        t
    } else {
        (t / round_s - ROUND_GRID_REL).ceil() * round_s
    }
}

/// A job submitted to the scheduler.
#[derive(Clone, Debug)]
pub struct SchedJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// How long the job holds its GPUs once started (training + startups).
    pub hold_s: f64,
    /// Smaller = more important.
    pub priority: u32,
}

/// Scheduling outcome for one job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchedOutcome {
    pub id: u64,
    pub start_s: f64,
    pub end_s: f64,
    pub queue_wait_s: f64,
}

/// A multi-segment job: each segment is one full startup plus its training
/// slice; segment `k+1` is submitted the instant segment `k` ends.
#[derive(Clone, Debug)]
pub struct ChainJob {
    pub id: u64,
    pub submit_s: f64,
    pub gpus: u32,
    /// Smaller = more important; restarts keep the job's priority.
    pub priority: u32,
    /// Hold duration of each segment, in order.
    pub segments: Vec<f64>,
}

/// One scheduled segment of a chain. With a [`FaultOracle`] in play a
/// scripted segment may appear several times: each interrupted run is
/// recorded (with `interrupted == true`) followed by its retries, until one
/// run completes or the oracle gives up.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SegmentOutcome {
    pub start_s: f64,
    pub end_s: f64,
    /// Time between (re-)submission and allocation.
    pub queue_wait_s: f64,
    /// The segment ended early at a failure instant (`end_s` is the
    /// failure time, not the planned hold end) and a retry re-entered the
    /// queue at `end_s`.
    pub interrupted: bool,
    /// Training progress rolled back at the interruption (seconds of work
    /// since the last resume point, lost and re-done by the retry). Zero
    /// for completed segments.
    pub lost_train_s: f64,
}

/// What a [`FaultOracle`] decides for one segment at allocation time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SegmentFate {
    /// The segment runs its full hold.
    Complete,
    /// The segment fails `after_s` seconds into its hold (`0 < after_s <
    /// hold`): its GPUs are released then and a retry with hold
    /// `retry_hold_s` re-enters the queue at the failure instant.
    /// `lost_train_s` is the training progress rolled back (recorded on
    /// the interrupted [`SegmentOutcome`]).
    Interrupt { after_s: f64, lost_train_s: f64, retry_hold_s: f64 },
}

/// Decides, deterministically, whether a segment run fails mid-hold.
/// Queried exactly once per (chain, scripted segment, retry) at the
/// allocation instant; implementations must be pure functions of those
/// identities (plus their own seed) so the schedule is reproducible. The
/// oracle is responsible for termination: it must return
/// [`SegmentFate::Complete`] once `retry` reaches its cap.
pub trait FaultOracle {
    fn fate(
        &self,
        chain: &ChainJob,
        seg: usize,
        retry: u32,
        start_s: f64,
        hold_s: f64,
    ) -> SegmentFate;
}

/// Scheduling outcome for a whole chain. `segments` is empty when the job
/// can never fit the pool (`gpus > pool_gpus`).
#[derive(Clone, Debug)]
pub struct ChainOutcome {
    pub id: u64,
    pub gpus: u32,
    pub segments: Vec<SegmentOutcome>,
}

/// Totally ordered f64 wrapper (times are finite and non-negative here).
#[derive(Clone, Copy, PartialEq)]
struct F64Ord(f64);
impl Eq for F64Ord {}
impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

/// Queue key: strict priority, then FIFO by (re-)submission time, then id.
/// `submit_bits` is the IEEE bit pattern of the non-negative submit time,
/// which orders identically to the float itself. `retry`/`hold_bits` ride
/// along so a retry keeps its chain's priority but carries its own
/// (shrunken) hold.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendKey {
    prio: u32,
    submit_bits: u64,
    id: u64,
    chain: usize,
    seg: usize,
    retry: u32,
    hold_bits: u64,
}

/// An arrival event: run (chain, seg, retry) (re-)enters the pending queue
/// at `t`, carrying its own hold. Min-ordered by `(t, id, chain, seg,
/// retry)` — the same tie-break order the pre-rewrite event tuples used,
/// so the drained batch at every instant is identical.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Arrival {
    t: F64Ord,
    id: u64,
    chain: usize,
    seg: usize,
    retry: u32,
    hold: F64Ord,
}

/// A release event: a running segment returns its GPUs at `t`. `preempt`
/// marks a failure instant — the same scripted segment re-enters the queue
/// as `retry + 1` with `retry_hold` (zero and unused for completions,
/// which re-submit the chain's next scripted segment instead).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Release {
    t: F64Ord,
    id: u64,
    chain: usize,
    seg: usize,
    retry: u32,
    retry_hold: F64Ord,
    preempt: bool,
}

/// The indexed free-pool: a GPU capacity ledger with O(1)
/// `fits`/`allocate`/`release`. The gang-admission event consults it at
/// trial capacity while popping queue heads, so admitting a front of `k`
/// gangs costs `k` ordered pops — no rescan of the pending set. (GPUs are
/// fungible here, so one counter *is* the fully-indexed structure: the
/// fits-at-capacity query for any gang size is a single compare. A
/// topology-aware pool would refine `fits` without touching the core.)
struct FreePool {
    capacity: u32,
    free: u32,
}

impl FreePool {
    fn new(capacity: u32) -> Self {
        Self { capacity, free: capacity }
    }
    fn fits(&self, gpus: u32) -> bool {
        gpus <= self.free
    }
    fn allocate(&mut self, gpus: u32) {
        debug_assert!(gpus <= self.free, "free-pool underflow: {gpus} > {}", self.free);
        self.free -= gpus;
    }
    fn release(&mut self, gpus: u32) {
        self.free += gpus;
        let cap = self.capacity;
        debug_assert!(self.free <= cap, "free-pool overflow: {} > {cap}", self.free);
    }
}

/// The pending queue: runs awaiting admission, ordered (priority, FIFO
/// submit, id). `BTreeSet` keeps head peek and ordered pops at O(log n)
/// without any full-queue rescan on the admission path.
struct PendingQueue(BTreeSet<PendKey>);

impl PendingQueue {
    fn new() -> Self {
        Self(BTreeSet::new())
    }
    fn insert(&mut self, key: PendKey) {
        self.0.insert(key);
    }
    fn head(&self) -> Option<PendKey> {
        self.0.iter().next().copied()
    }
    fn remove(&mut self, key: &PendKey) {
        self.0.remove(key);
    }
}

/// The event-driven scheduler core: arrival/release heaps, the pending
/// queue, the free pool, and the (at most one) armed gang admission.
struct EventCore<'a> {
    chains: &'a [ChainJob],
    round_s: f64,
    arrivals: BinaryHeap<Reverse<Arrival>>,
    releases: BinaryHeap<Reverse<Release>>,
    pending: PendingQueue,
    pool: FreePool,
    /// Due time of the armed gang-admission event, if any.
    next_admission: Option<f64>,
}

impl<'a> EventCore<'a> {
    fn new(pool_gpus: u32, chains: &'a [ChainJob], round_s: f64) -> Self {
        let initial: Vec<Reverse<Arrival>> = chains
            .iter()
            .enumerate()
            .filter(|(_, c)| c.gpus <= pool_gpus && !c.segments.is_empty())
            .map(|(ci, c)| {
                Reverse(Arrival {
                    t: F64Ord(c.submit_s.max(0.0)),
                    id: c.id,
                    chain: ci,
                    seg: 0,
                    retry: 0,
                    hold: F64Ord(c.segments[0]),
                })
            })
            .collect();
        Self {
            chains,
            round_s,
            arrivals: BinaryHeap::from(initial),
            releases: BinaryHeap::new(),
            pending: PendingQueue::new(),
            pool: FreePool::new(pool_gpus),
            next_admission: None,
        }
    }

    /// The next event instant: earliest arrival, release, or armed
    /// admission. Infinite when the system has drained.
    fn next_time(&self) -> f64 {
        let mut now = f64::INFINITY;
        if let Some(&Reverse(ev)) = self.arrivals.peek() {
            now = now.min(ev.t.0);
        }
        if let Some(&Reverse(ev)) = self.releases.peek() {
            now = now.min(ev.t.0);
        }
        if let Some(p) = self.next_admission {
            now = now.min(p);
        }
        now
    }

    /// Drains every release coalesced with `now`: GPUs return to the pool;
    /// a preemption re-enqueues the same scripted segment at `retry + 1`
    /// (retained chain priority, oracle-assigned hold), a completion
    /// re-submits the chain's next scripted segment. Returns whether
    /// anything released.
    fn drain_releases(&mut self, now: f64) -> bool {
        let mut changed = false;
        while let Some(&Reverse(ev)) = self.releases.peek() {
            if ev.t.0 > now + EVENT_COALESCE_S {
                break;
            }
            self.releases.pop();
            self.pool.release(self.chains[ev.chain].gpus);
            changed = true;
            if ev.preempt {
                self.arrivals.push(Reverse(Arrival {
                    t: F64Ord(now),
                    id: ev.id,
                    chain: ev.chain,
                    seg: ev.seg,
                    retry: ev.retry + 1,
                    hold: ev.retry_hold,
                }));
            } else if ev.seg + 1 < self.chains[ev.chain].segments.len() {
                self.arrivals.push(Reverse(Arrival {
                    t: F64Ord(now),
                    id: ev.id,
                    chain: ev.chain,
                    seg: ev.seg + 1,
                    retry: 0,
                    hold: F64Ord(self.chains[ev.chain].segments[ev.seg + 1]),
                }));
            }
        }
        changed
    }

    /// Drains every arrival coalesced with `now` into the pending queue.
    /// Returns whether anything arrived.
    fn drain_arrivals(&mut self, now: f64) -> bool {
        let mut changed = false;
        while let Some(&Reverse(ev)) = self.arrivals.peek() {
            if ev.t.0 > now + EVENT_COALESCE_S {
                break;
            }
            self.arrivals.pop();
            self.pending.insert(PendKey {
                prio: self.chains[ev.chain].priority,
                submit_bits: ev.t.0.to_bits(),
                id: ev.id,
                chain: ev.chain,
                seg: ev.seg,
                retry: ev.retry,
                hold_bits: ev.hold.0.to_bits(),
            });
            changed = true;
        }
        changed
    }

    /// Arms a gang-admission event on the round grid iff the queue head
    /// now fits the free pool. Skipping the arm when the head does not fit
    /// is unobservable relative to arming unconditionally: free GPUs only
    /// grow between an arm and its firing (allocation happens exclusively
    /// inside admission events, which disarm), so a pass armed on a
    /// blocked head would admit nothing and change no state; and every
    /// event that could unblock or replace the head — a release growing
    /// the pool, an arrival inserting a smaller head — re-runs this arm,
    /// at a grid point no later than the skipped pass would have reached
    /// it (`quantize_up` is monotone and fixes grid points). The
    /// `*_matches_reference` tests pin this bit-for-bit.
    fn arm_admission(&mut self, now: f64) {
        let Some(head) = self.pending.head() else { return };
        if !self.pool.fits(self.chains[head.chain].gpus) {
            return;
        }
        let p = quantize_up(now, self.round_s);
        self.next_admission = Some(match self.next_admission {
            Some(q) => q.min(p),
            None => p,
        });
    }

    /// The gang-admission event: atomically starts the maximal admissible
    /// front. Pops the queue head while it fits the pool at trial
    /// capacity — in (priority, submit, id) order, so the first head that
    /// does not fit blocks everything behind it (head-of-line, no
    /// backfill) — consulting the oracle once per admitted run. A
    /// completed run schedules a completion release at `now + hold`; an
    /// interrupted run schedules a preemption release at the failure
    /// instant. Disarms itself.
    fn gang_admit(
        &mut self,
        now: f64,
        oracle: Option<&dyn FaultOracle>,
        out: &mut [ChainOutcome],
    ) {
        while let Some(key) = self.pending.head() {
            let c = &self.chains[key.chain];
            if !self.pool.fits(c.gpus) {
                break; // head-of-line: no backfill past a blocked gang
            }
            self.pending.remove(&key);
            self.pool.allocate(c.gpus);
            let hold = f64::from_bits(key.hold_bits);
            let submit = f64::from_bits(key.submit_bits);
            let fate = match oracle {
                Some(o) => o.fate(c, key.seg, key.retry, now, hold),
                None => SegmentFate::Complete,
            };
            match fate {
                SegmentFate::Complete => {
                    out[key.chain].segments.push(SegmentOutcome {
                        start_s: now,
                        end_s: now + hold,
                        queue_wait_s: now - submit,
                        interrupted: false,
                        lost_train_s: 0.0,
                    });
                    self.releases.push(Reverse(Release {
                        t: F64Ord(now + hold),
                        id: key.id,
                        chain: key.chain,
                        seg: key.seg,
                        retry: key.retry,
                        retry_hold: F64Ord(0.0),
                        preempt: false,
                    }));
                }
                SegmentFate::Interrupt { after_s, lost_train_s, retry_hold_s } => {
                    let after = after_s.clamp(0.0, hold);
                    out[key.chain].segments.push(SegmentOutcome {
                        start_s: now,
                        end_s: now + after,
                        queue_wait_s: now - submit,
                        interrupted: true,
                        lost_train_s,
                    });
                    self.releases.push(Reverse(Release {
                        t: F64Ord(now + after),
                        id: key.id,
                        chain: key.chain,
                        seg: key.seg,
                        retry: key.retry,
                        retry_hold: F64Ord(retry_hold_s.max(0.0)),
                        preempt: true,
                    }));
                }
            }
        }
        self.next_admission = None;
    }
}

/// Event-driven scheduler over a pool of `pool_gpus` (single-segment form).
pub fn schedule(pool_gpus: u32, jobs: &[SchedJob]) -> Vec<SchedOutcome> {
    let chains: Vec<ChainJob> = jobs
        .iter()
        .map(|j| ChainJob {
            id: j.id,
            submit_s: j.submit_s,
            gpus: j.gpus,
            priority: j.priority,
            segments: vec![j.hold_s],
        })
        .collect();
    let mut out: Vec<SchedOutcome> = schedule_chains(pool_gpus, &chains, 0.0)
        .into_iter()
        .filter(|c| !c.segments.is_empty())
        .map(|c| SchedOutcome {
            id: c.id,
            start_s: c.segments[0].start_s,
            end_s: c.segments[0].end_s,
            queue_wait_s: c.segments[0].queue_wait_s,
        })
        .collect();
    out.sort_by_key(|o| o.id);
    out
}

/// Event-driven scheduler over a pool of `pool_gpus`, chain form: every
/// completed segment releases its GPUs and re-submits the chain's next
/// segment at the completion instant. Gang admissions fire at multiples of
/// `round_s` (0 = continuous). Strict priority order; within priority,
/// FIFO; a job that does not fit blocks same-or-lower-priority jobs behind
/// it (no backfill — conservative, like the paper's quota scheduler).
///
/// Returns one [`ChainOutcome`] per input chain, in input order.
pub fn schedule_chains(pool_gpus: u32, chains: &[ChainJob], round_s: f64) -> Vec<ChainOutcome> {
    schedule_chains_with(pool_gpus, chains, round_s, None)
}

/// [`schedule_chains`] with an optional fault oracle: at every segment
/// admission the oracle may declare the run interrupted mid-hold, in which
/// case the segment ends (and releases its GPUs) at the failure instant —
/// a preemption event — and a retry with the oracle's remaining hold
/// re-enters the queue right there, keeping the chain's priority. `None`
/// is bit-identical to [`schedule_chains`], and both are bit-identical to
/// the preserved [`reference::schedule_chains_reference`].
pub fn schedule_chains_with(
    pool_gpus: u32,
    chains: &[ChainJob],
    round_s: f64,
    oracle: Option<&dyn FaultOracle>,
) -> Vec<ChainOutcome> {
    let mut out: Vec<ChainOutcome> = chains
        .iter()
        .map(|c| ChainOutcome { id: c.id, gpus: c.gpus, segments: Vec::new() })
        .collect();

    let mut core = EventCore::new(pool_gpus, chains, round_s);
    loop {
        let now = core.next_time();
        if !now.is_finite() {
            break;
        }
        // Releases before arrivals at a coalesced instant: re-submissions
        // enter the arrival heap at `t = now` and are drained in the same
        // iteration, so the interleave is unobservable — both drains only
        // add to the pending set and grow the pool.
        let released = core.drain_releases(now);
        let arrived = core.drain_arrivals(now);
        if released || arrived {
            core.arm_admission(now);
        }
        if let Some(p) = core.next_admission {
            if p <= now + EVENT_COALESCE_S {
                core.gang_admit(now, oracle, &mut out);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Gang placement over the rack tree
// ---------------------------------------------------------------------------

/// Free-GPU accounting per rack, used by the replay to place each admitted
/// gang onto the topology tree (`cluster.racks`).
///
/// Placement is deliberately simple and deterministic:
///
/// 1. **Best-fit single rack** — among racks whose free GPUs cover the
///    whole gang, pick the one with the *least* free capacity (ties break
///    toward the lowest rack id). A gang that fits one rack never pays the
///    spine.
/// 2. **Greedy spill** — otherwise fill racks in descending free order
///    (ties toward the lowest id), taking what each has, until the gang is
///    covered. This is the contiguous-rack preference: the fewest racks
///    that can hold the job.
///
/// The pool is *total*: if the gang exceeds the free GPUs (the scheduler
/// already admitted it, so this only happens when rack accounting drifts
/// from the scheduler's scalar pool under retries), the remainder lands on
/// rack 0 and [`RackPool::release`] clamps frees back to capacity.
#[derive(Clone, Debug)]
pub struct RackPool {
    cap: Vec<u32>,
    free: Vec<u32>,
}

impl RackPool {
    /// A pool of `pool_gpus` split evenly over `racks` racks (the last
    /// rack absorbs the rounding remainder, mirroring
    /// [`crate::sim::Topology`]'s contiguous node→rack map).
    pub fn new(pool_gpus: u32, racks: u32) -> RackPool {
        let racks = racks.max(1);
        let per = ((pool_gpus + racks - 1) / racks).max(1);
        let mut cap = Vec::with_capacity(racks as usize);
        let mut left = pool_gpus;
        for _ in 0..racks {
            let c = per.min(left);
            cap.push(c);
            left -= c;
        }
        RackPool { free: cap.clone(), cap }
    }

    /// Number of racks in the pool.
    pub fn racks(&self) -> u32 {
        self.cap.len() as u32
    }

    /// Free GPUs currently available in rack `r`.
    pub fn free_in(&self, r: u32) -> u32 {
        self.free[r as usize]
    }

    /// Place a gang of `gpus` GPUs and return the rack of each of its
    /// nodes (`gpus_per_node` GPUs each; node `j` gets the rack covering
    /// GPU block `j * gpus_per_node` of the allocation). The returned
    /// vector is exactly what [`crate::sim::ClusterSim::build_placed`]
    /// takes as a placement.
    pub fn place(&mut self, gpus: u32, gpus_per_node: u32) -> Vec<u32> {
        let gpn = gpus_per_node.max(1);
        let nodes = ((gpus + gpn - 1) / gpn).max(1) as usize;
        // 1. Best fit: the fullest single rack that still covers the gang.
        let mut best: Option<(u32, usize)> = None;
        for (r, &f) in self.free.iter().enumerate() {
            if f >= gpus && best.map_or(true, |(bf, _)| f < bf) {
                best = Some((f, r));
            }
        }
        if let Some((_, r)) = best {
            self.free[r] -= gpus;
            return vec![r as u32; nodes];
        }
        // 2. Greedy spill over racks in descending free order.
        let mut order: Vec<usize> = (0..self.free.len()).collect();
        order.sort_by_key(|&r| (Reverse(self.free[r]), r));
        let mut gpu_rack: Vec<u32> = Vec::with_capacity(gpus as usize);
        let mut remaining = gpus;
        for &r in &order {
            if remaining == 0 {
                break;
            }
            let take = self.free[r].min(remaining);
            self.free[r] -= take;
            remaining -= take;
            gpu_rack.extend(std::iter::repeat(r as u32).take(take as usize));
        }
        // Total allocation: any remainder (rack drift under retries) lands
        // on rack 0; release() clamps the books back.
        gpu_rack.extend(std::iter::repeat(0).take(remaining as usize));
        (0..nodes).map(|j| gpu_rack[(j * gpn as usize).min(gpu_rack.len() - 1)]).collect()
    }

    /// Re-pin a gang onto a known `placement` (a warm restart landing
    /// back on its previous racks): decrement each placed rack's free
    /// GPUs, saturating at zero — the fault oracle already decided the
    /// restart lands warm, so the pin always succeeds even if the books
    /// drifted while the gang sat in the queue.
    pub fn take(&mut self, placement: &[u32], gpus: u32, gpus_per_node: u32) {
        let gpn = gpus_per_node.max(1);
        let mut left = gpus;
        for &r in placement {
            let grab = gpn.min(left);
            left -= grab;
            let r = r as usize;
            self.free[r] = self.free[r].saturating_sub(grab);
        }
    }

    /// Return a gang's GPUs to its racks. `placement` is what
    /// [`RackPool::place`] returned; each node gives back `gpus_per_node`
    /// (the last node gives back the gang's remainder). Frees are clamped
    /// to rack capacity, so over-placed remainders never inflate the pool.
    pub fn release(&mut self, placement: &[u32], gpus: u32, gpus_per_node: u32) {
        let gpn = gpus_per_node.max(1);
        let mut left = gpus;
        for &r in placement {
            let give = gpn.min(left);
            left -= give;
            let r = r as usize;
            self.free[r] = (self.free[r] + give).min(self.cap[r]);
        }
    }
}

/// Distance between two gang placements: the number of node slots whose
/// rack changed (length mismatches count as moved). Scaled by the node
/// count, this is the relocation-cost fraction a warm restart pays
/// (`cluster.relocation_cost_s`): 0 when the restart lands back on its
/// racks, 1 when every node moved.
pub fn placement_distance(a: &[u32], b: &[u32]) -> u32 {
    let n = a.len().max(b.len());
    (0..n).filter(|&i| a.get(i) != b.get(i)).count() as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn job(id: u64, submit: f64, gpus: u32, hold: f64, prio: u32) -> SchedJob {
        SchedJob { id, submit_s: submit, gpus, hold_s: hold, priority: prio }
    }

    #[test]
    fn immediate_start_when_free() {
        let out = schedule(100, &[job(1, 5.0, 50, 10.0, 1)]);
        assert_eq!(out[0].start_s, 5.0);
        assert_eq!(out[0].queue_wait_s, 0.0);
    }

    #[test]
    fn queues_when_full() {
        let out = schedule(100, &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 50, 5.0, 1)]);
        assert_eq!(out[1].start_s, 10.0);
        assert_eq!(out[1].queue_wait_s, 9.0);
    }

    #[test]
    fn priority_preempts_queue_order() {
        // Low-prio (2) submitted first, high-prio (0) second; pool fits one.
        let out = schedule(
            100,
            &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 100, 10.0, 2), job(3, 2.0, 100, 10.0, 0)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j3.start_s < j2.start_s, "high priority should run first");
    }

    #[test]
    fn fifo_within_priority() {
        let out = schedule(
            100,
            &[job(1, 0.0, 100, 10.0, 1), job(2, 1.0, 60, 5.0, 1), job(3, 2.0, 60, 5.0, 1)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j2.start_s <= j3.start_s);
    }

    #[test]
    fn head_of_line_blocks_same_priority() {
        // Big job waits; a small same-priority job behind it must not jump
        // the queue (no backfill).
        let out = schedule(
            100,
            &[job(1, 0.0, 80, 10.0, 1), job(2, 1.0, 80, 10.0, 1), job(3, 2.0, 10, 1.0, 1)],
        );
        let j2 = out.iter().find(|o| o.id == 2).unwrap();
        let j3 = out.iter().find(|o| o.id == 3).unwrap();
        assert!(j3.start_s >= j2.start_s, "no backfill past a blocked head");
    }

    #[test]
    fn prop_no_oversubscription_and_all_scheduled() {
        prop_check(32, |g| {
            let pool = g.u64_in(8, 512) as u32;
            let n = g.usize_in(1, 40);
            let jobs: Vec<SchedJob> = (0..n)
                .map(|i| SchedJob {
                    id: i as u64,
                    submit_s: g.f64_in(0.0, 100.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    hold_s: g.f64_in(1.0, 50.0),
                    priority: g.u64_in(0, 3) as u32,
                })
                .collect();
            let out = schedule(pool, &jobs);
            prop_assert!(out.len() == n, "all jobs scheduled");
            // Check instantaneous usage at every start event.
            for probe in &out {
                let t = probe.start_s + 1e-9;
                let used: u32 = out
                    .iter()
                    .zip(jobs.iter())
                    .filter(|(o, _)| o.start_s <= t && t < o.end_s)
                    .map(|(_, j)| j.gpus)
                    .sum();
                prop_assert!(used <= pool, "oversubscribed: {used} > {pool}");
            }
            // No job starts before submission.
            for (o, j) in out.iter().zip(jobs.iter()) {
                prop_assert!(o.start_s >= j.submit_s - 1e-9);
                prop_assert!((o.end_s - o.start_s - j.hold_s).abs() < 1e-9);
            }
            Ok(())
        });
    }

    // ---- chain engine ----

    #[test]
    fn chain_restarts_requeue_in_order() {
        // One 3-segment chain, empty pool: segments run back to back.
        let chains = [ChainJob {
            id: 1,
            submit_s: 4.0,
            gpus: 10,
            priority: 1,
            segments: vec![5.0, 7.0, 3.0],
        }];
        let out = schedule_chains(100, &chains, 0.0);
        assert_eq!(out[0].segments.len(), 3);
        assert_eq!(out[0].segments[0].start_s, 4.0);
        assert_eq!(out[0].segments[0].end_s, 9.0);
        assert_eq!(out[0].segments[1].start_s, 9.0);
        assert_eq!(out[0].segments[2].start_s, 16.0);
        for s in &out[0].segments {
            assert_eq!(s.queue_wait_s, 0.0);
        }
    }

    #[test]
    fn chain_restart_competes_with_queue() {
        // Chain A releases at t=10; a full-pool job B (submitted earlier,
        // same priority) is already queued, so A's restart waits behind B.
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 100, priority: 1, segments: vec![10.0, 5.0] },
            ChainJob { id: 2, submit_s: 1.0, gpus: 100, priority: 1, segments: vec![20.0] },
        ];
        let out = schedule_chains(100, &chains, 0.0);
        assert_eq!(out[0].segments[0].start_s, 0.0);
        assert_eq!(out[1].segments[0].start_s, 10.0, "B runs when A's first segment ends");
        assert_eq!(out[0].segments[1].start_s, 30.0, "A's restart waits behind B");
        assert_eq!(out[0].segments[1].queue_wait_s, 20.0);
    }

    #[test]
    fn oversized_chain_never_runs() {
        let chains =
            [ChainJob { id: 7, submit_s: 0.0, gpus: 200, priority: 0, segments: vec![1.0] }];
        let out = schedule_chains(100, &chains, 0.0);
        assert!(out[0].segments.is_empty());
    }

    #[test]
    fn rounds_quantize_start_times() {
        // With 30 s rounds, a job submitted at t=5 starts at the next pass.
        let chains =
            [ChainJob { id: 1, submit_s: 5.0, gpus: 10, priority: 1, segments: vec![4.0] }];
        let out = schedule_chains(100, &chains, 30.0);
        assert_eq!(out[0].segments[0].start_s, 30.0);
        assert_eq!(out[0].segments[0].queue_wait_s, 25.0);
        // A submission exactly on the grid is served at that pass.
        let chains =
            [ChainJob { id: 1, submit_s: 60.0, gpus: 10, priority: 1, segments: vec![4.0] }];
        let out = schedule_chains(100, &chains, 30.0);
        assert_eq!(out[0].segments[0].start_s, 60.0);
    }

    #[test]
    fn quantize_up_pins_round_grid_boundaries() {
        // The named epsilons are load-bearing schedule semantics: pin their
        // values so a change is a deliberate, golden-breaking act.
        assert_eq!(EVENT_COALESCE_S, 1e-12);
        assert_eq!(ROUND_GRID_REL, 1e-9);
        // Continuous degenerate: identity.
        assert_eq!(quantize_up(7.25, 0.0), 7.25);
        assert_eq!(quantize_up(7.25, -1.0), 7.25);
        // Strictly inside a round: snap up to the next grid point.
        assert_eq!(quantize_up(5.0, 30.0), 30.0);
        assert_eq!(quantize_up(29.999, 30.0), 30.0);
        // Exactly on-grid: served at that pass, not a round later.
        assert_eq!(quantize_up(0.0, 30.0), 0.0);
        assert_eq!(quantize_up(60.0, 30.0), 60.0);
        // Within ROUND_GRID_REL rounds above a grid point: still treated
        // as on-grid (fp noise from upstream arithmetic must not cost a
        // whole round).
        assert_eq!(quantize_up(200.0 * (1.0 + 0.5e-9), 200.0), 200.0);
        // Beyond the slack: genuinely past the pass, wait for the next.
        assert_eq!(quantize_up(200.0 * (1.0 + 2e-9), 200.0), 400.0);
        // Epsilon-close submissions coalesce into the same admission.
        let chains = [
            ChainJob { id: 1, submit_s: 5.0, gpus: 50, priority: 1, segments: vec![4.0] },
            ChainJob {
                id: 2,
                submit_s: 5.0 + 0.5 * EVENT_COALESCE_S,
                gpus: 50,
                priority: 1,
                segments: vec![4.0],
            },
        ];
        let out = schedule_chains(100, &chains, 30.0);
        assert_eq!(out[0].segments[0].start_s, 30.0);
        assert_eq!(out[1].segments[0].start_s, 30.0);
    }

    #[test]
    fn gang_front_admits_atomically() {
        // Three queued jobs that exactly fill the pool are one gang front:
        // a single admission event starts all three at the same instant. A
        // fourth (same priority, later submit) is blocked by capacity and
        // waits for the release.
        let chains = [
            ChainJob { id: 1, submit_s: 1.0, gpus: 40, priority: 1, segments: vec![10.0] },
            ChainJob { id: 2, submit_s: 2.0, gpus: 30, priority: 1, segments: vec![10.0] },
            ChainJob { id: 3, submit_s: 3.0, gpus: 30, priority: 1, segments: vec![10.0] },
            ChainJob { id: 4, submit_s: 4.0, gpus: 20, priority: 1, segments: vec![5.0] },
        ];
        let out = schedule_chains(100, &chains, 30.0);
        for o in &out[..3] {
            assert_eq!(o.segments[0].start_s, 30.0, "gang member starts at the front");
        }
        // Gang releases at t=40; next grid point is 60.
        assert_eq!(out[3].segments[0].start_s, 60.0, "blocked job waits out the gang");
    }

    // ---- interruption path ----

    /// Scripted oracle: fails the first `fails` runs of every segment at
    /// `after_s` into the hold, losing `lost` and requeuing the full hold.
    struct ScriptedFaults {
        fails: u32,
        after_s: f64,
        lost: f64,
    }

    impl FaultOracle for ScriptedFaults {
        fn fate(
            &self,
            _chain: &ChainJob,
            _seg: usize,
            retry: u32,
            _start_s: f64,
            hold_s: f64,
        ) -> SegmentFate {
            if retry < self.fails {
                SegmentFate::Interrupt {
                    after_s: self.after_s.min(hold_s),
                    lost_train_s: self.lost,
                    retry_hold_s: hold_s,
                }
            } else {
                SegmentFate::Complete
            }
        }
    }

    #[test]
    fn none_oracle_is_bit_identical() {
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 60, priority: 1, segments: vec![10.0, 5.0] },
            ChainJob { id: 2, submit_s: 1.0, gpus: 60, priority: 0, segments: vec![20.0] },
        ];
        let a = schedule_chains(100, &chains, 30.0);
        let b = schedule_chains_with(100, &chains, 30.0, None);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.segments, y.segments);
        }
    }

    #[test]
    fn interrupted_segment_requeues_and_completes() {
        // One chain, empty pool, continuous rounds: the first run of the
        // only segment fails at t=3, the retry starts immediately at the
        // failure instant and runs the full hold.
        let chains =
            [ChainJob { id: 1, submit_s: 0.0, gpus: 10, priority: 1, segments: vec![10.0] }];
        let oracle = ScriptedFaults { fails: 1, after_s: 3.0, lost: 2.0 };
        let out = schedule_chains_with(100, &chains, 0.0, Some(&oracle));
        assert_eq!(out[0].segments.len(), 2);
        let failed = out[0].segments[0];
        let retry = out[0].segments[1];
        assert!(failed.interrupted);
        assert_eq!(failed.start_s, 0.0);
        assert_eq!(failed.end_s, 3.0, "segment ends at the failure instant");
        assert_eq!(failed.lost_train_s, 2.0);
        assert!(!retry.interrupted);
        assert_eq!(retry.start_s, 3.0, "retry re-enters at the failure instant");
        assert_eq!(retry.end_s, 13.0);
        assert_eq!(retry.lost_train_s, 0.0);
    }

    #[test]
    fn interruption_releases_gpus_at_failure_instant() {
        // A full-pool chain fails at t=2; a queued job must be able to
        // start right then, not at the planned hold end (t=100).
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 100, priority: 1, segments: vec![100.0] },
            ChainJob { id: 2, submit_s: 0.5, gpus: 100, priority: 0, segments: vec![5.0] },
        ];
        let oracle = ScriptedFaults { fails: 1, after_s: 2.0, lost: 0.0 };
        let out = schedule_chains_with(100, &chains, 0.0, Some(&oracle));
        let b = out[1].segments[0];
        assert_eq!(b.start_s, 2.0, "failure instant frees the pool for the queued job");
        // The retry (same priority 1) waits behind the higher-priority B.
        let retry = out[0].segments[1];
        assert!(retry.start_s >= 7.0, "retry waits for B: {}", retry.start_s);
    }

    #[test]
    fn restart_keeps_chain_priority() {
        // High-priority chain A fails; its retry must beat a lower-priority
        // job B that queued earlier at the same failure instant.
        let chains = [
            ChainJob { id: 1, submit_s: 0.0, gpus: 100, priority: 0, segments: vec![50.0] },
            ChainJob { id: 2, submit_s: 0.1, gpus: 100, priority: 2, segments: vec![50.0] },
        ];
        let oracle = ScriptedFaults { fails: 1, after_s: 5.0, lost: 0.0 };
        let out = schedule_chains_with(100, &chains, 0.0, Some(&oracle));
        let retry = out[0].segments[1];
        let b = out[1].segments[0];
        assert!(!retry.interrupted && retry.start_s == 5.0, "retry preempts the queue");
        assert!(b.start_s >= retry.end_s, "low-priority job waits for the retry");
    }

    #[test]
    fn restart_storm_never_deadlocks() {
        // Many jobs all failing repeatedly inside one window: every chain
        // still finishes every scripted segment (each with its retries),
        // and the pool is never over-allocated.
        let chains: Vec<ChainJob> = (0..40)
            .map(|i| ChainJob {
                id: i + 1,
                submit_s: (i as f64) * 0.5,
                gpus: 20 + (i as u32 % 5) * 16,
                priority: (i % 3) as u32,
                segments: vec![30.0, 20.0],
            })
            .collect();
        let oracle = ScriptedFaults { fails: 3, after_s: 1.0, lost: 0.5 };
        let out = schedule_chains_with(256, &chains, 15.0, Some(&oracle));
        let mut evs: Vec<(f64, i64)> = Vec::new();
        for (c, o) in chains.iter().zip(&out) {
            // 2 scripted segments x (3 failures + 1 completion) each.
            assert_eq!(o.segments.len(), 8, "chain {} fully scheduled", c.id);
            assert_eq!(o.segments.iter().filter(|s| !s.interrupted).count(), 2);
            for s in &o.segments {
                assert!(s.end_s > s.start_s - 1e-9);
                evs.push((s.start_s, c.gpus as i64));
                evs.push((s.end_s, -(c.gpus as i64)));
            }
        }
        evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let mut used = 0i64;
        for (_, d) in evs {
            used += d;
            assert!(used <= 256, "pool over-allocated under the storm: {used}");
        }
    }

    #[test]
    fn prop_interrupted_chains_conserve_pool() {
        prop_check(16, |g| {
            let pool = g.u64_in(32, 256) as u32;
            let n = g.usize_in(1, 15);
            let chains: Vec<ChainJob> = (0..n)
                .map(|i| ChainJob {
                    id: i as u64 + 1,
                    submit_s: g.f64_in(0.0, 100.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    priority: g.u64_in(0, 3) as u32,
                    segments: (0..g.usize_in(1, 3)).map(|_| g.f64_in(5.0, 40.0)).collect(),
                })
                .collect();
            let fails = g.u64_in(0, 3) as u32;
            let oracle = ScriptedFaults { fails, after_s: g.f64_in(0.5, 10.0), lost: 1.0 };
            let out = schedule_chains_with(pool, &chains, 10.0, Some(&oracle));
            let mut evs: Vec<(f64, i64)> = Vec::new();
            for (c, o) in chains.iter().zip(&out) {
                let completed = o.segments.iter().filter(|s| !s.interrupted).count();
                prop_assert!(completed == c.segments.len(), "every scripted segment completes");
                for s in &o.segments {
                    prop_assert!(s.queue_wait_s >= -1e-9);
                    evs.push((s.start_s, c.gpus as i64));
                    evs.push((s.end_s, -(c.gpus as i64)));
                }
            }
            evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let mut used = 0i64;
            for (_, d) in evs {
                used += d;
                prop_assert!(used <= pool as i64, "pool over-allocated: {used} > {pool}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_chains_conserve_pool_and_order() {
        prop_check(24, |g| {
            let pool = g.u64_in(16, 256) as u32;
            let n = g.usize_in(1, 20);
            let round = if g.rng.chance(0.5) { 0.0 } else { g.f64_in(1.0, 60.0) };
            let chains: Vec<ChainJob> = (0..n)
                .map(|i| ChainJob {
                    id: i as u64,
                    submit_s: g.f64_in(0.0, 200.0),
                    gpus: g.u64_in(1, pool as u64) as u32,
                    priority: g.u64_in(0, 3) as u32,
                    segments: (0..g.usize_in(1, 4)).map(|_| g.f64_in(1.0, 40.0)).collect(),
                })
                .collect();
            let out = schedule_chains(pool, &chains, round);
            // Every segment of every fitting chain is scheduled.
            for (c, o) in chains.iter().zip(&out) {
                prop_assert!(o.segments.len() == c.segments.len(), "chain fully scheduled");
                // Segments are ordered; restarts re-enter the queue at the
                // previous segment's end, so waits are non-negative.
                let mut prev_end = c.submit_s;
                for (k, s) in o.segments.iter().enumerate() {
                    prop_assert!(s.start_s >= prev_end - 1e-9, "segment starts after re-submit");
                    prop_assert!(s.queue_wait_s >= -1e-9);
                    prop_assert!((s.end_s - s.start_s - c.segments[k]).abs() < 1e-9);
                    prev_end = s.end_s;
                }
            }
            // Pool conservation at every segment start.
            let mut evs: Vec<(f64, i64)> = Vec::new();
            for (c, o) in chains.iter().zip(&out) {
                for s in &o.segments {
                    evs.push((s.start_s, c.gpus as i64));
                    evs.push((s.end_s, -(c.gpus as i64)));
                }
            }
            // Process releases before acquisitions at equal times.
            evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let mut used = 0i64;
            for (_, d) in evs {
                used += d;
                prop_assert!(used <= pool as i64, "pool over-allocated: {used} > {pool}");
            }
            Ok(())
        });
    }

    // ---- equivalence with the preserved reference core ----

    /// Bit-exact `ChainOutcome` comparison: every f64 compared by IEEE bit
    /// pattern, so even a -0.0/+0.0 or NaN-payload drift fails.
    fn assert_outcomes_bit_identical(a: &[ChainOutcome], b: &[ChainOutcome], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: outcome count");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.id, y.id, "{ctx}: id");
            assert_eq!(x.gpus, y.gpus, "{ctx}: gpus");
            assert_eq!(x.segments.len(), y.segments.len(), "{ctx}: chain {} segment count", x.id);
            for (s, t) in x.segments.iter().zip(&y.segments) {
                assert_eq!(s.start_s.to_bits(), t.start_s.to_bits(), "{ctx}: chain {} start", x.id);
                assert_eq!(s.end_s.to_bits(), t.end_s.to_bits(), "{ctx}: chain {} end", x.id);
                assert_eq!(
                    s.queue_wait_s.to_bits(),
                    t.queue_wait_s.to_bits(),
                    "{ctx}: chain {} wait",
                    x.id
                );
                assert_eq!(s.interrupted, t.interrupted, "{ctx}: chain {} interrupted", x.id);
                assert_eq!(
                    s.lost_train_s.to_bits(),
                    t.lost_train_s.to_bits(),
                    "{ctx}: chain {} lost",
                    x.id
                );
            }
        }
    }

    #[test]
    fn event_core_matches_reference_on_seeded_storm() {
        // The deterministic storm workload, oracle on, through both cores.
        let chains: Vec<ChainJob> = (0..40)
            .map(|i| ChainJob {
                id: i + 1,
                submit_s: (i as f64) * 0.5,
                gpus: 20 + (i as u32 % 5) * 16,
                priority: (i % 3) as u32,
                segments: vec![30.0, 20.0],
            })
            .collect();
        let oracle = ScriptedFaults { fails: 3, after_s: 1.0, lost: 0.5 };
        for round in [0.0, 15.0, 200.0] {
            let a = schedule_chains_with(256, &chains, round, Some(&oracle));
            let b = reference::schedule_chains_reference(256, &chains, round, Some(&oracle));
            assert_outcomes_bit_identical(&a, &b, &format!("storm round={round}"));
        }
    }

    #[test]
    fn prop_event_core_matches_reference() {
        // Randomized workloads — oversized chains, ties, rounds on/off,
        // oracle on/off — must be bit-identical between the event-driven
        // core and the preserved pass-rescan reference.
        prop_check(32, |g| {
            let pool = g.u64_in(8, 512) as u32;
            let n = g.usize_in(1, 30);
            let round = if g.rng.chance(0.3) { 0.0 } else { g.f64_in(1.0, 60.0) };
            let chains: Vec<ChainJob> = (0..n)
                .map(|i| ChainJob {
                    id: i as u64 + 1,
                    submit_s: g.f64_in(0.0, 200.0),
                    // Up to 2x the pool so some chains are oversized.
                    gpus: g.u64_in(1, 2 * pool as u64) as u32,
                    priority: g.u64_in(0, 3) as u32,
                    segments: (0..g.usize_in(1, 4)).map(|_| g.f64_in(0.5, 40.0)).collect(),
                })
                .collect();
            let with_oracle = g.rng.chance(0.5);
            let oracle = ScriptedFaults {
                fails: g.u64_in(0, 3) as u32,
                after_s: g.f64_in(0.25, 10.0),
                lost: 1.0,
            };
            let orc: Option<&dyn FaultOracle> = if with_oracle { Some(&oracle) } else { None };
            let a = schedule_chains_with(pool, &chains, round, orc);
            let b = reference::schedule_chains_reference(pool, &chains, round, orc);
            prop_assert!(a.len() == b.len(), "outcome count");
            for (x, y) in a.iter().zip(&b) {
                prop_assert!(x.segments.len() == y.segments.len(), "segment count");
                for (s, t) in x.segments.iter().zip(&y.segments) {
                    prop_assert!(
                        s.start_s.to_bits() == t.start_s.to_bits()
                            && s.end_s.to_bits() == t.end_s.to_bits()
                            && s.queue_wait_s.to_bits() == t.queue_wait_s.to_bits()
                            && s.interrupted == t.interrupted
                            && s.lost_train_s.to_bits() == t.lost_train_s.to_bits(),
                        "segment drift vs reference"
                    );
                }
            }
            Ok(())
        });
    }
    #[test]
    fn rack_pool_best_fit_prefers_fullest_single_rack() {
        // 4 racks x 32 GPUs; rack 2 drained to 16 free. A 16-GPU gang
        // best-fits rack 2 (smallest free that still covers it).
        let mut pool = RackPool::new(128, 4);
        let p0 = pool.place(16, 8);
        assert_eq!(p0, vec![0, 0]); // all racks tie at 32 free -> lowest id
        let p1 = pool.place(16, 8);
        assert_eq!(p1, vec![0, 0]); // rack 0 now 16 free: tightest fit
        assert_eq!(pool.free_in(0), 0);
        let p2 = pool.place(16, 8);
        assert_eq!(p2, vec![1, 1]);
    }

    #[test]
    fn rack_pool_spills_across_racks_when_no_single_rack_fits() {
        let mut pool = RackPool::new(128, 4);
        // 64-GPU gang: no 32-GPU rack covers it; greedy fills two racks.
        let p = pool.place(64, 8);
        assert_eq!(p, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        assert_eq!((pool.free_in(0), pool.free_in(1)), (0, 0));
        pool.release(&p, 64, 8);
        assert_eq!((pool.free_in(0), pool.free_in(1)), (32, 32));
    }

    #[test]
    fn rack_pool_overflow_is_total_and_release_clamps() {
        let mut pool = RackPool::new(16, 2);
        let a = pool.place(16, 8);
        // Pool is empty; an over-admitted gang still gets a placement.
        let b = pool.place(16, 8);
        assert_eq!(b, vec![0, 0]);
        pool.release(&b, 16, 8);
        pool.release(&a, 16, 8);
        // Clamped: frees never exceed capacity.
        assert_eq!((pool.free_in(0), pool.free_in(1)), (8, 8));
    }

    #[test]
    fn rack_pool_is_deterministic() {
        let run = || {
            let mut pool = RackPool::new(256, 8);
            let mut got = Vec::new();
            for g in [48u32, 96, 16, 64, 32] {
                got.push(pool.place(g, 8));
            }
            got
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn placement_distance_counts_moved_nodes() {
        assert_eq!(placement_distance(&[0, 0, 1], &[0, 0, 1]), 0);
        assert_eq!(placement_distance(&[0, 0, 1], &[0, 1, 1]), 1);
        assert_eq!(placement_distance(&[0, 0], &[1, 1, 2]), 3);
        assert_eq!(placement_distance(&[], &[]), 0);
    }
}
