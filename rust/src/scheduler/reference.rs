//! The pre-rewrite round-grid scheduler, preserved verbatim as a *golden
//! reference*.
//!
//! [`schedule_chains_reference`] is `schedule_chains_with` exactly as it
//! stood before the event-driven rewrite of the core in
//! [`crate::scheduler`]: every arrival or completion re-arms a quantized
//! allocation pass, and each pass rescans the `BTreeSet` pending queue
//! from the head to find the admissible prefix. The rewrite replaced the
//! pass rescans with first-class gang-admission and preemption events over
//! an indexed free-pool, but the *semantics* — strict priority, FIFO
//! within priority, head-of-line blocking with no backfill, round-grid
//! quantization, interruption retries at retained priority — are pinned to
//! this implementation bit-for-bit.
//!
//! Two things keep it around:
//!
//! * `scheduler::tests` drives both cores through identical seeded
//!   workloads (fault oracle on and off) and asserts the `ChainOutcome`
//!   streams are bit-identical.
//! * `micro_replay_parallel` benchmarks the event-driven core's speedup
//!   against it, and the recorded ratio is regression-gated through
//!   `BENCH_replay.json`.
//!
//! Do not "fix" or optimize this file; it is a measurement baseline.

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

use super::{ChainJob, ChainOutcome, FaultOracle, SegmentFate, SegmentOutcome};

/// Totally ordered f64 wrapper (times are finite and non-negative here).
#[derive(Clone, Copy, PartialEq)]
struct F64Ord(f64);
impl Eq for F64Ord {}
impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

/// Queue key: strict priority, then FIFO by (re-)submission time, then id.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendKey {
    prio: u32,
    submit_bits: u64,
    id: u64,
    chain: usize,
    seg: usize,
    retry: u32,
    hold_bits: u64,
}

/// A timed scheduler event (arrival or completion), min-ordered by
/// `(t, id, chain, seg, retry)`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Ev {
    t: F64Ord,
    id: u64,
    chain: usize,
    seg: usize,
    retry: u32,
    hold: F64Ord,
    is_retry: bool,
}

/// The pre-rewrite `schedule_chains_with`: round-grid allocation passes
/// re-armed on every arrival/completion, each rescanning the pending set.
/// Kept only as the equivalence baseline for the event-driven core.
pub fn schedule_chains_reference(
    pool_gpus: u32,
    chains: &[ChainJob],
    round_s: f64,
    oracle: Option<&dyn FaultOracle>,
) -> Vec<ChainOutcome> {
    // Next allocation pass no earlier than `t`, quantized to the round grid.
    let quantize_up = |t: f64| -> f64 {
        if round_s <= 0.0 {
            t
        } else {
            (t / round_s - 1e-9).ceil() * round_s
        }
    };

    let mut out: Vec<ChainOutcome> = chains
        .iter()
        .map(|c| ChainOutcome { id: c.id, gpus: c.gpus, segments: Vec::new() })
        .collect();

    let mut arrivals: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    for (ci, c) in chains.iter().enumerate() {
        if c.gpus > pool_gpus || c.segments.is_empty() {
            continue; // can never run; outcome stays empty
        }
        arrivals.push(Reverse(Ev {
            t: F64Ord(c.submit_s.max(0.0)),
            id: c.id,
            chain: ci,
            seg: 0,
            retry: 0,
            hold: F64Ord(c.segments[0]),
            is_retry: false,
        }));
    }
    let mut completions: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut pending: BTreeSet<PendKey> = BTreeSet::new();
    let mut free = pool_gpus;
    let mut next_pass: Option<f64> = None;

    loop {
        // Advance to the next event: arrival, completion, or scheduled pass.
        let mut now = f64::INFINITY;
        if let Some(Reverse(ev)) = arrivals.peek() {
            now = now.min(ev.t.0);
        }
        if let Some(Reverse(ev)) = completions.peek() {
            now = now.min(ev.t.0);
        }
        if let Some(p) = next_pass {
            now = now.min(p);
        }
        if !now.is_finite() {
            break;
        }

        let mut changed = false;
        // Completions free GPUs and re-submit the chain's next run: the
        // retry of an interrupted segment, or the next scripted segment.
        while let Some(Reverse(ev)) = completions.peek() {
            if ev.t.0 > now + 1e-12 {
                break;
            }
            let Reverse(ev) = completions.pop().unwrap();
            free += chains[ev.chain].gpus;
            changed = true;
            if ev.is_retry {
                arrivals.push(Reverse(Ev {
                    t: F64Ord(now),
                    retry: ev.retry + 1,
                    is_retry: false,
                    ..ev
                }));
            } else if ev.seg + 1 < chains[ev.chain].segments.len() {
                arrivals.push(Reverse(Ev {
                    t: F64Ord(now),
                    seg: ev.seg + 1,
                    retry: 0,
                    hold: F64Ord(chains[ev.chain].segments[ev.seg + 1]),
                    is_retry: false,
                    ..ev
                }));
            }
        }
        // Arrivals enter the pending queue.
        while let Some(Reverse(ev)) = arrivals.peek() {
            if ev.t.0 > now + 1e-12 {
                break;
            }
            let Reverse(ev) = arrivals.pop().unwrap();
            pending.insert(PendKey {
                prio: chains[ev.chain].priority,
                submit_bits: ev.t.0.to_bits(),
                id: ev.id,
                chain: ev.chain,
                seg: ev.seg,
                retry: ev.retry,
                hold_bits: ev.hold.0.to_bits(),
            });
            changed = true;
        }
        // Any state change (re-)arms an allocation pass on the round grid.
        if changed && !pending.is_empty() {
            let p = quantize_up(now);
            next_pass = Some(match next_pass {
                Some(q) => q.min(p),
                None => p,
            });
        }

        // Allocation pass. Iteration is (priority, submit, id)-ordered, so
        // the first job that does not fit blocks everything behind it.
        if let Some(p) = next_pass {
            if p <= now + 1e-12 {
                let mut to_start: Vec<PendKey> = Vec::new();
                let mut trial_free = free;
                for &key in pending.iter() {
                    let c = &chains[key.chain];
                    if c.gpus <= trial_free {
                        trial_free -= c.gpus;
                        to_start.push(key);
                    } else {
                        break; // head-of-line: no backfill past a blocked job
                    }
                }
                for key in to_start {
                    pending.remove(&key);
                    let c = &chains[key.chain];
                    free -= c.gpus;
                    let hold = f64::from_bits(key.hold_bits);
                    let submit = f64::from_bits(key.submit_bits);
                    let fate = match oracle {
                        Some(o) => o.fate(c, key.seg, key.retry, now, hold),
                        None => SegmentFate::Complete,
                    };
                    match fate {
                        SegmentFate::Complete => {
                            out[key.chain].segments.push(SegmentOutcome {
                                start_s: now,
                                end_s: now + hold,
                                queue_wait_s: now - submit,
                                interrupted: false,
                                lost_train_s: 0.0,
                            });
                            completions.push(Reverse(Ev {
                                t: F64Ord(now + hold),
                                id: key.id,
                                chain: key.chain,
                                seg: key.seg,
                                retry: key.retry,
                                hold: F64Ord(0.0),
                                is_retry: false,
                            }));
                        }
                        SegmentFate::Interrupt { after_s, lost_train_s, retry_hold_s } => {
                            let after = after_s.clamp(0.0, hold);
                            out[key.chain].segments.push(SegmentOutcome {
                                start_s: now,
                                end_s: now + after,
                                queue_wait_s: now - submit,
                                interrupted: true,
                                lost_train_s,
                            });
                            completions.push(Reverse(Ev {
                                t: F64Ord(now + after),
                                id: key.id,
                                chain: key.chain,
                                seg: key.seg,
                                retry: key.retry,
                                hold: F64Ord(retry_hold_s.max(0.0)),
                                is_retry: true,
                            }));
                        }
                    }
                }
                next_pass = None;
            }
        }
    }
    out
}
