//! `bootseer` CLI — leader entrypoint.
//!
//! Subcommands:
//!   figures [--out DIR]          regenerate every paper figure's data
//!   startup --gpus N [...]       simulate one job startup, print stages
//!   trace [--jobs N] [...]       synthesize + replay a cluster week
//!   optimize [--seed S] [...]    closed-loop mitigation search (batched
//!                                what-if replay → Pareto frontier)
//!   train [--steps N] [...]      run real training over the AOT artifacts
//!                                (requires the `pjrt` feature)
//!   version

use bootseer::config::{BootseerConfig, CachePolicy, ClusterConfig, JobConfig, OverlapMode};
use bootseer::faults::FaultConfig;
use bootseer::figures;
use bootseer::startup::{run_startup, StartupKind, World};
use bootseer::trace::{gen_trace, replay_cluster, ReplayOptions};
use bootseer::util::{human, stats};
use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let code = match cmd {
        "figures" => cmd_figures(rest),
        "startup" => cmd_startup(rest),
        "trace" => cmd_trace(rest),
        "optimize" => cmd_optimize(rest),
        "train" => cmd_train(rest),
        "version" => {
            println!("bootseer {}", bootseer::version());
            0
        }
        _ => {
            eprintln!(
                "usage: bootseer <figures|startup|trace|optimize|train|version> [options]\n\
                 \n  figures [--out DIR]            regenerate paper figures (1,3,4,5,6,7,12,13,14,16) + overlap/artifact sweeps\
                 \n  startup --gpus N [--bootseer] [--hot-update] [--overlap sequential|overlapped|speculative]\
                 \n          [--dedup] [--delta-resume] [--seed S]\
                 \n  trace   [--jobs N] [--seed S] [--pool-gpus G] [--threads T] [--epochs E] [--bootseer]\
                 \n          [--overlap M] [--dedup] [--delta-resume] [--faults off|paper|storm|k=v,...]\
                 \n          [--no-replay] [--cache-capacity BYTES|Ng|unbounded] [--cache-policy lru|gdsf|pin]\
                 \n          [--racks R] [--spine-oversub F]\
                 \n  optimize [--seed S] [--threads T] [--quick] [--out FILE]\
                 \n          seeded successive-halving search over the mitigation knob space\
                 \n  train   [--steps N] [--artifacts DIR] [--seed S]   (pjrt feature)"
            );
            2
        }
    };
    std::process::exit(code);
}

fn flag(rest: &[String], name: &str) -> bool {
    rest.iter().any(|a| a == name)
}

fn opt(rest: &[String], name: &str) -> Option<String> {
    rest.iter().position(|a| a == name).and_then(|i| rest.get(i + 1).cloned())
}

/// `--overlap MODE` (default Sequential); exits with an error on a bad mode.
fn overlap_opt(rest: &[String]) -> Result<OverlapMode, String> {
    match opt(rest, "--overlap") {
        None => Ok(OverlapMode::Sequential),
        Some(s) => OverlapMode::parse(&s)
            .ok_or_else(|| format!("bad --overlap {s:?} (sequential|overlapped|speculative)")),
    }
}

/// `--cache-capacity` value: raw bytes, `Ng`/`Ngb` gigabytes (decimal,
/// 1 GB = 1e9 bytes), or `unbounded` (the default).
fn parse_capacity(s: &str) -> Option<u64> {
    let t = s.trim().to_ascii_lowercase();
    if t == "unbounded" {
        return Some(u64::MAX);
    }
    if let Some(num) = t.strip_suffix("gb").or_else(|| t.strip_suffix('g')) {
        return num.parse::<f64>().ok().filter(|v| *v >= 0.0).map(|v| (v * 1e9) as u64);
    }
    t.parse::<u64>().ok()
}

/// Artifact-layer feature flags shared by `startup` and `trace`:
/// `--dedup` (cross-artifact dedup) and `--delta-resume` (delta
/// checkpoint resume on warm restarts).
fn artifact_flags(rest: &[String], base: BootseerConfig) -> BootseerConfig {
    BootseerConfig {
        artifact_dedup: base.artifact_dedup || flag(rest, "--dedup"),
        delta_resume: base.delta_resume || flag(rest, "--delta-resume"),
        ..base
    }
}

fn cmd_figures(rest: &[String]) -> i32 {
    let out = opt(rest, "--out").map(PathBuf::from);
    if let Some(d) = &out {
        if let Err(e) = std::fs::create_dir_all(d) {
            eprintln!("cannot create {d:?}: {e}");
            return 1;
        }
    }
    let save = |name: &str, json: bootseer::util::json::Json| {
        if let Some(d) = &out {
            let p = d.join(format!("{name}.json"));
            if let Err(e) = std::fs::write(&p, json.to_pretty()) {
                eprintln!("write {p:?}: {e}");
            }
        }
    };
    println!("== week trace replay (figs 1, 3, 4, 5) ==");
    let r = figures::week_replay(1);
    let f1 = figures::fig01(&r);
    println!("-- Fig 1 --\n{}", f1.render());
    save("fig01", f1.to_json());
    let f3 = figures::fig03(&r);
    println!("-- Fig 3a/3b --\n{}", f3.render());
    save("fig03", f3.to_json());
    let f4 = figures::fig04(&r);
    println!("-- Fig 4 --\n{}", f4.render());
    save("fig04", f4.to_json());
    let f5 = figures::fig05(&r);
    println!("-- Fig 5 --\n{}", f5.render());
    save("fig05", f5.to_json());
    let f6 = figures::fig06(5);
    println!("-- Fig 6 --\n{}", f6.render());
    save("fig06", f6.to_json());
    let f7 = figures::fig07(2);
    println!("-- Fig 7 --\n{}", f7.render());
    save("fig07", f7.to_json());
    let f12 = figures::fig12(3);
    println!("-- Fig 12 --\n{}", f12.render());
    save("fig12", f12.to_json());
    println!("-- Fig 13 --\n{}", f12.render_stages());
    save("fig13", f12.stages_json());
    let f14 = figures::fig14(3);
    println!("-- Fig 14 --\n{}", f14.render());
    save("fig14", f14.to_json());
    let ov = figures::overlap_sweep(3);
    println!("-- Overlap-mode sweep (stage graph) --\n{}", ov.render());
    save("overlap", ov.to_json());
    let fa = figures::artifact_sweep(1);
    println!("-- Artifact-layer sweep (cold/warm/delta/dedup) --\n{}", fa.render());
    save("artifact", fa.to_json());
    let fw = figures::wasted_gpu_time_sweep(
        figures::FAULTS_SWEEP_SEED,
        figures::FAULTS_SWEEP_JOBS,
        &FaultConfig::paper(),
    );
    println!("-- Fig 16: wasted GPU time under fault injection --\n{}", fw.render());
    save("fig16", fw.to_json());
    let ft = figures::fragmentation_sweep(7);
    println!("-- Topology fragmentation sweep (startup vs gang spread) --\n{}", ft.render());
    save("topology", ft.to_json());
    let fc = figures::cache_economics_sweep(
        figures::FAULTS_SWEEP_SEED,
        figures::CACHE_SWEEP_JOBS,
        &figures::cache_sweep_faults(),
    );
    println!("-- Cache-economics sweep (capacity knee) --\n{}", fc.render());
    save("cache_econ", fc.to_json());
    let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
    let fo = figures::optimize_frontier(figures::FAULTS_SWEEP_SEED, 0, fast);
    println!("-- Optimize frontier (closed-loop mitigation search) --\n{}", fo.render());
    save("optimize", fo.to_json());
    0
}

fn cmd_optimize(rest: &[String]) -> i32 {
    let seed: u64 = opt(rest, "--seed").and_then(|s| s.parse().ok()).unwrap_or(11);
    let threads: usize = opt(rest, "--threads").and_then(|s| s.parse().ok()).unwrap_or(0);
    let mut params = if flag(rest, "--quick") {
        bootseer::optimize::OptimizeParams::quick(seed, threads)
    } else {
        bootseer::optimize::OptimizeParams::canonical(seed, threads)
    };
    if let Some(k) = opt(rest, "--survivors").and_then(|s| s.parse().ok()) {
        params.survivors = k;
    }
    let n = params.space.candidates().len();
    println!(
        "optimize: {} candidates, screen {} jobs / {:.1} days → {} survivors at {} jobs / {:.1} days",
        n,
        params.screen.jobs,
        params.screen.horizon_s / 86400.0,
        params.survivors.clamp(1, n.max(1)),
        params.full.jobs,
        params.full.horizon_s / 86400.0,
    );
    let t0 = std::time::Instant::now();
    let report = bootseer::optimize::run_optimize(&params);
    println!("{}", report.render());
    println!("search wall time: {}", human::secs(t0.elapsed().as_secs_f64()));
    if let Some(path) = opt(rest, "--out") {
        if let Err(e) = std::fs::write(&path, report.to_json().to_pretty()) {
            eprintln!("write {path:?}: {e}");
            return 1;
        }
        println!("frontier written to {path}");
    }
    0
}

fn cmd_startup(rest: &[String]) -> i32 {
    let gpus: u32 = opt(rest, "--gpus").and_then(|s| s.parse().ok()).unwrap_or(128);
    let seed: u64 = opt(rest, "--seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    let boot = flag(rest, "--bootseer");
    let kind = if flag(rest, "--hot-update") { StartupKind::HotUpdate } else { StartupKind::Full };
    let overlap = match overlap_opt(rest) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let base = if boot { BootseerConfig::bootseer() } else { BootseerConfig::baseline() };
    let cfg = artifact_flags(rest, BootseerConfig { overlap, ..base });
    let job = JobConfig::paper_moe(gpus);
    let cluster = ClusterConfig::default();
    let mut world = World::new();
    if boot {
        // Warm run to record hot set + create env cache.
        run_startup(1, 0, &cluster, &job, &cfg, &mut world, StartupKind::Full, seed);
    }
    let o = run_startup(1, 1, &cluster, &job, &cfg, &mut world, kind, seed + 1);
    println!(
        "job: {} gpus ({} nodes), {}, {} stage graph, image {}, ckpt {}",
        gpus,
        o.nodes,
        if boot { "BOOTSEER" } else { "baseline" },
        cfg.overlap.name(),
        human::bytes(job.image_bytes),
        human::bytes(job.ckpt_bytes)
    );
    let mut rows = vec![vec![
        "stage".to_string(),
        "begin".to_string(),
        "end".to_string(),
        "duration".to_string(),
    ]];
    for (s, b, e) in &o.stage_spans {
        rows.push(vec![s.name().to_string(), human::secs(*b), human::secs(*e), human::secs(e - b)]);
    }
    println!("{}", human::table(&rows));
    println!(
        "total (submit→training): {} | worker phase: {} | GPU-seconds wasted: {:.0}",
        human::secs(o.total_s),
        human::secs(o.worker_phase_s),
        o.gpu_seconds_wasted()
    );
    0
}

fn cmd_trace(rest: &[String]) -> i32 {
    let jobs: usize = opt(rest, "--jobs").and_then(|s| s.parse().ok()).unwrap_or(2000);
    let seed: u64 = opt(rest, "--seed").and_then(|s| s.parse().ok()).unwrap_or(1);
    let pool_gpus: Option<u32> = opt(rest, "--pool-gpus").and_then(|s| s.parse().ok());
    let threads: usize = opt(rest, "--threads").and_then(|s| s.parse().ok()).unwrap_or(0);
    // Replay-timeline epochs; 0 (default) auto-shards one epoch per
    // simulated day. A pure performance knob — byte-identical output.
    let epochs: usize = opt(rest, "--epochs").and_then(|s| s.parse().ok()).unwrap_or(0);
    let overlap = match overlap_opt(rest) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let faults = match opt(rest, "--faults") {
        None => FaultConfig::off(),
        Some(spec) => match FaultConfig::parse(&spec) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    let cache_capacity = match opt(rest, "--cache-capacity") {
        None => None,
        Some(s) => match parse_capacity(&s) {
            Some(v) => Some(v),
            None => {
                eprintln!("bad --cache-capacity {s:?} (bytes, `Ng`/`Ngb`, or `unbounded`)");
                return 2;
            }
        },
    };
    let cache_policy = match opt(rest, "--cache-policy") {
        None => None,
        Some(s) => match CachePolicy::parse(&s) {
            Some(p) => Some(p),
            None => {
                eprintln!("bad --cache-policy {s:?} (lru|gdsf|pin)");
                return 2;
            }
        },
    };
    // Hierarchical-topology overrides (see docs/topology.md): both default
    // to the config's flat values, where the tree is inert.
    let racks: Option<u32> = opt(rest, "--racks").and_then(|s| s.parse().ok());
    let spine_oversub: Option<f64> = opt(rest, "--spine-oversub").and_then(|s| s.parse().ok());
    // Speculative staging needs warm state (hot-set records, env caches) to
    // know what to stage, i.e. the BootSeer feature set.
    let boot = flag(rest, "--bootseer");
    if overlap == OverlapMode::Speculative && !boot {
        eprintln!(
            "note: --overlap speculative stages nothing without --bootseer (no records/caches)"
        );
    }
    let t = gen_trace(seed, jobs, 7.0 * 86400.0);
    let gpus: u64 = t.iter().map(|j| j.gpus as u64).sum();
    let startups: u64 = t.iter().map(|j| (j.full_startups + j.hot_updates) as u64).sum();
    println!(
        "trace: {} jobs, {} GPUs requested in total, {} startups over one week",
        t.len(),
        gpus,
        startups
    );
    for &(lo, hi, label) in &bootseer::trace::SCALE_BUCKETS {
        let n = t.iter().filter(|j| j.gpus >= lo && j.gpus <= hi).count();
        println!("  {label:>9}: {n} jobs");
    }
    if flag(rest, "--no-replay") {
        return 0;
    }
    let n_threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    println!(
        "\nreplaying the week ({n_threads} threads, {} config, {} stage graph, faults: {})...",
        if boot { "bootseer" } else { "baseline" },
        overlap.name(),
        faults.describe()
    );
    let t0 = std::time::Instant::now();
    let base = if boot { BootseerConfig::bootseer() } else { BootseerConfig::baseline() };
    let faults_on = faults.enabled();
    let cfg = artifact_flags(rest, BootseerConfig { overlap, ..base });
    // One override path: every CLI knob folds into the ReplayOptions
    // builder, and `replay_cluster` resolves it against the configs once.
    let mut opts = ReplayOptions::new()
        .with_pool_gpus(pool_gpus)
        .with_threads(threads)
        .with_faults(faults)
        .with_epochs(epochs);
    opts.cache_capacity = cache_capacity;
    opts.cache_policy = cache_policy;
    if let Some(r) = racks {
        opts = opts.with_racks(r);
    }
    if let Some(f) = spine_oversub {
        opts = opts.with_spine_oversub(f);
    }
    let cluster = ClusterConfig::default();
    let (_, eff_cfg) = opts.resolve(&cluster, &cfg);
    let r = replay_cluster(&t, &cluster, &cfg, seed, &opts);
    let wall = t0.elapsed().as_secs_f64();
    if !r.queue_waits.is_empty() {
        println!(
            "pool: {} GPUs | queue wait: median {} p90 {} max {} (scheduler-derived)",
            r.pool_gpus,
            human::secs(stats::median(&r.queue_waits)),
            human::secs(stats::quantile(&r.queue_waits, 0.9)),
            human::secs(stats::max(&r.queue_waits)),
        );
    }
    println!(
        "GPU-hours: training {:.0}, startup {:.0} → startup fraction {:.2}%",
        r.train_gpu_hours,
        r.startup_gpu_hours,
        100.0 * r.startup_fraction()
    );
    if faults_on {
        println!(
            "faults: {} generated restarts | rollback {:.0} GPU-h | wasted (startup+rollback) {:.2}%",
            r.fault_restarts,
            r.lost_train_gpu_hours,
            100.0 * r.wasted_fraction()
        );
    }
    if eff_cfg.cache_capacity_bytes != u64::MAX || r.shed_checks > 0 {
        println!(
            "cache: {} policy, hit rate {:.1}% ({} / {} demanded) | evicted {} | shed rate {:.1}% ({}/{} governed fetches)",
            eff_cfg.cache_policy.name(),
            100.0 * r.hit_rate(),
            human::bytes(r.credited_bytes),
            human::bytes(r.demanded_bytes),
            human::bytes(r.evicted_bytes),
            100.0 * r.shed_rate(),
            r.shed_events,
            r.shed_checks
        );
    }
    println!("replayed {} startups in {}", startups, human::secs(wall));
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_rest: &[String]) -> i32 {
    eprintln!(
        "the `train` subcommand needs the PJRT runtime: rebuild with\n\
         `cargo build --release --features pjrt` (requires the xla crate; see README)"
    );
    1
}

#[cfg(feature = "pjrt")]
fn cmd_train(rest: &[String]) -> i32 {
    use bootseer::trainer::{SyntheticCorpus, Trainer};
    let steps: u64 = opt(rest, "--steps").and_then(|s| s.parse().ok()).unwrap_or(100);
    let seed: i32 = opt(rest, "--seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    let dir = PathBuf::from(opt(rest, "--artifacts").unwrap_or_else(|| "artifacts".to_string()));
    if !dir.join("meta.json").exists() {
        eprintln!("no artifacts at {dir:?}; run `make artifacts` first");
        return 1;
    }
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("PJRT: {e:?}");
            return 1;
        }
    };
    let mut t = match Trainer::new(&client, &dir, seed) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trainer: {e:#}");
            return 1;
        }
    };
    println!(
        "model: {} params, vocab {}, {} layers, {} experts, batch {}x{}",
        t.meta.n_params, t.meta.vocab, t.meta.n_layers, t.meta.n_experts, t.meta.batch, t.meta.seq
    );
    let mut corpus = SyntheticCorpus::new(t.meta.vocab, 0.05, 7);
    let t0 = std::time::Instant::now();
    for s in 1..=steps {
        let (tok, tgt) = corpus.batch(t.meta.batch, t.meta.seq);
        let loss = t.train_step(&tok, &tgt).expect("train step");
        if s % 10 == 0 || s == 1 || s == steps {
            println!("step {s:>5}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("{} steps in {} ({:.1} steps/s)", steps, human::secs(dt), steps as f64 / dt);
    let first = t.loss_log.first().map(|&(_, l)| l).unwrap_or(0.0);
    let last = t.loss_log.last().map(|&(_, l)| l).unwrap_or(0.0);
    println!("loss: {first:.4} → {last:.4}");
    0
}
