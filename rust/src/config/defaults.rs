//! Calibrated simulator constants.
//!
//! These are the free parameters of the cluster model, set so the *baseline*
//! system lands inside the bands the paper reports (§3.2, §5):
//!
//!   - Image Loading (lazy baseline):   20–40 s
//!   - Environment Setup (baseline):    100–300 s
//!   - Model Initialization (baseline): 100–200 s
//!   - Resource Queuing:                ~100 s median, hours in the tail
//!   - Straggler Max/Median:            ~1.0 small jobs → ~1.5 at 1,000+ GPUs
//!
//! and so BootSeer's improvements match the paper's reported factors
//! (image 4–10x, env 2x, model-init 1.6x, end-to-end ~2x). EXPERIMENTS.md
//! records where each figure actually lands.

/// Bytes in one decimal gigabyte.
pub const GB: u64 = 1_000_000_000;
/// Bytes in one decimal megabyte.
pub const MB: u64 = 1_000_000;

// ---- Workload constants straight from the paper (§5.1) ----

/// Container image size for the MoE job: 28.62 GB.
pub const PAPER_IMAGE_BYTES: u64 = 28_620 * MB;
/// Checkpoint size for the 8-layer, 128-expert MoE model: 413 GB.
pub const PAPER_CKPT_BYTES: u64 = 413 * GB;
/// Compressed environment cache size: 270 MB.
pub const PAPER_ENV_CACHE_BYTES: u64 = 270 * MB;
/// Record window for hot-block capture: 2 minutes.
pub const PAPER_RECORD_WINDOW_S: f64 = 120.0;
/// Background prefetch threads for cold blocks.
pub const PAPER_PREFETCH_THREADS: u32 = 8;
/// GPUs per server in the paper's fleet.
pub const GPUS_PER_NODE: u32 = 8;

// ---- HDFS / striping constants (§4.4) ----

/// HDFS block size: 512 MB ("typically 512 MB" per §4.4).
pub const HDFS_BLOCK_BYTES: u64 = 512 * MB;
/// Striped-FUSE chunk size: 1 MB.
pub const STRIPE_CHUNK_BYTES: u64 = MB;
/// Stripe width: 4 chunks → 4 MB stripes.
pub const STRIPE_WIDTH: u32 = 4;
/// HDFS replication factor.
pub const HDFS_REPLICATION: u32 = 3;

// ---- Calibrated network model ----
// A star topology: every node has a frontend NIC; shared services (registry,
// SCM, HDFS, cluster cache) have aggregate egress caps. RDMA/IB is NOT used
// during startup (paper §7 notes it sits idle), so these are the
// "management network" numbers.

/// Per-node frontend NIC bandwidth (bytes/s): 25 Gbit/s.
pub const NODE_NIC_BPS: f64 = 25.0e9 / 8.0;
/// Per-node local disk write bandwidth (bytes/s) for staging blocks.
pub const NODE_DISK_WRITE_BPS: f64 = 4.0e9;
/// Per-node local disk read bandwidth (bytes/s).
pub const NODE_DISK_READ_BPS: f64 = 6.0e9;

/// Container registry aggregate egress (bytes/s): 80 Gbit/s.
/// Sized so that ~16 nodes pulling a 28.6 GB image lazily (hot set only)
/// take 20–40 s, and full concurrent pulls at 100+ nodes are painful.
pub const REGISTRY_EGRESS_BPS: f64 = 80.0e9 / 8.0;

/// Cluster-level block cache aggregate egress (bytes/s): 400 Gbit/s.
pub const CLUSTER_CACHE_EGRESS_BPS: f64 = 400.0e9 / 8.0;

/// SCM / package backend aggregate egress (bytes/s). Package distribution
/// is CDN/mirror-backed, so raw bandwidth is rarely the binding constraint;
/// the failure mode is request-rate limiting (admission latency + reject).
pub const SCM_EGRESS_BPS: f64 = 200.0e9;
/// Per-package admission latency against the SCM backend (seconds) at low
/// concurrency (metadata, auth, index resolution).
pub const SCM_ADMIT_BASE_S: f64 = 0.2;
/// Admission latency multiplier per concurrent node above the throttle
/// threshold (request-rate limiting; §3.4's NCCL incident where 6 s pulls
/// became 90 s under >1,000-node concurrency).
pub const SCM_ADMIT_PENALTY: f64 = 0.01;
/// Concurrent-request threshold beyond which the SCM backend throttles
/// (§3.4: >1,000 simultaneous pulls triggered rate limiting; per-job it
/// kicks in much earlier because other tenants share the backend).
pub const SCM_THROTTLE_CONCURRENCY: u32 = 96;
/// Bandwidth-collapse severity past the threshold (mild; the dominant
/// throttle effect is admission latency above).
pub const SCM_THROTTLE_PENALTY: f64 = 0.003;
/// Per-package rejection probability per unit of overload excess
/// (concurrency/threshold - 1); rejected pulls back off and retry — the
/// §3.4 failure mode that killed a 2,016-GPU job.
pub const SCM_REJECT_PROB: f64 = 0.0008;
/// Backoff base for rejected package pulls (seconds).
pub const SCM_BACKOFF_S: f64 = 5.0;

/// HDFS DataNode count serving checkpoint traffic.
pub const HDFS_DATANODES: u32 = 64;
/// Per-DataNode egress (bytes/s): 10 Gbit/s.
pub const HDFS_DATANODE_EGRESS_BPS: f64 = 10.0e9 / 8.0;
/// NameNode metadata op latency (seconds) — per open/locate call.
pub const HDFS_NN_OP_S: f64 = 0.004;
/// Single-stream HDFS read throughput cap (bytes/s): one DFSInputStream
/// over one TCP connection to one DataNode. The reason the baseline
/// download-and-resume path is slow regardless of cluster capacity.
pub const HDFS_STREAM_BPS: f64 = 1.6e9;
/// Parallel read streams per node with striped HDFS-FUSE (stripe width x
/// pipeline depth of in-flight chunk fetches).
pub const STRIPE_PARALLEL_STREAMS: u32 = 16;

// ---- Environment setup model ----

/// Number of runtime-installed packages for a typical large training job.
pub const ENV_PACKAGES: u32 = 24;
/// Mean package download size (bytes); NCCL-sized outliers included via the
/// lognormal sigma.
pub const ENV_PKG_MEAN_BYTES: u64 = 60 * MB;
/// Lognormal sigma of package sizes.
pub const ENV_PKG_SIGMA: f64 = 1.1;
/// CPU cost of installing (unpack + build) per package, mean seconds.
pub const ENV_INSTALL_CPU_MEAN_S: f64 = 4.5;
/// Fixed daemon/health-check time in Environment Setup (seconds), grows
/// slowly with job scale due to synchronization (§5.3 observes the 64→128
/// GPU jump).
pub const ENV_DAEMON_BASE_S: f64 = 55.0;
pub const ENV_DAEMON_PER_NODE_S: f64 = 1.2;

/// Daemon/health-check synchronization cost for an `n`-node job. Linear at
/// small scale (the visible 64→128 GPU bump in §5.3) but saturating —
/// production rendezvous is tree-structured, not all-to-all.
pub fn env_daemon_sync_s(n: usize) -> f64 {
    let n = n as f64;
    ENV_DAEMON_PER_NODE_S * n.min(48.0) + 10.0 * (1.0 + n / 48.0).ln()
}

/// Rank-launch/RDMA-setup synchronization for an `n`-node job (same
/// saturating shape).
pub fn model_init_sync_s(n: usize) -> f64 {
    let n = n as f64;
    MODEL_INIT_PER_NODE_S * n.min(64.0) + 12.0 * (1.0 + n / 64.0).ln()
}
/// Env-cache restore unpack throughput (bytes/s, archive decompress to disk).
pub const ENV_CACHE_UNPACK_BPS: f64 = 500.0e6;
/// Env-cache creation: compress+snapshot throughput on node 0 (bytes/s).
pub const ENV_CACHE_PACK_BPS: f64 = 100.0e6;

// ---- Model initialization model ----

/// Non-checkpoint model-init time (process launch, parallel groups, RDMA
/// connection setup), base seconds.
pub const MODEL_INIT_BASE_S: f64 = 38.0;
/// Per-node addition to model-init synchronization.
pub const MODEL_INIT_PER_NODE_S: f64 = 0.25;

// ---- Image model ----

/// Fraction of image bytes that are "hot" (touched during startup).
/// Slacker [15] reports ~6.4%; we use 7%.
pub const IMAGE_HOT_FRACTION: f64 = 0.07;
/// Image block size used by the flattened block-level layout.
pub const IMAGE_BLOCK_BYTES: u64 = 4 * MB;
/// Lazy-loading overhead per on-demand block miss (seconds): FUSE context
/// switch + RPC to the cache/registry, before bandwidth. Dominates the lazy
/// baseline at small scale (≈500 hot blocks × ~45 ms ≈ 23 s → the paper's
/// 20–40 s band).
pub const LAZY_MISS_LATENCY_S: f64 = 0.045;
/// Per-concurrent-node multiplier on miss latency: N nodes faulting against
/// the shared block service queue its IOPS, so per-miss latency grows
/// ~linearly with job size (the §5.3 explanation for why the baseline image
/// stage degrades 4–10x with scale while BootSeer stays flat).
pub const LAZY_CONTENTION_PENALTY: f64 = 0.055;
/// Misses are simulated in batches of this many blocks to bound event count
/// at 1,000+ node scale (pure aggregation, not a behavioural knob).
pub const LAZY_MISS_BATCH_BLOCKS: u32 = 16;
/// Container start (runtime init, mounts) once hot data is present.
pub const CONTAINER_START_S: f64 = 3.0;
/// Per-node byte budget for speculative staging during the Allocation
/// phase (`OverlapMode::Speculative`): enough for the paper image's hot
/// set (~2 GB) plus the env cache archive (270 MB), small enough that the
/// scheduler's allocation-phase dead time is not saturated by one job.
pub const SPEC_PREFETCH_BUDGET_BYTES: u64 = 4 * GB;
// ---- Artifact layer (content-addressed transfer plane) ----

/// Chunking of the env snapshot archive in its artifact manifest (matches
/// the image block size, so duplicated content lines up block-for-block).
pub const ENV_SNAPSHOT_CHUNK_BYTES: u64 = 4 * MB;
/// Fraction of env-snapshot chunks whose content duplicates blocks already
/// present in the image's hot runtime region (installed site-packages
/// overlapping libraries shipped in the image — the overlap the real-bytes
/// blockstore measures). Exploited only under `bootseer.artifact_dedup`.
pub const ENV_IMAGE_SHARED_FRACTION: f64 = 0.30;
/// Chunking of a checkpoint resume shard in its artifact manifest.
pub const CKPT_CHUNK_BYTES: u64 = 64 * MB;
/// Fraction of a resume shard's chunks rewritten since a restarted
/// attempt's locally resident copy (optimizer/param updates between the
/// crash's rollback point and the resident snapshot). A delta resume
/// (`bootseer.delta_resume`) refetches only these.
pub const CKPT_DELTA_CHANGED_FRACTION: f64 = 0.35;

// ---- Bounded caches & registry load-shedding (cache economics) ----

/// Per-node artifact-cache capacity (bytes). `u64::MAX` = unbounded, the
/// assumption every figure before the cache-economics sweep made; the
/// sweep bounds it and measures the knee.
pub const CACHE_CAPACITY_BYTES: u64 = u64::MAX;
/// Smallest foreign-churn artifact a node's bounded cache absorbs between
/// two attempts of the same job (other tenants' images, datasets, logs
/// landing on the shared local disk while the job was down).
pub const CACHE_CHURN_MIN_BYTES: u64 = GB;
/// Churn spread: churn bytes are log-uniform over
/// `CACHE_CHURN_MIN_BYTES × 2^[0, CACHE_CHURN_DOUBLINGS)` — 1–32 GB, a
/// heavy right tail against the ~2.3 GB hot-set + env working set, so
/// sweeping capacity from a few GB to unbounded traces out a knee.
pub const CACHE_CHURN_DOUBLINGS: f64 = 5.0;
/// Backoff base for a load-shed artifact fetch (seconds); doubles per
/// shed attempt with a seeded jitter (mirrors `SCM_BACKOFF_S`).
pub const SHED_BACKOFF_S: f64 = 5.0;
/// Attempts after which a fetch is always admitted regardless of
/// overload (the terminal attempt never sheds).
pub const SHED_MAX_RETRIES: u32 = 3;
/// Registry concurrency slots under the `storm` fault preset, in node
/// entitlements (cf. `FLEET_SERVICE_NODES`): restart storms exceed this,
/// shedding and delaying image pulls.
pub const STORM_REGISTRY_SLOTS: u32 = 64;
/// Cluster-cache concurrency slots under the `storm` fault preset.
pub const STORM_CACHE_SLOTS: u32 = 96;

/// Traditional OCI pull decompress+unpack throughput per node (bytes/s).
/// Layer extraction is CPU-bound and single-streamed in containerd — the
/// dominant cost of the OCI strawman and the reason flattened block images
/// win "up to 10x" (§4.2).
pub const OCI_UNPACK_BPS: f64 = 180.0e6;

// ---- Scheduler model (§3.2: queuing ~100 s median, tail to hours) ----

/// Lognormal mu of queue wait seconds. Used only by the *standalone*
/// single-job startup path (`startup::run_startup`); the cluster replay
/// derives queue waits from `scheduler::schedule_chains` over a finite pool.
pub const QUEUE_WAIT_MU: f64 = 4.4; // median ≈ 81 s
/// Lognormal sigma of queue wait (standalone path only; see above).
pub const QUEUE_WAIT_SIGMA: f64 = 1.4;
/// Resource allocation cost (seconds): "trivial, a few seconds".
pub const ALLOC_BASE_S: f64 = 2.0;

/// Scheduling-round cadence (seconds): the quota scheduler batches
/// allocation decisions into periodic passes, so even an uncontended job
/// waits ~U[0, round] — the structural source of the §3.2 "~100 s median"
/// queue wait. Contention (a busy pool, head-of-line blocking) produces the
/// hour-long tail on top.
pub const SCHED_ROUND_S: f64 = 200.0;

/// Target pool utilization when the cluster replay auto-sizes its GPU pool
/// from trace demand (production clusters run hot; below saturation but
/// close enough that bursts queue).
pub const POOL_TARGET_UTILIZATION: f64 = 0.70;

/// Fleet shared-service capacity, expressed in "node entitlements": the
/// registry / cluster cache / HDFS tier is provisioned to serve this many
/// concurrently-starting nodes at full per-node rate. When the set of
/// concurrently starting jobs exceeds it, every starter's share of the
/// shared services degrades proportionally (the §3 scale effect the
/// per-job-isolated replay could not express).
pub const FLEET_SERVICE_NODES: u32 = 256;

/// Cost of relocating a warm restart across the full cluster diameter
/// (seconds): re-registering with the far rack's ToR, rebinding RDMA
/// endpoints and re-mounting node-local state. The per-restart charge is
/// this scaled by the placement distance fraction
/// (`scheduler::placement_distance / nodes`), so an in-place restart pays
/// nothing and a whole-job migration across racks pays the full cost.
pub const RELOCATION_COST_S: f64 = 15.0;

/// Epoch span (seconds) the replay timeline auto-shards into when
/// `ReplayOptions::epochs` is 0: one epoch per simulated day. Epochs bound
/// the per-epoch prep memo tables and contention-scan subranges and give
/// the parallel phase a locality-friendly issue order; the cross-epoch
/// handoff fold keeps the result byte-identical at ANY epoch count, so
/// this is purely a performance knob.
pub const REPLAY_EPOCH_SPAN_S: f64 = 86_400.0;

/// Upper bound on auto-derived replay epochs (a fleet-*year* horizon, plus
/// one slack epoch for schedule overrun past day 365).
pub const REPLAY_MAX_EPOCHS: usize = 366;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants_exact() {
        assert_eq!(PAPER_IMAGE_BYTES, 28_620_000_000);
        assert_eq!(PAPER_CKPT_BYTES, 413_000_000_000);
        assert_eq!(PAPER_ENV_CACHE_BYTES, 270_000_000);
        assert_eq!(HDFS_BLOCK_BYTES, 512_000_000);
        assert_eq!(STRIPE_CHUNK_BYTES, 1_000_000);
        assert_eq!(STRIPE_WIDTH, 4);
    }

    #[test]
    fn queue_wait_median_near_100s() {
        // exp(mu) is the lognormal median; the paper says "around 100 s".
        let median = QUEUE_WAIT_MU.exp();
        assert!((60.0..150.0).contains(&median), "median {median}");
    }

    #[test]
    fn nic_slower_than_disk() {
        // Block staging is network-bound, as in the paper's clusters.
        assert!(NODE_NIC_BPS < NODE_DISK_WRITE_BPS);
    }
}
