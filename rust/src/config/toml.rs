//! TOML-subset parser for config files (no serde/toml crates offline).
//!
//! Supported grammar — the subset real configs in this repo use:
//!   - `[section]` and `[section.sub]` headers
//!   - `key = value` with value ∈ {integer, float, bool, "string", array}
//!   - `#` comments, blank lines
//!   - arrays of homogeneous scalars: `[1, 2, 3]`, `["a", "b"]`
//!
//! Values are stored flattened as `section.sub.key` → `Value`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(x) => Some(x),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(x) => write!(f, "{x}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(x) => write!(f, "{x}"),
            Value::Str(x) => write!(f, "\"{x}\""),
            Value::Arr(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// A parsed config document: flattened dotted keys.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if entries.insert(full.clone(), val).is_some() {
                return Err(format!("line {}: duplicate key {full}", lineno + 1));
            }
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    /// Unsigned byte/size quantity: a present key is clamped at 0 (a
    /// negative byte count must never wrap into an effectively unlimited
    /// one); an absent key passes `default` through untouched, so
    /// `u64::MAX` sentinels like the unbounded cache capacity survive.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        match self.get(key).and_then(Value::as_i64) {
            Some(v) => crate::util::cast::u64_from_i64_clamped(v),
            None => default,
        }
    }

    /// Unsigned count: a present key is clamped into `0..=u32::MAX`
    /// instead of bit-truncated; an absent key passes `default` through.
    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        match self.get(key).and_then(Value::as_i64) {
            Some(v) => crate::util::cast::u32_from_i64_clamped(v),
            None => default,
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// Keys not consumed by any accessor — used to flag typos in configs.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".to_string());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Arr(Vec::new()));
        }
        let items: Result<Vec<Value>, String> = split_top_level(inner)
            .into_iter()
            .map(|part| parse_value(part.trim()))
            .collect();
        return Ok(Value::Arr(items?));
    }
    // Numbers: underscores permitted as separators.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        cleaned
            .parse::<f64>()
            .map(Value::Float)
            .map_err(|_| format!("bad value: {s}"))
    } else {
        cleaned
            .parse::<i64>()
            .map(Value::Int)
            .map_err(|_| format!("bad value: {s}"))
    }
}

/// Split on commas not inside quotes (arrays are flat, no nesting needed).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Doc::parse(
            r#"
            # top comment
            top = 1
            [cluster]
            nodes = 16          # trailing comment
            nic_gbps = 25.0
            name = "h800-pool"
            enabled = true
            [cluster.hdfs]
            block_mb = 512
            "#,
        )
        .unwrap();
        assert_eq!(doc.i64_or("top", 0), 1);
        assert_eq!(doc.i64_or("cluster.nodes", 0), 16);
        assert_eq!(doc.f64_or("cluster.nic_gbps", 0.0), 25.0);
        assert_eq!(doc.str_or("cluster.name", ""), "h800-pool");
        assert!(doc.bool_or("cluster.enabled", false));
        assert_eq!(doc.i64_or("cluster.hdfs.block_mb", 0), 512);
    }

    #[test]
    fn arrays() {
        let doc = Doc::parse(r#"xs = [1, 2, 3]
names = ["a", "b,c"]"#).unwrap();
        match doc.get("xs").unwrap() {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
        match doc.get("names").unwrap() {
            Value::Arr(v) => {
                assert_eq!(v[1].as_str().unwrap(), "b,c");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn underscores_in_numbers() {
        let doc = Doc::parse("n = 28_620_000_000").unwrap();
        assert_eq!(doc.i64_or("n", 0), 28_620_000_000);
    }

    #[test]
    fn int_vs_float() {
        let doc = Doc::parse("a = 3\nb = 3.5\nc = 1e3").unwrap();
        assert_eq!(doc.get("a").unwrap(), &Value::Int(3));
        assert_eq!(doc.get("b").unwrap(), &Value::Float(3.5));
        assert_eq!(doc.get("c").unwrap(), &Value::Float(1000.0));
        // Int readable as f64.
        assert_eq!(doc.f64_or("a", 0.0), 3.0);
    }

    #[test]
    fn errors() {
        assert!(Doc::parse("[unterminated").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("k = ").is_err());
        assert!(Doc::parse("k = \"open").is_err());
        assert!(Doc::parse("k = 1\nk = 2").is_err());
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = Doc::parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }

    #[test]
    fn display_roundtrip() {
        let v = Value::Arr(vec![Value::Int(1), Value::Str("x".into())]);
        assert_eq!(v.to_string(), "[1, \"x\"]");
    }
}
