//! Typed configuration for the cluster model, the training job, and the
//! BootSeer feature set, loadable from a TOML-subset file (`toml.rs`) and
//! defaulting to the paper-calibrated constants (`defaults.rs`).

pub mod defaults;
pub mod toml;

use defaults as d;
use toml::Doc;

/// Which image-loading engine a run uses (paper §4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImageMode {
    /// Traditional OCI pull: download every byte before container start.
    OciFull,
    /// Block-level lazy loading (the paper's *baseline*).
    Lazy,
    /// BootSeer: record-and-prefetch hot blocks + background cold streaming.
    RecordPrefetch,
}

impl ImageMode {
    pub fn parse(s: &str) -> Option<ImageMode> {
        match s {
            "oci" | "oci_full" => Some(ImageMode::OciFull),
            "lazy" => Some(ImageMode::Lazy),
            "record_prefetch" | "bootseer" => Some(ImageMode::RecordPrefetch),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ImageMode::OciFull => "oci_full",
            ImageMode::Lazy => "lazy",
            ImageMode::RecordPrefetch => "record_prefetch",
        }
    }
}

/// How the worker-phase stages of the startup stage-graph are gated
/// relative to each other (see `docs/stage_graph.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapMode {
    /// Paper-faithful Figure 2: Image Loading → Env Setup → Model Init,
    /// each ending in a global sync barrier. Byte-identical to the
    /// pre-graph pipeline. The default.
    Sequential,
    /// Per-node chaining: a node starts Environment Setup as soon as its
    /// own image lands, and its checkpoint resume read starts streaming
    /// into the page cache then too; only training-begin still waits for
    /// every node. NIC contention between concurrently active stages is
    /// resolved by the max-min fair engine.
    Overlapped,
    /// Overlapped, plus speculative staging during the Allocation phase:
    /// nodes already granted begin pulling the image hot set and the env
    /// cache archive before the worker phase opens, bounded by
    /// `BootseerConfig::spec_prefetch_budget_bytes` per node.
    Speculative,
}

impl OverlapMode {
    pub const ALL: [OverlapMode; 3] =
        [OverlapMode::Sequential, OverlapMode::Overlapped, OverlapMode::Speculative];

    pub fn parse(s: &str) -> Option<OverlapMode> {
        match s {
            "sequential" | "seq" => Some(OverlapMode::Sequential),
            "overlapped" | "overlap" => Some(OverlapMode::Overlapped),
            "speculative" | "spec" => Some(OverlapMode::Speculative),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OverlapMode::Sequential => "sequential",
            OverlapMode::Overlapped => "overlapped",
            OverlapMode::Speculative => "speculative",
        }
    }
}

/// Which victim a bounded per-node artifact cache trims first when an
/// insert overflows `bootseer.cache_capacity_bytes` (see
/// `artifact::cache` and `docs/artifact_layer.md` §Bounded caches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    /// Least-recently-inserted artifact first (recency = insert order;
    /// the cache has no read clock). The default.
    Lru,
    /// Greedy-Dual-Size-Frequency: victim with the lowest
    /// `inflation + inserts / size_mb` priority — size-aware, so one huge
    /// cold artifact is trimmed before many small hot ones.
    Gdsf,
    /// LRU, but the job's image hot set is pinned and never evicted —
    /// churn lands on the env snapshot and checkpoint entries first.
    PinHotSet,
}

impl CachePolicy {
    pub const ALL: [CachePolicy; 3] = [CachePolicy::Lru, CachePolicy::Gdsf, CachePolicy::PinHotSet];

    pub fn parse(s: &str) -> Option<CachePolicy> {
        match s {
            "lru" => Some(CachePolicy::Lru),
            "gdsf" => Some(CachePolicy::Gdsf),
            "pin" | "pin_hot_set" => Some(CachePolicy::PinHotSet),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CachePolicy::Lru => "lru",
            CachePolicy::Gdsf => "gdsf",
            CachePolicy::PinHotSet => "pin_hot_set",
        }
    }

    /// Does this policy pin the image hot set on warm restarts?
    pub fn pins_hot_set(&self) -> bool {
        matches!(self, CachePolicy::PinHotSet)
    }
}

/// Physical cluster + shared-service model.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub nodes: u32,
    pub gpus_per_node: u32,
    /// Per-node frontend NIC bandwidth, bytes/s.
    pub node_nic_bps: f64,
    pub node_disk_write_bps: f64,
    pub node_disk_read_bps: f64,
    pub registry_egress_bps: f64,
    pub cluster_cache_egress_bps: f64,
    pub scm_egress_bps: f64,
    pub scm_throttle_concurrency: u32,
    pub scm_throttle_penalty: f64,
    pub scm_reject_prob: f64,
    pub scm_backoff_s: f64,
    pub hdfs_datanodes: u32,
    pub hdfs_datanode_egress_bps: f64,
    pub hdfs_block_bytes: u64,
    pub hdfs_replication: u32,
    pub hdfs_nn_op_s: f64,
    /// Node-slowdown straggler model.
    pub straggler_tail_prob: f64,
    pub straggler_body_std: f64,
    pub straggler_tail_alpha: f64,
    pub straggler_cap: f64,
    /// Fleet shared-service capacity in node entitlements (see
    /// `defaults::FLEET_SERVICE_NODES`); the cluster replay divides
    /// registry/cache/HDFS bandwidth among concurrently starting jobs once
    /// their aggregate node count exceeds this.
    pub fleet_service_nodes: u32,
    /// Rack count of the node → rack → spine tree. `1` (the default) is
    /// the flat star topology every figure before the topology layer used:
    /// no rack-uplink or spine-core pipes are created and startup traffic
    /// is byte-identical to the pre-topology pipeline.
    pub racks: u32,
    /// Spine-block count; racks are assigned to spines contiguously.
    pub spines: u32,
    /// Per-rack uplink (ToR → spine) capacity, bytes/s. `0.0` auto-sizes
    /// to `rack_size × node_nic_bps` (a non-blocking ToR).
    pub rack_uplink_bps: f64,
    /// Spine-core oversubscription ratio (≥ 1.0): the core carries
    /// `racks × rack_uplink / spine_oversub` when `spine_core_bps` is
    /// auto-sized.
    pub spine_oversub: f64,
    /// Spine-core (cross-rack aggregate) capacity, bytes/s. `0.0`
    /// auto-sizes from the rack uplinks and `spine_oversub`.
    pub spine_core_bps: f64,
    /// Relocation cost of a warm restart moved across the full cluster
    /// diameter, seconds (scaled by placement distance; see
    /// `defaults::RELOCATION_COST_S`).
    pub relocation_cost_s: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 16,
            gpus_per_node: d::GPUS_PER_NODE,
            node_nic_bps: d::NODE_NIC_BPS,
            node_disk_write_bps: d::NODE_DISK_WRITE_BPS,
            node_disk_read_bps: d::NODE_DISK_READ_BPS,
            registry_egress_bps: d::REGISTRY_EGRESS_BPS,
            cluster_cache_egress_bps: d::CLUSTER_CACHE_EGRESS_BPS,
            scm_egress_bps: d::SCM_EGRESS_BPS,
            scm_throttle_concurrency: d::SCM_THROTTLE_CONCURRENCY,
            scm_throttle_penalty: d::SCM_THROTTLE_PENALTY,
            scm_reject_prob: d::SCM_REJECT_PROB,
            scm_backoff_s: d::SCM_BACKOFF_S,
            hdfs_datanodes: d::HDFS_DATANODES,
            hdfs_datanode_egress_bps: d::HDFS_DATANODE_EGRESS_BPS,
            hdfs_block_bytes: d::HDFS_BLOCK_BYTES,
            hdfs_replication: d::HDFS_REPLICATION,
            hdfs_nn_op_s: d::HDFS_NN_OP_S,
            straggler_tail_prob: 0.01,
            straggler_body_std: 0.05,
            straggler_tail_alpha: 1.2,
            straggler_cap: 4.0,
            fleet_service_nodes: d::FLEET_SERVICE_NODES,
            racks: 1,
            spines: 1,
            rack_uplink_bps: 0.0,
            spine_oversub: 1.0,
            spine_core_bps: 0.0,
            relocation_cost_s: d::RELOCATION_COST_S,
        }
    }
}

impl ClusterConfig {
    /// Total GPU count.
    pub fn gpus(&self) -> u32 {
        self.nodes * self.gpus_per_node
    }

    /// Build a cluster of `nodes` nodes with otherwise default services.
    pub fn with_nodes(nodes: u32) -> ClusterConfig {
        ClusterConfig { nodes, ..ClusterConfig::default() }
    }

    pub fn from_doc(doc: &Doc) -> ClusterConfig {
        let base = ClusterConfig::default();
        ClusterConfig {
            nodes: doc.u32_or("cluster.nodes", base.nodes),
            gpus_per_node: doc.u32_or("cluster.gpus_per_node", base.gpus_per_node),
            node_nic_bps: doc.f64_or("cluster.node_nic_bps", base.node_nic_bps),
            node_disk_write_bps: doc
                .f64_or("cluster.node_disk_write_bps", base.node_disk_write_bps),
            node_disk_read_bps: doc.f64_or("cluster.node_disk_read_bps", base.node_disk_read_bps),
            registry_egress_bps: doc
                .f64_or("cluster.registry_egress_bps", base.registry_egress_bps),
            cluster_cache_egress_bps: doc
                .f64_or("cluster.cluster_cache_egress_bps", base.cluster_cache_egress_bps),
            scm_egress_bps: doc.f64_or("cluster.scm_egress_bps", base.scm_egress_bps),
            scm_throttle_concurrency: doc
                .u32_or("cluster.scm_throttle_concurrency", base.scm_throttle_concurrency),
            scm_throttle_penalty: doc
                .f64_or("cluster.scm_throttle_penalty", base.scm_throttle_penalty),
            scm_reject_prob: doc.f64_or("cluster.scm_reject_prob", base.scm_reject_prob),
            scm_backoff_s: doc.f64_or("cluster.scm_backoff_s", base.scm_backoff_s),
            hdfs_datanodes: doc.u32_or("cluster.hdfs_datanodes", base.hdfs_datanodes),
            hdfs_datanode_egress_bps: doc
                .f64_or("cluster.hdfs_datanode_egress_bps", base.hdfs_datanode_egress_bps),
            hdfs_block_bytes: doc.u64_or("cluster.hdfs_block_bytes", base.hdfs_block_bytes),
            hdfs_replication: doc.u32_or("cluster.hdfs_replication", base.hdfs_replication),
            hdfs_nn_op_s: doc.f64_or("cluster.hdfs_nn_op_s", base.hdfs_nn_op_s),
            straggler_tail_prob: doc
                .f64_or("cluster.straggler_tail_prob", base.straggler_tail_prob),
            straggler_body_std: doc.f64_or("cluster.straggler_body_std", base.straggler_body_std),
            straggler_tail_alpha: doc
                .f64_or("cluster.straggler_tail_alpha", base.straggler_tail_alpha),
            straggler_cap: doc.f64_or("cluster.straggler_cap", base.straggler_cap),
            fleet_service_nodes: doc.u32_or("cluster.fleet_service_nodes", base.fleet_service_nodes),
            racks: doc.u32_or("cluster.racks", base.racks).max(1),
            spines: doc.u32_or("cluster.spines", base.spines).max(1),
            rack_uplink_bps: doc.f64_or("cluster.rack_uplink_bps", base.rack_uplink_bps),
            spine_oversub: doc.f64_or("cluster.spine_oversub", base.spine_oversub).max(1.0),
            spine_core_bps: doc.f64_or("cluster.spine_core_bps", base.spine_core_bps),
            relocation_cost_s: doc
                .f64_or("cluster.relocation_cost_s", base.relocation_cost_s)
                .max(0.0),
        }
    }
}

/// One training job's startup-relevant parameters (paper §5.1 workload).
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub name: String,
    /// GPUs requested; nodes = gpus / gpus_per_node.
    pub gpus: u32,
    pub image_bytes: u64,
    pub image_hot_fraction: f64,
    pub image_block_bytes: u64,
    /// Runtime-installed dependency count.
    pub env_packages: u32,
    pub env_pkg_mean_bytes: u64,
    pub env_pkg_sigma: f64,
    pub env_install_cpu_mean_s: f64,
    pub env_cache_bytes: u64,
    pub ckpt_bytes: u64,
    /// Pipeline-parallel degree (checkpoint partitioning).
    pub pp: u32,
    /// Data-parallel degree (checkpoint replication factor on resume).
    pub dp: u32,
    /// Tensor-parallel degree within a node.
    pub tp: u32,
    /// Identity seed of the container image this job runs. Jobs sharing a
    /// seed share an image digest, so hot-set records recorded by one job
    /// benefit every other (the cluster replay sets this from the trace's
    /// `image_id`). `None` → derived per job id, the standalone behaviour.
    pub image_seed: Option<u64>,
    /// Identity seed of the runtime package set (keys the environment
    /// cache). `None` → derived per job id.
    pub env_seed: Option<u64>,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            name: "moe-8l-128e".to_string(),
            gpus: 128,
            image_bytes: d::PAPER_IMAGE_BYTES,
            image_hot_fraction: d::IMAGE_HOT_FRACTION,
            image_block_bytes: d::IMAGE_BLOCK_BYTES,
            env_packages: d::ENV_PACKAGES,
            env_pkg_mean_bytes: d::ENV_PKG_MEAN_BYTES,
            env_pkg_sigma: d::ENV_PKG_SIGMA,
            env_install_cpu_mean_s: d::ENV_INSTALL_CPU_MEAN_S,
            env_cache_bytes: d::PAPER_ENV_CACHE_BYTES,
            ckpt_bytes: d::PAPER_CKPT_BYTES,
            pp: 2,
            dp: 8,
            tp: 8,
            image_seed: None,
            env_seed: None,
        }
    }
}

impl JobConfig {
    /// The paper's §5.1 MoE workload at a given GPU scale. PP is fixed at 2,
    /// TP at 8 (one node per TP group), DP = gpus / (pp * tp).
    pub fn paper_moe(gpus: u32) -> JobConfig {
        let pp = 2;
        let tp = 8;
        JobConfig {
            gpus,
            pp,
            tp,
            dp: (gpus / (pp * tp)).max(1),
            ..JobConfig::default()
        }
    }

    pub fn nodes(&self, cluster: &ClusterConfig) -> u32 {
        (self.gpus + cluster.gpus_per_node - 1) / cluster.gpus_per_node
    }

    /// Identity seed of the job's container image when run as `job_id`:
    /// the explicit shared seed when set (cluster replay), else derived
    /// per job id. The one definition the pipeline, the replay's identity
    /// tables, and the artifact sweeps all share — artifact ids
    /// (`artifact::ArtifactManifest::image_hot_id`) key off the image
    /// this seed synthesizes.
    pub fn image_identity_seed(&self, job_id: u64) -> u64 {
        self.image_seed.unwrap_or(job_id ^ 0x1AA6E)
    }

    /// Identity seed of the job's runtime package set when run as
    /// `job_id` (keys the environment cache and the env-snapshot
    /// artifact id).
    pub fn env_identity_seed(&self, job_id: u64) -> u64 {
        self.env_seed.unwrap_or(job_id ^ 0x9AC5)
    }

    pub fn from_doc(doc: &Doc) -> JobConfig {
        let base = JobConfig::default();
        JobConfig {
            name: doc.str_or("job.name", &base.name),
            gpus: doc.u32_or("job.gpus", base.gpus),
            image_bytes: doc.u64_or("job.image_bytes", base.image_bytes),
            image_hot_fraction: doc.f64_or("job.image_hot_fraction", base.image_hot_fraction),
            image_block_bytes: doc.u64_or("job.image_block_bytes", base.image_block_bytes),
            env_packages: doc.u32_or("job.env_packages", base.env_packages),
            env_pkg_mean_bytes: doc.u64_or("job.env_pkg_mean_bytes", base.env_pkg_mean_bytes),
            env_pkg_sigma: doc.f64_or("job.env_pkg_sigma", base.env_pkg_sigma),
            env_install_cpu_mean_s: doc
                .f64_or("job.env_install_cpu_mean_s", base.env_install_cpu_mean_s),
            env_cache_bytes: doc.u64_or("job.env_cache_bytes", base.env_cache_bytes),
            ckpt_bytes: doc.u64_or("job.ckpt_bytes", base.ckpt_bytes),
            pp: doc.u32_or("job.pp", base.pp),
            dp: doc.u32_or("job.dp", base.dp),
            tp: doc.u32_or("job.tp", base.tp),
            image_seed: base.image_seed,
            env_seed: base.env_seed,
        }
    }
}

/// BootSeer feature toggles (what §5 ablates between "baseline" and
/// "Bootseer").
#[derive(Clone, Debug)]
pub struct BootseerConfig {
    pub image_mode: ImageMode,
    /// Peer-to-peer block sharing (on in BOTH paper configurations).
    pub p2p: bool,
    pub env_cache: bool,
    pub ckpt_striped: bool,
    pub record_window_s: f64,
    pub prefetch_threads: u32,
    pub stripe_chunk_bytes: u64,
    pub stripe_width: u32,
    /// Stage-graph gating between worker-phase stages (default Sequential,
    /// the paper-faithful pipeline).
    pub overlap: OverlapMode,
    /// Per-node byte budget for speculative staging during Allocation
    /// (`OverlapMode::Speculative` only).
    pub spec_prefetch_budget_bytes: u64,
    /// Cross-artifact dedup at the transfer plane: chunks whose content
    /// digest already landed via another artifact (env-snapshot chunks
    /// duplicating image blocks) are served from local disk instead of
    /// being re-fetched. Off by default — the paper's system moves each
    /// artifact independently.
    pub artifact_dedup: bool,
    /// Delta checkpoint resume: a warm restart that kept its nodes
    /// re-fetches only the resume-shard chunks rewritten since the
    /// resident copy, instead of the whole shard. Off by default.
    pub delta_resume: bool,
    /// Per-node artifact-cache capacity in bytes. `u64::MAX` (the
    /// default) models the unbounded cache every earlier PR assumed and
    /// is byte-identical to it; a finite capacity makes warm restarts
    /// compete with fleet churn for local disk (`artifact::cache`).
    pub cache_capacity_bytes: u64,
    /// Eviction policy of a bounded cache (ignored while unbounded).
    pub cache_policy: CachePolicy,
}

impl BootseerConfig {
    /// The paper's baseline: lazy image loading with P2P, on-the-fly pip
    /// installs, plain HDFS download-and-resume.
    pub fn baseline() -> BootseerConfig {
        BootseerConfig {
            image_mode: ImageMode::Lazy,
            p2p: true,
            env_cache: false,
            ckpt_striped: false,
            record_window_s: d::PAPER_RECORD_WINDOW_S,
            prefetch_threads: d::PAPER_PREFETCH_THREADS,
            stripe_chunk_bytes: d::STRIPE_CHUNK_BYTES,
            stripe_width: d::STRIPE_WIDTH,
            overlap: OverlapMode::Sequential,
            spec_prefetch_budget_bytes: d::SPEC_PREFETCH_BUDGET_BYTES,
            artifact_dedup: false,
            delta_resume: false,
            cache_capacity_bytes: d::CACHE_CAPACITY_BYTES,
            cache_policy: CachePolicy::Lru,
        }
    }

    /// Full BootSeer: record-and-prefetch, env cache, striped HDFS-FUSE.
    pub fn bootseer() -> BootseerConfig {
        BootseerConfig {
            image_mode: ImageMode::RecordPrefetch,
            env_cache: true,
            ckpt_striped: true,
            ..BootseerConfig::baseline()
        }
    }

    /// Pre-lazy-loading strawman (for the 10x OCI claim in §4.2).
    pub fn oci_strawman() -> BootseerConfig {
        BootseerConfig { image_mode: ImageMode::OciFull, p2p: false, ..BootseerConfig::baseline() }
    }

    pub fn from_doc(doc: &Doc) -> BootseerConfig {
        let base = if doc.bool_or("bootseer.enabled", true) {
            BootseerConfig::bootseer()
        } else {
            BootseerConfig::baseline()
        };
        BootseerConfig {
            image_mode: doc
                .get("bootseer.image_mode")
                .and_then(|v| v.as_str())
                .and_then(ImageMode::parse)
                .unwrap_or(base.image_mode),
            p2p: doc.bool_or("bootseer.p2p", base.p2p),
            env_cache: doc.bool_or("bootseer.env_cache", base.env_cache),
            ckpt_striped: doc.bool_or("bootseer.ckpt_striped", base.ckpt_striped),
            record_window_s: doc.f64_or("bootseer.record_window_s", base.record_window_s),
            prefetch_threads: doc.u32_or("bootseer.prefetch_threads", base.prefetch_threads),
            stripe_chunk_bytes: doc.u64_or("bootseer.stripe_chunk_bytes", base.stripe_chunk_bytes),
            stripe_width: doc.u32_or("bootseer.stripe_width", base.stripe_width),
            overlap: doc
                .get("bootseer.overlap")
                .and_then(|v| v.as_str())
                .and_then(OverlapMode::parse)
                .unwrap_or(base.overlap),
            // `u64_or` clamps a present negative value at 0 (it must not
            // wrap into an effectively unlimited budget) and passes an
            // absent key's default through untouched — which also keeps
            // the unbounded `u64::MAX` cache sentinel out of any i64
            // round-trip.
            spec_prefetch_budget_bytes: doc.u64_or(
                "bootseer.spec_prefetch_budget_bytes",
                base.spec_prefetch_budget_bytes,
            ),
            artifact_dedup: doc.bool_or("bootseer.artifact_dedup", base.artifact_dedup),
            delta_resume: doc.bool_or("bootseer.delta_resume", base.delta_resume),
            cache_capacity_bytes: doc
                .u64_or("bootseer.cache_capacity_bytes", base.cache_capacity_bytes),
            cache_policy: doc
                .get("bootseer.cache_policy")
                .and_then(|v| v.as_str())
                .and_then(CachePolicy::parse)
                .unwrap_or(base.cache_policy),
        }
    }
}

/// Fully resolved run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub cluster: ClusterConfig,
    pub job: JobConfig,
    pub bootseer: BootseerConfig,
    /// Fault-injection processes for the cluster replay (`[faults]`
    /// table; defaults to off — the fault-free replay).
    pub faults: crate::faults::FaultConfig,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            cluster: ClusterConfig::default(),
            job: JobConfig::default(),
            bootseer: BootseerConfig::baseline(),
            faults: crate::faults::FaultConfig::off(),
            seed: 0xB007_5EE3,
        }
    }
}

impl RunConfig {
    pub fn from_file(path: &str) -> Result<RunConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let doc = Doc::parse(&text)?;
        Ok(RunConfig {
            cluster: ClusterConfig::from_doc(&doc),
            job: JobConfig::from_doc(&doc),
            bootseer: BootseerConfig::from_doc(&doc),
            faults: crate::faults::FaultConfig::from_doc(&doc),
            seed: doc.i64_or("seed", 0xB007_5EE3) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_workload() {
        let job = JobConfig::default();
        assert_eq!(job.image_bytes, 28_620_000_000);
        assert_eq!(job.ckpt_bytes, 413_000_000_000);
        assert_eq!(job.env_cache_bytes, 270_000_000);
        assert_eq!(job.pp, 2);
    }

    #[test]
    fn paper_moe_scales_dp() {
        // §5.1: 16..128 GPUs ↔ DP 1,2,3,4,8.
        assert_eq!(JobConfig::paper_moe(16).dp, 1);
        assert_eq!(JobConfig::paper_moe(32).dp, 2);
        assert_eq!(JobConfig::paper_moe(48).dp, 3);
        assert_eq!(JobConfig::paper_moe(64).dp, 4);
        assert_eq!(JobConfig::paper_moe(128).dp, 8);
    }

    #[test]
    fn nodes_round_up() {
        let cluster = ClusterConfig::default();
        assert_eq!(JobConfig::paper_moe(16).nodes(&cluster), 2);
        assert_eq!(JobConfig::paper_moe(48).nodes(&cluster), 6);
        let odd = JobConfig { gpus: 9, ..JobConfig::default() };
        assert_eq!(odd.nodes(&cluster), 2);
    }

    #[test]
    fn bootseer_vs_baseline_flags() {
        let base = BootseerConfig::baseline();
        let boot = BootseerConfig::bootseer();
        assert_eq!(base.image_mode, ImageMode::Lazy);
        assert_eq!(boot.image_mode, ImageMode::RecordPrefetch);
        assert!(!base.env_cache && boot.env_cache);
        assert!(!base.ckpt_striped && boot.ckpt_striped);
        assert!(base.p2p && boot.p2p); // p2p on in both per §5.2
    }

    #[test]
    fn from_doc_overrides() {
        let doc = Doc::parse(
            r#"
            seed = 7
            [cluster]
            nodes = 32
            [job]
            gpus = 64
            [bootseer]
            enabled = false
            image_mode = "oci"
            "#,
        )
        .unwrap();
        let cluster = ClusterConfig::from_doc(&doc);
        let job = JobConfig::from_doc(&doc);
        let boot = BootseerConfig::from_doc(&doc);
        assert_eq!(cluster.nodes, 32);
        assert_eq!(job.gpus, 64);
        assert_eq!(boot.image_mode, ImageMode::OciFull);
        // Untouched values keep defaults.
        assert_eq!(job.image_bytes, 28_620_000_000);
    }

    #[test]
    fn image_mode_parse() {
        assert_eq!(ImageMode::parse("lazy"), Some(ImageMode::Lazy));
        assert_eq!(ImageMode::parse("bootseer"), Some(ImageMode::RecordPrefetch));
        assert_eq!(ImageMode::parse("nope"), None);
        assert_eq!(ImageMode::Lazy.name(), "lazy");
    }

    #[test]
    fn overlap_mode_parse_roundtrip() {
        for m in OverlapMode::ALL {
            assert_eq!(OverlapMode::parse(m.name()), Some(m));
        }
        assert_eq!(OverlapMode::parse("overlap"), Some(OverlapMode::Overlapped));
        assert_eq!(OverlapMode::parse("nope"), None);
        // Both paper configurations default to the paper-faithful pipeline.
        assert_eq!(BootseerConfig::baseline().overlap, OverlapMode::Sequential);
        assert_eq!(BootseerConfig::bootseer().overlap, OverlapMode::Sequential);
    }

    #[test]
    fn artifact_flags_default_off_and_parse() {
        // Both paper configurations move artifacts independently.
        assert!(!BootseerConfig::baseline().artifact_dedup);
        assert!(!BootseerConfig::bootseer().artifact_dedup);
        assert!(!BootseerConfig::bootseer().delta_resume);
        let doc = Doc::parse(
            r#"
            [bootseer]
            artifact_dedup = true
            delta_resume = true
            "#,
        )
        .unwrap();
        let boot = BootseerConfig::from_doc(&doc);
        assert!(boot.artifact_dedup);
        assert!(boot.delta_resume);
    }

    #[test]
    fn cache_policy_parse_roundtrip() {
        for p in CachePolicy::ALL {
            assert_eq!(CachePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(CachePolicy::parse("pin"), Some(CachePolicy::PinHotSet));
        assert_eq!(CachePolicy::parse("nope"), None);
        assert!(CachePolicy::PinHotSet.pins_hot_set());
        assert!(!CachePolicy::Lru.pins_hot_set());
    }

    #[test]
    fn cache_capacity_defaults_unbounded_and_parses() {
        // Both paper configurations assume an unbounded local cache.
        assert_eq!(BootseerConfig::baseline().cache_capacity_bytes, u64::MAX);
        assert_eq!(BootseerConfig::bootseer().cache_capacity_bytes, u64::MAX);
        assert_eq!(BootseerConfig::baseline().cache_policy, CachePolicy::Lru);
        let doc = Doc::parse(
            r#"
            [bootseer]
            cache_capacity_bytes = 4000000000
            cache_policy = "gdsf"
            "#,
        )
        .unwrap();
        let boot = BootseerConfig::from_doc(&doc);
        assert_eq!(boot.cache_capacity_bytes, 4_000_000_000);
        assert_eq!(boot.cache_policy, CachePolicy::Gdsf);
        // An absent key keeps the unbounded default (no i64 round-trip);
        // a negative value clamps to 0, not to unbounded.
        let neg = Doc::parse("[bootseer]\ncache_capacity_bytes = -5\n").unwrap();
        assert_eq!(BootseerConfig::from_doc(&neg).cache_capacity_bytes, 0);
        let absent = Doc::parse("[bootseer]\nenabled = true\n").unwrap();
        assert_eq!(BootseerConfig::from_doc(&absent).cache_capacity_bytes, u64::MAX);
    }

    #[test]
    fn topology_defaults_flat_and_parses() {
        let base = ClusterConfig::default();
        assert_eq!(base.racks, 1);
        assert_eq!(base.spines, 1);
        assert_eq!(base.rack_uplink_bps, 0.0);
        assert_eq!(base.spine_oversub, 1.0);
        assert_eq!(base.spine_core_bps, 0.0);
        assert!(base.relocation_cost_s > 0.0);
        let doc = Doc::parse(
            r#"
            [cluster]
            racks = 4
            spines = 2
            rack_uplink_bps = 5.0e9
            spine_oversub = 4.0
            "#,
        )
        .unwrap();
        let cluster = ClusterConfig::from_doc(&doc);
        assert_eq!(cluster.racks, 4);
        assert_eq!(cluster.spines, 2);
        assert_eq!(cluster.rack_uplink_bps, 5.0e9);
        assert_eq!(cluster.spine_oversub, 4.0);
        // Degenerate values clamp to the flat/neutral floor.
        let bad = Doc::parse("[cluster]\nracks = 0\nspines = 0\nspine_oversub = 0.5\n").unwrap();
        let c = ClusterConfig::from_doc(&bad);
        assert_eq!(c.racks, 1);
        assert_eq!(c.spines, 1);
        assert_eq!(c.spine_oversub, 1.0);
    }

    #[test]
    fn overlap_from_doc() {
        let doc = Doc::parse(
            r#"
            [bootseer]
            overlap = "speculative"
            spec_prefetch_budget_bytes = 1000000
            "#,
        )
        .unwrap();
        let boot = BootseerConfig::from_doc(&doc);
        assert_eq!(boot.overlap, OverlapMode::Speculative);
        assert_eq!(boot.spec_prefetch_budget_bytes, 1_000_000);
    }
}
