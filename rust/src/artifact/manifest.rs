//! Typed, content-addressed artifact manifests.
//!
//! Every byte set the startup pipeline moves — an image's startup-hot
//! block set, its cold tail, a job's environment snapshot archive, a
//! checkpoint resume shard — is described by one [`ArtifactManifest`]: a
//! stable artifact id plus an ordered list of content-addressed chunks.
//! The manifest is the unit the transfer plane materializes
//! ([`crate::artifact::transfer`]) and the unit the per-node cache tracks
//! residency of ([`crate::artifact::cache`]). Chunk digests are shared
//! with the underlying content model (image block digests; env chunks
//! that duplicate image blocks carry the image block's digest), which is
//! what makes cross-artifact dedup expressible at the transfer plane.

use crate::config::defaults as d;
use crate::config::JobConfig;
use crate::image::spec::ImageSpec;
use crate::util::cast::{u64_from_usize, usize_from_u32, usize_from_u64};
use crate::util::rng::mix64;
use crate::util::salts::{
    SALT_CKPT, SALT_CKPT_CHUNK, SALT_ENV, SALT_ENV_CHUNK, SALT_IMG_COLD, SALT_IMG_HOT,
};

/// What kind of content a manifest describes (the four artifact classes
/// the startup pipeline moves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// An image's startup-hot block set (record-and-prefetch foreground).
    ImageHotSet,
    /// The rest of the image, streamed in the background.
    ImageColdTail,
    /// A job's compressed environment snapshot archive.
    EnvSnapshot,
    /// One node's checkpoint resume share.
    CkptShard,
    /// Test/bench-only synthetic content.
    Synthetic,
}

/// One content-addressed chunk of an artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    /// Content digest; equal digests are the same bytes (dedup unit).
    pub digest: u64,
    pub bytes: u64,
}

/// An ordered chunk list with a stable identity. Chunk order is the
/// materialization order: a byte-bounded prefix of the list is what a
/// budget-clamped staging pass moves first.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// Stable artifact identity (pure function of the content identity —
    /// image digest, env signature, checkpoint identity).
    pub id: u64,
    pub kind: ArtifactKind,
    pub chunks: Vec<Chunk>,
    total: u64,
}

/// Split `total` bytes into `chunk_bytes`-sized chunks (partial tail),
/// digests supplied per chunk index — the one copy of the size
/// arithmetic every typed builder uses.
fn split(total: u64, chunk_bytes: u64, digest_of: impl Fn(usize) -> u64) -> Vec<Chunk> {
    assert!(chunk_bytes > 0);
    let n = usize_from_u64((total + chunk_bytes - 1) / chunk_bytes);
    (0..n)
        .map(|k| {
            let len = if u64_from_usize(k + 1) * chunk_bytes <= total {
                chunk_bytes
            } else {
                total - u64_from_usize(k) * chunk_bytes
            };
            Chunk { digest: digest_of(k), bytes: len }
        })
        .collect()
}

impl ArtifactManifest {
    fn build(id: u64, kind: ArtifactKind, chunks: Vec<Chunk>) -> ArtifactManifest {
        let total = chunks.iter().map(|c| c.bytes).sum();
        ArtifactManifest { id, kind, chunks, total }
    }

    /// A chunkless manifest carrying only identity + size. Sufficient for
    /// every non-dedup consumer (artifact-prefix credit, staging clamps
    /// — they never walk chunks), and what the stage planners declare on
    /// the default path so the replay hot loop allocates no chunk lists.
    /// The dedup plane needs the full typed builders.
    pub fn summary(id: u64, kind: ArtifactKind, total: u64) -> ArtifactManifest {
        ArtifactManifest { id, kind, chunks: Vec::new(), total }
    }

    /// Total logical bytes of the artifact.
    pub fn total_bytes(&self) -> u64 {
        self.total
    }

    /// Artifact id of an image's hot set, from the image digest.
    pub fn image_hot_id(image_digest: u64) -> u64 {
        mix64(SALT_IMG_HOT ^ image_digest)
    }

    /// Artifact id of an image's cold tail.
    pub fn image_cold_id(image_digest: u64) -> u64 {
        mix64(SALT_IMG_COLD ^ image_digest)
    }

    /// Artifact id of an environment snapshot, from the package signature.
    pub fn env_snapshot_id(signature: u64) -> u64 {
        mix64(SALT_ENV ^ signature)
    }

    /// Artifact id of a job's checkpoint resume shard. Keyed by the job's
    /// checkpoint identity (size, partitioning, image lineage) — unique
    /// among the artifacts of one startup, which is the scope a
    /// [`crate::artifact::cache::CacheState`] lives in.
    pub fn ckpt_shard_id(job: &JobConfig) -> u64 {
        mix64(
            SALT_CKPT
                ^ job.ckpt_bytes.wrapping_mul(0x9E3779B97F4A7C15)
                ^ (u64::from(job.pp) << 32)
                ^ job.image_seed.unwrap_or(0),
        )
    }

    /// The startup-hot block set of `img` (`hot` = block indices from the
    /// hot-set record). Chunk digests are the image's own block digests,
    /// so hot sets of images sharing blocks dedupe at the chunk level.
    pub fn image_hot_set(img: &ImageSpec, hot: &[u32]) -> ArtifactManifest {
        let chunks = hot
            .iter()
            .map(|&b| Chunk { digest: img.block_digests[usize_from_u32(b)], bytes: img.block_len(b) })
            .collect();
        Self::build(Self::image_hot_id(img.digest), ArtifactKind::ImageHotSet, chunks)
    }

    /// Every block of `img` outside the hot set, in block order.
    pub fn image_cold_tail(img: &ImageSpec, hot: &[u32]) -> ArtifactManifest {
        let hot_set: std::collections::BTreeSet<u32> = hot.iter().copied().collect();
        let chunks = (0..img.n_blocks())
            .filter(|b| !hot_set.contains(b))
            .map(|b| Chunk { digest: img.block_digests[usize_from_u32(b)], bytes: img.block_len(b) })
            .collect();
        Self::build(Self::image_cold_id(img.digest), ArtifactKind::ImageColdTail, chunks)
    }

    /// The compressed environment snapshot archive for package signature
    /// `sig`. When `shared_with` (the job's image hot-set manifest) is
    /// given, the first [`d::ENV_IMAGE_SHARED_FRACTION`] of the archive's
    /// chunks carry the corresponding image chunk digests — the archive's
    /// site-packages duplicating libraries already present in the image's
    /// hot runtime region (the overlap the real-bytes
    /// [`crate::image::blockstore::BlockStore`] measures). The transfer
    /// plane exploits the overlap only when cross-artifact dedup is
    /// enabled; the manifest itself always describes it.
    pub fn env_snapshot(
        sig: u64,
        bytes: u64,
        shared_with: Option<&ArtifactManifest>,
    ) -> ArtifactManifest {
        let chunk = d::ENV_SNAPSHOT_CHUNK_BYTES;
        let n = usize_from_u64((bytes + chunk - 1) / chunk);
        let shared_n = match shared_with {
            Some(m) => ((n as f64 * d::ENV_IMAGE_SHARED_FRACTION) as usize).min(m.chunks.len()),
            None => 0,
        };
        let chunks = split(bytes, chunk, |k| {
            if k < shared_n {
                shared_with.expect("shared_n > 0 implies Some").chunks[k].digest
            } else {
                mix64(SALT_ENV_CHUNK ^ sig ^ u64_from_usize(k).wrapping_mul(0xC2B2AE3D27D4EB4F))
            }
        });
        Self::build(Self::env_snapshot_id(sig), ArtifactKind::EnvSnapshot, chunks)
    }

    /// One node's checkpoint resume share (`per_node_bytes`), chunked at
    /// [`d::CKPT_CHUNK_BYTES`]. Chunk digests are keyed by the shard
    /// identity + chunk index, so the chunks a rollback did not rewrite
    /// keep their digests — the basis of delta resume.
    pub fn ckpt_shard(job: &JobConfig, per_node_bytes: u64) -> ArtifactManifest {
        let id = Self::ckpt_shard_id(job);
        let chunks = split(per_node_bytes, d::CKPT_CHUNK_BYTES, |k| {
            mix64(SALT_CKPT_CHUNK ^ id ^ u64_from_usize(k).wrapping_mul(0x165667B19E3779F9))
        });
        Self::build(id, ArtifactKind::CkptShard, chunks)
    }

    /// A synthetic manifest for tests and benches: `total` bytes in
    /// `chunk_bytes` chunks, digests keyed by `id`.
    pub fn synthetic(id: u64, total: u64, chunk_bytes: u64) -> ArtifactManifest {
        let chunks = split(total, chunk_bytes, |k| mix64(id ^ (u64_from_usize(k) << 17)));
        Self::build(id, ArtifactKind::Synthetic, chunks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::{IMAGE_BLOCK_BYTES, PAPER_IMAGE_BYTES};

    fn img() -> ImageSpec {
        ImageSpec::synth(1, PAPER_IMAGE_BYTES, IMAGE_BLOCK_BYTES, 0.07)
    }

    #[test]
    fn hot_and_cold_partition_the_image() {
        let img = img();
        let hot_blocks: Vec<u32> = {
            let mut h = img.startup_access.clone();
            h.sort_unstable();
            h
        };
        let hot = ArtifactManifest::image_hot_set(&img, &hot_blocks);
        let cold = ArtifactManifest::image_cold_tail(&img, &hot_blocks);
        assert_eq!(hot.total_bytes(), img.hot_bytes());
        assert_eq!(hot.total_bytes() + cold.total_bytes(), img.total_bytes);
        assert_eq!(hot.chunks.len() + cold.chunks.len(), img.n_blocks() as usize);
        assert_ne!(hot.id, cold.id);
        assert_eq!(hot.kind, ArtifactKind::ImageHotSet);
    }

    #[test]
    fn env_snapshot_totals_exact_and_shares_image_digests() {
        let img = img();
        let mut hot_blocks = img.startup_access.clone();
        hot_blocks.sort_unstable();
        let hotm = ArtifactManifest::image_hot_set(&img, &hot_blocks);
        let bytes = 270_000_000u64;
        let env = ArtifactManifest::env_snapshot(77, bytes, Some(&hotm));
        assert_eq!(env.total_bytes(), bytes);
        // The shared prefix carries the image chunk digests verbatim.
        let hot_digests: std::collections::BTreeSet<u64> =
            hotm.chunks.iter().map(|c| c.digest).collect();
        let shared = env.chunks.iter().filter(|c| hot_digests.contains(&c.digest)).count();
        let expect = (env.chunks.len() as f64 * d::ENV_IMAGE_SHARED_FRACTION) as usize;
        assert!(shared >= expect, "shared {shared} < expected {expect}");
        // Without a shared manifest the digests are disjoint from the image.
        let plain = ArtifactManifest::env_snapshot(77, bytes, None);
        assert_eq!(plain.total_bytes(), bytes);
        assert!(plain.chunks.iter().all(|c| !hot_digests.contains(&c.digest)));
        // Same signature → same id either way.
        assert_eq!(plain.id, env.id);
    }

    #[test]
    fn ckpt_shard_deterministic_per_job() {
        let job = JobConfig::paper_moe(128);
        let a = ArtifactManifest::ckpt_shard(&job, 206_500_000_000);
        let b = ArtifactManifest::ckpt_shard(&job, 206_500_000_000);
        assert_eq!(a.id, b.id);
        assert_eq!(a.total_bytes(), 206_500_000_000);
        assert_eq!(a.chunks.len(), b.chunks.len());
        assert_eq!(a.chunks[0].digest, b.chunks[0].digest);
        // A different checkpoint size is a different artifact.
        let other = JobConfig { ckpt_bytes: 1, ..JobConfig::paper_moe(128) };
        assert_ne!(ArtifactManifest::ckpt_shard(&other, 100).id, a.id);
    }

    #[test]
    fn summary_matches_full_manifest_identity_and_total() {
        let img = img();
        let mut hot = img.startup_access.clone();
        hot.sort_unstable();
        let full = ArtifactManifest::image_hot_set(&img, &hot);
        let s = ArtifactManifest::summary(
            ArtifactManifest::image_hot_id(img.digest),
            ArtifactKind::ImageHotSet,
            img.hot_bytes(),
        );
        assert_eq!(s.id, full.id);
        assert_eq!(s.total_bytes(), full.total_bytes());
        assert!(s.chunks.is_empty());
    }

    #[test]
    fn synthetic_chunks_cover_total() {
        let m = ArtifactManifest::synthetic(5, 10_500, 4_000);
        assert_eq!(m.total_bytes(), 10_500);
        assert_eq!(m.chunks.len(), 3);
        assert_eq!(m.chunks[2].bytes, 2_500);
        let empty = ArtifactManifest::synthetic(5, 0, 4_000);
        assert_eq!(empty.total_bytes(), 0);
        assert!(empty.chunks.is_empty());
    }

    #[test]
    fn ids_are_domain_separated() {
        let d = 0xABCD_u64;
        let ids = [
            ArtifactManifest::image_hot_id(d),
            ArtifactManifest::image_cold_id(d),
            ArtifactManifest::env_snapshot_id(d),
        ];
        assert_ne!(ids[0], ids[1]);
        assert_ne!(ids[0], ids[2]);
        assert_ne!(ids[1], ids[2]);
    }
}
