//! Unified content-addressed artifact layer.
//!
//! BootSeer's three mitigations — hot-block record-and-prefetch (§4.2),
//! environment snapshotting (§4.3), striped HDFS-FUSE resume (§4.4) — are
//! all the same problem: *move content-addressed bytes to the right node
//! before a stage needs them*. This module is the one plane that does it:
//!
//! * [`manifest`] — what the bytes are: an [`ArtifactManifest`] (typed:
//!   image hot set, image cold tail, env snapshot, checkpoint shard)
//!   lists chunk digests + sizes.
//! * [`cache`] — where the bytes already are: a per-node [`CacheState`]
//!   tracks resident chunks across attempts and segments of a replay.
//! * [`transfer`] — how missing bytes move: a [`TransferPlanner`] compiles
//!   "materialize manifest M on node i" onto the fluid sim from a tiered
//!   provider (local disk → peer swarm → registry / cluster cache /
//!   HDFS).
//!
//! The stage-graph planners ([`crate::startup::stages`]) declare manifests
//! instead of byte counts; speculative staging, warm-restart credit and
//! overlapped prefetch are all just "what's already in [`CacheState`]".
//! Cross-artifact dedup (`bootseer.artifact_dedup`) and delta checkpoint
//! resume (`bootseer.delta_resume`) are transfer-plane features no
//! per-subsystem byte channel could express. Bounded per-node capacity
//! with pluggable eviction ([`CacheState::with_capacity`]) and
//! registry/cluster-cache load shedding ([`Admission`]) put fleet cache
//! economics on top: what a restart storm costs when cached bytes can
//! actually fall out. Design note: `docs/artifact_layer.md`.

pub mod cache;
pub mod manifest;
pub mod transfer;

pub use cache::CacheState;
pub use manifest::{ArtifactKind, ArtifactManifest, Chunk};
pub use transfer::{admitted_peers, Admission, ProviderTier, TransferPlanner};
