//! The single transfer plane: materialize artifact bytes on a node from a
//! tiered provider.
//!
//! Every foreground byte the startup pipeline moves now flows through one
//! [`TransferPlanner`]: the image loaders, the environment installer, the
//! checkpoint resume and the stage-graph's speculative stager all pick a
//! [`ProviderTier`] and call [`TransferPlanner::fetch`] instead of
//! hand-rolling `Swarm` pools and flow paths per subsystem. The tier
//! encodes exactly the transport the pre-refactor subsystems used, so a
//! default-config run lays down a bit-identical task DAG:
//!
//! | tier            | path                                  | was |
//! |-----------------|---------------------------------------|-----|
//! | `RegistrySwarm` | P2P pool fed by the registry → NIC    | `image/loader.rs` OCI pull |
//! | `CacheSwarm`    | P2P pool fed by the block cache → NIC | hot-set prefetch, spec staging |
//! | `ClusterCache`  | block-cache egress → NIC              | lazy misses, non-P2P prefetch |
//! | `Registry`      | registry egress → NIC → local disk    | non-P2P OCI pull |
//! | `Scm`           | SCM backend → NIC                     | `env/installer.rs` package pulls |
//! | `Hdfs{nn_op}`   | [NameNode op →] DataNode group → NIC  | env-cache restore, spec staging |
//! | `HdfsStream`    | `hdfs::fuse::plan_read` engine        | `ckpt/resume.rs` resume reads |
//!
//! The *local disk* tier is implicit: bytes already resident per
//! [`crate::artifact::cache::CacheState`] are subtracted before `fetch` is
//! ever called, and never cross the network again.
//!
//! # Topology awareness
//!
//! On a non-flat cluster (`ClusterConfig::racks > 1`) every service-backed
//! fetch additionally traverses the node's tree tiers
//! ([`ClusterSim::tier_path`]: spine core + rack uplink — the services live
//! outside the racks), and the swarm tiers split each fetch by peer
//! locality: the in-rack share of the bytes stays under the ToR while the
//! cross-rack share (the fraction of the allocation's peers in *other*
//! racks, [`crate::sim::Topology::in_rack_peers`]) crosses the
//! oversubscribed tiers. A fragmented placement therefore pushes strictly
//! more swarm bytes through the spine — the monotonicity
//! `figures::fragmentation_sweep` measures. The flat default adds no path
//! elements and lays down the exact pre-topology task DAG.
//!
//! # Load-shedding & retry backoff
//!
//! The registry and the cluster cache are *shared* services: a restart
//! storm has every node of every restarting job hitting them at once.
//! [`Admission`] models their finite concurrency: when fleet demand
//! exceeds a tier's entitlement slots
//! ([`crate::faults::FaultConfig::registry_slots`] /
//! [`FaultConfig::cache_slots`](crate::faults::FaultConfig::cache_slots)),
//! a fetch is *shed* with probability `(demand − slots) / demand` and
//! retries after a seeded exponential backoff — the fetch itself then
//! runs exactly once, just later, so no byte is ever moved (or counted)
//! twice. The terminal attempt is always admitted: shedding delays, it
//! never starves. Every decision is `mix64`-derived from
//! `(seed, tier, artifact, node, attempt)` — never from simulator state —
//! so the parallel replay stays byte-identical at any `--threads`, and a
//! config without slot limits builds a planner with no admission at all
//! (`Option::None`), laying down the exact historical task DAG.

use crate::faults::FaultConfig;
use crate::hdfs::fuse::{plan_read, ReadEngine};
use crate::image::p2p::Swarm;
use crate::sim::{ClusterSim, NodeHandle, TaskId};
use crate::util::rng::mix64;
use crate::util::salts::{SALT_BACKOFF, SALT_PEER, SALT_SHED};

/// Uniform in `[0, 1)` from a mixed word (the one unit-float idiom in the
/// tree, cf. `util::rng`).
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Where a transfer pulls its bytes from (in preference order behind the
/// implicit local-disk tier).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProviderTier {
    /// P2P swarm fed by the container registry (full-image pulls, P2P on).
    RegistrySwarm,
    /// P2P swarm fed by the cluster block cache (hot-set prefetch and
    /// speculative staging, P2P on).
    CacheSwarm,
    /// Cluster block-cache egress, direct (P2P off, lazy miss service).
    ClusterCache,
    /// Container registry egress, staged through the node's local disk
    /// (the traditional OCI pull path).
    Registry,
    /// SCM / package backend (throttled shared service).
    Scm,
    /// An HDFS DataNode group, round-robin by node. `nn_op` charges one
    /// NameNode lookup before the transfer (the env-cache restore does;
    /// the speculative stager's pre-opened handle does not).
    Hdfs { nn_op: bool },
    /// A checkpoint read through HDFS-FUSE ([`plan_read`]): sequential
    /// download-and-resume or BootSeer's striped engine.
    HdfsStream(ReadEngine),
}

/// Deterministic load-shedding state for the shared registry and
/// cluster-cache tiers during one startup. Copy-cheap: planners embed it
/// by value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Admission {
    registry_slots: u32,
    cache_slots: u32,
    /// Fleet-wide concurrently-starting nodes contending for the shared
    /// services while this startup runs (from the replay's contention
    /// profile — phase-1 data, identical at any thread count).
    demand: u32,
    backoff_s: f64,
    max_retries: u32,
    seed: u64,
}

impl Admission {
    /// Admission control for one startup, or `None` when the fault config
    /// leaves both tiers unlimited (or nothing contends) — the planner
    /// then takes the exact historical code path.
    pub fn from_faults(f: &FaultConfig, demand: u32, seed: u64) -> Option<Admission> {
        if (f.registry_slots == u32::MAX && f.cache_slots == u32::MAX) || demand == 0 {
            return None;
        }
        Some(Admission {
            registry_slots: f.registry_slots,
            cache_slots: f.cache_slots,
            demand,
            backoff_s: f.shed_backoff_s,
            max_retries: f.shed_retries,
            seed,
        })
    }

    fn slots_for(&self, tier: ProviderTier) -> u32 {
        match tier {
            ProviderTier::Registry | ProviderTier::RegistrySwarm => self.registry_slots,
            ProviderTier::ClusterCache | ProviderTier::CacheSwarm => self.cache_slots,
            _ => u32::MAX,
        }
    }

    /// Decision-stream tag of the *service* behind a tier: the swarm and
    /// direct flavours of one service share a shed stream (it is the same
    /// backend saying no).
    fn service_salt(tier: ProviderTier) -> u64 {
        match tier {
            ProviderTier::Registry | ProviderTier::RegistrySwarm => 0x52,
            ProviderTier::ClusterCache | ProviderTier::CacheSwarm => 0x43,
            _ => 0,
        }
    }

    /// Is `tier` backed by one of the governed shared services?
    pub fn governs(tier: ProviderTier) -> bool {
        Admission::service_salt(tier) != 0
    }

    /// The decision-stream seed (peer-admission streams derive from it).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Probability one fetch attempt against `tier` is shed:
    /// `(demand − slots) / demand`, 0 when the tier keeps up.
    pub fn shed_prob(&self, tier: ProviderTier) -> f64 {
        let slots = self.slots_for(tier);
        if slots == u32::MAX || self.demand <= slots {
            return 0.0;
        }
        (self.demand - slots) as f64 / self.demand as f64
    }

    /// Is attempt `attempt` of `(artifact, node)` against `tier` shed?
    /// The attempt at `shed_retries` is always admitted (delay, never
    /// starvation). Pure in `(seed, tier, artifact, node, attempt)`.
    pub fn sheds(&self, tier: ProviderTier, artifact: u64, node: usize, attempt: u32) -> bool {
        if attempt >= self.max_retries {
            return false;
        }
        let p = self.shed_prob(tier);
        if p <= 0.0 {
            return false;
        }
        let x = mix64(
            self.seed
                ^ SALT_SHED
                ^ Admission::service_salt(tier).wrapping_mul(0xD6E8_FEB8_6659_FD93)
                ^ artifact.wrapping_mul(0x9E3779B97F4A7C15)
                ^ (node as u64).wrapping_mul(0xC2B2AE3D27D4EB4F)
                ^ (attempt as u64).wrapping_mul(0x165667B19E3779F9),
        );
        unit(x) < p
    }

    /// Backoff before retry `attempt + 1`: `backoff_s · 2^attempt`,
    /// jittered by a seeded factor in `[0.5, 1.5)` so shed retries don't
    /// re-collide in phase.
    pub fn backoff_s(&self, artifact: u64, node: usize, attempt: u32) -> f64 {
        let x = mix64(
            self.seed
                ^ SALT_BACKOFF
                ^ artifact.wrapping_mul(0x9E3779B97F4A7C15)
                ^ (node as u64).wrapping_mul(0xC2B2AE3D27D4EB4F)
                ^ (attempt as u64).wrapping_mul(0x165667B19E3779F9),
        );
        self.backoff_s * (1u64 << attempt.min(62)) as f64 * (0.5 + unit(x))
    }

    /// How many consecutive attempts of `(artifact, node)` are shed
    /// before one is admitted (0 = admitted immediately; capped at
    /// `shed_retries` by construction).
    pub fn shed_attempts(&self, tier: ProviderTier, artifact: u64, node: usize) -> u32 {
        let mut a = 0u32;
        while self.sheds(tier, artifact, node, a) {
            a += 1;
        }
        a
    }

    /// Total seconds `(artifact, node)` waits out in backoff before its
    /// admitted attempt; 0 when the first attempt is admitted.
    pub fn delay_before(&self, tier: ProviderTier, artifact: u64, node: usize) -> f64 {
        let n = self.shed_attempts(tier, artifact, node);
        let mut d = 0.0;
        for a in 0..n {
            d += self.backoff_s(artifact, node, a);
        }
        d
    }
}

/// Swarm peers a cache under eviction pressure still fields: each peer
/// keeps serving with probability `1 − pressure` (a peer about to evict
/// the chunks it would serve is not a useful peer). Pure in
/// `(seed, peer index)`; pressure 0 admits every peer — the historical
/// swarm, byte-identical.
pub fn admitted_peers(n_peers: u32, pressure: f64, seed: u64) -> u32 {
    if pressure <= 0.0 || n_peers == 0 {
        return n_peers;
    }
    let mut n = 0u32;
    for i in 0..n_peers {
        let x = mix64(seed ^ SALT_PEER ^ (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        if unit(x) >= pressure {
            n += 1;
        }
    }
    n
}

/// A provider bound to a sim: swarm tiers carry their (scoped) pool, the
/// rest resolve per fetch. Build once per artifact movement, fetch once
/// per node.
pub struct TransferPlanner {
    tier: ProviderTier,
    swarm: Option<Swarm>,
    admission: Option<Admission>,
    /// Identity of the artifact this planner moves, for the admission
    /// decision streams.
    artifact: u64,
}

impl TransferPlanner {
    /// Bind `tier` to the sim. Swarm tiers register a *scoped* pool named
    /// `name` that retires after exactly `uses` fetches (`n_peers` sizes
    /// its steady-state capacity); every other tier ignores the three
    /// parameters. On a non-flat topology each swarm fetch splits into an
    /// in-rack and a cross-rack flow, so the pool's use budget doubles.
    pub fn build(
        cs: &mut ClusterSim,
        name: &str,
        tier: ProviderTier,
        n_peers: u32,
        uses: u32,
    ) -> TransferPlanner {
        let pool_uses = if cs.topo.is_flat() { uses } else { uses * 2 };
        let swarm = match tier {
            ProviderTier::RegistrySwarm => Some(Swarm::build_scoped(
                &mut cs.sim,
                name,
                cs.cfg.registry_egress_bps,
                n_peers,
                cs.cfg.node_nic_bps,
                pool_uses,
            )),
            ProviderTier::CacheSwarm => Some(Swarm::build_scoped(
                &mut cs.sim,
                name,
                cs.cfg.cluster_cache_egress_bps,
                n_peers,
                cs.cfg.node_nic_bps,
                pool_uses,
            )),
            _ => None,
        };
        TransferPlanner { tier, swarm, admission: None, artifact: 0 }
    }

    /// Attach admission control for `artifact`'s decision streams.
    /// `None` (the default) admits everything immediately — the
    /// historical DAG, bit for bit.
    pub fn with_admission(mut self, admission: Option<Admission>, artifact: u64) -> Self {
        self.admission = admission;
        self.artifact = artifact;
        self
    }

    /// The bound tier.
    pub fn tier(&self) -> ProviderTier {
        self.tier
    }

    /// Consecutive shed attempts `node`'s fetch rides out before being
    /// admitted (0 without admission control — and then no extra task is
    /// ever laid down).
    pub fn shed_attempts(&self, node: NodeHandle) -> u32 {
        self.admission
            .as_ref()
            .map_or(0, |a| a.shed_attempts(self.tier, self.artifact, node.index()))
    }

    /// Move `bytes` onto `node` after `deps`; returns the completion task.
    /// Fractional byte counts are allowed (the lazy loader fetches
    /// per-batch fractions); use [`Self::fetch_u64`] for the stream tier.
    pub fn fetch(
        &self,
        cs: &mut ClusterSim,
        node: NodeHandle,
        bytes: f64,
        deps: &[TaskId],
        tag: u64,
    ) -> TaskId {
        let i = node.index();
        // Shed attempts surface as one backoff delay gating the single
        // real fetch: the bytes move exactly once, just later. No shed →
        // no extra task → byte-identical DAG.
        let gated;
        let deps = match &self.admission {
            Some(adm) => {
                let d = adm.delay_before(self.tier, self.artifact, i);
                if d > 0.0 {
                    gated = vec![cs.sim.delay(d, deps, 0)];
                    &gated[..]
                } else {
                    deps
                }
            }
            None => deps,
        };
        match (self.tier, &self.swarm) {
            (ProviderTier::RegistrySwarm | ProviderTier::CacheSwarm, Some(sw)) => {
                if cs.topo.is_flat() {
                    return sw.download(&mut cs.sim, bytes, cs.node_nic[i], deps, tag);
                }
                // Split by peer locality: the in-rack share stays under
                // the ToR, the cross-rack share crosses the tree tiers.
                // Both flows are always laid down (a zero-byte flow
                // completes instantly) so the scoped pool's doubled use
                // budget is consumed exactly.
                let peers = cs.nodes().saturating_sub(1);
                let cross_frac = if peers == 0 {
                    0.0
                } else {
                    (peers - cs.topo.in_rack_peers(node)) as f64 / peers as f64
                };
                let local = sw.download(
                    &mut cs.sim,
                    bytes * (1.0 - cross_frac),
                    cs.node_nic[i],
                    deps,
                    tag,
                );
                let mut cross_path = vec![sw.pool, cs.node_nic[i]];
                cross_path.extend(cs.tier_path(node));
                let cross = cs.sim.flow(bytes * cross_frac, cross_path, deps, tag);
                cs.sim.barrier(&[local, cross], tag)
            }
            (ProviderTier::RegistrySwarm | ProviderTier::CacheSwarm, None) => {
                unreachable!("swarm tiers always carry a pool")
            }
            (ProviderTier::ClusterCache, _) => {
                let mut path = vec![cs.cache, cs.node_nic[i]];
                path.extend(cs.tier_path(node));
                cs.sim.flow(bytes, path, deps, tag)
            }
            (ProviderTier::Registry, _) => {
                let mut path = vec![cs.registry, cs.node_nic[i], cs.node_disk[i]];
                path.extend(cs.tier_path(node));
                cs.sim.flow(bytes, path, deps, tag)
            }
            (ProviderTier::Scm, _) => {
                let mut path = vec![cs.scm, cs.node_nic[i]];
                path.extend(cs.tier_path(node));
                cs.sim.flow(bytes, path, deps, tag)
            }
            (ProviderTier::Hdfs { nn_op }, _) => {
                let group = cs.hdfs_group_of(node);
                let gate = if nn_op {
                    vec![cs.sim.delay(cs.cfg.hdfs_nn_op_s, deps, 0)]
                } else {
                    deps.to_vec()
                };
                let mut path = vec![group, cs.node_nic[i]];
                path.extend(cs.tier_path(node));
                cs.sim.flow(bytes, path, &gate, tag)
            }
            (ProviderTier::HdfsStream(_), _) => {
                panic!("HdfsStream reads whole-byte shards; use fetch_u64")
            }
        }
    }

    /// [`Self::fetch`] for whole-byte artifacts; the stream tier routes
    /// through the HDFS-FUSE read planner.
    pub fn fetch_u64(
        &self,
        cs: &mut ClusterSim,
        node: NodeHandle,
        bytes: u64,
        deps: &[TaskId],
        tag: u64,
    ) -> TaskId {
        match self.tier {
            ProviderTier::HdfsStream(engine) => {
                plan_read(cs, node.index(), bytes, engine, deps, tag)
            }
            _ => self.fetch(cs, node, bytes as f64, deps, tag),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::sim::engine::Capacity;

    fn sim(nodes: u32) -> ClusterSim {
        ClusterSim::build(&ClusterConfig::with_nodes(nodes), 42)
    }

    fn n0() -> NodeHandle {
        NodeHandle::new(0)
    }

    #[test]
    fn cache_tier_matches_direct_flow() {
        // The planner's flow must be indistinguishable from the bespoke
        // path the loaders used to build.
        let mut a = sim(1);
        let p = TransferPlanner::build(&mut a, "x", ProviderTier::ClusterCache, 0, 0);
        let t = p.fetch(&mut a, n0(), 1_000_000_000.0, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let path = vec![b.cache, b.node_nic[0]];
        let t2 = b.sim.flow(1_000_000_000.0, path, &[], 1);
        b.sim.run();
        assert_eq!(a.sim.finished_at(t).to_bits(), b.sim.finished_at(t2).to_bits());
    }

    #[test]
    fn swarm_tier_builds_one_scoped_pool() {
        let mut cs = sim(4);
        let before = cs.sim.resource_slots();
        let p = TransferPlanner::build(&mut cs, "t.swarm", ProviderTier::CacheSwarm, 4, 4);
        assert_eq!(cs.sim.resource_slots(), before + 1);
        for i in 0..4 {
            p.fetch(&mut cs, NodeHandle::new(i), 1000.0, &[], 0);
        }
        cs.sim.run();
        // Scoped: the pool slot recycles after its declared uses.
        let fresh = cs.sim.add_resource("fresh", Capacity::Fixed(1.0));
        assert_eq!(fresh.0, p.swarm.as_ref().unwrap().pool.0);
    }

    #[test]
    fn hdfs_tier_charges_nn_op_only_when_asked() {
        let mut a = sim(1);
        let with_nn = TransferPlanner::build(&mut a, "x", ProviderTier::Hdfs { nn_op: true }, 0, 0);
        let t = with_nn.fetch(&mut a, n0(), 0.0, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let without =
            TransferPlanner::build(&mut b, "x", ProviderTier::Hdfs { nn_op: false }, 0, 0);
        let t2 = without.fetch(&mut b, n0(), 0.0, &[], 1);
        b.sim.run();
        assert!(a.sim.finished_at(t) > b.sim.finished_at(t2));
        assert_eq!(b.sim.finished_at(t2), 0.0);
    }

    #[test]
    fn stream_tier_routes_through_fuse_planner() {
        let mut a = sim(1);
        let p = TransferPlanner::build(
            &mut a,
            "x",
            ProviderTier::HdfsStream(ReadEngine::Striped),
            0,
            0,
        );
        let t = p.fetch_u64(&mut a, n0(), 2_000_000, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let t2 = plan_read(&mut b, 0, 2_000_000, ReadEngine::Striped, &[], 1);
        b.sim.run();
        assert_eq!(a.sim.finished_at(t).to_bits(), b.sim.finished_at(t2).to_bits());
    }

    #[test]
    fn registry_tier_stages_through_disk() {
        // Slower than the cache tier for the same bytes at equal deps: the
        // disk leg and the smaller registry egress both bind.
        let mut a = sim(1);
        let reg = TransferPlanner::build(&mut a, "x", ProviderTier::Registry, 0, 0);
        let t = reg.fetch(&mut a, n0(), 50e9, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let cache = TransferPlanner::build(&mut b, "x", ProviderTier::ClusterCache, 0, 0);
        let t2 = cache.fetch(&mut b, n0(), 50e9, &[], 1);
        b.sim.run();
        assert!(a.sim.finished_at(t) >= b.sim.finished_at(t2));
    }

    #[test]
    fn fragmented_swarm_pays_the_spine_core() {
        // Same swarm fetch, same bytes: a fully in-rack placement never
        // touches the tight spine core, a one-node-per-rack placement
        // sends every byte through it.
        let cfg = ClusterConfig {
            racks: 4,
            spines: 2,
            spine_core_bps: crate::config::defaults::NODE_NIC_BPS / 10.0,
            ..ClusterConfig::with_nodes(4)
        };
        let run = |placement: &[u32]| {
            let mut cs = ClusterSim::build_placed(&cfg, 42, Some(placement));
            let p = TransferPlanner::build(&mut cs, "x", ProviderTier::CacheSwarm, 3, 1);
            let t = p.fetch(&mut cs, n0(), 1e9, &[], 1);
            cs.sim.run();
            cs.sim.finished_at(t)
        };
        let packed = run(&[0, 0, 0, 0]);
        let fragmented = run(&[0, 1, 2, 3]);
        assert!(
            fragmented > packed,
            "cross-rack swarm bytes must bind on the core: {fragmented} vs {packed}"
        );
    }

    #[test]
    fn generous_tree_matches_flat_service_time() {
        // With auto-sized (non-blocking) uplinks and a 1.0 oversub core,
        // a single service fetch sees the same bottleneck as the flat
        // star — the tree changes the path, not the rate.
        let flat_cfg = ClusterConfig::with_nodes(4);
        let mut flat = sim(4);
        let p = TransferPlanner::build(&mut flat, "x", ProviderTier::ClusterCache, 0, 0);
        let t = p.fetch(&mut flat, n0(), 1e9, &[], 1);
        flat.sim.run();
        let tree_cfg = ClusterConfig { racks: 2, spines: 2, ..flat_cfg };
        let mut tree = ClusterSim::build(&tree_cfg, 42);
        let q = TransferPlanner::build(&mut tree, "x", ProviderTier::ClusterCache, 0, 0);
        let t2 = q.fetch(&mut tree, n0(), 1e9, &[], 1);
        tree.sim.run();
        assert_eq!(flat.sim.finished_at(t), tree.sim.finished_at(t2));
    }

    // ---- admission control (load shedding & retry backoff) -------------

    fn storm_admission(demand: u32, seed: u64) -> Admission {
        Admission::from_faults(&FaultConfig::storm(), demand, seed)
            .expect("storm has finite slots")
    }

    #[test]
    fn unlimited_slots_build_no_admission() {
        assert_eq!(Admission::from_faults(&FaultConfig::off(), 500, 1), None);
        assert_eq!(Admission::from_faults(&FaultConfig::paper(), 500, 1), None);
        // Nothing contending → nothing to shed.
        assert_eq!(Admission::from_faults(&FaultConfig::storm(), 0, 1), None);
        // Demand within the entitlement → zero shed probability.
        let adm = storm_admission(64, 1);
        assert_eq!(adm.shed_prob(ProviderTier::Registry), 0.0);
        assert!(!adm.sheds(ProviderTier::Registry, 9, 0, 0));
        // Unshared tiers are never governed.
        let adm = storm_admission(4096, 1);
        assert_eq!(adm.shed_prob(ProviderTier::Scm), 0.0);
        assert_eq!(adm.shed_prob(ProviderTier::Hdfs { nn_op: true }), 0.0);
    }

    #[test]
    fn shed_then_retry_fetches_exactly_once_shifted_by_backoff() {
        let adm = storm_admission(1024, 7);
        let art = (0..256u64)
            .find(|&a| adm.shed_attempts(ProviderTier::ClusterCache, a, 0) >= 1)
            .expect("p = (1024-96)/1024: some artifact sheds");
        let d = adm.delay_before(ProviderTier::ClusterCache, art, 0);
        assert!(d > 0.0);
        let mut a = sim(1);
        let p = TransferPlanner::build(&mut a, "x", ProviderTier::ClusterCache, 0, 0)
            .with_admission(Some(adm), art);
        assert!(p.shed_attempts(n0()) >= 1);
        let t = p.fetch(&mut a, n0(), 1e9, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let q = TransferPlanner::build(&mut b, "x", ProviderTier::ClusterCache, 0, 0);
        let t2 = q.fetch(&mut b, n0(), 1e9, &[], 1);
        b.sim.run();
        // One fetch, shifted by exactly the backoff: the flow itself is
        // the same single task, so the bytes move (and count) once.
        assert!(
            (a.sim.finished_at(t) - (b.sim.finished_at(t2) + d)).abs() < 1e-9,
            "shed fetch must be the unshifted fetch plus its backoff"
        );
    }

    #[test]
    fn admitted_first_try_is_bit_identical_to_no_admission() {
        let adm = storm_admission(1024, 7);
        let art = (0..256u64)
            .find(|&a| adm.shed_attempts(ProviderTier::ClusterCache, a, 0) == 0)
            .expect("some artifact is admitted immediately");
        let mut a = sim(1);
        let p = TransferPlanner::build(&mut a, "x", ProviderTier::ClusterCache, 0, 0)
            .with_admission(Some(adm), art);
        let t = p.fetch(&mut a, n0(), 1e9, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let q = TransferPlanner::build(&mut b, "x", ProviderTier::ClusterCache, 0, 0);
        let t2 = q.fetch(&mut b, n0(), 1e9, &[], 1);
        b.sim.run();
        assert_eq!(a.sim.finished_at(t).to_bits(), b.sim.finished_at(t2).to_bits());
    }

    #[test]
    fn backoff_schedule_reproducible_and_bounded() {
        let a = storm_admission(512, 11);
        let b = storm_admission(512, 11);
        let c = storm_admission(512, 12);
        let mut differs = false;
        for att in 0..4u32 {
            let x = a.backoff_s(5, 3, att);
            assert_eq!(x.to_bits(), b.backoff_s(5, 3, att).to_bits());
            differs |= x.to_bits() != c.backoff_s(5, 3, att).to_bits();
            let base = FaultConfig::storm().shed_backoff_s * (1u64 << att) as f64;
            assert!(x >= base * 0.5 && x < base * 1.5, "attempt {att}: {x}");
        }
        assert!(differs, "the seed must key the schedule");
        // delay_before is the sum of the shed attempts' backoffs.
        let n = a.shed_attempts(ProviderTier::Registry, 5, 3);
        let sum: f64 = (0..n).map(|k| a.backoff_s(5, 3, k)).sum();
        assert_eq!(a.delay_before(ProviderTier::Registry, 5, 3).to_bits(), sum.to_bits());
        // The terminal attempt is always admitted: delay, never
        // starvation.
        assert!(!a.sheds(ProviderTier::Registry, 5, 3, FaultConfig::storm().shed_retries));
        assert!(n <= FaultConfig::storm().shed_retries);
    }

    #[test]
    fn shed_rate_tracks_excess_demand() {
        let adm = storm_admission(384, 3);
        // Cache tier: p = (384 − 96) / 384 = 0.75.
        let shed = (0..2000u64)
            .filter(|&a| adm.sheds(ProviderTier::CacheSwarm, a, 0, 0))
            .count() as f64
            / 2000.0;
        assert!((shed - 0.75).abs() < 0.05, "cache shed rate {shed}");
        // Registry tier: p = (384 − 64) / 384 ≈ 0.833, an independent
        // stream.
        let reg = (0..2000u64)
            .filter(|&a| adm.sheds(ProviderTier::Registry, a, 0, 0))
            .count() as f64
            / 2000.0;
        assert!((reg - 320.0 / 384.0).abs() < 0.05, "registry shed rate {reg}");
    }

    #[test]
    fn peer_admission_thins_the_swarm_under_pressure() {
        assert_eq!(admitted_peers(8, 0.0, 9), 8);
        assert_eq!(admitted_peers(8, 1.0, 9), 0);
        assert_eq!(admitted_peers(0, 0.7, 9), 0);
        let n = admitted_peers(64, 0.5, 9);
        assert!(n > 8 && n < 56, "half pressure thins roughly half: {n}");
        assert_eq!(n, admitted_peers(64, 0.5, 9));
    }
}
