//! The single transfer plane: materialize artifact bytes on a node from a
//! tiered provider.
//!
//! Every foreground byte the startup pipeline moves now flows through one
//! [`TransferPlanner`]: the image loaders, the environment installer, the
//! checkpoint resume and the stage-graph's speculative stager all pick a
//! [`ProviderTier`] and call [`TransferPlanner::fetch`] instead of
//! hand-rolling `Swarm` pools and flow paths per subsystem. The tier
//! encodes exactly the transport the pre-refactor subsystems used, so a
//! default-config run lays down a bit-identical task DAG:
//!
//! | tier            | path                                  | was |
//! |-----------------|---------------------------------------|-----|
//! | `RegistrySwarm` | P2P pool fed by the registry → NIC    | `image/loader.rs` OCI pull |
//! | `CacheSwarm`    | P2P pool fed by the block cache → NIC | hot-set prefetch, spec staging |
//! | `ClusterCache`  | block-cache egress → NIC              | lazy misses, non-P2P prefetch |
//! | `Registry`      | registry egress → NIC → local disk    | non-P2P OCI pull |
//! | `Scm`           | SCM backend → NIC                     | `env/installer.rs` package pulls |
//! | `Hdfs{nn_op}`   | [NameNode op →] DataNode group → NIC  | env-cache restore, spec staging |
//! | `HdfsStream`    | `hdfs::fuse::plan_read` engine        | `ckpt/resume.rs` resume reads |
//!
//! The *local disk* tier is implicit: bytes already resident per
//! [`crate::artifact::cache::CacheState`] are subtracted before `fetch` is
//! ever called, and never cross the network again.

use crate::hdfs::fuse::{plan_read, ReadEngine};
use crate::image::p2p::Swarm;
use crate::sim::{ClusterSim, TaskId};

/// Where a transfer pulls its bytes from (in preference order behind the
/// implicit local-disk tier).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProviderTier {
    /// P2P swarm fed by the container registry (full-image pulls, P2P on).
    RegistrySwarm,
    /// P2P swarm fed by the cluster block cache (hot-set prefetch and
    /// speculative staging, P2P on).
    CacheSwarm,
    /// Cluster block-cache egress, direct (P2P off, lazy miss service).
    ClusterCache,
    /// Container registry egress, staged through the node's local disk
    /// (the traditional OCI pull path).
    Registry,
    /// SCM / package backend (throttled shared service).
    Scm,
    /// An HDFS DataNode group, round-robin by node. `nn_op` charges one
    /// NameNode lookup before the transfer (the env-cache restore does;
    /// the speculative stager's pre-opened handle does not).
    Hdfs { nn_op: bool },
    /// A checkpoint read through HDFS-FUSE ([`plan_read`]): sequential
    /// download-and-resume or BootSeer's striped engine.
    HdfsStream(ReadEngine),
}

/// A provider bound to a sim: swarm tiers carry their (scoped) pool, the
/// rest resolve per fetch. Build once per artifact movement, fetch once
/// per node.
pub struct TransferPlanner {
    tier: ProviderTier,
    swarm: Option<Swarm>,
}

impl TransferPlanner {
    /// Bind `tier` to the sim. Swarm tiers register a *scoped* pool named
    /// `name` that retires after exactly `uses` fetches (`n_peers` sizes
    /// its steady-state capacity); every other tier ignores the three
    /// parameters.
    pub fn build(
        cs: &mut ClusterSim,
        name: &str,
        tier: ProviderTier,
        n_peers: u32,
        uses: u32,
    ) -> TransferPlanner {
        let swarm = match tier {
            ProviderTier::RegistrySwarm => Some(Swarm::build_scoped(
                &mut cs.sim,
                name,
                cs.cfg.registry_egress_bps,
                n_peers,
                cs.cfg.node_nic_bps,
                uses,
            )),
            ProviderTier::CacheSwarm => Some(Swarm::build_scoped(
                &mut cs.sim,
                name,
                cs.cfg.cluster_cache_egress_bps,
                n_peers,
                cs.cfg.node_nic_bps,
                uses,
            )),
            _ => None,
        };
        TransferPlanner { tier, swarm }
    }

    /// The bound tier.
    pub fn tier(&self) -> ProviderTier {
        self.tier
    }

    /// Move `bytes` onto `node` after `deps`; returns the completion task.
    /// Fractional byte counts are allowed (the lazy loader fetches
    /// per-batch fractions); use [`Self::fetch_u64`] for the stream tier.
    pub fn fetch(
        &self,
        cs: &mut ClusterSim,
        node: usize,
        bytes: f64,
        deps: &[TaskId],
        tag: u64,
    ) -> TaskId {
        match (self.tier, &self.swarm) {
            (ProviderTier::RegistrySwarm | ProviderTier::CacheSwarm, Some(sw)) => {
                sw.download(&mut cs.sim, bytes, cs.node_nic[node], deps, tag)
            }
            (ProviderTier::RegistrySwarm | ProviderTier::CacheSwarm, None) => {
                unreachable!("swarm tiers always carry a pool")
            }
            (ProviderTier::ClusterCache, _) => {
                let path = vec![cs.cache, cs.node_nic[node]];
                cs.sim.flow(bytes, path, deps, tag)
            }
            (ProviderTier::Registry, _) => {
                let path = vec![cs.registry, cs.node_nic[node], cs.node_disk[node]];
                cs.sim.flow(bytes, path, deps, tag)
            }
            (ProviderTier::Scm, _) => {
                let path = vec![cs.scm, cs.node_nic[node]];
                cs.sim.flow(bytes, path, deps, tag)
            }
            (ProviderTier::Hdfs { nn_op }, _) => {
                let group = cs.hdfs_group_of(node);
                let gate = if nn_op {
                    vec![cs.sim.delay(cs.cfg.hdfs_nn_op_s, deps, 0)]
                } else {
                    deps.to_vec()
                };
                cs.sim.flow(bytes, vec![group, cs.node_nic[node]], &gate, tag)
            }
            (ProviderTier::HdfsStream(_), _) => {
                panic!("HdfsStream reads whole-byte shards; use fetch_u64")
            }
        }
    }

    /// [`Self::fetch`] for whole-byte artifacts; the stream tier routes
    /// through the HDFS-FUSE read planner.
    pub fn fetch_u64(
        &self,
        cs: &mut ClusterSim,
        node: usize,
        bytes: u64,
        deps: &[TaskId],
        tag: u64,
    ) -> TaskId {
        match self.tier {
            ProviderTier::HdfsStream(engine) => plan_read(cs, node, bytes, engine, deps, tag),
            _ => self.fetch(cs, node, bytes as f64, deps, tag),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::sim::engine::Capacity;

    fn sim(nodes: u32) -> ClusterSim {
        ClusterSim::build(&ClusterConfig::with_nodes(nodes), 42)
    }

    #[test]
    fn cache_tier_matches_direct_flow() {
        // The planner's flow must be indistinguishable from the bespoke
        // path the loaders used to build.
        let mut a = sim(1);
        let p = TransferPlanner::build(&mut a, "x", ProviderTier::ClusterCache, 0, 0);
        let t = p.fetch(&mut a, 0, 1_000_000_000.0, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let path = vec![b.cache, b.node_nic[0]];
        let t2 = b.sim.flow(1_000_000_000.0, path, &[], 1);
        b.sim.run();
        assert_eq!(a.sim.finished_at(t).to_bits(), b.sim.finished_at(t2).to_bits());
    }

    #[test]
    fn swarm_tier_builds_one_scoped_pool() {
        let mut cs = sim(4);
        let before = cs.sim.resource_slots();
        let p = TransferPlanner::build(&mut cs, "t.swarm", ProviderTier::CacheSwarm, 4, 4);
        assert_eq!(cs.sim.resource_slots(), before + 1);
        for i in 0..4 {
            p.fetch(&mut cs, i, 1000.0, &[], 0);
        }
        cs.sim.run();
        // Scoped: the pool slot recycles after its declared uses.
        let fresh = cs.sim.add_resource("fresh", Capacity::Fixed(1.0));
        assert_eq!(fresh.0, p.swarm.as_ref().unwrap().pool.0);
    }

    #[test]
    fn hdfs_tier_charges_nn_op_only_when_asked() {
        let mut a = sim(1);
        let with_nn = TransferPlanner::build(&mut a, "x", ProviderTier::Hdfs { nn_op: true }, 0, 0);
        let t = with_nn.fetch(&mut a, 0, 0.0, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let without =
            TransferPlanner::build(&mut b, "x", ProviderTier::Hdfs { nn_op: false }, 0, 0);
        let t2 = without.fetch(&mut b, 0, 0.0, &[], 1);
        b.sim.run();
        assert!(a.sim.finished_at(t) > b.sim.finished_at(t2));
        assert_eq!(b.sim.finished_at(t2), 0.0);
    }

    #[test]
    fn stream_tier_routes_through_fuse_planner() {
        let mut a = sim(1);
        let p = TransferPlanner::build(
            &mut a,
            "x",
            ProviderTier::HdfsStream(ReadEngine::Striped),
            0,
            0,
        );
        let t = p.fetch_u64(&mut a, 0, 2_000_000, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let t2 = plan_read(&mut b, 0, 2_000_000, ReadEngine::Striped, &[], 1);
        b.sim.run();
        assert_eq!(a.sim.finished_at(t).to_bits(), b.sim.finished_at(t2).to_bits());
    }

    #[test]
    fn registry_tier_stages_through_disk() {
        // Slower than the cache tier for the same bytes at equal deps: the
        // disk leg and the smaller registry egress both bind.
        let mut a = sim(1);
        let reg = TransferPlanner::build(&mut a, "x", ProviderTier::Registry, 0, 0);
        let t = reg.fetch(&mut a, 0, 50e9, &[], 1);
        a.sim.run();
        let mut b = sim(1);
        let cache = TransferPlanner::build(&mut b, "x", ProviderTier::ClusterCache, 0, 0);
        let t2 = cache.fetch(&mut b, 0, 50e9, &[], 1);
        b.sim.run();
        assert!(a.sim.finished_at(t) >= b.sim.finished_at(t2));
    }
}
