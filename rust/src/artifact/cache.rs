//! Per-node residency tracking for content-addressed artifacts.
//!
//! A [`CacheState`] answers one question for the transfer plane: *how many
//! bytes of manifest M are already on node i's local disk?* Everything
//! that used to be a bespoke byte-credit side channel — PR 2's
//! `prestaged` vectors, PR 3's warm-restart `local_{image,env}_bytes` —
//! is now an entry here:
//!
//! * **Artifact-scoped residency** — "the first `b` bytes of artifact `a`
//!   are resident" (a staged prefix, a warm restart's surviving hot set, a
//!   delta-resume retained checkpoint). This is the default-config path
//!   and is exact prefix arithmetic, no chunk walk.
//! * **Chunk-level residency** — digest → resident bytes, consulted only
//!   when cross-artifact dedup is enabled: a chunk of manifest M counts as
//!   resident if its *content digest* landed via any other artifact (an
//!   env-snapshot chunk duplicating an image hot block).
//!
//! Residency is tracked per node plus a `shared` layer that applies to
//! every node of the allocation (the warm-restart case: all nodes of the
//! restarted job kept their local state). All maps are `BTreeMap` so no
//! iteration order can leak into simulation results.

use crate::artifact::manifest::ArtifactManifest;
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
struct Layer {
    /// artifact id → resident prefix bytes.
    artifacts: BTreeMap<u64, u64>,
    /// chunk digest → resident bytes of that chunk's content.
    chunks: BTreeMap<u64, u64>,
}

impl Layer {
    fn add_artifact(&mut self, id: u64, bytes: u64) {
        let e = self.artifacts.entry(id).or_insert(0);
        *e = e.saturating_add(bytes);
    }

    fn add_chunks(&mut self, m: &ArtifactManifest) {
        for c in &m.chunks {
            let e = self.chunks.entry(c.digest).or_insert(0);
            *e = (*e).max(c.bytes);
        }
    }
}

/// Chunks resident across the nodes of one allocation (one startup's
/// scope: built from the previous attempt's state, mutated as stages
/// materialize artifacts during the run).
#[derive(Clone, Debug, Default)]
pub struct CacheState {
    shared: Layer,
    per_node: BTreeMap<usize, Layer>,
}

impl CacheState {
    pub fn new() -> CacheState {
        CacheState::default()
    }

    /// Nothing resident anywhere?
    pub fn is_empty(&self) -> bool {
        self.shared.artifacts.is_empty()
            && self.shared.chunks.is_empty()
            && self.per_node.is_empty()
    }

    /// Record the first `bytes` of artifact `id` resident on every node.
    pub fn insert_shared_artifact(&mut self, id: u64, bytes: u64) {
        if bytes > 0 {
            self.shared.add_artifact(id, bytes);
        }
    }

    /// Record the first `bytes` of artifact `id` resident on node `node`.
    pub fn insert_node_artifact(&mut self, node: usize, id: u64, bytes: u64) {
        if bytes > 0 {
            self.per_node.entry(node).or_default().add_artifact(id, bytes);
        }
    }

    /// Record every chunk of `m` resident on every node (content-level
    /// entry, feeds cross-artifact dedup).
    pub fn insert_shared_chunks(&mut self, m: &ArtifactManifest) {
        self.shared.add_chunks(m);
        self.shared.add_artifact(m.id, m.total_bytes());
    }

    /// Record every chunk of `m` resident on node `node`.
    pub fn insert_node_chunks(&mut self, node: usize, m: &ArtifactManifest) {
        let layer = self.per_node.entry(node).or_default();
        layer.add_chunks(m);
        layer.add_artifact(m.id, m.total_bytes());
    }

    /// Drop artifact `id` everywhere (eviction: a relocated restart, local
    /// disk reclaimed). Chunk-level entries inserted via `insert_*_chunks`
    /// for other artifacts are unaffected.
    pub fn evict_artifact(&mut self, id: u64) {
        self.shared.artifacts.remove(&id);
        for layer in self.per_node.values_mut() {
            layer.artifacts.remove(&id);
        }
    }

    fn artifact_prefix(&self, node: usize, id: u64) -> u64 {
        let shared = self.shared.artifacts.get(&id).copied().unwrap_or(0);
        let local = self
            .per_node
            .get(&node)
            .and_then(|l| l.artifacts.get(&id))
            .copied()
            .unwrap_or(0);
        shared.saturating_add(local)
    }

    fn chunk_resident(&self, node: usize, digest: u64) -> u64 {
        let shared = self.shared.chunks.get(&digest).copied().unwrap_or(0);
        let local = self
            .per_node
            .get(&node)
            .and_then(|l| l.chunks.get(&digest))
            .copied()
            .unwrap_or(0);
        shared.max(local)
    }

    /// Bytes of manifest `m` already resident on `node`.
    ///
    /// Without `dedup` this is exact prefix arithmetic over the
    /// artifact-scoped entries — `min(resident prefix, total)` — the path
    /// every default-config replay takes. With `dedup` the chunk list is
    /// walked: a chunk not covered by the prefix still counts if its
    /// content digest is resident via any other artifact.
    pub fn resident_bytes(&self, node: usize, m: &ArtifactManifest, dedup: bool) -> u64 {
        self.resident_bytes_beyond(node, m, 0, dedup)
    }

    /// [`Self::resident_bytes`], excluding the first `skip_prefix` bytes
    /// of the manifest from the count. The caller uses this when that
    /// prefix is already accounted elsewhere — a speculative staging flow
    /// covering the manifest's head must not be double-credited when its
    /// chunks are also content-resident (they are the shared prefix of an
    /// env snapshot whose blocks the image stage just landed).
    pub fn resident_bytes_beyond(
        &self,
        node: usize,
        m: &ArtifactManifest,
        skip_prefix: u64,
        dedup: bool,
    ) -> u64 {
        let prefix = self.artifact_prefix(node, m.id).min(m.total_bytes());
        // Chunkless summary manifests carry no content digests to walk;
        // prefix arithmetic is all there is for them even under dedup.
        if !dedup || m.chunks.is_empty() {
            return prefix.saturating_sub(skip_prefix.min(m.total_bytes()));
        }
        let mut covered = 0u64;
        let mut cum = 0u64;
        for c in &m.chunks {
            let by_skip = skip_prefix.saturating_sub(cum).min(c.bytes);
            let by_prefix = prefix.saturating_sub(cum).min(c.bytes);
            let by_content = self.chunk_resident(node, c.digest).min(c.bytes);
            covered += by_prefix.max(by_content).saturating_sub(by_skip);
            cum += c.bytes;
        }
        covered.min(m.total_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::manifest::ArtifactManifest;

    fn m(id: u64, total: u64) -> ArtifactManifest {
        ArtifactManifest::synthetic(id, total, 100)
    }

    #[test]
    fn empty_cache_has_nothing() {
        let c = CacheState::new();
        assert!(c.is_empty());
        assert_eq!(c.resident_bytes(0, &m(1, 1000), false), 0);
        assert_eq!(c.resident_bytes(3, &m(1, 1000), true), 0);
    }

    #[test]
    fn shared_prefix_applies_to_every_node() {
        let mut c = CacheState::new();
        c.insert_shared_artifact(7, 350);
        let man = m(7, 1000);
        for node in [0usize, 5, 99] {
            assert_eq!(c.resident_bytes(node, &man, false), 350);
        }
        // Capped at the manifest total.
        c.insert_shared_artifact(7, 10_000);
        assert_eq!(c.resident_bytes(0, &man, false), 1000);
    }

    #[test]
    fn node_entries_are_node_local_and_stack_on_shared() {
        let mut c = CacheState::new();
        c.insert_shared_artifact(7, 100);
        c.insert_node_artifact(2, 7, 250);
        let man = m(7, 1000);
        assert_eq!(c.resident_bytes(0, &man, false), 100);
        assert_eq!(c.resident_bytes(2, &man, false), 350);
    }

    #[test]
    fn dedup_credits_shared_content_across_artifacts() {
        // Artifact B's second chunk duplicates artifact A's content.
        let a = ArtifactManifest::synthetic(1, 300, 100);
        let mut b = ArtifactManifest::synthetic(2, 300, 100);
        b.chunks[1].digest = a.chunks[0].digest;
        let mut c = CacheState::new();
        c.insert_node_chunks(0, &a);
        // Without dedup, B has no residency; with dedup, the duplicated
        // chunk counts.
        assert_eq!(c.resident_bytes(0, &b, false), 0);
        assert_eq!(c.resident_bytes(0, &b, true), 100);
        // And on another node nothing is resident either way.
        assert_eq!(c.resident_bytes(1, &b, true), 0);

        // A chunkless summary manifest credits via prefix arithmetic even
        // under dedup (there are no digests to walk).
        use crate::artifact::manifest::ArtifactKind;
        let s = ArtifactManifest::summary(9, ArtifactKind::Synthetic, 300);
        let mut c2 = CacheState::new();
        c2.insert_shared_artifact(9, 120);
        assert_eq!(c2.resident_bytes(0, &s, true), 120);
    }

    #[test]
    fn dedup_does_not_double_count_prefix_and_content() {
        let a = ArtifactManifest::synthetic(1, 300, 100);
        let mut c = CacheState::new();
        c.insert_node_chunks(0, &a); // records prefix 300 AND all chunks
        assert_eq!(c.resident_bytes(0, &a, true), 300);
        assert_eq!(c.resident_bytes(0, &a, false), 300);
    }

    #[test]
    fn eviction_drops_artifact_scope_only() {
        let a = ArtifactManifest::synthetic(1, 300, 100);
        let mut b = ArtifactManifest::synthetic(2, 100, 100);
        b.chunks[0].digest = a.chunks[2].digest;
        let mut c = CacheState::new();
        c.insert_node_chunks(0, &a);
        c.evict_artifact(a.id);
        assert_eq!(c.resident_bytes(0, &a, false), 0);
        // Content-level entries survive (the bytes are still on disk under
        // another artifact's chunk).
        assert_eq!(c.resident_bytes(0, &b, true), 100);
    }

    #[test]
    fn beyond_prefix_excludes_already_counted_bytes() {
        // Artifact B's first two chunks duplicate A's content; a staging
        // flow already covers B's first 150 bytes. Credit beyond the
        // staged prefix must count only content not in that prefix — no
        // double-counting of the shared head.
        let a = ArtifactManifest::synthetic(1, 300, 100);
        let mut b = ArtifactManifest::synthetic(2, 300, 100);
        b.chunks[0].digest = a.chunks[0].digest;
        b.chunks[1].digest = a.chunks[1].digest;
        let mut c = CacheState::new();
        c.insert_node_chunks(0, &a);
        // Without skip: both shared chunks count.
        assert_eq!(c.resident_bytes(0, &b, true), 200);
        // Skipping the staged 150-byte prefix leaves only the unstaged
        // half of chunk 1.
        assert_eq!(c.resident_bytes_beyond(0, &b, 150, true), 50);
        // Skipping past all shared content leaves nothing.
        assert_eq!(c.resident_bytes_beyond(0, &b, 200, true), 0);
        // Non-dedup prefix arithmetic honors the skip too.
        let mut d = CacheState::new();
        d.insert_shared_artifact(9, 250);
        let man = m(9, 1000);
        assert_eq!(d.resident_bytes_beyond(0, &man, 100, false), 150);
        assert_eq!(d.resident_bytes_beyond(0, &man, 400, false), 0);
    }

    #[test]
    fn partial_prefix_counts_partial_tail_chunk() {
        let man = m(9, 1000); // 10 chunks of 100
        let mut c = CacheState::new();
        c.insert_shared_artifact(9, 250);
        assert_eq!(c.resident_bytes(0, &man, false), 250);
        // Chunk walk agrees with prefix arithmetic.
        assert_eq!(c.resident_bytes(0, &man, true), 250);
    }
}
