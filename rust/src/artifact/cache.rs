//! Per-node residency tracking for content-addressed artifacts.
//!
//! A [`CacheState`] answers one question for the transfer plane: *how many
//! bytes of manifest M are already on node i's local disk?* Everything
//! that used to be a bespoke byte-credit side channel — PR 2's
//! `prestaged` vectors, PR 3's warm-restart `local_{image,env}_bytes` —
//! is now an entry here:
//!
//! * **Artifact-scoped residency** — "the first `b` bytes of artifact `a`
//!   are resident" (a staged prefix, a warm restart's surviving hot set, a
//!   delta-resume retained checkpoint). This is the default-config path
//!   and is exact prefix arithmetic, no chunk walk.
//! * **Chunk-level residency** — digest → resident bytes, consulted only
//!   when cross-artifact dedup is enabled: a chunk of manifest M counts as
//!   resident if its *content digest* landed via any other artifact (an
//!   env-snapshot chunk duplicating an image hot block).
//!
//! Residency is tracked per node plus a `shared` layer that applies to
//! every node of the allocation (the warm-restart case: all nodes of the
//! restarted job kept their local state). All maps are `BTreeMap` so no
//! iteration order can leak into simulation results.
//!
//! # Bounded caches & eviction
//!
//! By default the cache is **unbounded** — the assumption every PR up to
//! the cache-economics sweep made, and the code path a default config
//! still takes bit-for-bit. [`CacheState::with_capacity`] bounds it: the
//! artifact-prefix bytes visible to any one node (shared layer + that
//! node's layer) may never exceed the capacity. An insert that overflows
//! trims victims chosen by a [`CachePolicy`] — LRU (least recently
//! *inserted*; the cache has no read clock), size-aware GDSF, or LRU with
//! the hot set pinned. Eviction is a **tail trim**: the victim keeps a
//! shorter resident *prefix*, so the credit arithmetic in
//! `startup::graph` charges a warm restart exactly the evicted bytes.
//! Pinned entries are never chosen as victims; when every candidate is
//! pinned, the *incoming* insert itself is trimmed (admission trim, not
//! counted as eviction). Chunk-level (dedup) entries are an index over
//! the same bytes and are not separately accounted. Every decision is a
//! pure function of the insert sequence — no clock, no RNG — so bounded
//! replays stay byte-identical at any thread count.

use crate::artifact::manifest::ArtifactManifest;
use crate::config::CachePolicy;
use std::collections::BTreeMap;

/// Scope key of the shared layer in the bounded-accounting entry table
/// (per-node scopes use the node index).
const SHARED_SCOPE: usize = usize::MAX;

/// Bounded-mode bookkeeping for one `(scope, artifact)` entry.
#[derive(Clone, Debug)]
struct EntryMeta {
    /// Mirror of the layer's resident prefix for this entry.
    bytes: u64,
    /// Last-insert sequence number (recency).
    seq: u64,
    /// Insert count (GDSF frequency).
    inserts: u64,
    pinned: bool,
    /// GDSF priority at last insert: `inflation + inserts / size_mb`.
    h: f64,
}

/// Capacity accounting of a bounded cache.
#[derive(Clone, Debug)]
struct Bound {
    capacity: u64,
    policy: CachePolicy,
    /// Monotone insert clock (recency source; no wall time).
    seq: u64,
    /// GDSF aging term: priority of the last evicted entry.
    inflation: f64,
    /// Total bytes trimmed from *resident* entries (admission trims of
    /// the insert being admitted are not eviction).
    evicted: u64,
    entries: BTreeMap<(usize, u64), EntryMeta>,
}

#[derive(Clone, Debug, Default)]
struct Layer {
    /// artifact id → resident prefix bytes.
    artifacts: BTreeMap<u64, u64>,
    /// chunk digest → resident bytes of that chunk's content.
    chunks: BTreeMap<u64, u64>,
}

impl Layer {
    fn add_artifact(&mut self, id: u64, bytes: u64) {
        let e = self.artifacts.entry(id).or_insert(0);
        *e = e.saturating_add(bytes);
    }

    fn add_chunks(&mut self, m: &ArtifactManifest) {
        for c in &m.chunks {
            let e = self.chunks.entry(c.digest).or_insert(0);
            *e = (*e).max(c.bytes);
        }
    }
}

/// Chunks resident across the nodes of one allocation (one startup's
/// scope: built from the previous attempt's state, mutated as stages
/// materialize artifacts during the run).
#[derive(Clone, Debug, Default)]
pub struct CacheState {
    shared: Layer,
    per_node: BTreeMap<usize, Layer>,
    /// `None` (the default) is the unbounded legacy cache: inserts never
    /// trim and none of the bounded bookkeeping below runs.
    bound: Option<Bound>,
}

impl CacheState {
    pub fn new() -> CacheState {
        CacheState::default()
    }

    /// A cache bounded at `capacity_bytes` per node view (shared layer +
    /// any one node's layer), trimming by `policy` on overflow.
    /// `u64::MAX` means unbounded and returns the exact legacy
    /// [`CacheState::new`] state — byte-identical behavior, no
    /// bookkeeping.
    pub fn with_capacity(capacity_bytes: u64, policy: CachePolicy) -> CacheState {
        if capacity_bytes == u64::MAX {
            return CacheState::new();
        }
        CacheState {
            bound: Some(Bound {
                capacity: capacity_bytes,
                policy,
                seq: 0,
                inflation: 0.0,
                evicted: 0,
                entries: BTreeMap::new(),
            }),
            ..CacheState::default()
        }
    }

    /// Capacity in bytes, or `None` when unbounded.
    pub fn capacity_bytes(&self) -> Option<u64> {
        self.bound.as_ref().map(|b| b.capacity)
    }

    /// Total bytes trimmed from previously resident entries (admission
    /// trims of an oversized incoming insert do not count).
    pub fn evicted_bytes(&self) -> u64 {
        self.bound.as_ref().map_or(0, |b| b.evicted)
    }

    /// How hard this cache is churning, as evicted bytes over capacity,
    /// clamped to `[0, 1]`. Unbounded caches report `0`. Swarm peer
    /// admission uses this: a peer about to evict what it would serve is
    /// not a useful peer.
    pub fn eviction_pressure(&self) -> f64 {
        match &self.bound {
            Some(b) if b.capacity > 0 => {
                (b.evicted as f64 / b.capacity as f64).clamp(0.0, 1.0)
            }
            Some(b) => {
                if b.evicted > 0 {
                    1.0
                } else {
                    0.0
                }
            }
            None => 0.0,
        }
    }

    /// Artifact-prefix bytes occupying `node`'s view of the cache
    /// (shared layer + that node's layer).
    pub fn used_bytes(&self, node: usize) -> u64 {
        let shared: u64 = self.shared.artifacts.values().sum();
        let local: u64 = self
            .per_node
            .get(&node)
            .map_or(0, |l| l.artifacts.values().sum());
        shared.saturating_add(local)
    }

    /// Pin a shared-layer artifact: never chosen as an eviction victim
    /// (the `pin_hot_set` policy pins the image hot set this way). No-op
    /// on unbounded caches and on entries not yet inserted.
    pub fn pin_shared_artifact(&mut self, id: u64) {
        self.pin(SHARED_SCOPE, id);
    }

    /// Pin a node-layer artifact. See [`Self::pin_shared_artifact`].
    pub fn pin_node_artifact(&mut self, node: usize, id: u64) {
        self.pin(node, id);
    }

    fn pin(&mut self, scope: usize, id: u64) {
        if let Some(b) = &mut self.bound {
            if let Some(m) = b.entries.get_mut(&(scope, id)) {
                m.pinned = true;
            }
        }
    }

    /// Nothing resident anywhere?
    pub fn is_empty(&self) -> bool {
        self.shared.artifacts.is_empty()
            && self.shared.chunks.is_empty()
            && self.per_node.is_empty()
    }

    /// Record the first `bytes` of artifact `id` resident on every node.
    pub fn insert_shared_artifact(&mut self, id: u64, bytes: u64) {
        if bytes > 0 {
            self.shared.add_artifact(id, bytes);
            self.bounded_insert(SHARED_SCOPE, id);
        }
    }

    /// Record the first `bytes` of artifact `id` resident on node `node`.
    pub fn insert_node_artifact(&mut self, node: usize, id: u64, bytes: u64) {
        if bytes > 0 {
            self.per_node.entry(node).or_default().add_artifact(id, bytes);
            self.bounded_insert(node, id);
        }
    }

    /// Record every chunk of `m` resident on every node (content-level
    /// entry, feeds cross-artifact dedup).
    pub fn insert_shared_chunks(&mut self, m: &ArtifactManifest) {
        self.shared.add_chunks(m);
        self.shared.add_artifact(m.id, m.total_bytes());
        self.bounded_insert(SHARED_SCOPE, m.id);
    }

    /// Record every chunk of `m` resident on node `node`.
    pub fn insert_node_chunks(&mut self, node: usize, m: &ArtifactManifest) {
        let layer = self.per_node.entry(node).or_default();
        layer.add_chunks(m);
        layer.add_artifact(m.id, m.total_bytes());
        self.bounded_insert(node, m.id);
    }

    /// Drop artifact `id` everywhere (eviction: a relocated restart, local
    /// disk reclaimed). Chunk-level entries inserted via `insert_*_chunks`
    /// for other artifacts are unaffected. Explicit drops are not counted
    /// in [`Self::evicted_bytes`] — that tracks capacity pressure only.
    pub fn evict_artifact(&mut self, id: u64) {
        self.shared.artifacts.remove(&id);
        for layer in self.per_node.values_mut() {
            layer.artifacts.remove(&id);
        }
        if let Some(b) = &mut self.bound {
            b.entries.retain(|(_, aid), _| *aid != id);
        }
    }

    fn scope_artifact_bytes(&self, scope: usize, id: u64) -> u64 {
        let layer = if scope == SHARED_SCOPE {
            Some(&self.shared)
        } else {
            self.per_node.get(&scope)
        };
        layer
            .and_then(|l| l.artifacts.get(&id))
            .copied()
            .unwrap_or(0)
    }

    /// Bounded-mode bookkeeping after a layer insert of `(scope, id)`:
    /// refresh the entry's meta (recency, frequency, GDSF priority) and
    /// trim victims until the capacity invariant holds again.
    fn bounded_insert(&mut self, scope: usize, id: u64) {
        if self.bound.is_none() {
            return;
        }
        let total = self.scope_artifact_bytes(scope, id);
        let b = self.bound.as_mut().unwrap();
        b.seq += 1;
        let seq = b.seq;
        let inflation = b.inflation;
        let e = b.entries.entry((scope, id)).or_insert(EntryMeta {
            bytes: 0,
            seq: 0,
            inserts: 0,
            pinned: false,
            h: 0.0,
        });
        e.bytes = total;
        e.seq = seq;
        e.inserts += 1;
        let size_mb = (total as f64 / 1e6).max(1e-6);
        e.h = inflation + e.inserts as f64 / size_mb;
        self.enforce((scope, id));
    }

    /// Trim victims until `shared + max-over-nodes ≤ capacity`. The victim
    /// set is the shared layer plus the currently-worst node's layer;
    /// pinned entries are skipped, and if nothing unpinned remains the
    /// incoming entry itself is trimmed (admission trim). A victim is
    /// tail-trimmed only as far as needed — partial eviction keeps a
    /// shorter resident prefix.
    fn enforce(&mut self, incoming: (usize, u64)) {
        loop {
            let Some(b) = self.bound.as_ref() else { return };
            let cap = b.capacity;
            let shared_sum: u64 = self.shared.artifacts.values().sum();
            let mut worst = SHARED_SCOPE;
            let mut worst_sum = 0u64;
            for (n, l) in &self.per_node {
                let s: u64 = l.artifacts.values().sum();
                if s > worst_sum {
                    worst = *n;
                    worst_sum = s;
                }
            }
            let used = shared_sum.saturating_add(worst_sum);
            if used <= cap {
                return;
            }
            let overflow = used - cap;
            let Some(key) = self.pick_victim(worst, incoming) else {
                return;
            };
            let have = self.scope_artifact_bytes(key.0, key.1);
            let trim = overflow.min(have);
            if trim == 0 {
                return;
            }
            self.apply_trim(key, trim, incoming);
        }
    }

    /// Lowest-priority unpinned entry among the shared layer and the worst
    /// node's layer, or the incoming entry when everything else is pinned.
    /// Ordering is total and data-structure-independent: ties break on
    /// `(scope, id)`.
    fn pick_victim(&self, worst: usize, incoming: (usize, u64)) -> Option<(usize, u64)> {
        let b = self.bound.as_ref()?;
        let mut best: Option<((u64, u64, usize, u64), (usize, u64))> = None;
        for (&(scope, id), m) in &b.entries {
            if scope != SHARED_SCOPE && scope != worst {
                continue;
            }
            if m.pinned || m.bytes == 0 {
                continue;
            }
            let key = match b.policy {
                CachePolicy::Lru | CachePolicy::PinHotSet => (m.seq, 0u64, scope, id),
                CachePolicy::Gdsf => (m.h.to_bits(), m.seq, scope, id),
            };
            let better = match &best {
                None => true,
                Some((k, _)) => key < *k,
            };
            if better {
                best = Some((key, (scope, id)));
            }
        }
        match best {
            Some((_, k)) => Some(k),
            // Everything unpinned is gone: trim the insert being admitted,
            // if it still holds bytes in a victim scope.
            None => {
                let (scope, _) = incoming;
                let in_scope = scope == SHARED_SCOPE || scope == worst;
                if in_scope && self.scope_artifact_bytes(incoming.0, incoming.1) > 0 {
                    Some(incoming)
                } else {
                    None
                }
            }
        }
    }

    fn apply_trim(&mut self, key: (usize, u64), trim: u64, incoming: (usize, u64)) {
        let (scope, id) = key;
        let layer = if scope == SHARED_SCOPE {
            &mut self.shared
        } else {
            self.per_node.get_mut(&scope).expect("victim layer exists")
        };
        let v = layer.artifacts.get_mut(&id).expect("victim entry exists");
        *v -= trim;
        if *v == 0 {
            layer.artifacts.remove(&id);
        }
        let b = self.bound.as_mut().expect("bounded");
        let h = {
            let m = b.entries.get_mut(&key).expect("victim meta exists");
            m.bytes -= trim;
            let h = m.h;
            if m.bytes == 0 {
                b.entries.remove(&key);
            }
            h
        };
        if b.policy == CachePolicy::Gdsf {
            // Classic GDSF aging: future priorities start from the
            // evicted entry's priority, so long-resident entries decay
            // relative to fresh traffic.
            b.inflation = b.inflation.max(h);
        }
        if key != incoming {
            b.evicted += trim;
        }
    }

    fn artifact_prefix(&self, node: usize, id: u64) -> u64 {
        let shared = self.shared.artifacts.get(&id).copied().unwrap_or(0);
        let local = self
            .per_node
            .get(&node)
            .and_then(|l| l.artifacts.get(&id))
            .copied()
            .unwrap_or(0);
        shared.saturating_add(local)
    }

    fn chunk_resident(&self, node: usize, digest: u64) -> u64 {
        let shared = self.shared.chunks.get(&digest).copied().unwrap_or(0);
        let local = self
            .per_node
            .get(&node)
            .and_then(|l| l.chunks.get(&digest))
            .copied()
            .unwrap_or(0);
        shared.max(local)
    }

    /// Bytes of manifest `m` already resident on `node`.
    ///
    /// Without `dedup` this is exact prefix arithmetic over the
    /// artifact-scoped entries — `min(resident prefix, total)` — the path
    /// every default-config replay takes. With `dedup` the chunk list is
    /// walked: a chunk not covered by the prefix still counts if its
    /// content digest is resident via any other artifact.
    pub fn resident_bytes(&self, node: usize, m: &ArtifactManifest, dedup: bool) -> u64 {
        self.resident_bytes_beyond(node, m, 0, dedup)
    }

    /// [`Self::resident_bytes`], excluding the first `skip_prefix` bytes
    /// of the manifest from the count. The caller uses this when that
    /// prefix is already accounted elsewhere — a speculative staging flow
    /// covering the manifest's head must not be double-credited when its
    /// chunks are also content-resident (they are the shared prefix of an
    /// env snapshot whose blocks the image stage just landed).
    pub fn resident_bytes_beyond(
        &self,
        node: usize,
        m: &ArtifactManifest,
        skip_prefix: u64,
        dedup: bool,
    ) -> u64 {
        let prefix = self.artifact_prefix(node, m.id).min(m.total_bytes());
        // Chunkless summary manifests carry no content digests to walk;
        // prefix arithmetic is all there is for them even under dedup.
        if !dedup || m.chunks.is_empty() {
            return prefix.saturating_sub(skip_prefix.min(m.total_bytes()));
        }
        let mut covered = 0u64;
        let mut cum = 0u64;
        for c in &m.chunks {
            let by_skip = skip_prefix.saturating_sub(cum).min(c.bytes);
            let by_prefix = prefix.saturating_sub(cum).min(c.bytes);
            let by_content = self.chunk_resident(node, c.digest).min(c.bytes);
            covered += by_prefix.max(by_content).saturating_sub(by_skip);
            cum += c.bytes;
        }
        covered.min(m.total_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::manifest::ArtifactManifest;

    fn m(id: u64, total: u64) -> ArtifactManifest {
        ArtifactManifest::synthetic(id, total, 100)
    }

    #[test]
    fn empty_cache_has_nothing() {
        let c = CacheState::new();
        assert!(c.is_empty());
        assert_eq!(c.resident_bytes(0, &m(1, 1000), false), 0);
        assert_eq!(c.resident_bytes(3, &m(1, 1000), true), 0);
    }

    #[test]
    fn shared_prefix_applies_to_every_node() {
        let mut c = CacheState::new();
        c.insert_shared_artifact(7, 350);
        let man = m(7, 1000);
        for node in [0usize, 5, 99] {
            assert_eq!(c.resident_bytes(node, &man, false), 350);
        }
        // Capped at the manifest total.
        c.insert_shared_artifact(7, 10_000);
        assert_eq!(c.resident_bytes(0, &man, false), 1000);
    }

    #[test]
    fn node_entries_are_node_local_and_stack_on_shared() {
        let mut c = CacheState::new();
        c.insert_shared_artifact(7, 100);
        c.insert_node_artifact(2, 7, 250);
        let man = m(7, 1000);
        assert_eq!(c.resident_bytes(0, &man, false), 100);
        assert_eq!(c.resident_bytes(2, &man, false), 350);
    }

    #[test]
    fn dedup_credits_shared_content_across_artifacts() {
        // Artifact B's second chunk duplicates artifact A's content.
        let a = ArtifactManifest::synthetic(1, 300, 100);
        let mut b = ArtifactManifest::synthetic(2, 300, 100);
        b.chunks[1].digest = a.chunks[0].digest;
        let mut c = CacheState::new();
        c.insert_node_chunks(0, &a);
        // Without dedup, B has no residency; with dedup, the duplicated
        // chunk counts.
        assert_eq!(c.resident_bytes(0, &b, false), 0);
        assert_eq!(c.resident_bytes(0, &b, true), 100);
        // And on another node nothing is resident either way.
        assert_eq!(c.resident_bytes(1, &b, true), 0);

        // A chunkless summary manifest credits via prefix arithmetic even
        // under dedup (there are no digests to walk).
        use crate::artifact::manifest::ArtifactKind;
        let s = ArtifactManifest::summary(9, ArtifactKind::Synthetic, 300);
        let mut c2 = CacheState::new();
        c2.insert_shared_artifact(9, 120);
        assert_eq!(c2.resident_bytes(0, &s, true), 120);
    }

    #[test]
    fn dedup_does_not_double_count_prefix_and_content() {
        let a = ArtifactManifest::synthetic(1, 300, 100);
        let mut c = CacheState::new();
        c.insert_node_chunks(0, &a); // records prefix 300 AND all chunks
        assert_eq!(c.resident_bytes(0, &a, true), 300);
        assert_eq!(c.resident_bytes(0, &a, false), 300);
    }

    #[test]
    fn eviction_drops_artifact_scope_only() {
        let a = ArtifactManifest::synthetic(1, 300, 100);
        let mut b = ArtifactManifest::synthetic(2, 100, 100);
        b.chunks[0].digest = a.chunks[2].digest;
        let mut c = CacheState::new();
        c.insert_node_chunks(0, &a);
        c.evict_artifact(a.id);
        assert_eq!(c.resident_bytes(0, &a, false), 0);
        // Content-level entries survive (the bytes are still on disk under
        // another artifact's chunk).
        assert_eq!(c.resident_bytes(0, &b, true), 100);
    }

    #[test]
    fn beyond_prefix_excludes_already_counted_bytes() {
        // Artifact B's first two chunks duplicate A's content; a staging
        // flow already covers B's first 150 bytes. Credit beyond the
        // staged prefix must count only content not in that prefix — no
        // double-counting of the shared head.
        let a = ArtifactManifest::synthetic(1, 300, 100);
        let mut b = ArtifactManifest::synthetic(2, 300, 100);
        b.chunks[0].digest = a.chunks[0].digest;
        b.chunks[1].digest = a.chunks[1].digest;
        let mut c = CacheState::new();
        c.insert_node_chunks(0, &a);
        // Without skip: both shared chunks count.
        assert_eq!(c.resident_bytes(0, &b, true), 200);
        // Skipping the staged 150-byte prefix leaves only the unstaged
        // half of chunk 1.
        assert_eq!(c.resident_bytes_beyond(0, &b, 150, true), 50);
        // Skipping past all shared content leaves nothing.
        assert_eq!(c.resident_bytes_beyond(0, &b, 200, true), 0);
        // Non-dedup prefix arithmetic honors the skip too.
        let mut d = CacheState::new();
        d.insert_shared_artifact(9, 250);
        let man = m(9, 1000);
        assert_eq!(d.resident_bytes_beyond(0, &man, 100, false), 150);
        assert_eq!(d.resident_bytes_beyond(0, &man, 400, false), 0);
    }

    #[test]
    fn partial_prefix_counts_partial_tail_chunk() {
        let man = m(9, 1000); // 10 chunks of 100
        let mut c = CacheState::new();
        c.insert_shared_artifact(9, 250);
        assert_eq!(c.resident_bytes(0, &man, false), 250);
        // Chunk walk agrees with prefix arithmetic.
        assert_eq!(c.resident_bytes(0, &man, true), 250);
    }

    // ---- bounded caches & eviction -------------------------------------

    #[test]
    fn unbounded_capacity_constructs_legacy_cache() {
        let mut c = CacheState::with_capacity(u64::MAX, CachePolicy::Gdsf);
        assert!(c.capacity_bytes().is_none());
        let mut legacy = CacheState::new();
        for (id, b) in [(1u64, 500u64), (2, 700), (1, 300)] {
            c.insert_shared_artifact(id, b);
            legacy.insert_shared_artifact(id, b);
        }
        let man = m(1, 2000);
        assert_eq!(
            c.resident_bytes(0, &man, false),
            legacy.resident_bytes(0, &man, false)
        );
        assert_eq!(c.used_bytes(0), legacy.used_bytes(0));
        assert_eq!(c.evicted_bytes(), 0);
        assert_eq!(c.eviction_pressure(), 0.0);
    }

    #[test]
    fn lru_trims_oldest_insert_first_and_partially() {
        let mut c = CacheState::with_capacity(1000, CachePolicy::Lru);
        c.insert_shared_artifact(1, 400);
        c.insert_shared_artifact(2, 400);
        c.insert_shared_artifact(3, 400);
        // Overflow 200 tail-trims the oldest insert to a 200-byte prefix.
        assert_eq!(c.resident_bytes(0, &m(1, 400), false), 200);
        assert_eq!(c.resident_bytes(0, &m(2, 400), false), 400);
        assert_eq!(c.resident_bytes(0, &m(3, 400), false), 400);
        assert_eq!(c.evicted_bytes(), 200);
        assert_eq!(c.used_bytes(0), 1000);
        c.insert_shared_artifact(4, 600);
        // 1 then 2 go entirely; 3 and 4 fit exactly.
        assert_eq!(c.resident_bytes(0, &m(1, 400), false), 0);
        assert_eq!(c.resident_bytes(0, &m(2, 400), false), 0);
        assert_eq!(c.resident_bytes(0, &m(3, 400), false), 400);
        assert_eq!(c.resident_bytes(0, &m(4, 600), false), 600);
        assert_eq!(c.evicted_bytes(), 800);
    }

    #[test]
    fn pinned_hot_set_survives_churn() {
        let mut c = CacheState::with_capacity(1000, CachePolicy::PinHotSet);
        c.insert_shared_artifact(10, 300); // hot set
        c.pin_shared_artifact(10);
        c.insert_shared_artifact(11, 200); // env snapshot
        c.insert_shared_artifact(12, 900); // churn
        // env evicted entirely, churn admission-trimmed to fit; the
        // pinned hot set is untouched.
        assert_eq!(c.resident_bytes(0, &m(10, 300), false), 300);
        assert_eq!(c.resident_bytes(0, &m(11, 200), false), 0);
        assert_eq!(c.resident_bytes(0, &m(12, 900), false), 700);
        // Only env's 200 bytes count as eviction (churn's own trim is
        // admission, not eviction).
        assert_eq!(c.evicted_bytes(), 200);
    }

    #[test]
    fn gdsf_prefers_the_large_cold_artifact() {
        let mut c = CacheState::with_capacity(1000, CachePolicy::Gdsf);
        for _ in 0..3 {
            c.insert_shared_artifact(1, 50); // small & hot: high priority
        }
        c.insert_shared_artifact(2, 980); // big one-shot insert
        // LRU would trim artifact 1 (older); GDSF trims the big cold one.
        assert_eq!(c.resident_bytes(0, &m(1, 200), false), 150);
        assert_eq!(c.resident_bytes(0, &m(2, 980), false), 850);
        // The victim was the incoming insert itself: admission trim.
        assert_eq!(c.evicted_bytes(), 0);
    }

    #[test]
    fn admission_trim_caps_oversized_insert() {
        let mut c = CacheState::with_capacity(500, CachePolicy::Lru);
        c.insert_shared_artifact(1, 800);
        assert_eq!(c.resident_bytes(0, &m(1, 800), false), 500);
        assert_eq!(c.used_bytes(0), 500);
        assert_eq!(c.evicted_bytes(), 0);
        assert_eq!(c.eviction_pressure(), 0.0);
    }

    #[test]
    fn eviction_pressure_tracks_churn() {
        let mut c = CacheState::with_capacity(1000, CachePolicy::Lru);
        c.insert_shared_artifact(1, 600);
        c.insert_shared_artifact(2, 900);
        // 500 bytes of artifact 1 evicted for artifact 2 → pressure 0.5.
        assert_eq!(c.evicted_bytes(), 500);
        assert!((c.eviction_pressure() - 0.5).abs() < 1e-12);
        assert_eq!(CacheState::new().eviction_pressure(), 0.0);
    }

    #[test]
    fn per_node_layers_bound_the_worst_node_view() {
        let mut c = CacheState::with_capacity(1000, CachePolicy::Lru);
        c.insert_shared_artifact(1, 400);
        c.insert_node_artifact(0, 2, 500);
        c.insert_node_artifact(1, 3, 500);
        // Each node's view is 900 ≤ 1000: nothing trims, even though the
        // total footprint across nodes exceeds the capacity.
        assert_eq!(c.evicted_bytes(), 0);
        assert_eq!(c.used_bytes(0), 900);
        assert_eq!(c.used_bytes(1), 900);
        // Growing node 1's layer past the bound trims within that view.
        c.insert_node_artifact(1, 4, 300);
        assert_eq!(c.used_bytes(1), 1000);
        // The shared artifact was the oldest candidate; trimming it also
        // shrinks every other node's view.
        assert_eq!(c.resident_bytes(0, &m(1, 400), false), 200);
        assert_eq!(c.used_bytes(0), 700);
        assert_eq!(c.evicted_bytes(), 200);
    }

    #[test]
    fn explicit_evict_clears_bounded_meta() {
        let mut c = CacheState::with_capacity(1000, CachePolicy::Lru);
        c.insert_shared_artifact(1, 600);
        c.evict_artifact(1);
        assert_eq!(c.evicted_bytes(), 0); // explicit drops aren't pressure
        // The freed space is genuinely free again.
        c.insert_shared_artifact(2, 1000);
        assert_eq!(c.resident_bytes(0, &m(2, 1000), false), 1000);
        assert_eq!(c.evicted_bytes(), 0);
    }

    // ---- property suite: bounded accounting vs. policy oracle ----------
    //
    // Same style as `sim::golden`: an independently-coded reference model
    // (linear `Vec` scans, no `BTreeMap`) is driven through the identical
    // op sequence and the full byte-state is compared after *every* op —
    // which pins the eviction order, not just the end state.

    #[derive(Clone)]
    struct OEntry {
        scope: usize,
        id: u64,
        bytes: u64,
        seq: u64,
        inserts: u64,
        pinned: bool,
        h: f64,
    }

    struct Oracle {
        capacity: u64,
        policy: CachePolicy,
        seq: u64,
        inflation: f64,
        evicted: u64,
        entries: Vec<OEntry>,
    }

    impl Oracle {
        fn new(capacity: u64, policy: CachePolicy) -> Oracle {
            Oracle {
                capacity,
                policy,
                seq: 0,
                inflation: 0.0,
                evicted: 0,
                entries: Vec::new(),
            }
        }

        fn find(&mut self, scope: usize, id: u64) -> Option<&mut OEntry> {
            self.entries
                .iter_mut()
                .find(|e| e.scope == scope && e.id == id)
        }

        fn bytes(&self, scope: usize, id: u64) -> u64 {
            self.entries
                .iter()
                .find(|e| e.scope == scope && e.id == id)
                .map_or(0, |e| e.bytes)
        }

        fn scope_sum(&self, scope: usize) -> u64 {
            self.entries
                .iter()
                .filter(|e| e.scope == scope)
                .map(|e| e.bytes)
                .sum()
        }

        fn insert(&mut self, scope: usize, id: u64, bytes: u64) {
            if bytes == 0 {
                return;
            }
            self.seq += 1;
            let (seq, inflation) = (self.seq, self.inflation);
            if self.find(scope, id).is_none() {
                self.entries.push(OEntry {
                    scope,
                    id,
                    bytes: 0,
                    seq: 0,
                    inserts: 0,
                    pinned: false,
                    h: 0.0,
                });
            }
            let e = self.find(scope, id).unwrap();
            e.bytes = e.bytes.saturating_add(bytes);
            e.seq = seq;
            e.inserts += 1;
            let size_mb = (e.bytes as f64 / 1e6).max(1e-6);
            e.h = inflation + e.inserts as f64 / size_mb;
            self.enforce((scope, id));
        }

        fn pin(&mut self, scope: usize, id: u64) {
            if let Some(e) = self.find(scope, id) {
                e.pinned = true;
            }
        }

        fn evict(&mut self, id: u64) {
            self.entries.retain(|e| e.id != id);
        }

        fn worst_node(&self) -> usize {
            let mut nodes: Vec<usize> = self
                .entries
                .iter()
                .filter(|e| e.scope != SHARED_SCOPE)
                .map(|e| e.scope)
                .collect();
            nodes.sort_unstable();
            nodes.dedup();
            let mut worst = SHARED_SCOPE;
            let mut worst_sum = 0u64;
            for n in nodes {
                let s = self.scope_sum(n);
                if s > worst_sum {
                    worst = n;
                    worst_sum = s;
                }
            }
            worst
        }

        fn enforce(&mut self, incoming: (usize, u64)) {
            loop {
                let worst = self.worst_node();
                let used = self
                    .scope_sum(SHARED_SCOPE)
                    .saturating_add(if worst == SHARED_SCOPE {
                        0
                    } else {
                        self.scope_sum(worst)
                    });
                if used <= self.capacity {
                    return;
                }
                let overflow = used - self.capacity;
                let Some(idx) = self.pick(worst, incoming) else {
                    return;
                };
                let trim = overflow.min(self.entries[idx].bytes);
                if trim == 0 {
                    return;
                }
                let key = (self.entries[idx].scope, self.entries[idx].id);
                let h = self.entries[idx].h;
                self.entries[idx].bytes -= trim;
                if self.entries[idx].bytes == 0 {
                    self.entries.remove(idx);
                }
                if self.policy == CachePolicy::Gdsf {
                    self.inflation = self.inflation.max(h);
                }
                if key != incoming {
                    self.evicted += trim;
                }
            }
        }

        fn pick(&self, worst: usize, incoming: (usize, u64)) -> Option<usize> {
            let mut best: Option<((u64, u64, usize, u64), usize)> = None;
            for (i, e) in self.entries.iter().enumerate() {
                if e.scope != SHARED_SCOPE && e.scope != worst {
                    continue;
                }
                if e.pinned || e.bytes == 0 {
                    continue;
                }
                let key = match self.policy {
                    CachePolicy::Lru | CachePolicy::PinHotSet => {
                        (e.seq, 0u64, e.scope, e.id)
                    }
                    CachePolicy::Gdsf => (e.h.to_bits(), e.seq, e.scope, e.id),
                };
                let better = match &best {
                    None => true,
                    Some((k, _)) => key < *k,
                };
                if better {
                    best = Some((key, i));
                }
            }
            match best {
                Some((_, i)) => Some(i),
                None => {
                    if incoming.0 == SHARED_SCOPE || incoming.0 == worst {
                        self.entries.iter().position(|e| {
                            e.scope == incoming.0 && e.id == incoming.1 && e.bytes > 0
                        })
                    } else {
                        None
                    }
                }
            }
        }
    }

    #[test]
    fn prop_bounded_accounting_matches_policy_oracle() {
        use crate::prop_assert;
        use crate::util::prop::prop_check;
        const SCOPES: [usize; 4] = [SHARED_SCOPE, 0, 1, 2];
        prop_check(48, |g| {
            let cap = g.u64_in(500, 4_000);
            let policy = CachePolicy::ALL[g.usize_in(0, 2)];
            let mut cache = CacheState::with_capacity(cap, policy);
            let mut oracle = Oracle::new(cap, policy);
            let n_ops = g.usize_in(10, 60);
            for _ in 0..n_ops {
                let roll = g.usize_in(0, 99);
                let scope = SCOPES[g.usize_in(0, 3)];
                let id = g.u64_in(1, 6);
                // Snapshot pinned entries: nothing may shrink them except
                // an op targeting that same entry.
                let pinned_before: Vec<((usize, u64), u64)> = oracle
                    .entries
                    .iter()
                    .filter(|e| e.pinned)
                    .map(|e| ((e.scope, e.id), e.bytes))
                    .collect();
                let own_target: Option<(usize, u64)>;
                let evicts_id: bool;
                if roll < 55 {
                    let bytes = g.u64_in(1, 1_200);
                    match scope {
                        SHARED_SCOPE => cache.insert_shared_artifact(id, bytes),
                        n => cache.insert_node_artifact(n, id, bytes),
                    }
                    oracle.insert(scope, id, bytes);
                    own_target = Some((scope, id));
                    evicts_id = false;
                } else if roll < 70 {
                    // Dedup-path insert: chunked manifest, the artifact
                    // total is what gets accounted.
                    let total = g.u64_in(1, 9) * 100;
                    let man = ArtifactManifest::synthetic(id, total, 100);
                    match scope {
                        SHARED_SCOPE => cache.insert_shared_chunks(&man),
                        n => cache.insert_node_chunks(n, &man),
                    }
                    oracle.insert(scope, id, total);
                    own_target = Some((scope, id));
                    evicts_id = false;
                } else if roll < 85 {
                    match scope {
                        SHARED_SCOPE => cache.pin_shared_artifact(id),
                        n => cache.pin_node_artifact(n, id),
                    }
                    oracle.pin(scope, id);
                    own_target = None;
                    evicts_id = false;
                } else {
                    cache.evict_artifact(id);
                    oracle.evict(id);
                    own_target = None;
                    evicts_id = true;
                }
                // Capacity invariant: no node's view ever exceeds cap.
                for node in 0..3usize {
                    prop_assert!(
                        cache.used_bytes(node) <= cap,
                        "node {} used {} > cap {}",
                        node,
                        cache.used_bytes(node),
                        cap
                    );
                }
                // Pinned entries only shrink via their own insert/evict.
                for ((s, i), before) in pinned_before {
                    if evicts_id && i == id {
                        continue;
                    }
                    if own_target == Some((s, i)) {
                        continue;
                    }
                    prop_assert!(
                        cache.scope_artifact_bytes(s, i) >= before,
                        "pinned ({s},{i}) shrank from {before}"
                    );
                }
                // Full byte-state equality against the oracle — this is
                // what pins the eviction *order* per policy.
                prop_assert!(
                    cache.evicted_bytes() == oracle.evicted,
                    "evicted {} != oracle {}",
                    cache.evicted_bytes(),
                    oracle.evicted
                );
                for s in SCOPES {
                    for i in 1..=6u64 {
                        let got = cache.scope_artifact_bytes(s, i);
                        let want = oracle.bytes(s, i);
                        prop_assert!(
                            got == want,
                            "scope {s} id {i}: cache {got} != oracle {want}"
                        );
                    }
                }
            }
            Ok(())
        });
    }
}
