//! HDFS-FUSE read/write planners over the cluster sim (§4.4).
//!
//! Baseline (`Sequential`): the training program downloads the checkpoint
//! through a single DFSInputStream — one TCP stream to one DataNode group
//! at a time, capped by `HDFS_STREAM_BPS` — staging it to local disk and
//! then loading it ("download-and-resume").
//!
//! BootSeer (`Striped`): the striped layout lets the FUSE client keep
//! `STRIPE_PARALLEL_STREAMS` chunk fetches in flight across many DataNode
//! groups at once, streaming straight into the training process and
//! overlapping local I/O with the HDFS transfer.
//!
//! These planners implement the HDFS provider tiers of the unified
//! transfer plane ([`crate::artifact::transfer::ProviderTier`]): bulk
//! group fetches (`Hdfs`) and whole-shard stream reads (`HdfsStream`)
//! both resolve here, so no caller hand-builds HDFS flow paths anymore.

use crate::config::defaults as d;
use crate::hdfs::layout::StripeLayout;
use crate::sim::engine::Capacity;
use crate::sim::{ClusterSim, NodeHandle, TaskId};

/// How a node reads a (checkpoint) file out of HDFS.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadEngine {
    /// Single-stream download to local disk, then load.
    Sequential,
    /// Striped parallel read, streamed directly.
    Striped,
}

/// Plan one node's read of `bytes` from HDFS. Returns the completion task.
pub fn plan_read(
    cs: &mut ClusterSim,
    node: usize,
    bytes: u64,
    engine: ReadEngine,
    deps: &[TaskId],
    tag: u64,
) -> TaskId {
    match engine {
        ReadEngine::Sequential => plan_read_sequential(cs, node, bytes, deps, tag),
        ReadEngine::Striped => plan_read_striped(cs, node, bytes, deps, tag),
    }
}

fn plan_read_sequential(
    cs: &mut ClusterSim,
    node: usize,
    bytes: u64,
    deps: &[TaskId],
    tag: u64,
) -> TaskId {
    // NameNode lookup, then a single stream capped by HDFS_STREAM_BPS.
    // The stream walks blocks across groups sequentially; because only one
    // group is active at a time, we model it as one flow through a
    // per-read stream-cap resource plus a representative group. The stream
    // resource lives exactly as long as its one flow (scoped), so a long
    // simulation's resource table doesn't accrete one slot per read.
    let h = NodeHandle::new(node);
    let nn = cs.sim.delay(cs.cfg.hdfs_nn_op_s, deps, 0);
    let stream = cs.sim.add_resource_scoped(
        &format!("hdfs.stream.n{node}"),
        Capacity::Fixed(d::HDFS_STREAM_BPS),
        1,
    );
    let group = cs.hdfs_group_of(h);
    // Download to local disk (traversing the tree tiers on a non-flat
    // topology — the DataNodes live outside the racks)...
    let mut path = vec![stream, group, cs.node_nic[node], cs.node_disk[node]];
    path.extend(cs.tier_path(h));
    let dl = cs.sim.flow(bytes as f64, path, &[nn], 0);
    // ...then load from disk into the training process.
    let load = bytes as f64 / cs.cfg.node_disk_read_bps;
    cs.sim.delay(cs.cpu_time(h, load), &[dl], tag)
}

fn plan_read_striped(
    cs: &mut ClusterSim,
    node: usize,
    bytes: u64,
    deps: &[TaskId],
    tag: u64,
) -> TaskId {
    let layout = StripeLayout::new(
        bytes,
        d::STRIPE_CHUNK_BYTES,
        d::STRIPE_WIDTH,
        cs.cfg.hdfs_block_bytes,
    );
    // The FUSE client keeps P streams in flight; each stream is capped at
    // HDFS_STREAM_BPS and the set of streams spreads over the groups the
    // striped placement touches. One NameNode op per *non-empty* physical
    // file: a file with fewer chunks than the stripe width only
    // materializes that many stripe files, so a tiny checkpoint shard must
    // not pay `width` NameNode ops (regression test below).
    let n_streams = d::STRIPE_PARALLEL_STREAMS.min(layout.n_chunks().max(1) as u32);
    let nn_ops = (layout.width as u64).min(layout.n_chunks()).max(1);
    let nn = cs.sim.delay(cs.cfg.hdfs_nn_op_s * nn_ops as f64, deps, 0);
    let n_groups = cs.hdfs_groups.len();
    let mut touched = layout.groups_touched(n_groups as u32, (node % n_groups) as u32);
    if touched.is_empty() {
        // Zero-byte file: no blocks anywhere; keep the read well-formed.
        touched.push((node % n_groups) as u32);
    }
    let per_stream = bytes as f64 / n_streams as f64;
    let mut parts = Vec::with_capacity(n_streams as usize);
    for s in 0..n_streams {
        // Per-read stream resources are scoped to their single flow and
        // their slots recycled once the read completes.
        let stream = cs.sim.add_resource_scoped(
            &format!("hdfs.stripe.n{node}.s{s}"),
            Capacity::Fixed(d::HDFS_STREAM_BPS),
            1,
        );
        // Stride group assignment by node so concurrent readers spread over
        // the whole DataNode fleet instead of piling on the same groups.
        let gi = (node * n_streams as usize + s as usize) % touched.len();
        let group = cs.hdfs_groups[touched[gi] as usize];
        // Streamed directly into the process (no local-disk staging pass).
        let mut path = vec![stream, group, cs.node_nic[node]];
        path.extend(cs.tier_path(NodeHandle::new(node)));
        parts.push(cs.sim.flow(per_stream, path, &[nn], 0));
    }
    cs.sim.barrier(&parts, tag)
}

/// Plan one node's write of `bytes` into HDFS (checkpoint save, env-cache
/// upload). Striping helps writes the same way (parallel pipelines).
pub fn plan_write(
    cs: &mut ClusterSim,
    node: usize,
    bytes: u64,
    engine: ReadEngine,
    deps: &[TaskId],
    tag: u64,
) -> TaskId {
    let n_streams = match engine {
        ReadEngine::Sequential => 1,
        ReadEngine::Striped => d::STRIPE_WIDTH,
    };
    let nn = cs.sim.delay(cs.cfg.hdfs_nn_op_s * n_streams as f64, deps, 0);
    let per = bytes as f64 / n_streams as f64;
    let n_groups = cs.hdfs_groups.len();
    let mut parts = Vec::with_capacity(n_streams as usize);
    for s in 0..n_streams {
        let stream = cs.sim.add_resource_scoped(
            &format!("hdfs.wstream.n{node}.s{s}"),
            Capacity::Fixed(d::HDFS_STREAM_BPS),
            1,
        );
        let group = cs.hdfs_groups[(node + s as usize) % n_groups];
        let mut path = vec![cs.node_nic[node], stream, group];
        path.extend(cs.tier_path(NodeHandle::new(node)));
        parts.push(cs.sim.flow(per, path, &[nn], 0));
    }
    cs.sim.barrier(&parts, tag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn read_time(nodes: u32, per_node_bytes: u64, engine: ReadEngine) -> f64 {
        let mut cs = ClusterSim::build(&ClusterConfig::with_nodes(nodes), 42);
        let dones: Vec<TaskId> = (0..nodes as usize)
            .map(|i| plan_read(&mut cs, i, per_node_bytes, engine, &[], 1))
            .collect();
        cs.sim.run();
        dones.iter().map(|&t| cs.sim.finished_at(t)).fold(0.0, f64::max)
    }

    #[test]
    fn striped_beats_sequential() {
        // Per-node share of the paper's 413 GB checkpoint (PP=2 → 206.5 GB).
        let bytes = 206_500_000_000;
        let seq = read_time(2, bytes, ReadEngine::Sequential);
        let par = read_time(2, bytes, ReadEngine::Striped);
        let ratio = seq / par;
        assert!((1.5..6.0).contains(&ratio), "seq {seq} vs striped {par} = {ratio}x");
    }

    #[test]
    fn sequential_is_stream_capped() {
        // 16 GB at 1.6 GB/s ≈ 10 s + disk load.
        let t = read_time(1, 16_000_000_000, ReadEngine::Sequential);
        assert!((10.0..16.0).contains(&t), "t={t}");
    }

    #[test]
    fn striped_is_nic_capped() {
        // 31.25 GB at NIC 3.125 GB/s ≈ 10 s (16 streams not the limit).
        let t = read_time(1, 31_250_000_000, ReadEngine::Striped);
        assert!((10.0..12.5).contains(&t), "t={t}");
    }

    #[test]
    fn scale_stability() {
        // §5.3: model-init duration stays stable with scale (HDFS not yet
        // the bottleneck at 16 nodes).
        let b = 206_500_000_000;
        let t2 = read_time(2, b, ReadEngine::Striped);
        let t16 = read_time(16, b, ReadEngine::Striped);
        assert!(t16 < t2 * 1.6, "striped degraded: {t2} → {t16}");
        let s2 = read_time(2, b, ReadEngine::Sequential);
        let s16 = read_time(16, b, ReadEngine::Sequential);
        assert!(s16 < s2 * 1.3, "sequential should also be stable: {s2} → {s16}");
    }

    #[test]
    fn write_striped_faster() {
        let mut cs = ClusterSim::build(&ClusterConfig::with_nodes(1), 1);
        let w1 = plan_write(&mut cs, 0, 20_000_000_000, ReadEngine::Sequential, &[], 1);
        cs.sim.run();
        let t_seq = cs.sim.finished_at(w1);
        let mut cs2 = ClusterSim::build(&ClusterConfig::with_nodes(1), 1);
        let w2 = plan_write(&mut cs2, 0, 20_000_000_000, ReadEngine::Striped, &[], 1);
        cs2.sim.run();
        let t_par = cs2.sim.finished_at(w2);
        assert!(t_seq / t_par > 1.5, "seq {t_seq} striped {t_par}");
    }

    #[test]
    fn small_file_charges_fewer_nn_ops() {
        // A 2 MB shard has 2 chunks < STRIPE_WIDTH=4 stripe files, so the
        // NameNode pays 2 ops (0.008 s), not 4 (0.016 s). The transfer
        // itself is ~0.6 ms, so the stage time pins the op count.
        let mut cs = ClusterSim::build(&ClusterConfig::with_nodes(1), 42);
        let r = plan_read(&mut cs, 0, 2_000_000, ReadEngine::Striped, &[], 1);
        cs.sim.run();
        let t = cs.sim.finished_at(r);
        assert!(
            (0.008..0.012).contains(&t),
            "2-chunk read should pay 2 NN ops: t={t}"
        );
    }

    #[test]
    fn zero_byte_striped_read_is_one_nn_op() {
        let mut cs = ClusterSim::build(&ClusterConfig::with_nodes(1), 42);
        let r = plan_read(&mut cs, 0, 0, ReadEngine::Striped, &[], 1);
        cs.sim.run();
        let t = cs.sim.finished_at(r);
        assert!((0.0039..0.0061).contains(&t), "zero-byte read t={t}");
    }

    #[test]
    fn large_reads_unchanged_by_small_file_fix() {
        // n_chunks >= width ⇒ min(width, n_chunks) == width: the replay's
        // GB-scale resume shares see the exact same NN charge as before.
        let b = 206_500_000_000u64;
        let layout = StripeLayout::new(
            b,
            d::STRIPE_CHUNK_BYTES,
            d::STRIPE_WIDTH,
            ClusterConfig::default().hdfs_block_bytes,
        );
        assert!(layout.n_chunks() >= layout.width as u64);
        assert_eq!((layout.width as u64).min(layout.n_chunks()).max(1), d::STRIPE_WIDTH as u64);
    }

    #[test]
    fn stream_resources_retire_after_read() {
        // Per-read streams must not accrete resource slots across reads.
        let mut cs = ClusterSim::build(&ClusterConfig::with_nodes(1), 42);
        let first = plan_read(&mut cs, 0, 64_000_000, ReadEngine::Striped, &[], 1);
        cs.sim.run();
        assert!(cs.sim.is_done(first));
        let slots_after_one = cs.sim.resource_slots();
        for k in 0..10 {
            let r = plan_read(&mut cs, 0, 64_000_000, ReadEngine::Striped, &[], 2 + k);
            cs.sim.run();
            assert!(cs.sim.is_done(r));
        }
        assert_eq!(
            cs.sim.resource_slots(),
            slots_after_one,
            "stream slots should be recycled read-over-read"
        );
    }

    #[test]
    fn deps_gate_read() {
        let mut cs = ClusterSim::build(&ClusterConfig::with_nodes(1), 1);
        let gate = cs.sim.delay(30.0, &[], 0);
        let r = plan_read(&mut cs, 0, 1_000_000, ReadEngine::Striped, &[gate], 1);
        cs.sim.run();
        assert!(cs.sim.finished_at(r) > 30.0);
    }
}
