//! Real on-disk striped store — the local embodiment of striped HDFS-FUSE.
//!
//! The simulator answers cluster-scale questions; this module proves the
//! striping *implementation* on a real filesystem with real bytes. A
//! logical file is written as `width` physical stripe files (1 MB chunks
//! round-robin, exactly the `StripeLayout` math) plus a manifest; reads
//! come back either sequentially (chunk-by-chunk in logical order — the
//! baseline's single-stream access pattern) or in parallel (one reader
//! thread per stripe file, each scattering its chunks directly into the
//! output buffer). Checkpoint save/resume in the e2e example runs on this.

use crate::hdfs::layout::StripeLayout;
use crate::util::cast::{u64_from_usize, usize_from_u64};
use crate::util::json::{self, Json};
use crate::bail;
use crate::util::error::{Context, Result};
use std::fs::{self, File};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;

/// A directory acting as the store (the "DataNode pool").
pub struct LocalStore {
    pub root: PathBuf,
}

/// Wrapper to send a raw pointer to scoped reader threads; each thread
/// writes a disjoint set of chunk-sized regions (round-robin ownership), so
/// the aliasing is safe by construction.
#[derive(Clone, Copy)]
struct SendPtr(*mut u8);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl LocalStore {
    pub fn open(root: impl Into<PathBuf>) -> Result<LocalStore> {
        let root = root.into();
        fs::create_dir_all(&root)?;
        Ok(LocalStore { root })
    }

    fn manifest_path(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.manifest.json"))
    }

    fn stripe_path(&self, name: &str, f: u32) -> PathBuf {
        self.root.join(format!("{name}.stripe{f}"))
    }

    /// Write `data` as a striped file.
    pub fn write_striped(
        &self,
        name: &str,
        data: &[u8],
        chunk_bytes: u64,
        width: u32,
    ) -> Result<StripeLayout> {
        let layout =
            StripeLayout::new(u64_from_usize(data.len()), chunk_bytes, width, u64::MAX / 4);
        // One buffered writer per stripe file; walk chunks in logical order.
        let mut writers: Vec<std::io::BufWriter<File>> = (0..width)
            .map(|f| {
                Ok(std::io::BufWriter::new(
                    File::create(self.stripe_path(name, f))
                        .with_context(|| format!("create stripe {f}"))?,
                ))
            })
            .collect::<Result<_>>()?;
        for c in 0..layout.n_chunks() {
            let loc = layout.locate(c);
            let start = usize_from_u64(c * chunk_bytes);
            let end = usize_from_u64(u64_from_usize(start) + layout.chunk_len(c));
            writers[loc.file as usize].write_all(&data[start..end])?;
        }
        for mut w in writers {
            w.flush()?;
        }
        let mut m = Json::obj();
        m.set("logical_bytes", u64_from_usize(data.len()))
            .set("chunk_bytes", chunk_bytes)
            .set("width", u64::from(width));
        fs::write(self.manifest_path(name), m.to_string())?;
        Ok(layout)
    }

    /// Load the layout of a stored file.
    pub fn layout(&self, name: &str) -> Result<StripeLayout> {
        let text = fs::read_to_string(self.manifest_path(name))
            .with_context(|| format!("manifest for {name}"))?;
        let m = json::parse(&text).map_err(|e| crate::anyhow!("manifest parse: {e}"))?;
        let get = |k: &str| -> Result<u64> {
            m.get(k)
                .and_then(|v| v.as_f64())
                .map(|x| x as u64)
                .ok_or_else(|| crate::anyhow!("manifest missing {k}"))
        };
        Ok(StripeLayout::new(
            get("logical_bytes")?,
            get("chunk_bytes")?,
            get("width")? as u32,
            u64::MAX / 4,
        ))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.manifest_path(name).exists()
    }

    pub fn delete(&self, name: &str) -> Result<()> {
        let layout = self.layout(name)?;
        for f in 0..layout.width {
            let _ = fs::remove_file(self.stripe_path(name, f));
        }
        fs::remove_file(self.manifest_path(name))?;
        Ok(())
    }

    /// Baseline read: walk chunks in logical order, seeking into the stripe
    /// files one chunk at a time (single stream, no overlap).
    pub fn read_sequential(&self, name: &str) -> Result<Vec<u8>> {
        let layout = self.layout(name)?;
        let mut files: Vec<File> = (0..layout.width)
            .map(|f| File::open(self.stripe_path(name, f)).map_err(Into::into))
            .collect::<Result<_>>()?;
        let mut out = vec![0u8; usize_from_u64(layout.logical_bytes)];
        for c in 0..layout.n_chunks() {
            let loc = layout.locate(c);
            let fh = &mut files[loc.file as usize];
            fh.seek(SeekFrom::Start(loc.index_in_file * layout.chunk_bytes))?;
            let start = usize_from_u64(c * layout.chunk_bytes);
            let end = start + usize_from_u64(layout.chunk_len(c));
            fh.read_exact(&mut out[start..end])?;
        }
        Ok(out)
    }

    /// Striped read: one thread per stripe file, each streaming its file
    /// and scattering chunks into the shared output buffer (disjoint
    /// regions by round-robin ownership).
    pub fn read_striped_parallel(&self, name: &str) -> Result<Vec<u8>> {
        let layout = self.layout(name)?;
        let mut out = vec![0u8; usize_from_u64(layout.logical_bytes)];
        let ptr = SendPtr(out.as_mut_ptr());
        let chunk = layout.chunk_bytes;
        let errs: Vec<String> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for f in 0..layout.width {
                let path = self.stripe_path(name, f);
                let layoutc = layout;
                handles.push(scope.spawn(move || -> Result<(), String> {
                    let p = ptr; // capture
                    let mut fh = File::open(&path).map_err(|e| format!("{path:?}: {e}"))?;
                    let mut buf = vec![0u8; chunk as usize];
                    let mut index_in_file = 0u64;
                    loop {
                        // Logical chunk this position corresponds to.
                        let c = index_in_file * layoutc.width as u64 + f as u64;
                        if c >= layoutc.n_chunks() {
                            break;
                        }
                        let len = layoutc.chunk_len(c) as usize;
                        fh.read_exact(&mut buf[..len]).map_err(|e| e.to_string())?;
                        let dst = (c * chunk) as usize;
                        // SAFETY: chunk regions are disjoint across logical
                        // chunk indices, and each (file,index) maps to a
                        // unique logical chunk (see layout prop test).
                        unsafe {
                            std::ptr::copy_nonoverlapping(buf.as_ptr(), p.0.add(dst), len);
                        }
                        index_in_file += 1;
                    }
                    Ok(())
                }));
            }
            handles
                .into_iter()
                .filter_map(|h| h.join().expect("reader thread panicked").err())
                .collect()
        });
        if !errs.is_empty() {
            bail!("striped read failed: {}", errs.join("; "));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn store(name: &str) -> LocalStore {
        let p = std::env::temp_dir().join(format!("bootseer-local-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        LocalStore::open(p).unwrap()
    }

    fn random_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seeded(seed);
        (0..n).map(|_| rng.next_u64() as u8).collect()
    }

    #[test]
    fn roundtrip_both_engines() {
        let s = store("rt");
        let data = random_bytes(10_000_000, 1); // 10 MB, not chunk-aligned sizes below
        s.write_striped("ckpt", &data, 1_000_000, 4).unwrap();
        assert!(s.exists("ckpt"));
        assert_eq!(s.read_sequential("ckpt").unwrap(), data);
        assert_eq!(s.read_striped_parallel("ckpt").unwrap(), data);
        fs::remove_dir_all(&s.root).unwrap();
    }

    #[test]
    fn partial_tail_chunk() {
        let s = store("tail");
        let data = random_bytes(2_500_123, 2); // ragged tail
        s.write_striped("x", &data, 1_000_000, 4).unwrap();
        assert_eq!(s.read_striped_parallel("x").unwrap(), data);
        assert_eq!(s.read_sequential("x").unwrap(), data);
        fs::remove_dir_all(&s.root).unwrap();
    }

    #[test]
    fn width_one_is_flat() {
        let s = store("w1");
        let data = random_bytes(3_000_000, 3);
        s.write_striped("f", &data, 1_000_000, 1).unwrap();
        assert_eq!(s.read_striped_parallel("f").unwrap(), data);
        fs::remove_dir_all(&s.root).unwrap();
    }

    #[test]
    fn small_file_smaller_than_chunk() {
        let s = store("small");
        let data = b"tiny checkpoint".to_vec();
        s.write_striped("t", &data, 1_000_000, 4).unwrap();
        assert_eq!(s.read_striped_parallel("t").unwrap(), data);
        assert_eq!(s.read_sequential("t").unwrap(), data);
        fs::remove_dir_all(&s.root).unwrap();
    }

    #[test]
    fn empty_file() {
        let s = store("empty");
        s.write_striped("e", &[], 1_000_000, 4).unwrap();
        assert_eq!(s.read_striped_parallel("e").unwrap(), Vec::<u8>::new());
        fs::remove_dir_all(&s.root).unwrap();
    }

    #[test]
    fn delete_removes_everything() {
        let s = store("del");
        s.write_striped("d", &[1, 2, 3], 1_000_000, 4).unwrap();
        s.delete("d").unwrap();
        assert!(!s.exists("d"));
        assert!(s.read_sequential("d").is_err());
        fs::remove_dir_all(&s.root).unwrap();
    }

    #[test]
    fn missing_file_errors() {
        let s = store("missing");
        assert!(s.read_striped_parallel("nope").is_err());
        fs::remove_dir_all(&s.root).unwrap();
    }

    #[test]
    fn stripe_files_hold_interleaved_content() {
        let s = store("interleave");
        // 4 chunks of 2 bytes, width 2: file0 = chunks 0,2; file1 = 1,3.
        let data = vec![0, 0, 1, 1, 2, 2, 3, 3];
        s.write_striped("i", &data, 2, 2).unwrap();
        assert_eq!(fs::read(s.stripe_path("i", 0)).unwrap(), vec![0, 0, 2, 2]);
        assert_eq!(fs::read(s.stripe_path("i", 1)).unwrap(), vec![1, 1, 3, 3]);
        fs::remove_dir_all(&s.root).unwrap();
    }
}
