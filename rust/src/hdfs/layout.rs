//! Striped HDFS layout math (§4.4, Figure 11).
//!
//! The logical checkpoint file is split into 1 MB *chunks*; chunks are
//! distributed round-robin across `width` *physical files* (so a 4-wide
//! stripe interleaves chunks 0,1,2,3 across files 0,1,2,3, chunk 4 back on
//! file 0, ...). Each physical file is stored in HDFS as a sequence of
//! 512 MB HDFS blocks, and blocks land on DataNode replication groups
//! round-robin. A striped read therefore touches `width` physical files —
//! i.e. `width`+ independent DataNode groups — in parallel, where the
//! original layout streams one block at a time from one group.

/// Placement of one logical chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkLoc {
    /// Which physical stripe file holds it.
    pub file: u32,
    /// Chunk index within that physical file.
    pub index_in_file: u64,
    /// HDFS block (within the physical file) containing it.
    pub hdfs_block: u64,
}

/// The striped layout of one logical file.
#[derive(Clone, Copy, Debug)]
pub struct StripeLayout {
    pub logical_bytes: u64,
    pub chunk_bytes: u64,
    pub width: u32,
    pub hdfs_block_bytes: u64,
}

impl StripeLayout {
    pub fn new(logical_bytes: u64, chunk_bytes: u64, width: u32, hdfs_block_bytes: u64) -> Self {
        assert!(chunk_bytes > 0 && width > 0 && hdfs_block_bytes >= chunk_bytes);
        StripeLayout { logical_bytes, chunk_bytes, width, hdfs_block_bytes }
    }

    /// Number of logical chunks (last may be partial).
    pub fn n_chunks(&self) -> u64 {
        (self.logical_bytes + self.chunk_bytes - 1) / self.chunk_bytes
    }

    /// Byte length of logical chunk `c`.
    pub fn chunk_len(&self, c: u64) -> u64 {
        debug_assert!(c < self.n_chunks());
        if c + 1 == self.n_chunks() && self.logical_bytes % self.chunk_bytes != 0 {
            self.logical_bytes % self.chunk_bytes
        } else {
            self.chunk_bytes
        }
    }

    /// Placement of logical chunk `c`.
    pub fn locate(&self, c: u64) -> ChunkLoc {
        debug_assert!(c < self.n_chunks());
        let file = (c % self.width as u64) as u32;
        let index_in_file = c / self.width as u64;
        let chunks_per_block = self.hdfs_block_bytes / self.chunk_bytes;
        ChunkLoc { file, index_in_file, hdfs_block: index_in_file / chunks_per_block }
    }

    /// Bytes stored in physical file `f`.
    pub fn file_bytes(&self, f: u32) -> u64 {
        (0..self.n_chunks())
            .filter(|&c| (c % self.width as u64) as u32 == f)
            .map(|c| self.chunk_len(c))
            .sum()
    }

    /// Number of HDFS blocks of physical file `f`.
    pub fn file_hdfs_blocks(&self, f: u32) -> u64 {
        let b = self.file_bytes(f);
        (b + self.hdfs_block_bytes - 1) / self.hdfs_block_bytes
    }

    /// Total HDFS blocks across all physical files.
    pub fn total_hdfs_blocks(&self) -> u64 {
        (0..self.width).map(|f| self.file_hdfs_blocks(f)).sum()
    }

    /// DataNode groups touched by a full-file read, given round-robin block
    /// placement over `n_groups` groups starting at `first_group`. This is
    /// the read-parallelism the striped layout unlocks.
    pub fn groups_touched(&self, n_groups: u32, first_group: u32) -> Vec<u32> {
        let mut touched = std::collections::BTreeSet::new();
        let mut g = first_group % n_groups;
        for f in 0..self.width {
            for _ in 0..self.file_hdfs_blocks(f) {
                touched.insert(g);
                g = (g + 1) % n_groups;
            }
        }
        touched.into_iter().collect()
    }

    /// The *unstriped* original layout: one physical file, whole 512 MB
    /// blocks in sequence. Reads stream block-by-block → parallelism 1.
    pub fn unstriped(logical_bytes: u64, hdfs_block_bytes: u64) -> StripeLayout {
        StripeLayout {
            logical_bytes,
            chunk_bytes: hdfs_block_bytes,
            width: 1,
            hdfs_block_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::{HDFS_BLOCK_BYTES, STRIPE_CHUNK_BYTES, STRIPE_WIDTH};
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn paper_layout(bytes: u64) -> StripeLayout {
        StripeLayout::new(bytes, STRIPE_CHUNK_BYTES, STRIPE_WIDTH, HDFS_BLOCK_BYTES)
    }

    #[test]
    fn round_robin_placement() {
        let l = paper_layout(10 * 1_000_000);
        assert_eq!(l.n_chunks(), 10);
        assert_eq!(l.locate(0), ChunkLoc { file: 0, index_in_file: 0, hdfs_block: 0 });
        assert_eq!(l.locate(1).file, 1);
        assert_eq!(l.locate(4), ChunkLoc { file: 0, index_in_file: 1, hdfs_block: 0 });
        assert_eq!(l.locate(9).file, 1);
    }

    #[test]
    fn chunk_lengths_sum_to_logical() {
        let l = paper_layout(10_500_000);
        let total: u64 = (0..l.n_chunks()).map(|c| l.chunk_len(c)).sum();
        assert_eq!(total, 10_500_000);
        assert_eq!(l.chunk_len(l.n_chunks() - 1), 500_000);
    }

    #[test]
    fn file_bytes_partition_logical() {
        let l = paper_layout(413_000_000_000);
        let total: u64 = (0..l.width).map(|f| l.file_bytes(f)).sum();
        assert_eq!(total, 413_000_000_000);
        // 4-way stripe of 413 GB → ~103 GB per physical file.
        for f in 0..l.width {
            let fb = l.file_bytes(f) as f64;
            assert!((fb - 103.25e9).abs() < 0.1e9, "file {f}: {fb}");
        }
    }

    #[test]
    fn hdfs_block_counts() {
        let l = paper_layout(413_000_000_000);
        // 103.25 GB / 512 MB ≈ 202 blocks per physical file.
        for f in 0..l.width {
            assert_eq!(l.file_hdfs_blocks(f), 202);
        }
        assert_eq!(l.total_hdfs_blocks(), 808);
    }

    #[test]
    fn striped_touches_more_groups_than_unstriped() {
        let striped = paper_layout(8 * HDFS_BLOCK_BYTES);
        let flat = StripeLayout::unstriped(8 * HDFS_BLOCK_BYTES, HDFS_BLOCK_BYTES);
        let gs = striped.groups_touched(21, 0);
        let gf = flat.groups_touched(21, 0);
        assert!(gs.len() >= gf.len());
        assert_eq!(gf.len(), 8.min(21)); // flat: 8 sequential blocks → 8 groups
    }

    #[test]
    fn chunks_within_block_boundary() {
        let l = paper_layout(3 * HDFS_BLOCK_BYTES * 4);
        let chunks_per_block = HDFS_BLOCK_BYTES / STRIPE_CHUNK_BYTES;
        // Chunk on file 0 with index_in_file = chunks_per_block lands in
        // hdfs_block 1.
        let c = l.locate(chunks_per_block * l.width as u64);
        assert_eq!(c.file, 0);
        assert_eq!(c.hdfs_block, 1);
    }

    #[test]
    fn zero_byte_file() {
        let l = paper_layout(0);
        assert_eq!(l.n_chunks(), 0);
        assert_eq!((0..l.width).map(|f| l.file_bytes(f)).sum::<u64>(), 0);
        assert_eq!(l.total_hdfs_blocks(), 0);
        assert!(l.groups_touched(21, 0).is_empty());
        let flat = StripeLayout::unstriped(0, HDFS_BLOCK_BYTES);
        assert_eq!(flat.n_chunks(), 0);
        assert_eq!(flat.total_hdfs_blocks(), 0);
    }

    #[test]
    fn file_smaller_than_one_chunk() {
        let l = paper_layout(123);
        assert_eq!(l.n_chunks(), 1);
        assert_eq!(l.chunk_len(0), 123);
        assert_eq!(l.locate(0), ChunkLoc { file: 0, index_in_file: 0, hdfs_block: 0 });
        assert_eq!(l.file_bytes(0), 123);
        // The other stripe files are empty and hold no HDFS blocks.
        for f in 1..l.width {
            assert_eq!(l.file_bytes(f), 0);
            assert_eq!(l.file_hdfs_blocks(f), 0);
        }
        assert_eq!(l.total_hdfs_blocks(), 1);
        assert_eq!(l.groups_touched(21, 0), vec![0]);
    }

    #[test]
    fn exact_multiple_boundary() {
        // Logical size an exact multiple of the chunk size: no partial tail
        // chunk, every chunk full-length.
        let chunks = 4 * STRIPE_WIDTH as u64;
        let l = paper_layout(chunks * STRIPE_CHUNK_BYTES);
        assert_eq!(l.n_chunks(), chunks);
        for c in 0..l.n_chunks() {
            assert_eq!(l.chunk_len(c), STRIPE_CHUNK_BYTES);
        }
        for f in 0..l.width {
            assert_eq!(l.file_bytes(f), 4 * STRIPE_CHUNK_BYTES);
        }
        // And exactly one HDFS-block boundary: a file of exactly one block.
        let one = StripeLayout::new(HDFS_BLOCK_BYTES, STRIPE_CHUNK_BYTES, 1, HDFS_BLOCK_BYTES);
        assert_eq!(one.file_hdfs_blocks(0), 1);
        let last = one.locate(one.n_chunks() - 1);
        assert_eq!(last.hdfs_block, 0); // last chunk still in block 0
    }

    #[test]
    fn prop_locate_bijective() {
        prop_check(24, |g| {
            let bytes = g.u64_in(1, 50_000_000);
            let chunk = g.u64_in(1000, 2_000_000);
            let width = g.u64_in(1, 8) as u32;
            let block = chunk * g.u64_in(1, 600);
            let l = StripeLayout::new(bytes, chunk, width, block);
            let mut seen = std::collections::BTreeSet::new();
            for c in 0..l.n_chunks() {
                let loc = l.locate(c);
                prop_assert!(loc.file < width);
                prop_assert!(seen.insert((loc.file, loc.index_in_file)), "collision at {c}");
            }
            // Reconstruct: chunk count per file matches file_bytes.
            let total: u64 = (0..width).map(|f| l.file_bytes(f)).sum();
            prop_assert!(total == bytes, "{total} != {bytes}");
            Ok(())
        });
    }
}
