//! HDFS + striped HDFS-FUSE subsystem (§4.4): stripe layout math, the
//! cluster-sim read/write planners (sequential vs striped), and the real
//! on-disk striped store used by checkpoint save/resume.

pub mod fuse;
pub mod layout;
pub mod local;

pub use fuse::{plan_read, plan_write, ReadEngine};
pub use layout::{ChunkLoc, StripeLayout};
pub use local::LocalStore;
