//! PJRT runtime: loads the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs on this path — the artifacts are self-contained.

use crate::util::json::{self, Json};
use crate::anyhow;
use crate::util::error::{Context, Result};
use std::path::Path;

/// Parsed `artifacts/meta.json`: the wire contract between aot.py and the
/// trainer (parameter order/shapes, batch geometry).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub batch: usize,
    pub seq: usize,
    pub lr: f64,
    pub n_params: usize,
    /// (name, shape) in wire order.
    pub params: Vec<(String, Vec<usize>)>,
}

impl ModelMeta {
    pub fn load(path: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
        let j = json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let cfg = j.get("config").context("meta config")?;
        let geti = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(Json::as_usize).with_context(|| format!("config.{k}"))
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .context("meta params")?
            .iter()
            .map(|p| -> Result<(String, Vec<usize>)> {
                Ok((
                    p.get("name").and_then(Json::as_str).context("param name")?.to_string(),
                    p.get("shape")
                        .and_then(Json::as_arr)
                        .context("param shape")?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelMeta {
            vocab: geti("vocab")?,
            d_model: geti("d_model")?,
            n_layers: geti("n_layers")?,
            n_experts: geti("n_experts")?,
            batch: geti("batch")?,
            seq: geti("seq")?,
            lr: cfg.get("lr").and_then(Json::as_f64).unwrap_or(0.5),
            n_params: j.get("n_params").and_then(Json::as_usize).context("n_params")?,
            params,
        })
    }

    /// Total parameter element count (must equal `n_params`).
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// A compiled HLO artifact, ready to execute.
pub struct Engine {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Engine {
    /// Load HLO text, compile on `client`.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Engine> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("load {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Engine {
            exe,
            name: path.file_stem().and_then(|s| s.to_str()).unwrap_or("?").to_string(),
        })
    }

    /// Execute with the given inputs; the artifact returns a tuple
    /// (aot.py lowers with `return_tuple=True`), which is decomposed.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {}: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {}: {e:?}", self.name))
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    crate::ensure!(n == data.len(), "shape {shape:?} != len {}", data.len());
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("{e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    crate::ensure!(n == data.len(), "shape {shape:?} != len {}", data.len());
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("{e:?}"))
}

/// Extract an f32 vec from a literal.
pub fn literal_f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}

/// Extract the scalar f32 (loss) from a literal.
pub fn literal_scalar(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("meta.json").exists().then_some(d)
    }

    #[test]
    fn meta_parses() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let meta = ModelMeta::load(&dir.join("meta.json")).unwrap();
        assert_eq!(meta.param_elems(), meta.n_params);
        assert!(meta.params.iter().any(|(n, _)| n == "embed"));
        assert_eq!(meta.params.last().unwrap().0, "head");
    }

    #[test]
    fn literal_roundtrip() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(literal_f32s(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let i = i32_literal(&[5, 6], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![5, 6]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0], &[2, 2]).is_err());
        assert!(i32_literal(&[1, 2, 3], &[2]).is_err());
    }

    #[test]
    fn init_artifact_executes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let client = xla::PjRtClient::cpu().unwrap();
        let engine = Engine::load(&client, &dir.join("init.hlo.txt")).unwrap();
        let meta = ModelMeta::load(&dir.join("meta.json")).unwrap();
        let out = engine.execute(&[xla::Literal::scalar(42i32)]).unwrap();
        assert_eq!(out.len(), meta.params.len());
        // Shapes match the meta contract.
        for (lit, (name, shape)) in out.iter().zip(&meta.params) {
            let n: usize = shape.iter().product();
            assert_eq!(lit.element_count(), n, "param {name}");
        }
    }
}
