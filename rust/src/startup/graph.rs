//! The unified startup stage-graph.
//!
//! Subsystem planners used to be three free functions with three different
//! signatures, composed by hand-written barrier plumbing in `pipeline.rs`.
//! Here each subsystem instead implements [`StagePlanner`]: it declares its
//! profiler [`Stage`], how it attaches to the stage before it
//! ([`EdgeKind`], per [`OverlapMode`]), optionally what it could usefully
//! pre-stage during the Allocation phase ([`SpecRequest`]), and how to lay
//! its per-node tasks onto the fluid sim. [`StageGraph::compile`] turns an
//! ordered set of planners into one task DAG and returns a
//! [`CompiledGraph`] from which the pipeline emits events and spans
//! uniformly.
//!
//! The three gating disciplines (see `docs/stage_graph.md`):
//!
//! * `Sequential` — every stage ends in a global sync barrier, exactly the
//!   paper's Figure 2. Compiles to the same task structure the pre-graph
//!   pipeline built, so outcomes are byte-identical.
//! * `Overlapped` — stages chain per node: a node enters Environment Setup
//!   the moment its own image lands, and its checkpoint hot-chunk prefetch
//!   starts then too. NIC/service contention between concurrently active
//!   stages is resolved by the max-min fair engine.
//! * `Speculative` — `Overlapped`, plus staging flows that start during the
//!   Allocation phase on nodes already granted, bounded by a per-node byte
//!   budget. Staged bytes are credited to the stage's foreground work, and
//!   the stage gates on its staging flow (no free lunch: the bytes still
//!   cross the same pipes, just during the scheduler's dead time).

use crate::config::OverlapMode;
use crate::image::p2p::Swarm;
use crate::profiler::events::Stage;
use crate::sim::{ClusterSim, TaskId};
use crate::startup::World;

/// How a stage's per-node tasks attach to the stage before it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeKind {
    /// Every node waits for every node of the upstream stage — the paper's
    /// "(Sync)" barrier.
    GlobalBarrier,
    /// Node `i` waits only for node `i` of the upstream stage.
    PerNode,
    /// No dependency on the upstream stage: gated at the graph entry only
    /// (allocation complete).
    Entry,
}

/// Where speculative staging pulls its bytes from. Each variant mirrors
/// the transport the requesting stage itself would use for the same
/// bytes, so staged bytes never move slower than the in-stage fetch they
/// replace — the structural guarantee behind Overlapped ≥ Speculative.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecSource {
    /// P2P swarm fed by the cluster cache (image hot set with `p2p` on) —
    /// the transport `plan_prefetch` uses in-stage.
    CacheSwarm,
    /// Plain cluster-cache egress (image hot set with `p2p` off).
    ClusterCache,
    /// An HDFS DataNode group, round-robin by node (env cache archive) —
    /// the same group the restore download would hit.
    Hdfs,
}

/// A stage's request for speculative staging during Allocation.
#[derive(Clone, Copy, Debug)]
pub struct SpecRequest {
    pub bytes_per_node: u64,
    pub source: SpecSource,
}

/// What a planner laid down for its stage.
pub struct PlannedStage {
    /// Per-node stage completion.
    pub node_done: Vec<TaskId>,
    /// Sub-stage spans to report (e.g. InstallScript inside EnvSetup):
    /// per-node `(begin, end)` task pairs.
    pub sub_spans: Vec<(Stage, Vec<(TaskId, TaskId)>)>,
}

/// Inputs the graph hands a planner when compiling its stage.
pub struct StageInputs<'a> {
    /// Per-node gate tasks this stage must respect.
    pub deps: &'a [Vec<TaskId>],
    /// Bytes already staged per node during Allocation (empty → none).
    pub prestaged: &'a [u64],
    /// `(stage, per-node done)` of every stage already compiled, in graph
    /// order — planners pull custom overlap edges from here.
    pub upstream: &'a [(Stage, Vec<TaskId>)],
    pub mode: OverlapMode,
    /// Tag to attach to the stage's node-done tasks.
    pub tag: u64,
}

impl StageInputs<'_> {
    /// Per-node completion of an already-compiled stage, if present.
    pub fn done_of(&self, s: Stage) -> Option<&[TaskId]> {
        self.upstream.iter().find(|(st, _)| *st == s).map(|(_, v)| v.as_slice())
    }
}

/// One subsystem's startup stage, pluggable into the graph.
pub trait StagePlanner {
    /// Profiler stage this planner's tasks report under.
    fn stage(&self) -> Stage;

    /// How this stage attaches to the stage before it, per overlap mode.
    fn edge(&self, mode: OverlapMode) -> EdgeKind;

    /// Bytes this stage would pre-stage per node during the Allocation
    /// phase (`Speculative` mode). `None` → nothing useful to stage.
    fn spec_request(&self, world: &World) -> Option<SpecRequest> {
        let _ = world;
        None
    }

    /// Lay the stage's tasks onto the sim.
    fn plan(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        inp: &StageInputs<'_>,
    ) -> PlannedStage;
}

/// Tag attached to a stage's node-done tasks (the pre-graph pipeline used
/// the same numbering).
fn stage_tag(s: Stage) -> u64 {
    match s {
        Stage::ImageLoading => 1,
        Stage::EnvSetup => 2,
        Stage::ModelInit => 3,
        _ => 0,
    }
}

/// One compiled stage: enough handles to emit events and spans after the
/// sim has run.
pub struct CompiledStage {
    pub stage: Stage,
    /// Per-node gate whose completion timestamps the stage Begin events.
    pub begin_gate: Vec<TaskId>,
    pub node_done: Vec<TaskId>,
    pub sub_spans: Vec<(Stage, Vec<(TaskId, TaskId)>)>,
    /// Bytes staged per node during Allocation (empty → none).
    pub prestaged: Vec<u64>,
}

/// The compiled graph.
pub struct CompiledGraph {
    /// Stages in graph order.
    pub stages: Vec<CompiledStage>,
    /// Completion of the whole graph (every node of the final stage).
    pub done: TaskId,
}

impl CompiledGraph {
    pub fn stage(&self, s: Stage) -> Option<&CompiledStage> {
        self.stages.iter().find(|c| c.stage == s)
    }
}

/// An ordered set of stage planners plus the gating discipline to compile
/// them under.
pub struct StageGraph<'p> {
    planners: Vec<Box<dyn StagePlanner + 'p>>,
    mode: OverlapMode,
    /// Per-node speculative staging budget, bytes (`Speculative` only).
    budget: u64,
}

impl<'p> StageGraph<'p> {
    pub fn new(mode: OverlapMode, budget: u64) -> StageGraph<'p> {
        StageGraph { planners: Vec::new(), mode, budget }
    }

    pub fn add(&mut self, planner: Box<dyn StagePlanner + 'p>) {
        self.planners.push(planner);
    }

    /// Compile every stage onto the sim. `entry[i]` gates node `i`'s first
    /// stage (allocation complete); `grants[i]` (Speculative mode) is the
    /// task marking node `i`'s allocation grant, where staging flows start.
    pub fn compile(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        entry: &[Vec<TaskId>],
        grants: Option<&[TaskId]>,
    ) -> CompiledGraph {
        self.compile_with(cs, world, entry, grants, &[])
    }

    /// [`Self::compile`] with per-stage bytes already resident on every
    /// node's local disk (`local`): a warm restart that lands back on its
    /// previous nodes still holds the staged image hot set and the
    /// environment archive locally, so those bytes are credited against
    /// each stage's foreground fetch without any staging flow (they never
    /// cross the network again). An empty `local` compiles identically to
    /// [`Self::compile`].
    pub fn compile_with(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        entry: &[Vec<TaskId>],
        grants: Option<&[TaskId]>,
        local: &[(Stage, u64)],
    ) -> CompiledGraph {
        let n = cs.nodes();
        assert_eq!(entry.len(), n, "one entry gate set per node");
        assert!(!self.planners.is_empty(), "graph has at least one stage");

        // ---- Speculative staging during Allocation ----
        // For each planner: (bytes staged per node, staging task per node).
        let mut staged: Vec<Option<(Vec<u64>, Vec<TaskId>)>> =
            (0..self.planners.len()).map(|_| None).collect();
        if self.mode == OverlapMode::Speculative {
            if let Some(grants) = grants {
                assert_eq!(grants.len(), n, "one grant per node");
                let mut remaining = vec![self.budget; n];
                for (k, p) in self.planners.iter().enumerate() {
                    let Some(req) = p.spec_request(world) else { continue };
                    let bytes_v: Vec<u64> = (0..n)
                        .map(|i| {
                            let b = req.bytes_per_node.min(remaining[i]);
                            remaining[i] -= b;
                            b
                        })
                        .collect();
                    if bytes_v.iter().all(|&b| b == 0) {
                        continue; // budget exhausted: no flows, no join
                    }
                    // Only nodes with a nonzero staging share download
                    // through the pool; scope it to exactly that count so
                    // its slot recycles after the staging wave.
                    let stagers = bytes_v.iter().filter(|&&b| b > 0).count() as u32;
                    let swarm = if req.source == SpecSource::CacheSwarm {
                        Some(Swarm::build_scoped(
                            &mut cs.sim,
                            "spec.swarm",
                            cs.cfg.cluster_cache_egress_bps,
                            n as u32,
                            cs.cfg.node_nic_bps,
                            stagers,
                        ))
                    } else {
                        None
                    };
                    let task_v: Vec<TaskId> = (0..n)
                        .map(|i| {
                            if bytes_v[i] == 0 {
                                // Nothing to stage here; the placeholder is
                                // never joined (the join checks bytes > 0).
                                return grants[i];
                            }
                            let b = bytes_v[i] as f64;
                            match (req.source, &swarm) {
                                (SpecSource::CacheSwarm, Some(sw)) => {
                                    sw.download(&mut cs.sim, b, cs.node_nic[i], &[grants[i]], 0)
                                }
                                (SpecSource::Hdfs, _) => {
                                    let g = cs.hdfs_group_of(i);
                                    cs.sim.flow(b, vec![g, cs.node_nic[i]], &[grants[i]], 0)
                                }
                                _ => cs.sim.flow(
                                    b,
                                    vec![cs.cache, cs.node_nic[i]],
                                    &[grants[i]],
                                    0,
                                ),
                            }
                        })
                        .collect();
                    staged[k] = Some((bytes_v, task_v));
                }
            }
        }

        // ---- Stages in graph order ----
        let mut upstream: Vec<(Stage, Vec<TaskId>)> = Vec::new();
        let mut compiled: Vec<CompiledStage> = Vec::new();
        let mut prev_done: Option<Vec<TaskId>> = None;
        for (k, p) in self.planners.iter_mut().enumerate() {
            // The first stage has no upstream: PerNode degenerates to Entry
            // (GlobalBarrier still syncs on every node's entry gate — the
            // hot-update shape).
            let edge = match (p.edge(self.mode), prev_done.is_some()) {
                (EdgeKind::PerNode, false) => EdgeKind::Entry,
                (e, _) => e,
            };
            let (mut deps, mut begin_gate): (Vec<Vec<TaskId>>, Vec<TaskId>) = match edge {
                EdgeKind::Entry => {
                    let bg = entry
                        .iter()
                        .map(|g| if g.len() == 1 { g[0] } else { cs.sim.barrier(g, 0) })
                        .collect();
                    (entry.to_vec(), bg)
                }
                EdgeKind::PerNode => {
                    let prev = prev_done.as_ref().expect("PerNode edge needs upstream");
                    (prev.iter().map(|&t| vec![t]).collect(), prev.clone())
                }
                EdgeKind::GlobalBarrier => {
                    let bar = match prev_done.as_ref() {
                        Some(prev) => cs.sim.barrier(prev, 0),
                        None => {
                            let all: Vec<TaskId> =
                                entry.iter().flat_map(|g| g.iter().copied()).collect();
                            cs.sim.barrier(&all, 0)
                        }
                    };
                    (vec![vec![bar]; n], vec![bar; n])
                }
            };

            // Join the stage's speculative staging flows: the stage starts
            // once its normal gate AND its staged bytes have landed.
            // Locally resident bytes (warm restart on the same nodes) are
            // pure credit — no flow, no join.
            let local_bytes = local
                .iter()
                .find(|(s, _)| *s == p.stage())
                .map(|&(_, b)| b)
                .unwrap_or(0);
            let prestaged: Vec<u64> = match &staged[k] {
                Some((bytes, tasks)) => {
                    for i in 0..n {
                        if bytes[i] > 0 {
                            let mut d = std::mem::take(&mut deps[i]);
                            d.push(tasks[i]);
                            let joined = cs.sim.barrier(&d, 0);
                            deps[i] = vec![joined];
                            begin_gate[i] = joined;
                        }
                    }
                    if local_bytes == 0 {
                        bytes.clone()
                    } else {
                        bytes.iter().map(|&b| b + local_bytes).collect()
                    }
                }
                None if local_bytes > 0 => vec![local_bytes; n],
                None => Vec::new(),
            };

            let inp = StageInputs {
                deps: &deps,
                prestaged: &prestaged,
                upstream: &upstream,
                mode: self.mode,
                tag: stage_tag(p.stage()),
            };
            let plan = p.plan(cs, world, &inp);
            assert_eq!(plan.node_done.len(), n, "one done task per node");
            upstream.push((p.stage(), plan.node_done.clone()));
            compiled.push(CompiledStage {
                stage: p.stage(),
                begin_gate,
                node_done: plan.node_done.clone(),
                sub_spans: plan.sub_spans,
                prestaged,
            });
            prev_done = Some(plan.node_done);
        }

        let done = cs.sim.barrier(prev_done.as_ref().expect("nonempty graph"), 0);
        CompiledGraph { stages: compiled, done }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    /// A synthetic stage: per-node fixed delays, plus an optional staging
    /// request whose credited bytes become extra per-node delay (so tests
    /// can observe what the graph passed in).
    struct FixedStage {
        stage: Stage,
        edge: EdgeKind,
        durations: Vec<f64>,
        spec: Option<SpecRequest>,
        /// Seconds of extra delay per staged byte (observability hook).
        s_per_staged_byte: f64,
    }

    impl FixedStage {
        fn new(stage: Stage, edge: EdgeKind, durations: Vec<f64>) -> FixedStage {
            FixedStage { stage, edge, durations, spec: None, s_per_staged_byte: 0.0 }
        }
    }

    impl StagePlanner for FixedStage {
        fn stage(&self) -> Stage {
            self.stage
        }

        fn edge(&self, _mode: OverlapMode) -> EdgeKind {
            self.edge
        }

        fn spec_request(&self, _world: &World) -> Option<SpecRequest> {
            self.spec
        }

        fn plan(
            &mut self,
            cs: &mut ClusterSim,
            _world: &mut World,
            inp: &StageInputs<'_>,
        ) -> PlannedStage {
            let node_done = (0..cs.nodes())
                .map(|i| {
                    let staged = inp.prestaged.get(i).copied().unwrap_or(0);
                    let dur =
                        self.durations[i] + staged as f64 * self.s_per_staged_byte;
                    cs.sim.delay(dur, &inp.deps[i], inp.tag)
                })
                .collect();
            PlannedStage { node_done, sub_spans: Vec::new() }
        }
    }

    fn setup(nodes: u32) -> (ClusterSim, World) {
        (ClusterSim::build(&ClusterConfig::with_nodes(nodes), 42), World::new())
    }

    #[test]
    fn global_barrier_waits_for_slowest() {
        let (mut cs, mut w) = setup(2);
        let gate0 = cs.sim.delay(0.0, &[], 0);
        let entry = vec![vec![gate0]; 2];
        let mut g = StageGraph::new(OverlapMode::Sequential, 0);
        g.add(Box::new(FixedStage::new(
            Stage::ImageLoading,
            EdgeKind::Entry,
            vec![1.0, 10.0],
        )));
        g.add(Box::new(FixedStage::new(
            Stage::EnvSetup,
            EdgeKind::GlobalBarrier,
            vec![1.0, 1.0],
        )));
        let c = g.compile(&mut cs, &mut w, &entry, None);
        cs.sim.run();
        // Node 0's env starts only after node 1's image (t=10).
        let env = c.stage(Stage::EnvSetup).unwrap();
        assert_eq!(cs.sim.finished_at(env.begin_gate[0]), 10.0);
        assert_eq!(cs.sim.finished_at(env.node_done[0]), 11.0);
        assert_eq!(cs.sim.finished_at(c.done), 11.0);
    }

    #[test]
    fn per_node_edge_lets_fast_nodes_run_ahead() {
        let (mut cs, mut w) = setup(2);
        let gate0 = cs.sim.delay(0.0, &[], 0);
        let entry = vec![vec![gate0]; 2];
        let mut g = StageGraph::new(OverlapMode::Overlapped, 0);
        g.add(Box::new(FixedStage::new(
            Stage::ImageLoading,
            EdgeKind::Entry,
            vec![1.0, 10.0],
        )));
        g.add(Box::new(FixedStage::new(
            Stage::EnvSetup,
            EdgeKind::PerNode,
            vec![1.0, 1.0],
        )));
        let c = g.compile(&mut cs, &mut w, &entry, None);
        cs.sim.run();
        // Node 0 chains off its own image at t=1; the whole graph still
        // completes when the slowest node does.
        let env = c.stage(Stage::EnvSetup).unwrap();
        assert_eq!(cs.sim.finished_at(env.node_done[0]), 2.0);
        assert_eq!(cs.sim.finished_at(env.node_done[1]), 11.0);
        assert_eq!(cs.sim.finished_at(c.done), 11.0);
    }

    #[test]
    fn speculative_staging_respects_budget() {
        let (mut cs, mut w) = setup(2);
        let gate0 = cs.sim.delay(5.0, &[], 0);
        let entry = vec![vec![gate0]; 2];
        let grants: Vec<TaskId> = (0..2).map(|_| cs.sim.delay(1.0, &[], 0)).collect();
        let mut g = StageGraph::new(OverlapMode::Speculative, 400);
        let mut img = FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![0.0, 0.0]);
        img.spec = Some(SpecRequest { bytes_per_node: 300, source: SpecSource::ClusterCache });
        let mut env = FixedStage::new(Stage::EnvSetup, EdgeKind::PerNode, vec![0.0, 0.0]);
        env.spec = Some(SpecRequest { bytes_per_node: 300, source: SpecSource::Hdfs });
        g.add(Box::new(img));
        g.add(Box::new(env));
        let c = g.compile(&mut cs, &mut w, &entry, Some(&grants));
        cs.sim.run();
        // First stage gets its full request; the second is clamped by what
        // remains of the per-node budget.
        assert_eq!(c.stages[0].prestaged, vec![300, 300]);
        assert_eq!(c.stages[1].prestaged, vec![100, 100]);
    }

    #[test]
    fn non_speculative_modes_never_stage() {
        for mode in [OverlapMode::Sequential, OverlapMode::Overlapped] {
            let (mut cs, mut w) = setup(2);
            let gate0 = cs.sim.delay(0.0, &[], 0);
            let entry = vec![vec![gate0]; 2];
            let grants: Vec<TaskId> = (0..2).map(|_| cs.sim.delay(0.0, &[], 0)).collect();
            let mut g = StageGraph::new(mode, u64::MAX);
            let mut img =
                FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![1.0, 1.0]);
            img.spec =
                Some(SpecRequest { bytes_per_node: 300, source: SpecSource::ClusterCache });
            g.add(Box::new(img));
            let c = g.compile(&mut cs, &mut w, &entry, Some(&grants));
            cs.sim.run();
            assert!(c.stages[0].prestaged.is_empty());
        }
    }

    #[test]
    fn local_credit_feeds_prestaged_without_flows() {
        // Warm-restart credit: bytes appear in `prestaged` for the matching
        // stage only, with no staging flows (works in every mode).
        for mode in OverlapMode::ALL {
            let (mut cs, mut w) = setup(2);
            let gate0 = cs.sim.delay(0.0, &[], 0);
            let entry = vec![vec![gate0]; 2];
            let mut g = StageGraph::new(mode, 0);
            g.add(Box::new(FixedStage::new(
                Stage::ImageLoading,
                EdgeKind::Entry,
                vec![1.0, 1.0],
            )));
            g.add(Box::new(FixedStage::new(
                Stage::EnvSetup,
                EdgeKind::GlobalBarrier,
                vec![1.0, 1.0],
            )));
            let local = [(Stage::ImageLoading, 700u64)];
            let c = g.compile_with(&mut cs, &mut w, &entry, None, &local);
            cs.sim.run();
            assert_eq!(c.stages[0].prestaged, vec![700, 700], "{mode:?}");
            assert!(c.stages[1].prestaged.is_empty(), "{mode:?}");
            // Credit does not delay the stage: begin gate is the entry gate.
            assert_eq!(cs.sim.finished_at(c.stages[0].begin_gate[0]), 0.0);
        }
    }

    #[test]
    fn local_credit_adds_to_speculative_staging() {
        let (mut cs, mut w) = setup(2);
        let gate0 = cs.sim.delay(5.0, &[], 0);
        let entry = vec![vec![gate0]; 2];
        let grants: Vec<TaskId> = (0..2).map(|_| cs.sim.delay(1.0, &[], 0)).collect();
        let mut g = StageGraph::new(OverlapMode::Speculative, 400);
        let mut img = FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![0.0, 0.0]);
        img.spec = Some(SpecRequest { bytes_per_node: 300, source: SpecSource::ClusterCache });
        g.add(Box::new(img));
        let local = [(Stage::ImageLoading, 50u64)];
        let c = g.compile_with(&mut cs, &mut w, &entry, Some(&grants), &local);
        cs.sim.run();
        assert_eq!(c.stages[0].prestaged, vec![350, 350]);
    }

    #[test]
    fn empty_local_compiles_identically() {
        let build = |local: &[(Stage, u64)]| {
            let (mut cs, mut w) = setup(2);
            let gate0 = cs.sim.delay(0.0, &[], 0);
            let entry = vec![vec![gate0]; 2];
            let mut g = StageGraph::new(OverlapMode::Sequential, 0);
            g.add(Box::new(FixedStage::new(
                Stage::ImageLoading,
                EdgeKind::Entry,
                vec![2.0, 3.0],
            )));
            let c = g.compile_with(&mut cs, &mut w, &entry, None, local);
            cs.sim.run();
            cs.sim.finished_at(c.done).to_bits()
        };
        assert_eq!(build(&[]), build(&[(Stage::EnvSetup, 100)]));
    }

    #[test]
    fn first_stage_global_barrier_syncs_on_entry() {
        // The hot-update shape: the first stage is behind a global barrier
        // over every node's entry gate.
        let (mut cs, mut w) = setup(2);
        let g0 = cs.sim.delay(3.0, &[], 0);
        let g1 = cs.sim.delay(7.0, &[], 0);
        let entry = vec![vec![g0], vec![g1]];
        let mut g = StageGraph::new(OverlapMode::Sequential, 0);
        g.add(Box::new(FixedStage::new(
            Stage::EnvSetup,
            EdgeKind::GlobalBarrier,
            vec![1.0, 1.0],
        )));
        let c = g.compile(&mut cs, &mut w, &entry, None);
        cs.sim.run();
        // Both nodes start at t=7 (slowest entry gate).
        assert_eq!(cs.sim.finished_at(c.stages[0].node_done[0]), 8.0);
        assert_eq!(cs.sim.finished_at(c.stages[0].node_done[1]), 8.0);
    }

    #[test]
    fn upstream_handles_visible_to_later_stages() {
        struct Probing;
        impl StagePlanner for Probing {
            fn stage(&self) -> Stage {
                Stage::ModelInit
            }
            fn edge(&self, _m: OverlapMode) -> EdgeKind {
                EdgeKind::PerNode
            }
            fn plan(
                &mut self,
                cs: &mut ClusterSim,
                _world: &mut World,
                inp: &StageInputs<'_>,
            ) -> PlannedStage {
                // Gate on the image stage directly (the overlap edge).
                let img = inp.done_of(Stage::ImageLoading).expect("image compiled");
                let node_done = (0..cs.nodes())
                    .map(|i| cs.sim.delay(1.0, &[img[i]], inp.tag))
                    .collect();
                PlannedStage { node_done, sub_spans: Vec::new() }
            }
        }
        let (mut cs, mut w) = setup(1);
        let gate0 = cs.sim.delay(0.0, &[], 0);
        let entry = vec![vec![gate0]];
        let mut g = StageGraph::new(OverlapMode::Overlapped, 0);
        g.add(Box::new(FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![2.0])));
        g.add(Box::new(FixedStage::new(Stage::EnvSetup, EdgeKind::PerNode, vec![50.0])));
        g.add(Box::new(Probing));
        let c = g.compile(&mut cs, &mut w, &entry, None);
        cs.sim.run();
        // ModelInit gated on image (t=2), not env (t=52).
        assert_eq!(cs.sim.finished_at(c.stage(Stage::ModelInit).unwrap().node_done[0]), 3.0);
    }
}
