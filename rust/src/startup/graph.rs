//! The unified startup stage-graph.
//!
//! Subsystem planners used to be three free functions with three different
//! signatures, composed by hand-written barrier plumbing in `pipeline.rs`.
//! Here each subsystem instead implements [`StagePlanner`]: it declares its
//! profiler [`Stage`], how it attaches to the stage before it
//! ([`EdgeKind`], per [`OverlapMode`]), which content-addressed artifacts
//! it moves ([`ArtifactDecl`] — manifests, not byte counts), and how to
//! lay its per-node tasks onto the fluid sim. [`StageGraph::compile`]
//! turns an ordered set of planners into one task DAG and returns a
//! [`CompiledGraph`] from which the pipeline emits events and spans
//! uniformly.
//!
//! The artifact declarations collapse what used to be three parallel byte
//! side channels into one: speculative staging (`Speculative` mode moves a
//! budget-bounded prefix of each stage-ahead manifest during Allocation),
//! warm-restart credit (bytes already resident per the caller's
//! [`CacheState`]), and cross-artifact dedup (chunks whose content landed
//! via an earlier stage's manifest) are all just "what's already in the
//! cache" by the time a stage plans its foreground fetch.
//!
//! The three gating disciplines (see `docs/stage_graph.md`):
//!
//! * `Sequential` — every stage ends in a global sync barrier, exactly the
//!   paper's Figure 2. Compiles to the same task structure the pre-graph
//!   pipeline built, so outcomes are byte-identical.
//! * `Overlapped` — stages chain per node: a node enters Environment Setup
//!   the moment its own image lands, and its checkpoint hot-chunk prefetch
//!   starts then too. NIC/service contention between concurrently active
//!   stages is resolved by the max-min fair engine.
//! * `Speculative` — `Overlapped`, plus staging flows that start during the
//!   Allocation phase on nodes already granted, bounded by a per-node byte
//!   budget. Staged bytes are credited to the stage's foreground work, and
//!   the stage gates on its staging flow (no free lunch: the bytes still
//!   cross the same pipes, just during the scheduler's dead time).

use crate::artifact::cache::CacheState;
use crate::artifact::manifest::ArtifactManifest;
use crate::artifact::transfer::{admitted_peers, Admission, ProviderTier, TransferPlanner};
use crate::config::OverlapMode;
use crate::profiler::events::Stage;
use crate::sim::{ClusterSim, NodeHandle, TaskId};
use crate::startup::World;
use crate::util::cast::u32_from_usize;

/// How a stage's per-node tasks attach to the stage before it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeKind {
    /// Every node waits for every node of the upstream stage — the paper's
    /// "(Sync)" barrier.
    GlobalBarrier,
    /// Node `i` waits only for node `i` of the upstream stage.
    PerNode,
    /// No dependency on the upstream stage: gated at the graph entry only
    /// (allocation complete).
    Entry,
}

/// One artifact a stage moves, declared to the graph as a manifest plus
/// the transport its bytes would ride if staged ahead of time.
#[derive(Clone, Debug)]
pub struct ArtifactDecl {
    pub manifest: ArtifactManifest,
    /// Transport for staging this artifact during Allocation. Mirrors the
    /// transport the stage itself would use for the same bytes, so staged
    /// bytes never move slower than the in-stage fetch they replace — the
    /// structural guarantee behind Overlapped ≥ Speculative.
    pub tier: ProviderTier,
    /// Eligible for speculative staging during Allocation (`Speculative`
    /// mode). At most one stage-ahead artifact per stage.
    pub stage_ahead: bool,
    /// Resident bytes of this manifest are credited against the stage's
    /// foreground fetch (background-only artifacts set `false`).
    pub credit: bool,
}

/// What a planner laid down for its stage.
pub struct PlannedStage {
    /// Per-node stage completion.
    pub node_done: Vec<TaskId>,
    /// Sub-stage spans to report (e.g. InstallScript inside EnvSetup):
    /// per-node `(begin, end)` task pairs.
    pub sub_spans: Vec<(Stage, Vec<(TaskId, TaskId)>)>,
    /// Foreground bytes the stage fetched over the network, across nodes
    /// (after resident credit).
    pub fetched_bytes: u64,
}

/// Inputs the graph hands a planner when compiling its stage.
pub struct StageInputs<'a> {
    /// Per-node gate tasks this stage must respect.
    pub deps: &'a [Vec<TaskId>],
    /// Bytes already locally resident per node (empty → none): the sum of
    /// speculative staging and cache-resident credit for this stage's
    /// credited artifacts. Consumers subtract with saturation.
    pub prestaged: &'a [u64],
    /// `(stage, per-node done)` of every stage already compiled, in graph
    /// order — planners pull custom overlap edges from here.
    pub upstream: &'a [(Stage, Vec<TaskId>)],
    pub mode: OverlapMode,
    /// Tag to attach to the stage's node-done tasks.
    pub tag: u64,
}

impl StageInputs<'_> {
    /// Per-node completion of an already-compiled stage, if present.
    pub fn done_of(&self, s: Stage) -> Option<&[TaskId]> {
        self.upstream.iter().find(|(st, _)| *st == s).map(|(_, v)| v.as_slice())
    }
}

/// One subsystem's startup stage, pluggable into the graph.
pub trait StagePlanner {
    /// Profiler stage this planner's tasks report under.
    fn stage(&self) -> Stage;

    /// How this stage attaches to the stage before it, per overlap mode.
    fn edge(&self, mode: OverlapMode) -> EdgeKind;

    /// The content-addressed artifacts this stage moves. Empty (the
    /// default) → nothing to stage ahead, nothing to credit. `dedup` says
    /// whether the graph's cross-artifact dedup plane is on: chunk lists
    /// are only walked then, so planners may skip materializing manifests
    /// whose chunks have no other consumer.
    fn artifacts(&self, world: &World, dedup: bool) -> Vec<ArtifactDecl> {
        let _ = (world, dedup);
        Vec::new()
    }

    /// Lay the stage's tasks onto the sim.
    fn plan(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        inp: &StageInputs<'_>,
    ) -> PlannedStage;
}

/// Tag attached to a stage's node-done tasks (the pre-graph pipeline used
/// the same numbering).
fn stage_tag(s: Stage) -> u64 {
    match s {
        Stage::ImageLoading => 1,
        Stage::EnvSetup => 2,
        Stage::ModelInit => 3,
        _ => 0,
    }
}

/// One compiled stage: enough handles to emit events and spans after the
/// sim has run.
pub struct CompiledStage {
    pub stage: Stage,
    /// Per-node gate whose completion timestamps the stage Begin events.
    pub begin_gate: Vec<TaskId>,
    pub node_done: Vec<TaskId>,
    pub sub_spans: Vec<(Stage, Vec<(TaskId, TaskId)>)>,
    /// Bytes credited per node (staging + cache residency; empty → none).
    pub prestaged: Vec<u64>,
    /// Foreground bytes the stage fetched over the network, across nodes.
    pub fetched_bytes: u64,
}

/// The compiled graph.
pub struct CompiledGraph {
    /// Stages in graph order.
    pub stages: Vec<CompiledStage>,
    /// Completion of the whole graph (every node of the final stage).
    pub done: TaskId,
    /// Bytes moved by speculative staging flows during Allocation, across
    /// stages and nodes (0 outside `Speculative` mode).
    pub staged_bytes: u64,
    /// Cache-resident bytes credited against credited artifacts' fetches,
    /// across stages and nodes (the cache-hit numerator).
    pub credited_bytes: u64,
    /// Total bytes of credited artifacts the stages wanted on every node
    /// (the cache-hit denominator; credited ≤ demanded).
    pub demanded_bytes: u64,
    /// Governed fetches shed at least once before admission.
    pub shed_events: u64,
    /// Governed fetches whose admission was evaluated (the shed-rate
    /// denominator; 0 whenever no [`Admission`] is attached).
    pub shed_checks: u64,
}

impl CompiledGraph {
    pub fn stage(&self, s: Stage) -> Option<&CompiledStage> {
        self.stages.iter().find(|c| c.stage == s)
    }

    /// Total foreground bytes fetched over the network: per-stage fetches
    /// plus the speculative staging flows.
    pub fn fetched_bytes(&self) -> u64 {
        self.staged_bytes + self.stages.iter().map(|c| c.fetched_bytes).sum::<u64>()
    }
}

/// An ordered set of stage planners plus the gating discipline to compile
/// them under.
pub struct StageGraph<'p> {
    planners: Vec<Box<dyn StagePlanner + 'p>>,
    mode: OverlapMode,
    /// Per-node speculative staging budget, bytes (`Speculative` only).
    budget: u64,
    /// Cross-artifact dedup: materialized manifests feed the run cache so
    /// later stages can credit shared content chunks.
    dedup: bool,
    /// Registry/cluster-cache admission control for this startup's
    /// governed fetches (`None` — the default — admits everything
    /// immediately and lays down the exact historical DAG).
    admission: Option<Admission>,
}

impl<'p> StageGraph<'p> {
    pub fn new(mode: OverlapMode, budget: u64) -> StageGraph<'p> {
        StageGraph { planners: Vec::new(), mode, budget, dedup: false, admission: None }
    }

    pub fn add(&mut self, planner: Box<dyn StagePlanner + 'p>) {
        self.planners.push(planner);
    }

    /// Enable cross-artifact dedup at the transfer plane
    /// (`bootseer.artifact_dedup`).
    pub fn set_dedup(&mut self, on: bool) {
        self.dedup = on;
    }

    /// Attach admission control (load shedding + retry backoff) for the
    /// registry/cluster-cache fetches this graph compiles.
    pub fn set_admission(&mut self, admission: Option<Admission>) {
        self.admission = admission;
    }

    /// Compile every stage onto the sim with nothing resident. `entry[i]`
    /// gates node `i`'s first stage (allocation complete); `grants[i]`
    /// (Speculative mode) is the task marking node `i`'s allocation grant,
    /// where staging flows start.
    pub fn compile(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        entry: &[Vec<TaskId>],
        grants: Option<&[TaskId]>,
    ) -> CompiledGraph {
        self.compile_cached(cs, world, entry, grants, &CacheState::new())
    }

    /// [`Self::compile`] against a [`CacheState`] of already-resident
    /// chunks: a warm restart that lands back on its previous nodes still
    /// holds the staged image hot set, the environment archive, and (with
    /// delta resume) most of its checkpoint shard locally, so those bytes
    /// are credited against each stage's foreground fetch without any
    /// extra flow. An empty cache compiles identically to
    /// [`Self::compile`].
    ///
    /// One deliberate exception: in `Speculative` mode the Allocation-time
    /// staging pass still moves its budget-bounded prefix regardless of
    /// residency (the grant-time stager has no view of node-local disks
    /// yet), exactly as the pre-refactor pipeline did — the residency
    /// credit then covers only bytes *beyond* that staged prefix, so
    /// nothing is ever credited twice.
    pub fn compile_cached(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        entry: &[Vec<TaskId>],
        grants: Option<&[TaskId]>,
        cache: &CacheState,
    ) -> CompiledGraph {
        let n = cs.nodes();
        assert_eq!(entry.len(), n, "one entry gate set per node");
        assert!(!self.planners.is_empty(), "graph has at least one stage");

        // Artifact declarations, one immutable pass before any planning.
        let decls: Vec<Vec<ArtifactDecl>> =
            self.planners.iter().map(|p| p.artifacts(world, self.dedup)).collect();

        // Run-local residency: starts from the caller's warm state; with
        // dedup on, stages insert their materialized manifests as they
        // compile so downstream stages can credit shared content.
        let mut run_cache = cache.clone();

        // Fleet cache economics: a cache under eviction pressure fields
        // fewer useful swarm peers, and governed fetches may be shed.
        // Both are no-ops (zero pressure, no admission) on default
        // configs — bit-identical DAGs.
        let pressure = cache.eviction_pressure();
        let peer_seed = self.admission.as_ref().map_or(0x5EED, |a| a.seed());
        let mut credited_bytes = 0u64;
        let mut demanded_bytes = 0u64;
        let mut shed_events = 0u64;
        let mut shed_checks = 0u64;

        // ---- Speculative staging during Allocation ----
        // For each planner: (bytes staged per node, staging task per node).
        let mut staged: Vec<Option<(Vec<u64>, Vec<TaskId>)>> =
            (0..self.planners.len()).map(|_| None).collect();
        let mut staged_bytes_total = 0u64;
        if self.mode == OverlapMode::Speculative {
            if let Some(grants) = grants {
                assert_eq!(grants.len(), n, "one grant per node");
                let mut remaining = vec![self.budget; n];
                for (k, decl_list) in decls.iter().enumerate() {
                    let Some(a) = decl_list.iter().find(|a| a.stage_ahead) else { continue };
                    debug_assert!(
                        decl_list.iter().filter(|a| a.stage_ahead).count() <= 1,
                        "at most one stage-ahead artifact per stage"
                    );
                    let total = a.manifest.total_bytes();
                    if total == 0 {
                        continue;
                    }
                    let bytes_v: Vec<u64> = (0..n)
                        .map(|i| {
                            let b = total.min(remaining[i]);
                            remaining[i] -= b;
                            b
                        })
                        .collect();
                    if bytes_v.iter().all(|&b| b == 0) {
                        continue; // budget exhausted: no flows, no join
                    }
                    // Only nodes with a nonzero staging share download
                    // through the pool; scope it to exactly that count so
                    // its slot recycles after the staging wave. Peers
                    // under eviction pressure drop out of the pool (they
                    // are about to evict what they would serve).
                    let stagers = u32_from_usize(bytes_v.iter().filter(|&&b| b > 0).count());
                    let peers = admitted_peers(n as u32, pressure, peer_seed);
                    let provider =
                        TransferPlanner::build(cs, "spec.swarm", a.tier, peers, stagers)
                            .with_admission(self.admission, a.manifest.id);
                    if let Some(adm) = &self.admission {
                        if Admission::governs(a.tier) {
                            for (i, &b) in bytes_v.iter().enumerate() {
                                if b == 0 {
                                    continue;
                                }
                                shed_checks += 1;
                                if adm.shed_attempts(a.tier, a.manifest.id, i) > 0 {
                                    shed_events += 1;
                                }
                            }
                        }
                    }
                    let task_v: Vec<TaskId> = (0..n)
                        .map(|i| {
                            if bytes_v[i] == 0 {
                                // Nothing to stage here; the placeholder is
                                // never joined (the join checks bytes > 0).
                                return grants[i];
                            }
                            provider.fetch(
                                cs,
                                NodeHandle::new(i),
                                bytes_v[i] as f64,
                                &[grants[i]],
                                0,
                            )
                        })
                        .collect();
                    staged_bytes_total += bytes_v.iter().sum::<u64>();
                    staged[k] = Some((bytes_v, task_v));
                }
            }
        }

        // ---- Stages in graph order ----
        let mut upstream: Vec<(Stage, Vec<TaskId>)> = Vec::new();
        let mut compiled: Vec<CompiledStage> = Vec::new();
        let mut prev_done: Option<Vec<TaskId>> = None;
        for (k, p) in self.planners.iter_mut().enumerate() {
            // The first stage has no upstream: PerNode degenerates to Entry
            // (GlobalBarrier still syncs on every node's entry gate — the
            // hot-update shape).
            let edge = match (p.edge(self.mode), prev_done.is_some()) {
                (EdgeKind::PerNode, false) => EdgeKind::Entry,
                (e, _) => e,
            };
            let (mut deps, mut begin_gate): (Vec<Vec<TaskId>>, Vec<TaskId>) = match edge {
                EdgeKind::Entry => {
                    let bg = entry
                        .iter()
                        .map(|g| if g.len() == 1 { g[0] } else { cs.sim.barrier(g, 0) })
                        .collect();
                    (entry.to_vec(), bg)
                }
                EdgeKind::PerNode => {
                    let prev = prev_done.as_ref().expect("PerNode edge needs upstream");
                    (prev.iter().map(|&t| vec![t]).collect(), prev.clone())
                }
                EdgeKind::GlobalBarrier => {
                    let bar = match prev_done.as_ref() {
                        Some(prev) => cs.sim.barrier(prev, 0),
                        None => {
                            let all: Vec<TaskId> =
                                entry.iter().flat_map(|g| g.iter().copied()).collect();
                            cs.sim.barrier(&all, 0)
                        }
                    };
                    (vec![vec![bar]; n], vec![bar; n])
                }
            };

            // Cache-resident credit for the stage's credited artifacts:
            // warm-restart state plus (under dedup) content chunks landed
            // by earlier stages. Pure credit — no flow, no join. The
            // stage-ahead artifact's staged prefix is excluded: those
            // bytes are counted by the staging flow itself, and its head
            // chunks may also be content-resident (the env snapshot's
            // image-shared prefix) — they must not be credited twice.
            let mut credit = vec![0u64; n];
            let mut any_credit = false;
            // Per-node admission backoff accrued by this stage's governed
            // foreground fetches (0 everywhere without shedding).
            let mut shed_delay = vec![0.0f64; n];
            for a in decls[k].iter().filter(|a| a.credit) {
                for (i, c) in credit.iter_mut().enumerate() {
                    let (skip, staged_here) = match &staged[k] {
                        Some((bytes, _)) if a.stage_ahead => (bytes[i], bytes[i] > 0),
                        _ => (0, false),
                    };
                    let r = run_cache.resident_bytes_beyond(i, &a.manifest, skip, self.dedup);
                    demanded_bytes += a.manifest.total_bytes();
                    credited_bytes += r.min(a.manifest.total_bytes());
                    if r > 0 {
                        *c = c.saturating_add(r);
                        any_credit = true;
                    }
                    // Shed the foreground fetch of a governed artifact:
                    // the stage waits out the seeded backoff before its
                    // (single) fetch runs. A node whose bytes are fully
                    // resident never hits the service; a node with a
                    // staging flow is gated inside that flow instead.
                    if let Some(adm) = &self.admission {
                        let remaining =
                            a.manifest.total_bytes().saturating_sub(skip).saturating_sub(r);
                        if Admission::governs(a.tier) && remaining > 0 && !staged_here {
                            shed_checks += 1;
                            let att = adm.shed_attempts(a.tier, a.manifest.id, i);
                            if att > 0 {
                                shed_events += 1;
                                shed_delay[i] +=
                                    adm.delay_before(a.tier, a.manifest.id, i);
                            }
                        }
                    }
                }
            }
            for i in 0..n {
                if shed_delay[i] > 0.0 {
                    let d = std::mem::take(&mut deps[i]);
                    let gate = cs.sim.delay(shed_delay[i], &d, 0);
                    deps[i] = vec![gate];
                    begin_gate[i] = gate;
                }
            }

            // Join the stage's speculative staging flows: the stage starts
            // once its normal gate AND its staged bytes have landed.
            let prestaged: Vec<u64> = match &staged[k] {
                Some((bytes, tasks)) => {
                    for i in 0..n {
                        if bytes[i] > 0 {
                            let mut d = std::mem::take(&mut deps[i]);
                            d.push(tasks[i]);
                            let joined = cs.sim.barrier(&d, 0);
                            deps[i] = vec![joined];
                            begin_gate[i] = joined;
                        }
                    }
                    if !any_credit {
                        bytes.clone()
                    } else {
                        bytes.iter().zip(&credit).map(|(&b, &c)| b.saturating_add(c)).collect()
                    }
                }
                None if any_credit => credit,
                None => Vec::new(),
            };

            let inp = StageInputs {
                deps: &deps,
                prestaged: &prestaged,
                upstream: &upstream,
                mode: self.mode,
                tag: stage_tag(p.stage()),
            };
            let plan = p.plan(cs, world, &inp);
            assert_eq!(plan.node_done.len(), n, "one done task per node");

            // Under dedup, the stage's manifests are now materialized on
            // every node of the allocation (foreground by stage end,
            // background eventually): record their chunks in the shared
            // layer so later stages credit shared content.
            if self.dedup {
                for a in &decls[k] {
                    run_cache.insert_shared_chunks(&a.manifest);
                }
            }

            upstream.push((p.stage(), plan.node_done.clone()));
            compiled.push(CompiledStage {
                stage: p.stage(),
                begin_gate,
                node_done: plan.node_done.clone(),
                sub_spans: plan.sub_spans,
                prestaged,
                fetched_bytes: plan.fetched_bytes,
            });
            prev_done = Some(plan.node_done);
        }

        let done = cs.sim.barrier(prev_done.as_ref().expect("nonempty graph"), 0);
        CompiledGraph {
            stages: compiled,
            done,
            staged_bytes: staged_bytes_total,
            credited_bytes,
            demanded_bytes,
            shed_events,
            shed_checks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    /// A synthetic stage: per-node fixed delays, plus an optional artifact
    /// declaration whose credited bytes become extra per-node delay (so
    /// tests can observe what the graph passed in).
    struct FixedStage {
        stage: Stage,
        edge: EdgeKind,
        durations: Vec<f64>,
        decl: Option<ArtifactDecl>,
        /// Seconds of extra delay per credited byte (observability hook).
        s_per_staged_byte: f64,
    }

    impl FixedStage {
        fn new(stage: Stage, edge: EdgeKind, durations: Vec<f64>) -> FixedStage {
            FixedStage { stage, edge, durations, decl: None, s_per_staged_byte: 0.0 }
        }

        /// Declare a stage-ahead synthetic artifact of `bytes` bytes.
        fn with_artifact(mut self, id: u64, bytes: u64, tier: ProviderTier) -> FixedStage {
            self.decl = Some(ArtifactDecl {
                manifest: ArtifactManifest::synthetic(id, bytes, 100),
                tier,
                stage_ahead: true,
                credit: true,
            });
            self
        }
    }

    impl StagePlanner for FixedStage {
        fn stage(&self) -> Stage {
            self.stage
        }

        fn edge(&self, _mode: OverlapMode) -> EdgeKind {
            self.edge
        }

        fn artifacts(&self, _world: &World, _dedup: bool) -> Vec<ArtifactDecl> {
            self.decl.iter().cloned().collect()
        }

        fn plan(
            &mut self,
            cs: &mut ClusterSim,
            _world: &mut World,
            inp: &StageInputs<'_>,
        ) -> PlannedStage {
            let node_done = (0..cs.nodes())
                .map(|i| {
                    let staged = inp.prestaged.get(i).copied().unwrap_or(0);
                    let dur =
                        self.durations[i] + staged as f64 * self.s_per_staged_byte;
                    cs.sim.delay(dur, &inp.deps[i], inp.tag)
                })
                .collect();
            PlannedStage { node_done, sub_spans: Vec::new(), fetched_bytes: 0 }
        }
    }

    fn setup(nodes: u32) -> (ClusterSim, World) {
        (ClusterSim::build(&ClusterConfig::with_nodes(nodes), 42), World::new())
    }

    #[test]
    fn global_barrier_waits_for_slowest() {
        let (mut cs, mut w) = setup(2);
        let gate0 = cs.sim.delay(0.0, &[], 0);
        let entry = vec![vec![gate0]; 2];
        let mut g = StageGraph::new(OverlapMode::Sequential, 0);
        g.add(Box::new(FixedStage::new(
            Stage::ImageLoading,
            EdgeKind::Entry,
            vec![1.0, 10.0],
        )));
        g.add(Box::new(FixedStage::new(
            Stage::EnvSetup,
            EdgeKind::GlobalBarrier,
            vec![1.0, 1.0],
        )));
        let c = g.compile(&mut cs, &mut w, &entry, None);
        cs.sim.run();
        // Node 0's env starts only after node 1's image (t=10).
        let env = c.stage(Stage::EnvSetup).unwrap();
        assert_eq!(cs.sim.finished_at(env.begin_gate[0]), 10.0);
        assert_eq!(cs.sim.finished_at(env.node_done[0]), 11.0);
        assert_eq!(cs.sim.finished_at(c.done), 11.0);
    }

    #[test]
    fn per_node_edge_lets_fast_nodes_run_ahead() {
        let (mut cs, mut w) = setup(2);
        let gate0 = cs.sim.delay(0.0, &[], 0);
        let entry = vec![vec![gate0]; 2];
        let mut g = StageGraph::new(OverlapMode::Overlapped, 0);
        g.add(Box::new(FixedStage::new(
            Stage::ImageLoading,
            EdgeKind::Entry,
            vec![1.0, 10.0],
        )));
        g.add(Box::new(FixedStage::new(
            Stage::EnvSetup,
            EdgeKind::PerNode,
            vec![1.0, 1.0],
        )));
        let c = g.compile(&mut cs, &mut w, &entry, None);
        cs.sim.run();
        // Node 0 chains off its own image at t=1; the whole graph still
        // completes when the slowest node does.
        let env = c.stage(Stage::EnvSetup).unwrap();
        assert_eq!(cs.sim.finished_at(env.node_done[0]), 2.0);
        assert_eq!(cs.sim.finished_at(env.node_done[1]), 11.0);
        assert_eq!(cs.sim.finished_at(c.done), 11.0);
    }

    #[test]
    fn speculative_staging_respects_budget() {
        let (mut cs, mut w) = setup(2);
        let gate0 = cs.sim.delay(5.0, &[], 0);
        let entry = vec![vec![gate0]; 2];
        let grants: Vec<TaskId> = (0..2).map(|_| cs.sim.delay(1.0, &[], 0)).collect();
        let mut g = StageGraph::new(OverlapMode::Speculative, 400);
        g.add(Box::new(
            FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![0.0, 0.0])
                .with_artifact(0xA, 300, ProviderTier::ClusterCache),
        ));
        g.add(Box::new(
            FixedStage::new(Stage::EnvSetup, EdgeKind::PerNode, vec![0.0, 0.0])
                .with_artifact(0xB, 300, ProviderTier::Hdfs { nn_op: false }),
        ));
        let c = g.compile(&mut cs, &mut w, &entry, Some(&grants));
        cs.sim.run();
        // First stage gets its full request; the second is clamped by what
        // remains of the per-node budget.
        assert_eq!(c.stages[0].prestaged, vec![300, 300]);
        assert_eq!(c.stages[1].prestaged, vec![100, 100]);
        assert_eq!(c.staged_bytes, 2 * 300 + 2 * 100);
    }

    #[test]
    fn non_speculative_modes_never_stage() {
        for mode in [OverlapMode::Sequential, OverlapMode::Overlapped] {
            let (mut cs, mut w) = setup(2);
            let gate0 = cs.sim.delay(0.0, &[], 0);
            let entry = vec![vec![gate0]; 2];
            let grants: Vec<TaskId> = (0..2).map(|_| cs.sim.delay(0.0, &[], 0)).collect();
            let mut g = StageGraph::new(mode, u64::MAX);
            g.add(Box::new(
                FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![1.0, 1.0])
                    .with_artifact(0xA, 300, ProviderTier::ClusterCache),
            ));
            let c = g.compile(&mut cs, &mut w, &entry, Some(&grants));
            cs.sim.run();
            assert!(c.stages[0].prestaged.is_empty());
            assert_eq!(c.staged_bytes, 0);
        }
    }

    #[test]
    fn cache_residency_feeds_prestaged_without_flows() {
        // Warm-restart credit: resident bytes appear in `prestaged` for
        // the declaring stage only, with no staging flows (every mode).
        for mode in OverlapMode::ALL {
            let (mut cs, mut w) = setup(2);
            let gate0 = cs.sim.delay(0.0, &[], 0);
            let entry = vec![vec![gate0]; 2];
            let mut g = StageGraph::new(mode, 0);
            g.add(Box::new(
                FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![1.0, 1.0])
                    .with_artifact(0xA, 700, ProviderTier::ClusterCache),
            ));
            g.add(Box::new(FixedStage::new(
                Stage::EnvSetup,
                EdgeKind::GlobalBarrier,
                vec![1.0, 1.0],
            )));
            let mut cache = CacheState::new();
            cache.insert_shared_artifact(ArtifactManifest::synthetic(0xA, 700, 100).id, 700);
            let c = g.compile_cached(&mut cs, &mut w, &entry, None, &cache);
            cs.sim.run();
            assert_eq!(c.stages[0].prestaged, vec![700, 700], "{mode:?}");
            assert!(c.stages[1].prestaged.is_empty(), "{mode:?}");
            // Credit does not delay the stage: begin gate is the entry gate.
            assert_eq!(cs.sim.finished_at(c.stages[0].begin_gate[0]), 0.0);
            assert_eq!(c.staged_bytes, 0);
            // The hit-rate counters see a fully warm demand.
            assert_eq!(c.demanded_bytes, 1400, "{mode:?}");
            assert_eq!(c.credited_bytes, 1400, "{mode:?}");
        }
    }

    #[test]
    fn cache_credit_adds_to_speculative_staging() {
        let (mut cs, mut w) = setup(2);
        let gate0 = cs.sim.delay(5.0, &[], 0);
        let entry = vec![vec![gate0]; 2];
        let grants: Vec<TaskId> = (0..2).map(|_| cs.sim.delay(1.0, &[], 0)).collect();
        let mut g = StageGraph::new(OverlapMode::Speculative, 400);
        g.add(Box::new(
            FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![0.0, 0.0])
                .with_artifact(0xA, 300, ProviderTier::ClusterCache),
        ));
        let mut cache = CacheState::new();
        cache.insert_shared_artifact(ArtifactManifest::synthetic(0xA, 300, 100).id, 50);
        let c = g.compile_cached(&mut cs, &mut w, &entry, Some(&grants), &cache);
        cs.sim.run();
        assert_eq!(c.stages[0].prestaged, vec![350, 350]);
    }

    #[test]
    fn empty_cache_compiles_identically() {
        let build = |cache: &CacheState| {
            let (mut cs, mut w) = setup(2);
            let gate0 = cs.sim.delay(0.0, &[], 0);
            let entry = vec![vec![gate0]; 2];
            let mut g = StageGraph::new(OverlapMode::Sequential, 0);
            g.add(Box::new(FixedStage::new(
                Stage::ImageLoading,
                EdgeKind::Entry,
                vec![2.0, 3.0],
            )));
            let c = g.compile_cached(&mut cs, &mut w, &entry, None, cache);
            cs.sim.run();
            cs.sim.finished_at(c.done).to_bits()
        };
        // An empty cache and a cache holding only undeclared artifacts
        // both compile exactly like compile().
        let mut unrelated = CacheState::new();
        unrelated.insert_shared_artifact(0xDEAD, 100);
        assert_eq!(build(&CacheState::new()), build(&unrelated));
    }

    #[test]
    fn dedup_credits_content_landed_by_earlier_stage() {
        // Stage 2's artifact shares half its chunks with stage 1's. With
        // dedup on, stage 2 sees the shared bytes as credit; off, nothing.
        let run = |dedup: bool| {
            let (mut cs, mut w) = setup(1);
            let gate0 = cs.sim.delay(0.0, &[], 0);
            let entry = vec![vec![gate0]];
            let mut g = StageGraph::new(OverlapMode::Sequential, 0);
            g.set_dedup(dedup);
            let a = ArtifactManifest::synthetic(0xA, 400, 100);
            let mut b_manifest = ArtifactManifest::synthetic(0xB, 400, 100);
            for k in 0..2 {
                b_manifest.chunks[k].digest = a.chunks[k].digest;
            }
            let mut img =
                FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![1.0]);
            img.decl = Some(ArtifactDecl {
                manifest: a,
                tier: ProviderTier::ClusterCache,
                stage_ahead: false,
                credit: true,
            });
            let mut env =
                FixedStage::new(Stage::EnvSetup, EdgeKind::GlobalBarrier, vec![1.0]);
            env.decl = Some(ArtifactDecl {
                manifest: b_manifest,
                tier: ProviderTier::ClusterCache,
                stage_ahead: false,
                credit: true,
            });
            g.add(Box::new(img));
            g.add(Box::new(env));
            let c = g.compile(&mut cs, &mut w, &entry, None);
            cs.sim.run();
            c.stages[1].prestaged.clone()
        };
        assert_eq!(run(false), Vec::<u64>::new());
        assert_eq!(run(true), vec![200]);
    }

    #[test]
    fn admission_shed_delays_stage_entry_and_counts() {
        use crate::faults::FaultConfig;
        // Fleet demand far above storm's cache entitlement: most governed
        // fetches shed at least once.
        let adm = Admission::from_faults(&FaultConfig::storm(), 4096, 5).unwrap();
        let art = (1..256u64)
            .find(|&a| adm.shed_attempts(ProviderTier::ClusterCache, a, 0) >= 1)
            .expect("some artifact sheds");
        let build = |admission: Option<Admission>| {
            let (mut cs, mut w) = setup(1);
            let gate0 = cs.sim.delay(0.0, &[], 0);
            let entry = vec![vec![gate0]];
            let mut g = StageGraph::new(OverlapMode::Sequential, 0);
            g.set_admission(admission);
            g.add(Box::new(
                FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![1.0])
                    .with_artifact(art, 700, ProviderTier::ClusterCache),
            ));
            let c = g.compile(&mut cs, &mut w, &entry, None);
            cs.sim.run();
            (cs.sim.finished_at(c.done), c.shed_events, c.shed_checks)
        };
        let (base, e0, k0) = build(None);
        assert_eq!((e0, k0), (0, 0));
        let (shed, e1, k1) = build(Some(adm));
        assert_eq!((e1, k1), (1, 1));
        let d = adm.delay_before(ProviderTier::ClusterCache, art, 0);
        assert!(d > 0.0);
        // The stage runs once, shifted by exactly its backoff: shedding
        // delays bytes, it never re-fetches them.
        assert!((shed - (base + d)).abs() < 1e-9, "done {shed} vs base {base} + {d}");
    }

    #[test]
    fn fully_resident_fetches_skip_admission() {
        use crate::faults::FaultConfig;
        let adm = Admission::from_faults(&FaultConfig::storm(), 4096, 5).unwrap();
        let (mut cs, mut w) = setup(1);
        let gate0 = cs.sim.delay(0.0, &[], 0);
        let entry = vec![vec![gate0]];
        let mut g = StageGraph::new(OverlapMode::Sequential, 0);
        g.set_admission(Some(adm));
        g.add(Box::new(
            FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![1.0])
                .with_artifact(0xA, 700, ProviderTier::ClusterCache),
        ));
        let mut cache = CacheState::new();
        cache.insert_shared_artifact(0xA, 700);
        let c = g.compile_cached(&mut cs, &mut w, &entry, None, &cache);
        cs.sim.run();
        // Every byte is local: the node never hits the service, so there
        // is nothing to shed and nothing to delay.
        assert_eq!((c.shed_events, c.shed_checks), (0, 0));
        assert_eq!(cs.sim.finished_at(c.done), 1.0);
    }

    #[test]
    fn first_stage_global_barrier_syncs_on_entry() {
        // The hot-update shape: the first stage is behind a global barrier
        // over every node's entry gate.
        let (mut cs, mut w) = setup(2);
        let g0 = cs.sim.delay(3.0, &[], 0);
        let g1 = cs.sim.delay(7.0, &[], 0);
        let entry = vec![vec![g0], vec![g1]];
        let mut g = StageGraph::new(OverlapMode::Sequential, 0);
        g.add(Box::new(FixedStage::new(
            Stage::EnvSetup,
            EdgeKind::GlobalBarrier,
            vec![1.0, 1.0],
        )));
        let c = g.compile(&mut cs, &mut w, &entry, None);
        cs.sim.run();
        // Both nodes start at t=7 (slowest entry gate).
        assert_eq!(cs.sim.finished_at(c.stages[0].node_done[0]), 8.0);
        assert_eq!(cs.sim.finished_at(c.stages[0].node_done[1]), 8.0);
    }

    #[test]
    fn upstream_handles_visible_to_later_stages() {
        struct Probing;
        impl StagePlanner for Probing {
            fn stage(&self) -> Stage {
                Stage::ModelInit
            }
            fn edge(&self, _m: OverlapMode) -> EdgeKind {
                EdgeKind::PerNode
            }
            fn plan(
                &mut self,
                cs: &mut ClusterSim,
                _world: &mut World,
                inp: &StageInputs<'_>,
            ) -> PlannedStage {
                // Gate on the image stage directly (the overlap edge).
                let img = inp.done_of(Stage::ImageLoading).expect("image compiled");
                let node_done = (0..cs.nodes())
                    .map(|i| cs.sim.delay(1.0, &[img[i]], inp.tag))
                    .collect();
                PlannedStage { node_done, sub_spans: Vec::new(), fetched_bytes: 0 }
            }
        }
        let (mut cs, mut w) = setup(1);
        let gate0 = cs.sim.delay(0.0, &[], 0);
        let entry = vec![vec![gate0]];
        let mut g = StageGraph::new(OverlapMode::Overlapped, 0);
        g.add(Box::new(FixedStage::new(Stage::ImageLoading, EdgeKind::Entry, vec![2.0])));
        g.add(Box::new(FixedStage::new(Stage::EnvSetup, EdgeKind::PerNode, vec![50.0])));
        g.add(Box::new(Probing));
        let c = g.compile(&mut cs, &mut w, &entry, None);
        cs.sim.run();
        // ModelInit gated on image (t=2), not env (t=52).
        assert_eq!(cs.sim.finished_at(c.stage(Stage::ModelInit).unwrap().node_done[0]), 3.0);
    }
}
