//! The startup pipeline (paper Figure 2): Queuing → Allocation → Image
//! Loading → Environment Setup → Model Initialization → Training. The
//! worker-phase stages are compiled through the unified stage-graph
//! ([`crate::startup::graph`]): planners declare their tasks and gating
//! edges, the graph lays them onto the fluid sim under the configured
//! [`OverlapMode`], and this module emits profiler events and stage spans
//! uniformly from the compiled graph. `OverlapMode::Sequential` (the
//! default) compiles to the same task structure the pre-graph pipeline
//! built — global sync barriers between stages — so its outcomes are
//! byte-identical to the paper-faithful behaviour.

use crate::artifact::cache::CacheState;
use crate::artifact::Admission;
use crate::config::defaults as d;
use crate::config::{BootseerConfig, ClusterConfig, ImageMode, JobConfig, OverlapMode};
use crate::env::cache::EnvCacheRegistry;
use crate::env::packages::PackageSet;
use crate::image::access::{AccessRecorder, HotSetRegistry};
use crate::image::spec::ImageSpec;
use crate::profiler::events::{EventKind, Stage, StageEvent, JOB_LEVEL};
use crate::sim::{ClusterSim, TaskId};
use crate::startup::graph::StageGraph;
use crate::startup::stages::{EnvStage, ImageStage, InitStage};
use crate::util::rng::Rng;

/// Full startup vs Hot Update (partial: env setup + model setup only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StartupKind {
    Full,
    HotUpdate,
}

/// Cluster-persistent state that carries across startups: the image
/// hot-set records and the job-level environment caches.
#[derive(Debug)]
pub struct World {
    pub hotset: HotSetRegistry,
    pub envcache: EnvCacheRegistry,
}

impl World {
    pub fn new() -> World {
        World {
            hotset: HotSetRegistry::new(d::PAPER_RECORD_WINDOW_S),
            envcache: EnvCacheRegistry::new(),
        }
    }
}

impl Default for World {
    fn default() -> Self {
        Self::new()
    }
}

/// Everything a single startup run produced.
#[derive(Clone, Debug)]
pub struct StartupOutcome {
    pub job_id: u64,
    pub gpus: u32,
    pub nodes: u32,
    /// Profiler events (ts = seconds since submission).
    pub events: Vec<StageEvent>,
    /// Install-script durations per node (§3.3 straggler proxy).
    pub install_durations: Vec<f64>,
    /// Job-level span of each stage.
    pub stage_spans: Vec<(Stage, f64, f64)>,
    /// Submission → training-begin (job-level startup overhead, §3.1).
    pub total_s: f64,
    /// Worker-phase-only startup (image+env+init; the §5 metric which
    /// excludes queuing/allocation variability).
    pub worker_phase_s: f64,
    /// Foreground bytes each worker-phase stage fetched over the network
    /// (after resident-cache credit), in graph order.
    pub stage_fetched: Vec<(Stage, u64)>,
    /// Total foreground bytes fetched over the network: every stage's
    /// foreground fetch plus speculative staging flows. Background
    /// cold-tail streaming is excluded (it never gates a stage).
    pub fetched_bytes: u64,
    /// Bytes credited from cache residency against stage demand.
    pub credited_bytes: u64,
    /// Total bytes the stages demanded (denominator of the hit rate).
    pub demanded_bytes: u64,
    /// Governed fetches that were shed at least once before admission.
    pub shed_events: u64,
    /// Governed fetches evaluated against the admission limits.
    pub shed_checks: u64,
    /// Bytes the warm cache evicted under capacity pressure before this
    /// startup ran (0 for unbounded or cold caches).
    pub evicted_bytes: u64,
}

impl StartupOutcome {
    pub fn span(&self, stage: Stage) -> Option<(f64, f64)> {
        self.stage_spans.iter().find(|(s, _, _)| *s == stage).map(|&(_, b, e)| (b, e))
    }

    pub fn stage_duration(&self, stage: Stage) -> f64 {
        self.span(stage).map(|(b, e)| e - b).unwrap_or(0.0)
    }

    /// GPU-seconds consumed by the worker-phase startup.
    pub fn gpu_seconds_wasted(&self) -> f64 {
        self.worker_phase_s * self.gpus as f64
    }

    /// Foreground bytes a stage fetched (0 if the stage did not run).
    pub fn fetched(&self, stage: Stage) -> u64 {
        self.stage_fetched.iter().find(|(s, _)| *s == stage).map(|&(_, b)| b).unwrap_or(0)
    }
}

/// The pre-worker phase a startup runs under: how long it queued and how
/// long allocation took. The standalone [`run_startup`] samples `queue_s`
/// from the §3.2 lognormal; the cluster replay ([`crate::trace`]) passes
/// waits derived from [`crate::scheduler::schedule_chains`] over a finite
/// pool.
///
/// `cache` models a warm restart that landed back on its previous nodes
/// (fault-injection restart policy, [`crate::faults`]): a
/// [`CacheState`] of artifacts still resident on every node's local disk
/// — the staged image hot set, the environment archive, and (with delta
/// resume) the retained checkpoint shard — whose bytes are credited
/// against the stages' foreground fetches. An empty cache (the default)
/// is byte-identical to a cold allocation.
#[derive(Clone, Debug, Default)]
pub struct StartupContext {
    pub queue_s: f64,
    pub alloc_s: f64,
    pub cache: CacheState,
    /// Registry/cluster-cache admission limits for this startup (`None` —
    /// the default — admits everything: historical behaviour).
    pub admission: Option<Admission>,
    /// Rack of each node of the allocation, as assigned by the replay's
    /// gang placement over the topology tree
    /// ([`crate::scheduler::RackPool`]). `None` — the default — uses the
    /// cluster config's contiguous node→rack map, which on a flat
    /// topology (`racks <= 1`) is byte-identical to the pre-topology
    /// pipeline.
    pub placement: Option<std::sync::Arc<Vec<u32>>>,
}

/// Run one startup of `job` on a fresh allocation, mutating `world`
/// (hot-set records, env caches). Deterministic for a given seed.
///
/// Standalone form: samples the queue wait from the §3.2 marginal
/// distribution (single-job demos, figure sweeps). Inside the cluster
/// replay use [`run_startup_with`], which takes the scheduler-derived
/// [`StartupContext`] instead.
pub fn run_startup(
    job_id: u64,
    attempt: u32,
    cluster_cfg: &ClusterConfig,
    job: &JobConfig,
    cfg: &BootseerConfig,
    world: &mut World,
    kind: StartupKind,
    seed: u64,
) -> StartupOutcome {
    let nodes = job.nodes(cluster_cfg);
    let mut rng = Rng::seeded(seed ^ 0x57A2_7009 ^ job_id);
    let ctx = if kind == StartupKind::Full {
        StartupContext {
            queue_s: rng.lognormal(d::QUEUE_WAIT_MU, d::QUEUE_WAIT_SIGMA),
            alloc_s: d::ALLOC_BASE_S + 0.02 * nodes as f64,
            ..StartupContext::default()
        }
    } else {
        StartupContext::default() // hot update keeps its allocation
    };
    run_startup_with(job_id, attempt, cluster_cfg, job, cfg, world, kind, seed, ctx)
}

/// Run one startup with an externally supplied scheduler phase (`ctx`).
/// This is the replay path: no sampling happens here — queue waits come
/// from the caller, worker-phase durations from the fluid simulator.
#[allow(clippy::too_many_arguments)]
pub fn run_startup_with(
    job_id: u64,
    attempt: u32,
    cluster_cfg: &ClusterConfig,
    job: &JobConfig,
    cfg: &BootseerConfig,
    world: &mut World,
    kind: StartupKind,
    seed: u64,
    ctx: StartupContext,
) -> StartupOutcome {
    let nodes = job.nodes(cluster_cfg);
    let cluster = ClusterConfig { nodes, ..cluster_cfg.clone() };
    let mut cs = ClusterSim::build_placed(
        &cluster,
        seed ^ job_id.wrapping_mul(0x9E37_79B9),
        ctx.placement.as_ref().map(|p| p.as_slice()),
    );

    let img = ImageSpec::synth(
        // Image identity: shared across jobs when the caller assigns one
        // (cluster replay), else per-job (same across restarts either way).
        job.image_identity_seed(job_id),
        job.image_bytes,
        job.image_block_bytes,
        job.image_hot_fraction,
    );
    let pkgs = PackageSet::synth(job, job.env_identity_seed(job_id));

    let mut events = Vec::new();
    let n = nodes as usize;

    // ---- Scheduler phase (job-level; GPUs not yet allocated) ----
    let (queue_s, alloc_s) = if kind == StartupKind::Full {
        (ctx.queue_s, ctx.alloc_s)
    } else {
        (0.0, 0.0) // hot update keeps its allocation
    };
    events.push(StageEvent {
        job: job_id,
        attempt,
        node: JOB_LEVEL,
        stage: Stage::Queuing,
        kind: EventKind::Begin,
        ts: 0.0,
    });
    events.push(StageEvent {
        job: job_id,
        attempt,
        node: JOB_LEVEL,
        stage: Stage::Queuing,
        kind: EventKind::End,
        ts: queue_s,
    });
    events.push(StageEvent {
        job: job_id,
        attempt,
        node: JOB_LEVEL,
        stage: Stage::Allocation,
        kind: EventKind::Begin,
        ts: queue_s,
    });
    events.push(StageEvent {
        job: job_id,
        attempt,
        node: JOB_LEVEL,
        stage: Stage::Allocation,
        kind: EventKind::End,
        ts: queue_s + alloc_s,
    });

    let worker_t0 = queue_s + alloc_s;
    let gate0 = cs.sim.delay(worker_t0, &[], 0);

    // ---- Speculative staging grants (OverlapMode::Speculative) ----
    // Nodes are granted partway through the allocation pass; staging flows
    // start there, before the worker phase opens.
    let grants: Option<Vec<TaskId>> =
        if cfg.overlap == OverlapMode::Speculative && kind == StartupKind::Full {
            Some(
                (0..n)
                    .map(|i| {
                        let t = queue_s + alloc_s * (i + 1) as f64 / (n + 1) as f64;
                        cs.sim.delay(t, &[], 0)
                    })
                    .collect(),
            )
        } else {
            None
        };

    // ---- Compile the worker-phase stage graph ----
    // (hot update: container already runs, so no image stage)
    let mut graph = StageGraph::new(cfg.overlap, cfg.spec_prefetch_budget_bytes);
    graph.set_dedup(cfg.artifact_dedup);
    graph.set_admission(ctx.admission);
    if kind == StartupKind::Full {
        graph.add(Box::new(ImageStage::new(&img, cfg)));
    }
    graph.add(Box::new(EnvStage::new(&img, &pkgs, job, cfg)));
    graph.add(Box::new(InitStage::new(job, cfg, &cluster)));
    let entry: Vec<Vec<TaskId>> = vec![vec![gate0]; n];
    // Warm-restart credit: chunks still on every node's local disk from
    // the previous attempt on the same nodes, per the caller's cache
    // state (empty for cold allocations — byte-identical to compile()).
    let compiled = graph.compile_cached(&mut cs, world, &entry, grants.as_deref(), &ctx.cache);

    // ---- Run the simulation ----
    cs.sim.run();

    // ---- Record phase upload (§4.2): first BootSeer run records the
    // startup access trace and uploads it for subsequent runs. ----
    if kind == StartupKind::Full
        && cfg.image_mode == ImageMode::RecordPrefetch
        && !world.hotset.has_record(img.digest)
    {
        let mut rec = AccessRecorder::new();
        for (k, &b) in img.startup_access.iter().enumerate() {
            rec.record(b, (k as f64 * 0.05).min(d::PAPER_RECORD_WINDOW_S - 1.0));
        }
        world.hotset.upload(img.digest, &rec);
    }

    // ---- Emit per-node events, uniformly from the compiled graph ----
    for i in 0..n {
        for cst in &compiled.stages {
            events.push(StageEvent {
                job: job_id,
                attempt,
                node: i as u32,
                stage: cst.stage,
                kind: EventKind::Begin,
                ts: cs.sim.finished_at(cst.begin_gate[i]),
            });
            events.push(StageEvent {
                job: job_id,
                attempt,
                node: i as u32,
                stage: cst.stage,
                kind: EventKind::End,
                ts: cs.sim.finished_at(cst.node_done[i]),
            });
            for (sub, spans) in &cst.sub_spans {
                let (s0, s1) = spans[i];
                events.push(StageEvent {
                    job: job_id,
                    attempt,
                    node: i as u32,
                    stage: *sub,
                    kind: EventKind::Begin,
                    ts: cs.sim.finished_at(s0),
                });
                events.push(StageEvent {
                    job: job_id,
                    attempt,
                    node: i as u32,
                    stage: *sub,
                    kind: EventKind::End,
                    ts: cs.sim.finished_at(s1),
                });
            }
        }
    }
    let training_begin = cs.sim.finished_at(compiled.done);
    events.push(StageEvent {
        job: job_id,
        attempt,
        node: 0,
        stage: Stage::Training,
        kind: EventKind::Begin,
        ts: training_begin,
    });

    // ---- Stage spans: earliest node begin → latest node end. Under
    // Sequential gating this reduces to the barrier-to-barrier spans the
    // pre-graph pipeline reported; under the overlap modes spans of
    // consecutive stages genuinely overlap. ----
    let mut stage_spans = vec![
        (Stage::Queuing, 0.0, queue_s),
        (Stage::Allocation, queue_s, worker_t0),
    ];
    for cst in &compiled.stages {
        let begin = cst
            .begin_gate
            .iter()
            .map(|&t| cs.sim.finished_at(t))
            .fold(f64::INFINITY, f64::min);
        let end = cst
            .node_done
            .iter()
            .map(|&t| cs.sim.finished_at(t))
            .fold(f64::NEG_INFINITY, f64::max);
        stage_spans.push((cst.stage, begin, end));
    }

    // Install-script durations (§3.3 straggler proxy) from the sub-spans.
    let install_durations: Vec<f64> = compiled
        .stages
        .iter()
        .flat_map(|cst| cst.sub_spans.iter())
        .filter(|(s, _)| *s == Stage::InstallScript)
        .flat_map(|(_, spans)| {
            spans.iter().map(|&(b, e)| cs.sim.finished_at(e) - cs.sim.finished_at(b))
        })
        .collect();

    let stage_fetched: Vec<(Stage, u64)> =
        compiled.stages.iter().map(|c| (c.stage, c.fetched_bytes)).collect();
    let fetched_bytes = compiled.fetched_bytes();

    StartupOutcome {
        job_id,
        gpus: job.gpus,
        nodes,
        install_durations,
        events,
        stage_spans,
        total_s: training_begin,
        worker_phase_s: training_begin - worker_t0,
        stage_fetched,
        fetched_bytes,
        credited_bytes: compiled.credited_bytes,
        demanded_bytes: compiled.demanded_bytes,
        shed_events: compiled.shed_events,
        shed_checks: compiled.shed_checks,
        evicted_bytes: ctx.cache.evicted_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::{LogParser, StageAnalysisService};
    use crate::util::stats;

    fn run(
        gpus: u32,
        cfg: &BootseerConfig,
        world: &mut World,
        kind: StartupKind,
    ) -> StartupOutcome {
        let job = JobConfig::paper_moe(gpus);
        run_startup(1, 0, &ClusterConfig::default(), &job, cfg, world, kind, 42)
    }

    #[test]
    fn stages_are_ordered_and_synced() {
        let mut w = World::new();
        let o = run(32, &BootseerConfig::baseline(), &mut w, StartupKind::Full);
        let img = o.span(Stage::ImageLoading).unwrap();
        let env = o.span(Stage::EnvSetup).unwrap();
        let init = o.span(Stage::ModelInit).unwrap();
        assert!(img.1 <= env.0 + 1e-9);
        assert!(env.1 <= init.0 + 1e-9);
        assert!((init.1 - o.total_s).abs() < 1e-9);
        assert!(o.worker_phase_s < o.total_s);
    }

    #[test]
    fn bootseer_halves_worker_phase_after_warm_run() {
        let mut wb = World::new();
        // Warm-up run: records hot set + creates env cache.
        let _ = run(128, &BootseerConfig::bootseer(), &mut wb, StartupKind::Full);
        let boot = run(128, &BootseerConfig::bootseer(), &mut wb, StartupKind::Full);
        let mut w0 = World::new();
        let base = run(128, &BootseerConfig::baseline(), &mut w0, StartupKind::Full);
        let ratio = base.worker_phase_s / boot.worker_phase_s;
        // §5.2: ~2x end-to-end.
        assert!((1.6..3.2).contains(&ratio), "e2e improvement {ratio}");
    }

    #[test]
    fn first_bootseer_run_records_then_benefits() {
        let mut w = World::new();
        let first = run(32, &BootseerConfig::bootseer(), &mut w, StartupKind::Full);
        let second = run(32, &BootseerConfig::bootseer(), &mut w, StartupKind::Full);
        assert!(
            second.stage_duration(Stage::ImageLoading)
                < first.stage_duration(Stage::ImageLoading) / 2.0,
            "second run should prefetch: {} vs {}",
            first.stage_duration(Stage::ImageLoading),
            second.stage_duration(Stage::ImageLoading)
        );
    }

    #[test]
    fn hot_update_skips_image_and_queue() {
        let mut w = World::new();
        let o = run(32, &BootseerConfig::baseline(), &mut w, StartupKind::HotUpdate);
        assert!(o.span(Stage::ImageLoading).is_none());
        assert_eq!(o.stage_duration(Stage::Queuing), 0.0);
        let mut w2 = World::new();
        let full = run(32, &BootseerConfig::baseline(), &mut w2, StartupKind::Full);
        assert!(o.total_s < full.total_s);
    }

    #[test]
    fn events_feed_the_profiler() {
        let mut w = World::new();
        let o = run(16, &BootseerConfig::baseline(), &mut w, StartupKind::Full);
        let log: String = o.events.iter().map(|e| e.log_line() + "\n").collect();
        let mut svc = StageAnalysisService::new();
        svc.ingest_all(LogParser::parse_stream(&log));
        assert_eq!(svc.anomalies.len(), 0);
        // Training has begin but no end → one open stage.
        assert_eq!(svc.open_stages(), 1);
        let node_overhead = svc.db.node_startup_overhead(1, 0, 0).unwrap();
        assert!(node_overhead > 0.0);
        // Node-level ≤ job-level (§3.1: job-level includes barrier waits).
        assert!(node_overhead <= o.total_s + 1e-6);
        let installs = svc.db.job_stage_durations(1, Stage::InstallScript);
        assert_eq!(installs.len(), 2); // 16 GPUs = 2 nodes
    }

    #[test]
    fn install_durations_match_events() {
        let mut w = World::new();
        let o = run(32, &BootseerConfig::baseline(), &mut w, StartupKind::Full);
        assert_eq!(o.install_durations.len(), 4);
        assert!(stats::min(&o.install_durations) > 0.0);
    }

    /// Golden-schedule determinism: the full per-node `(stage, kind, ts)`
    /// event stream of a fixed-seed startup — the pipeline-level
    /// `(finished_at, tag)` stream — must be bit-identical run over run,
    /// at 16 and at 128 nodes, cold and warm, in every overlap mode.
    ///
    /// Scope: both captures come from the *same* engine, so this pins
    /// run-over-run determinism (iteration-order leaks, uninitialized
    /// scratch, recycled-slot state), not schedule preservation across
    /// engine changes — that cross-engine pin lives in `sim::golden`,
    /// which replays identical workloads through the preserved
    /// pre-refactor `ReferenceSim` and the current engine.
    #[test]
    fn golden_event_streams_bit_identical_at_16_and_128_nodes() {
        for &nodes in &[16u32, 128] {
            for mode in OverlapMode::ALL {
                let gpus = nodes * 8;
                let cfg = BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() };
                let capture = || {
                    let job = JobConfig::paper_moe(gpus);
                    let mut w = World::new();
                    // Warm-up records the hot set + creates the env cache,
                    // then the measured run takes the warm path too.
                    let cold = run_startup(
                        3,
                        0,
                        &ClusterConfig::default(),
                        &job,
                        &cfg,
                        &mut w,
                        StartupKind::Full,
                        1234,
                    );
                    let warm = run_startup(
                        3,
                        1,
                        &ClusterConfig::default(),
                        &job,
                        &cfg,
                        &mut w,
                        StartupKind::Full,
                        1235,
                    );
                    let mut stream: Vec<(u64, u32, u64)> = Vec::new();
                    for o in [&cold, &warm] {
                        for e in &o.events {
                            stream.push((
                                e.ts.to_bits(),
                                e.node,
                                ((e.stage as u64) << 1) | ((e.kind as u64) & 1),
                            ));
                        }
                    }
                    stream
                };
                let a = capture();
                let b = capture();
                assert_eq!(a, b, "nodes={nodes} mode={mode:?}");
                assert!(!a.is_empty());
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let job = JobConfig::paper_moe(32);
        let mk = || {
            let mut w = World::new();
            run_startup(
                5,
                0,
                &ClusterConfig::default(),
                &job,
                &BootseerConfig::baseline(),
                &mut w,
                StartupKind::Full,
                7,
            )
            .total_s
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn overlap_modes_strictly_reduce_worker_phase() {
        // Acceptance: warm BootSeer at 128 GPUs, Sequential ≥ Overlapped ≥
        // Speculative — strictly, since per-node chaining removes barrier
        // waits and speculative staging uses the Allocation dead time.
        let job = JobConfig::paper_moe(128);
        let cluster = ClusterConfig::default();
        let run_mode = |mode: OverlapMode| {
            let cfg = BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() };
            let mut w = World::new();
            // Warm-up run records the hot set + creates the env cache.
            run_startup(1, 0, &cluster, &job, &cfg, &mut w, StartupKind::Full, 42);
            run_startup(1, 1, &cluster, &job, &cfg, &mut w, StartupKind::Full, 43)
                .worker_phase_s
        };
        let seq = run_mode(OverlapMode::Sequential);
        let ovl = run_mode(OverlapMode::Overlapped);
        let spec = run_mode(OverlapMode::Speculative);
        assert!(ovl < seq, "overlapped {ovl} vs sequential {seq}");
        assert!(spec < ovl, "speculative {spec} vs overlapped {ovl}");
    }

    #[test]
    fn overlapped_events_still_feed_the_profiler() {
        for mode in [OverlapMode::Overlapped, OverlapMode::Speculative] {
            let cfg = BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() };
            let mut w = World::new();
            run(16, &cfg, &mut w, StartupKind::Full); // warm
            let o = run(16, &cfg, &mut w, StartupKind::Full);
            let log: String = o.events.iter().map(|e| e.log_line() + "\n").collect();
            let mut svc = StageAnalysisService::new();
            svc.ingest_all(LogParser::parse_stream(&log));
            assert_eq!(svc.anomalies.len(), 0, "{mode:?}");
            assert_eq!(svc.open_stages(), 1, "{mode:?}"); // Training open
        }
    }

    #[test]
    fn overlap_preserves_final_sync() {
        // Whatever the gating, training begins only after every node has
        // finished Model Initialization.
        let cfg = BootseerConfig {
            overlap: OverlapMode::Overlapped,
            ..BootseerConfig::baseline()
        };
        let mut w = World::new();
        let o = run(32, &cfg, &mut w, StartupKind::Full);
        let init_end = o.span(Stage::ModelInit).unwrap().1;
        assert!((init_end - o.total_s).abs() < 1e-9);
        // And some node's env began strictly before the slowest image
        // finished (under Sequential gating these are exactly equal, so
        // strictness is what detects the per-node chaining).
        let img = o.span(Stage::ImageLoading).unwrap();
        let env = o.span(Stage::EnvSetup).unwrap();
        assert!(env.0 < img.1, "env {env:?} vs img {img:?}");
    }

    #[test]
    fn hot_update_supports_overlap_modes() {
        for mode in OverlapMode::ALL {
            let cfg = BootseerConfig { overlap: mode, ..BootseerConfig::baseline() };
            let mut w = World::new();
            let o = run(32, &cfg, &mut w, StartupKind::HotUpdate);
            assert!(o.span(Stage::ImageLoading).is_none());
            assert!(o.total_s > 0.0);
        }
    }

    #[test]
    fn warm_cache_speeds_up_restart() {
        // A warm restart on the same nodes (fault-injection restart
        // policy) carries a CacheState with the image hot set + env
        // archive resident; an empty cache is byte-identical to cold.
        use crate::artifact::manifest::ArtifactManifest;
        let job = JobConfig::paper_moe(64);
        let cluster = ClusterConfig::default();
        let cfg = BootseerConfig::bootseer();
        let img = ImageSpec::synth(
            job.image_identity_seed(9),
            job.image_bytes,
            job.image_block_bytes,
            job.image_hot_fraction,
        );
        let sig = PackageSet::synth(&job, job.env_identity_seed(9)).signature();
        let run_ctx = |cache: crate::artifact::CacheState| {
            let mut w = World::new();
            // Warm run records the hot set + creates the env cache.
            run_startup(9, 0, &cluster, &job, &cfg, &mut w, StartupKind::Full, 21);
            run_startup_with(
                9,
                1,
                &cluster,
                &job,
                &cfg,
                &mut w,
                StartupKind::Full,
                22,
                StartupContext { queue_s: 10.0, alloc_s: 2.0, cache, ..Default::default() },
            )
        };
        let cold = run_ctx(CacheState::new());
        let mut warm_cache = CacheState::new();
        warm_cache
            .insert_shared_artifact(ArtifactManifest::image_hot_id(img.digest), img.hot_bytes());
        warm_cache.insert_shared_artifact(
            ArtifactManifest::env_snapshot_id(sig),
            job.env_cache_bytes,
        );
        let warm = run_ctx(warm_cache);
        assert!(
            warm.worker_phase_s < cold.worker_phase_s,
            "warm {} vs cold {}",
            warm.worker_phase_s,
            cold.worker_phase_s
        );
        // Warm fetched strictly fewer bytes; image + env foreground were
        // fully resident, so the stages fetched exactly zero.
        assert!(warm.fetched_bytes < cold.fetched_bytes);
        assert_eq!(warm.fetched(Stage::ImageLoading), 0);
        assert_eq!(warm.fetched(Stage::EnvSetup), 0);
        assert_eq!(
            cold.fetched_bytes - warm.fetched_bytes,
            warm.nodes as u64 * (img.hot_bytes() + job.env_cache_bytes),
            "credit accounts exactly for the resident artifacts"
        );
        // Empty cache is exactly the plain context path.
        let again = run_ctx(CacheState::new());
        assert_eq!(cold.worker_phase_s.to_bits(), again.worker_phase_s.to_bits());
    }

    #[test]
    fn dedup_credits_env_archive_against_image_content() {
        // With cross-artifact dedup on, the env archive's chunks that
        // duplicate image hot blocks are served from the blocks the image
        // stage just landed — strictly fewer env bytes, identical image
        // bytes, and the stage can only get faster.
        let job = JobConfig::paper_moe(32);
        let cluster = ClusterConfig::default();
        let run_dedup = |dedup: bool| {
            let cfg = BootseerConfig { artifact_dedup: dedup, ..BootseerConfig::bootseer() };
            let mut w = World::new();
            run_startup(3, 0, &cluster, &job, &cfg, &mut w, StartupKind::Full, 5);
            run_startup(3, 1, &cluster, &job, &cfg, &mut w, StartupKind::Full, 6)
        };
        let off = run_dedup(false);
        let on = run_dedup(true);
        assert!(
            on.fetched(Stage::EnvSetup) < off.fetched(Stage::EnvSetup),
            "dedup env fetch {} vs plain {}",
            on.fetched(Stage::EnvSetup),
            off.fetched(Stage::EnvSetup)
        );
        assert_eq!(on.fetched(Stage::ImageLoading), off.fetched(Stage::ImageLoading));
        assert!(on.fetched_bytes < off.fetched_bytes);
        assert!(on.worker_phase_s <= off.worker_phase_s + 1e-9);
    }

    #[test]
    fn delta_resume_shrinks_warm_restart_read() {
        use crate::artifact::manifest::ArtifactManifest;
        use crate::ckpt::resume::{resume_bytes_per_node, retained_resume_bytes_per_node};
        let job = JobConfig::paper_moe(64);
        let cluster = ClusterConfig::default();
        let per_node = resume_bytes_per_node(&job, &cluster);
        let retained = retained_resume_bytes_per_node(&job, &cluster);
        let run = |delta: bool, cache: crate::artifact::CacheState| {
            let cfg = BootseerConfig { delta_resume: delta, ..BootseerConfig::bootseer() };
            let mut w = World::new();
            run_startup(4, 0, &cluster, &job, &cfg, &mut w, StartupKind::Full, 31);
            run_startup_with(
                4,
                1,
                &cluster,
                &job,
                &cfg,
                &mut w,
                StartupKind::Full,
                32,
                StartupContext { queue_s: 0.0, alloc_s: 2.0, cache, ..Default::default() },
            )
        };
        let mut warm = CacheState::new();
        warm.insert_shared_artifact(ArtifactManifest::ckpt_shard_id(&job), retained);
        let plain = run(false, warm.clone());
        let delta = run(true, warm);
        // Without the feature the resident shard is ignored entirely.
        assert_eq!(plain.fetched(Stage::ModelInit), plain.nodes as u64 * per_node);
        assert_eq!(
            delta.fetched(Stage::ModelInit),
            delta.nodes as u64 * (per_node - retained)
        );
        assert!(delta.worker_phase_s < plain.worker_phase_s);
    }

    #[test]
    fn larger_jobs_start_slower() {
        // §3.1: job-level startup overhead increases with job size.
        let mut a = World::new();
        let small = run(16, &BootseerConfig::baseline(), &mut a, StartupKind::Full);
        let mut b = World::new();
        let large = run(128, &BootseerConfig::baseline(), &mut b, StartupKind::Full);
        assert!(
            large.worker_phase_s > small.worker_phase_s,
            "{} vs {}",
            small.worker_phase_s,
            large.worker_phase_s
        );
    }
}
