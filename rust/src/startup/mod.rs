//! Startup pipeline (paper Figure 2): stage orchestration with global sync
//! barriers, full-startup vs hot-update, and the cluster-persistent
//! [`World`] (hot-set records, env caches) that BootSeer exploits across
//! restarts.
//!
//! Entry points:
//!
//! * [`run_startup`] — standalone single-job form: the Scheduler phase is
//!   sampled from the §3.2 marginal distribution. Used by the CLI
//!   `startup` subcommand, the figure sweeps (Figs 6/7/12/13/14) and the
//!   examples.
//! * [`run_startup_with`] — replay form: the caller supplies a
//!   [`StartupContext`] whose queue wait was derived by
//!   [`crate::scheduler::schedule_chains`] over a finite pool, and a
//!   cluster whose shared-service capacities already reflect contention
//!   with concurrently starting jobs. This is what [`crate::trace`]'s
//!   cluster replay drives in parallel.
//!
//! Worker-phase stages (Image Loading → Environment Setup → Model
//! Initialization) are planned by the subsystem [`graph::StagePlanner`]s in
//! [`stages`] — thin adapters over [`crate::image`], [`crate::env`] and
//! [`crate::ckpt`] — and compiled onto the fluid simulator ([`crate::sim`])
//! by the [`graph::StageGraph`] under one of three gating disciplines
//! ([`crate::config::OverlapMode`]): `Sequential` (paper-faithful global
//! barriers, the default), `Overlapped` (per-node chaining), or
//! `Speculative` (staging during Allocation). Every stage emits profiler
//! events ([`crate::profiler`]) exactly like the production deployment logs
//! them. Planners declare the content-addressed artifacts they move
//! ([`crate::artifact`]); speculative staging, warm-restart credit and
//! cross-artifact dedup all resolve through one per-node
//! [`crate::artifact::CacheState`]. Design notes: `docs/stage_graph.md`,
//! `docs/artifact_layer.md`.

pub mod graph;
pub mod pipeline;
pub mod stages;

pub use graph::{
    ArtifactDecl, CompiledGraph, CompiledStage, EdgeKind, PlannedStage, StageGraph, StageInputs,
    StagePlanner,
};
pub use pipeline::{
    run_startup, run_startup_with, StartupContext, StartupKind, StartupOutcome, World,
};
pub use stages::{EnvStage, ImageStage, InitStage};
