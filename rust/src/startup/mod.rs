//! Startup pipeline (paper Figure 2): stage orchestration with global sync
//! barriers, full-startup vs hot-update, and the cluster-persistent World
//! (hot-set records, env caches) that BootSeer exploits across restarts.

pub mod pipeline;

pub use pipeline::{run_startup, StartupKind, StartupOutcome, World};
