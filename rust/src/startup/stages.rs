//! The three subsystem planners of the Figure-2 worker phase, adapted to
//! the [`StagePlanner`] trait: Image Loading (`crate::image`), Environment
//! Setup (`crate::env`) and Model Initialization (`crate::ckpt`). Each
//! declares its profiler stage, its gating edge per overlap mode, and the
//! content-addressed artifacts it moves ([`ArtifactDecl`]) — the one
//! declaration that powers speculative staging, warm-restart credit and
//! cross-artifact dedup alike.

use crate::artifact::manifest::{ArtifactKind, ArtifactManifest};
use crate::artifact::transfer::ProviderTier;
use crate::ckpt::resume::{plan_model_init_with, resume_bytes_per_node};
use crate::config::{BootseerConfig, ClusterConfig, JobConfig, OverlapMode};
use crate::env::installer::plan_env_setup_with;
use crate::env::packages::PackageSet;
use crate::hdfs::fuse::ReadEngine;
use crate::image::loader::plan_image_load_with;
use crate::image::spec::ImageSpec;
use crate::profiler::events::Stage;
use crate::sim::ClusterSim;
use crate::startup::graph::{ArtifactDecl, EdgeKind, PlannedStage, StageInputs, StagePlanner};
use crate::startup::World;

/// Image Loading (§4.2) as a graph stage.
pub struct ImageStage<'a> {
    img: &'a ImageSpec,
    cfg: &'a BootseerConfig,
}

impl<'a> ImageStage<'a> {
    pub fn new(img: &'a ImageSpec, cfg: &'a BootseerConfig) -> ImageStage<'a> {
        ImageStage { img, cfg }
    }
}

impl StagePlanner for ImageStage<'_> {
    fn stage(&self) -> Stage {
        Stage::ImageLoading
    }

    fn edge(&self, _mode: OverlapMode) -> EdgeKind {
        // Image loading is the first worker-phase stage in every mode.
        EdgeKind::Entry
    }

    fn artifacts(&self, world: &World, dedup: bool) -> Vec<ArtifactDecl> {
        // Only a recorded hot set has a manifest: before the record run
        // nobody knows which blocks startup will touch. The staging
        // transport mirrors what the stage itself would use.
        let Some(hot) = world.hotset.lookup(self.img.digest) else {
            return Vec::new();
        };
        let tier =
            if self.cfg.p2p { ProviderTier::CacheSwarm } else { ProviderTier::ClusterCache };
        // Chunk lists only feed the dedup plane; the default path declares
        // chunkless (id, total) summaries so the replay hot loop allocates
        // nothing per startup.
        let hot_manifest = if dedup {
            ArtifactManifest::image_hot_set(self.img, &hot)
        } else {
            ArtifactManifest::summary(
                ArtifactManifest::image_hot_id(self.img.digest),
                ArtifactKind::ImageHotSet,
                hot.iter().map(|&b| self.img.block_len(b)).sum(),
            )
        };
        let mut decls = Vec::new();
        if hot_manifest.total_bytes() > 0 {
            decls.push(ArtifactDecl {
                manifest: hot_manifest,
                tier,
                stage_ahead: true,
                credit: true,
            });
        }
        // The cold tail streams in the background after container start:
        // never staged ahead, never credited against the foreground fetch.
        // Its chunk list only feeds the dedup plane, so it is not
        // materialized on the default path (the replay hot loop).
        if dedup {
            let cold = ArtifactManifest::image_cold_tail(self.img, &hot);
            if cold.total_bytes() > 0 {
                decls.push(ArtifactDecl {
                    manifest: cold,
                    tier,
                    stage_ahead: false,
                    credit: false,
                });
            }
        }
        decls
    }

    fn plan(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        inp: &StageInputs<'_>,
    ) -> PlannedStage {
        let plan = plan_image_load_with(
            cs,
            self.img,
            self.cfg,
            &world.hotset,
            inp.deps,
            inp.prestaged,
            inp.tag,
        );
        PlannedStage {
            node_done: plan.node_done,
            sub_spans: Vec::new(),
            fetched_bytes: plan.fetched_bytes,
        }
    }
}

/// Environment Setup (§4.3) as a graph stage. Reports the InstallScript
/// sub-span (§3.3's straggler proxy).
pub struct EnvStage<'a> {
    img: &'a ImageSpec,
    pkgs: &'a PackageSet,
    job: &'a JobConfig,
    cfg: &'a BootseerConfig,
}

impl<'a> EnvStage<'a> {
    pub fn new(
        img: &'a ImageSpec,
        pkgs: &'a PackageSet,
        job: &'a JobConfig,
        cfg: &'a BootseerConfig,
    ) -> EnvStage<'a> {
        EnvStage { img, pkgs, job, cfg }
    }
}

impl StagePlanner for EnvStage<'_> {
    fn stage(&self) -> Stage {
        Stage::EnvSetup
    }

    fn edge(&self, mode: OverlapMode) -> EdgeKind {
        match mode {
            OverlapMode::Sequential => EdgeKind::GlobalBarrier,
            // A node enters env setup the moment its own image lands.
            OverlapMode::Overlapped | OverlapMode::Speculative => EdgeKind::PerNode,
        }
    }

    fn artifacts(&self, world: &World, dedup: bool) -> Vec<ArtifactDecl> {
        // Only a cache hit has an archive to move; a miss installs from
        // scratch and there is nothing to stage or credit.
        if !self.cfg.env_cache {
            return Vec::new();
        }
        let Some(entry) = world.envcache.lookup(self.pkgs.signature()) else {
            return Vec::new();
        };
        if entry.compressed_bytes == 0 {
            return Vec::new();
        }
        // The archive's manifest shares content chunks with the image's
        // hot runtime region (installed site-packages duplicating shipped
        // libraries). Only the dedup plane reads chunk digests, so the
        // default path declares a chunkless summary and skips rebuilding
        // the shared hot manifest.
        let manifest = if dedup {
            let shared = world
                .hotset
                .lookup(self.img.digest)
                .map(|hot| ArtifactManifest::image_hot_set(self.img, &hot));
            ArtifactManifest::env_snapshot(
                self.pkgs.signature(),
                entry.compressed_bytes,
                shared.as_ref(),
            )
        } else {
            ArtifactManifest::summary(
                ArtifactManifest::env_snapshot_id(self.pkgs.signature()),
                ArtifactKind::EnvSnapshot,
                entry.compressed_bytes,
            )
        };
        vec![ArtifactDecl {
            manifest,
            tier: ProviderTier::Hdfs { nn_op: false },
            stage_ahead: true,
            credit: true,
        }]
    }

    fn plan(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        inp: &StageInputs<'_>,
    ) -> PlannedStage {
        let plan = plan_env_setup_with(
            cs,
            self.pkgs,
            self.job,
            self.cfg,
            &mut world.envcache,
            inp.deps,
            inp.prestaged,
            inp.tag,
        );
        PlannedStage {
            node_done: plan.node_done,
            sub_spans: vec![(Stage::InstallScript, plan.install_span)],
            fetched_bytes: plan.fetched_bytes,
        }
    }
}

/// Model Initialization (§4.4) as a graph stage.
pub struct InitStage<'a> {
    job: &'a JobConfig,
    cfg: &'a BootseerConfig,
    cluster: &'a ClusterConfig,
}

impl<'a> InitStage<'a> {
    pub fn new(
        job: &'a JobConfig,
        cfg: &'a BootseerConfig,
        cluster: &'a ClusterConfig,
    ) -> InitStage<'a> {
        InitStage { job, cfg, cluster }
    }
}

impl StagePlanner for InitStage<'_> {
    fn stage(&self) -> Stage {
        Stage::ModelInit
    }

    fn edge(&self, mode: OverlapMode) -> EdgeKind {
        match mode {
            OverlapMode::Sequential => EdgeKind::GlobalBarrier,
            OverlapMode::Overlapped | OverlapMode::Speculative => EdgeKind::PerNode,
        }
    }

    fn artifacts(&self, _world: &World, _dedup: bool) -> Vec<ArtifactDecl> {
        // Never staged ahead: the per-node resume share is hundreds of GB —
        // far past any allocation-window budget — and which replica reads
        // which shard is only known once ranks are assigned. With delta
        // resume on, the shard manifest is declared credit-only so a warm
        // restart's resident chunks shrink the read. Always a chunkless
        // summary: shard chunk digests are domain-separated and can never
        // match another artifact's content, so a dedup walk could credit
        // nothing beyond the prefix arithmetic anyway.
        if !self.cfg.delta_resume {
            return Vec::new();
        }
        let per_node = resume_bytes_per_node(self.job, self.cluster);
        if per_node == 0 {
            return Vec::new();
        }
        let engine =
            if self.cfg.ckpt_striped { ReadEngine::Striped } else { ReadEngine::Sequential };
        vec![ArtifactDecl {
            manifest: ArtifactManifest::summary(
                ArtifactManifest::ckpt_shard_id(self.job),
                ArtifactKind::CkptShard,
                per_node,
            ),
            tier: ProviderTier::HdfsStream(engine),
            stage_ahead: false,
            credit: true,
        }]
    }

    fn plan(
        &mut self,
        cs: &mut ClusterSim,
        _world: &mut World,
        inp: &StageInputs<'_>,
    ) -> PlannedStage {
        // Overlapped modes: the node's resume share starts streaming
        // through the host-level HDFS-FUSE client as soon as its container
        // is up (image stage done), concurrent with env setup; rank launch
        // still waits for env.
        let read_gates = match inp.mode {
            OverlapMode::Sequential => None,
            OverlapMode::Overlapped | OverlapMode::Speculative => {
                inp.done_of(Stage::ImageLoading)
            }
        };
        let plan = plan_model_init_with(
            cs,
            self.job,
            self.cfg,
            inp.deps,
            read_gates,
            inp.prestaged,
            inp.tag,
        );
        PlannedStage {
            node_done: plan.node_done,
            sub_spans: Vec::new(),
            fetched_bytes: plan.fetched_bytes,
        }
    }
}
