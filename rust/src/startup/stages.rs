//! The three subsystem planners of the Figure-2 worker phase, adapted to
//! the [`StagePlanner`] trait: Image Loading (`crate::image`), Environment
//! Setup (`crate::env`) and Model Initialization (`crate::ckpt`). Each
//! declares its profiler stage, its gating edge per overlap mode, and —
//! where staging ahead of time is physically possible — its speculative
//! prefetch request.

use crate::ckpt::resume::plan_model_init_with;
use crate::config::{BootseerConfig, JobConfig, OverlapMode};
use crate::env::installer::plan_env_setup_with;
use crate::env::packages::PackageSet;
use crate::image::loader::plan_image_load_with;
use crate::image::spec::ImageSpec;
use crate::profiler::events::Stage;
use crate::sim::ClusterSim;
use crate::startup::graph::{
    EdgeKind, PlannedStage, SpecRequest, SpecSource, StageInputs, StagePlanner,
};
use crate::startup::World;

/// Image Loading (§4.2) as a graph stage.
pub struct ImageStage<'a> {
    img: &'a ImageSpec,
    cfg: &'a BootseerConfig,
}

impl<'a> ImageStage<'a> {
    pub fn new(img: &'a ImageSpec, cfg: &'a BootseerConfig) -> ImageStage<'a> {
        ImageStage { img, cfg }
    }
}

impl StagePlanner for ImageStage<'_> {
    fn stage(&self) -> Stage {
        Stage::ImageLoading
    }

    fn edge(&self, _mode: OverlapMode) -> EdgeKind {
        // Image loading is the first worker-phase stage in every mode.
        EdgeKind::Entry
    }

    fn spec_request(&self, world: &World) -> Option<SpecRequest> {
        // Only a recorded hot set can be staged ahead of time: before the
        // record run nobody knows which blocks startup will touch. The
        // staging transport mirrors what the stage itself would use.
        let hot = world.hotset.lookup(self.img.digest)?;
        let bytes: u64 = hot.iter().map(|&b| self.img.block_len(b)).sum();
        let source =
            if self.cfg.p2p { SpecSource::CacheSwarm } else { SpecSource::ClusterCache };
        (bytes > 0).then_some(SpecRequest { bytes_per_node: bytes, source })
    }

    fn plan(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        inp: &StageInputs<'_>,
    ) -> PlannedStage {
        let plan = plan_image_load_with(
            cs,
            self.img,
            self.cfg,
            &world.hotset,
            inp.deps,
            inp.prestaged,
            inp.tag,
        );
        PlannedStage { node_done: plan.node_done, sub_spans: Vec::new() }
    }
}

/// Environment Setup (§4.3) as a graph stage. Reports the InstallScript
/// sub-span (§3.3's straggler proxy).
pub struct EnvStage<'a> {
    pkgs: &'a PackageSet,
    job: &'a JobConfig,
    cfg: &'a BootseerConfig,
}

impl<'a> EnvStage<'a> {
    pub fn new(pkgs: &'a PackageSet, job: &'a JobConfig, cfg: &'a BootseerConfig) -> EnvStage<'a> {
        EnvStage { pkgs, job, cfg }
    }
}

impl StagePlanner for EnvStage<'_> {
    fn stage(&self) -> Stage {
        Stage::EnvSetup
    }

    fn edge(&self, mode: OverlapMode) -> EdgeKind {
        match mode {
            OverlapMode::Sequential => EdgeKind::GlobalBarrier,
            // A node enters env setup the moment its own image lands.
            OverlapMode::Overlapped | OverlapMode::Speculative => EdgeKind::PerNode,
        }
    }

    fn spec_request(&self, world: &World) -> Option<SpecRequest> {
        // Only a cache hit has an archive to stage; a miss installs from
        // scratch and there is nothing to pull early.
        if !self.cfg.env_cache {
            return None;
        }
        let entry = world.envcache.lookup(self.pkgs.signature())?;
        (entry.compressed_bytes > 0).then_some(SpecRequest {
            bytes_per_node: entry.compressed_bytes,
            source: SpecSource::Hdfs,
        })
    }

    fn plan(
        &mut self,
        cs: &mut ClusterSim,
        world: &mut World,
        inp: &StageInputs<'_>,
    ) -> PlannedStage {
        let plan = plan_env_setup_with(
            cs,
            self.pkgs,
            self.job,
            self.cfg,
            &mut world.envcache,
            inp.deps,
            inp.prestaged,
            inp.tag,
        );
        PlannedStage {
            node_done: plan.node_done,
            sub_spans: vec![(Stage::InstallScript, plan.install_span)],
        }
    }
}

/// Model Initialization (§4.4) as a graph stage.
pub struct InitStage<'a> {
    job: &'a JobConfig,
    cfg: &'a BootseerConfig,
}

impl<'a> InitStage<'a> {
    pub fn new(job: &'a JobConfig, cfg: &'a BootseerConfig) -> InitStage<'a> {
        InitStage { job, cfg }
    }
}

impl StagePlanner for InitStage<'_> {
    fn stage(&self) -> Stage {
        Stage::ModelInit
    }

    fn edge(&self, mode: OverlapMode) -> EdgeKind {
        match mode {
            OverlapMode::Sequential => EdgeKind::GlobalBarrier,
            OverlapMode::Overlapped | OverlapMode::Speculative => EdgeKind::PerNode,
        }
    }

    // No speculative request: the per-node resume share is hundreds of GB —
    // far past any allocation-window budget — and which replica reads which
    // shard is only known once ranks are assigned.

    fn plan(
        &mut self,
        cs: &mut ClusterSim,
        _world: &mut World,
        inp: &StageInputs<'_>,
    ) -> PlannedStage {
        // Overlapped modes: the node's resume share starts streaming
        // through the host-level HDFS-FUSE client as soon as its container
        // is up (image stage done), concurrent with env setup; rank launch
        // still waits for env.
        let read_gates = match inp.mode {
            OverlapMode::Sequential => None,
            OverlapMode::Overlapped | OverlapMode::Speculative => {
                inp.done_of(Stage::ImageLoading)
            }
        };
        let plan = plan_model_init_with(cs, self.job, self.cfg, inp.deps, read_gates, inp.tag);
        PlannedStage { node_done: plan.node_done, sub_spans: Vec::new() }
    }
}
