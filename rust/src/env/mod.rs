//! Environment Setup subsystem (§4.3): runtime dependency model, the
//! install-script simulator with SCM throttling, and the job-level
//! environment cache (real snapshot/pack/restore engine + registry).

pub mod cache;
pub mod installer;
pub mod packages;

pub use cache::{CacheCapture, EnvCacheRegistry};
pub use installer::{plan_env_setup, plan_env_setup_with, EnvSetupPlan};
pub use packages::{Package, PackageSet};
