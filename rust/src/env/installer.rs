//! Environment Setup stage planner (§4.3).
//!
//! Baseline: every node runs the install script — for each package, an
//! admission round-trip against the SCM backend (rate-limited under
//! concurrency), the download, and CPU-bound unpack/build. Then daemons and
//! health checks start, with a cluster-wide synchronization component.
//!
//! BootSeer: on a cache hit, the node downloads the job's environment cache
//! archive from HDFS, unpacks it, and skips every install command. On the
//! first run (miss), everyone installs normally and node 0 additionally
//! captures + uploads the cache for next time.

use crate::artifact::transfer::{ProviderTier, TransferPlanner};
use crate::config::defaults as d;
use crate::config::{BootseerConfig, JobConfig};
use crate::env::cache::EnvCacheRegistry;
use crate::env::packages::PackageSet;
use crate::image::loader::staged_of;
use crate::sim::{ClusterSim, NodeHandle, TaskId};

/// Planned Environment Setup stage.
pub struct EnvSetupPlan {
    /// Per-node: stage fully done (installs/restore + daemons).
    pub node_done: Vec<TaskId>,
    /// Per-node: (install-script start, install-script end) markers — the
    /// paper's straggler proxy (§3.3) measures exactly this span.
    pub install_span: Vec<(TaskId, TaskId)>,
    /// Whether this plan restored from the environment cache.
    pub cache_hit: bool,
    /// Task that finishes the cache capture+upload (first run only).
    pub cache_capture_done: Option<TaskId>,
    /// Foreground ingress bytes across nodes: archive restore downloads
    /// (after prestaged/resident credit) on a hit, package downloads on a
    /// miss. The capture upload is egress and not counted.
    pub fetched_bytes: u64,
}

impl EnvSetupPlan {
    /// Install-script durations per node after the sim has run.
    pub fn install_durations(&self, cs: &ClusterSim) -> Vec<f64> {
        self.install_span
            .iter()
            .map(|&(s, e)| cs.sim.finished_at(e) - cs.sim.finished_at(s))
            .collect()
    }
}

/// Plan the Environment Setup stage for every node.
pub fn plan_env_setup(
    cs: &mut ClusterSim,
    pkgs: &PackageSet,
    job: &JobConfig,
    cfg: &BootseerConfig,
    cache_reg: &mut EnvCacheRegistry,
    deps: &[Vec<TaskId>],
    tag: u64,
) -> EnvSetupPlan {
    plan_env_setup_with(cs, pkgs, job, cfg, cache_reg, deps, &[], tag)
}

/// [`plan_env_setup`] with per-node env-cache-archive bytes already staged
/// by speculative prefetch (`prestaged`, empty → none): on a cache hit the
/// restore download shrinks by the staged amount. A cache miss ignores it
/// (there is nothing to stage before the cache exists).
#[allow(clippy::too_many_arguments)]
pub fn plan_env_setup_with(
    cs: &mut ClusterSim,
    pkgs: &PackageSet,
    job: &JobConfig,
    cfg: &BootseerConfig,
    cache_reg: &mut EnvCacheRegistry,
    deps: &[Vec<TaskId>],
    prestaged: &[u64],
    tag: u64,
) -> EnvSetupPlan {
    let n = cs.nodes();
    assert!(deps.is_empty() || deps.len() == n);
    assert!(prestaged.is_empty() || prestaged.len() == n);
    let sig = pkgs.signature();
    // One registry lookup for the whole plan (it used to be re-run per
    // node inside the loop below).
    let cache_entry = if cfg.env_cache { cache_reg.lookup(sig) } else { None };
    let hit = cache_entry.is_some();

    let mut node_done = Vec::with_capacity(n);
    let mut install_span = Vec::with_capacity(n);
    let mut cache_capture_done = None;

    // Admission latency model: request-rate limiting at the SCM backend.
    let over = (n as f64 / cs.cfg.scm_throttle_concurrency as f64 - 1.0).max(0.0);
    let throttled = (n as f64 - cs.cfg.scm_throttle_concurrency as f64).max(0.0);
    let admit_s = d::SCM_ADMIT_BASE_S * (1.0 + d::SCM_ADMIT_PENALTY * throttled);
    let reject_p = (cs.cfg.scm_reject_prob * over * cs.cfg.scm_throttle_concurrency as f64)
        .clamp(0.0, 0.15);

    let mut rng = cs.rng.fork(0xE27);
    // The two transports of this stage, both through the unified transfer
    // plane: archive restores ride an HDFS group (one NameNode op each),
    // package pulls ride the throttled SCM backend.
    let restore =
        TransferPlanner::build(cs, "env.restore", ProviderTier::Hdfs { nn_op: true }, 0, 0);
    let scm = TransferPlanner::build(cs, "env.scm", ProviderTier::Scm, 0, 0);
    let mut fetched = 0u64;

    for i in 0..n {
        let h = NodeHandle::new(i);
        let gate: &[TaskId] = if deps.is_empty() { &[] } else { &deps[i] };
        let start = cs.sim.barrier(gate, 0);

        let installed_end = if let Some(entry) = &cache_entry {
            // Restore: fetch archive from HDFS (round-robin group), unpack.
            // Staged bytes (speculative prefetch / resident chunks) are
            // already local.
            let staged = staged_of(prestaged, i);
            let dl_bytes = entry.compressed_bytes.saturating_sub(staged);
            fetched += dl_bytes;
            let dl = restore.fetch(cs, h, dl_bytes as f64, &[start], 0);
            let unpack_s =
                cs.cpu_time(h, entry.compressed_bytes as f64 / d::ENV_CACHE_UNPACK_BPS);
            cs.sim.delay(unpack_s, &[dl], 0)
        } else {
            // Install script: sequential per-package admission → download →
            // CPU install, with rare rejection+backoff under overload.
            let mut prev = start;
            for p in &pkgs.packages {
                if reject_p > 0.0 && rng.chance(reject_p) {
                    let backoff = cs.cfg.scm_backoff_s * (1.0 + 2.0 * rng.f64());
                    prev = cs.sim.delay(backoff, &[prev], 0);
                }
                let admit = cs.sim.delay(cs.cpu_time(h, admit_s), &[prev], 0);
                fetched += p.bytes;
                let dl = scm.fetch(cs, h, p.bytes as f64, &[admit], 0);
                prev = cs.sim.delay(cs.cpu_time(h, p.install_cpu_s), &[dl], 0);
            }
            prev
        };
        install_span.push((start, installed_end));

        // First run with env-cache enabled: node 0 captures + uploads the
        // cache (dir diff → compress → HDFS put) in the background; it
        // must be finished before the job can claim a reusable cache but
        // does not gate this node's own stage completion.
        if cfg.env_cache && !hit && i == 0 {
            let pack_s =
                cs.cpu_time(h, job.env_cache_bytes as f64 / d::ENV_CACHE_PACK_BPS);
            let packed = cs.sim.delay(pack_s, &[installed_end], 0);
            let group = cs.hdfs_groups[0];
            // The upload leaves node 0's rack for the HDFS tier, so it
            // crosses the tree on a non-flat topology.
            let mut path = vec![cs.node_nic[0], group];
            path.extend(cs.tier_path(h));
            let up = cs.sim.flow(job.env_cache_bytes as f64, path, &[packed], 0);
            cache_capture_done = Some(up);
        }

        // Daemons + health checks; the synchronization component grows with
        // job scale (§5.3's 64→128 GPU bump), the base part runs at node
        // speed.
        let daemon_s = cs.cpu_time(h, d::ENV_DAEMON_BASE_S) + d::env_daemon_sync_s(n);
        node_done.push(cs.sim.delay(daemon_s, &[installed_end], tag));
    }

    // Register the cache as available for subsequent runs.
    if cfg.env_cache && !hit {
        cache_reg.store(sig, job.env_cache_bytes);
    }

    EnvSetupPlan {
        node_done,
        install_span,
        cache_hit: hit,
        cache_capture_done,
        fetched_bytes: fetched,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BootseerConfig, ClusterConfig};
    use crate::util::stats;

    fn setup(nodes: u32) -> (ClusterSim, PackageSet, JobConfig) {
        let cs = ClusterSim::build(&ClusterConfig::with_nodes(nodes), 42);
        let job = JobConfig::paper_moe(nodes * 8);
        let pkgs = PackageSet::synth(&job, 42);
        (cs, pkgs, job)
    }

    fn run_env(
        nodes: u32,
        cfg: &BootseerConfig,
        reg: &mut EnvCacheRegistry,
    ) -> (f64, Vec<f64>, bool) {
        let (mut cs, pkgs, job) = setup(nodes);
        let plan = plan_env_setup(&mut cs, &pkgs, &job, cfg, reg, &[], 1);
        cs.sim.run();
        let stage_end = plan
            .node_done
            .iter()
            .map(|&t| cs.sim.finished_at(t))
            .fold(0.0, f64::max);
        (stage_end, plan.install_durations(&cs), plan.cache_hit)
    }

    #[test]
    fn baseline_env_in_paper_band() {
        let mut reg = EnvCacheRegistry::new();
        let (t, _, hit) = run_env(16, &BootseerConfig::baseline(), &mut reg);
        assert!(!hit);
        assert!((100.0..300.0).contains(&t), "baseline env stage {t}");
    }

    #[test]
    fn cache_hit_halves_stage() {
        let mut reg = EnvCacheRegistry::new();
        let cfg = BootseerConfig::bootseer();
        // First run: miss (creates cache).
        let (t_first, _, hit_first) = run_env(16, &cfg, &mut reg);
        assert!(!hit_first);
        // Second run: hit.
        let (t_hit, durs, hit) = run_env(16, &cfg, &mut reg);
        assert!(hit);
        let (t_base, _, _) = run_env(16, &BootseerConfig::baseline(), &mut EnvCacheRegistry::new());
        let ratio = t_base / t_hit;
        assert!((1.6..4.0).contains(&ratio), "env improvement {ratio} ({t_base} vs {t_hit})");
        assert!(t_first >= t_base * 0.9, "first run not faster than baseline");
        // Restore is seconds, not minutes.
        assert!(stats::max(&durs) < 15.0, "restore durations {durs:?}");
    }

    #[test]
    fn cache_capture_only_on_first_run() {
        let (mut cs, pkgs, job) = setup(4);
        let cfg = BootseerConfig::bootseer();
        let mut reg = EnvCacheRegistry::new();
        let plan = plan_env_setup(&mut cs, &pkgs, &job, &cfg, &mut reg, &[], 1);
        assert!(plan.cache_capture_done.is_some());
        cs.sim.run();
        let (mut cs2, pkgs2, job2) = setup(4);
        let plan2 = plan_env_setup(&mut cs2, &pkgs2, &job2, &cfg, &mut reg, &[], 1);
        assert!(plan2.cache_capture_done.is_none());
        assert!(plan2.cache_hit);
    }

    #[test]
    fn signature_change_misses_cache() {
        let (mut cs, pkgs, job) = setup(4);
        let cfg = BootseerConfig::bootseer();
        let mut reg = EnvCacheRegistry::new();
        let _ = plan_env_setup(&mut cs, &pkgs, &job, &cfg, &mut reg, &[], 1);
        // Bump a version → new signature → miss.
        let bumped = pkgs.with_bumped_version(0);
        let (mut cs2, _, job2) = setup(4);
        let plan = plan_env_setup(&mut cs2, &bumped, &job2, &cfg, &mut reg, &[], 1);
        assert!(!plan.cache_hit);
    }

    #[test]
    fn install_durations_have_straggler_tail_at_scale() {
        // 1,440 nodes (the paper's 11,520-GPU job): Max/Median well above 1,
        // and far above the small-job ratio.
        let mut reg = EnvCacheRegistry::new();
        let (_, durs_small, _) = run_env(4, &BootseerConfig::baseline(), &mut reg);
        let (_, durs_big, _) = run_env(180, &BootseerConfig::baseline(), &mut reg);
        let r_small = stats::max_median_ratio(&durs_small);
        let r_big = stats::max_median_ratio(&durs_big);
        assert!(r_big > r_small, "straggler ratio should grow: {r_small} vs {r_big}");
        assert!(r_big > 1.2, "big-job ratio {r_big}");
    }

    #[test]
    fn cache_eliminates_stragglers() {
        let cfg = BootseerConfig::bootseer();
        let mut reg = EnvCacheRegistry::new();
        let _ = run_env(16, &cfg, &mut reg); // create cache
        let (_, durs_hit, hit) = run_env(16, &cfg, &mut reg);
        assert!(hit);
        let (_, durs_base, _) =
            run_env(16, &BootseerConfig::baseline(), &mut EnvCacheRegistry::new());
        // Fig 14: BootSeer's distribution is dramatically tighter.
        let spread_hit = stats::max(&durs_hit) - stats::min(&durs_hit);
        let spread_base = stats::max(&durs_base) - stats::min(&durs_base);
        assert!(
            spread_hit < spread_base / 3.0,
            "spread hit {spread_hit} vs base {spread_base}"
        );
    }

    #[test]
    fn fetched_bytes_hit_miss_and_credit() {
        let cfg = BootseerConfig::bootseer();
        let (mut cs, pkgs, job) = setup(4);
        let mut reg = EnvCacheRegistry::new();
        let miss = plan_env_setup(&mut cs, &pkgs, &job, &cfg, &mut reg, &[], 1);
        assert_eq!(miss.fetched_bytes, 4 * pkgs.total_bytes());
        let (mut cs2, pkgs2, job2) = setup(4);
        let hit = plan_env_setup(&mut cs2, &pkgs2, &job2, &cfg, &mut reg, &[], 1);
        assert!(hit.cache_hit);
        assert_eq!(hit.fetched_bytes, 4 * job2.env_cache_bytes);
        // Full residency credit → zero restore bytes over the network.
        let (mut cs3, pkgs3, job3) = setup(4);
        let staged = vec![job3.env_cache_bytes; 4];
        let zero =
            plan_env_setup_with(&mut cs3, &pkgs3, &job3, &cfg, &mut reg, &[], &staged, 1);
        assert!(zero.cache_hit);
        assert_eq!(zero.fetched_bytes, 0);
    }

    #[test]
    fn deps_gate_start() {
        let (mut cs, pkgs, job) = setup(2);
        let gate = cs.sim.delay(50.0, &[], 0);
        let deps = vec![vec![gate]; 2];
        let plan = plan_env_setup(
            &mut cs,
            &pkgs,
            &job,
            &BootseerConfig::baseline(),
            &mut EnvCacheRegistry::new(),
            &deps,
            1,
        );
        cs.sim.run();
        for &t in &plan.node_done {
            assert!(cs.sim.finished_at(t) > 50.0);
        }
    }
}
