//! Job-level environment cache (§4.3) — real-bytes engine.
//!
//! On the first run of a job, BootSeer diffs the *target directory* (the
//! dependency install path, e.g. site-packages) before and after the
//! Environment Setup phase on worker node 0, packs every added or modified
//! file into a compressed archive, and uploads it to HDFS. Subsequent runs
//! (restarts, node replacements) download the archive and restore the files,
//! skipping every install command. A changed job signature (package
//! versions, GPU type, ...) expires the cache.
//!
//! This module does the real filesystem work — snapshot, diff, pack
//! (custom archive + RLE compression), unpack — and keeps the registry of
//! cache entries. The simulator models the *time* of these operations; the
//! e2e example and tests run them for real.

use crate::util::cast::{u32_from_usize, u64_from_usize, usize_from_u32, usize_from_u64};
use crate::util::compress::{compress, decompress};
use crate::util::error::{Context, Result};
use crate::util::sha256::Sha256;
use crate::bail;
use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// Content fingerprint of one file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FileStamp {
    pub len: u64,
    pub sha: [u8; 32],
}

/// Recursive snapshot of a directory: relative path → content stamp.
pub fn snapshot_dir(root: &Path) -> Result<BTreeMap<PathBuf, FileStamp>> {
    let mut out = BTreeMap::new();
    if !root.exists() {
        return Ok(out);
    }
    walk(root, root, &mut out)?;
    Ok(out)
}

fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<PathBuf, FileStamp>) -> Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("read_dir {dir:?}"))? {
        let entry = entry?;
        let path = entry.path();
        let ft = entry.file_type()?;
        if ft.is_dir() {
            walk(root, &path, out)?;
        } else if ft.is_file() {
            let data = fs::read(&path)?;
            let mut h = Sha256::new();
            h.update(&data);
            out.insert(
                path.strip_prefix(root).unwrap().to_path_buf(),
                FileStamp { len: data.len() as u64, sha: h.finalize() },
            );
        }
        // Symlinks and special files are skipped (matches the paper's
        // "added or modified files" capture granularity).
    }
    Ok(())
}

/// Paths added or modified between two snapshots.
pub fn diff_snapshots(
    before: &BTreeMap<PathBuf, FileStamp>,
    after: &BTreeMap<PathBuf, FileStamp>,
) -> Vec<PathBuf> {
    after
        .iter()
        .filter(|(p, stamp)| before.get(*p) != Some(stamp))
        .map(|(p, _)| p.clone())
        .collect()
}

/// Archive format: magic, then per file
/// `[u32 path_len][path utf8][u64 data_len][data]`, RLE-compressed
/// (`util::compress`).
const MAGIC: &[u8; 8] = b"BSENVC01";

/// Pack `files` (relative to `root`) into a compressed archive.
pub fn pack(root: &Path, files: &[PathBuf], level: i32) -> Result<Vec<u8>> {
    let mut raw = Vec::new();
    raw.extend_from_slice(MAGIC);
    for rel in files {
        let abs = root.join(rel);
        let data = fs::read(&abs).with_context(|| format!("read {abs:?}"))?;
        let p = rel.to_string_lossy();
        raw.extend_from_slice(&u32_from_usize(p.len()).to_le_bytes());
        raw.extend_from_slice(p.as_bytes());
        raw.extend_from_slice(&u64_from_usize(data.len()).to_le_bytes());
        raw.extend_from_slice(&data);
    }
    Ok(compress(&raw, level))
}

/// Restore an archive into `dest` (creating directories as needed).
/// Returns the restored relative paths.
pub fn unpack(archive: &[u8], dest: &Path) -> Result<Vec<PathBuf>> {
    let raw = decompress(archive).context("env-cache archive")?;
    if raw.len() < 8 || &raw[..8] != MAGIC {
        bail!("bad env-cache archive magic");
    }
    let mut i = 8usize;
    let mut restored = Vec::new();
    while i < raw.len() {
        if i + 4 > raw.len() {
            bail!("truncated archive (path len)");
        }
        let plen = usize_from_u32(u32::from_le_bytes(raw[i..i + 4].try_into().unwrap()));
        i += 4;
        if i + plen > raw.len() {
            bail!("truncated archive (path)");
        }
        let rel = PathBuf::from(std::str::from_utf8(&raw[i..i + plen])?);
        // Refuse path escapes.
        let escapes = rel.components().any(|c| matches!(c, std::path::Component::ParentDir));
        if rel.is_absolute() || escapes {
            bail!("archive path escapes destination: {rel:?}");
        }
        i += plen;
        if i + 8 > raw.len() {
            bail!("truncated archive (data len)");
        }
        let dlen = usize_from_u64(u64::from_le_bytes(raw[i..i + 8].try_into().unwrap()));
        i += 8;
        if i + dlen > raw.len() {
            bail!("truncated archive (data)");
        }
        let abs = dest.join(&rel);
        if let Some(parent) = abs.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(&abs, &raw[i..i + dlen])?;
        i += dlen;
        restored.push(rel);
    }
    Ok(restored)
}

/// Capture an environment cache: snapshot-diff the target directory around
/// a setup action and pack the changes.
pub struct CacheCapture {
    before: BTreeMap<PathBuf, FileStamp>,
    root: PathBuf,
}

impl CacheCapture {
    /// Snapshot `root` before Environment Setup runs.
    pub fn begin(root: &Path) -> Result<CacheCapture> {
        Ok(CacheCapture { before: snapshot_dir(root)?, root: root.to_path_buf() })
    }

    /// Snapshot again after setup; pack added/modified files.
    pub fn finish(self, level: i32) -> Result<Vec<u8>> {
        let after = snapshot_dir(&self.root)?;
        let changed = diff_snapshots(&self.before, &after);
        pack(&self.root, &changed, level)
    }
}

/// Simulation-level registry of cache entries: job signature → entry.
#[derive(Clone, Debug, Default)]
pub struct EnvCacheRegistry {
    entries: std::collections::BTreeMap<u64, CacheEntry>,
}

#[derive(Clone, Copy, Debug)]
pub struct CacheEntry {
    pub compressed_bytes: u64,
    pub expired: bool,
}

impl EnvCacheRegistry {
    pub fn new() -> EnvCacheRegistry {
        EnvCacheRegistry::default()
    }

    pub fn store(&mut self, signature: u64, compressed_bytes: u64) {
        self.entries.insert(signature, CacheEntry { compressed_bytes, expired: false });
    }

    /// A usable (present, unexpired) entry for this signature.
    pub fn lookup(&self, signature: u64) -> Option<CacheEntry> {
        self.entries.get(&signature).copied().filter(|e| !e.expired)
    }

    /// §4.3: runtime-parameter changes mark the cache expired.
    pub fn expire(&mut self, signature: u64) {
        if let Some(e) = self.entries.get_mut(&signature) {
            e.expired = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("bootseer-envcache-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn snapshot_diff_detects_adds_and_mods() {
        let d = tmpdir("diff");
        fs::write(d.join("keep.txt"), b"same").unwrap();
        fs::write(d.join("mod.txt"), b"v1").unwrap();
        let before = snapshot_dir(&d).unwrap();
        fs::write(d.join("mod.txt"), b"v2").unwrap();
        fs::create_dir_all(d.join("pkg")).unwrap();
        fs::write(d.join("pkg/new.py"), b"import x").unwrap();
        let after = snapshot_dir(&d).unwrap();
        let mut changed = diff_snapshots(&before, &after);
        changed.sort();
        assert_eq!(changed, vec![PathBuf::from("mod.txt"), PathBuf::from("pkg/new.py")]);
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn same_content_not_flagged() {
        let d = tmpdir("same");
        fs::write(d.join("a"), b"x").unwrap();
        let before = snapshot_dir(&d).unwrap();
        // Rewrite identical content: sha identical → no diff.
        fs::write(d.join("a"), b"x").unwrap();
        let after = snapshot_dir(&d).unwrap();
        assert!(diff_snapshots(&before, &after).is_empty());
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let src = tmpdir("pack-src");
        fs::create_dir_all(src.join("lib/site")).unwrap();
        fs::write(src.join("lib/site/mod.py"), vec![42u8; 100_000]).unwrap();
        fs::write(src.join("top.cfg"), b"k=v").unwrap();
        let files = vec![PathBuf::from("lib/site/mod.py"), PathBuf::from("top.cfg")];
        let archive = pack(&src, &files, 3).unwrap();
        // Compressible content compresses.
        assert!(archive.len() < 50_000, "archive {} bytes", archive.len());

        let dst = tmpdir("pack-dst");
        let restored = unpack(&archive, &dst).unwrap();
        assert_eq!(restored.len(), 2);
        assert_eq!(fs::read(dst.join("lib/site/mod.py")).unwrap(), vec![42u8; 100_000]);
        assert_eq!(fs::read(dst.join("top.cfg")).unwrap(), b"k=v");
        fs::remove_dir_all(&src).unwrap();
        fs::remove_dir_all(&dst).unwrap();
    }

    #[test]
    fn capture_end_to_end() {
        let d = tmpdir("capture");
        fs::write(d.join("preexisting.so"), b"base").unwrap();
        let cap = CacheCapture::begin(&d).unwrap();
        // "Environment Setup" installs things:
        fs::create_dir_all(d.join("nccl")).unwrap();
        fs::write(d.join("nccl/lib.so"), vec![7u8; 5000]).unwrap();
        fs::write(d.join("preexisting.so"), b"patched").unwrap();
        let archive = cap.finish(3).unwrap();

        let d2 = tmpdir("capture-restore");
        fs::write(d2.join("preexisting.so"), b"base").unwrap();
        let restored = unpack(&archive, &d2).unwrap();
        assert_eq!(restored.len(), 2);
        assert_eq!(fs::read(d2.join("preexisting.so")).unwrap(), b"patched");
        assert_eq!(fs::read(d2.join("nccl/lib.so")).unwrap(), vec![7u8; 5000]);
        fs::remove_dir_all(&d).unwrap();
        fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn unpack_rejects_escape_paths() {
        // Hand-craft an archive with a parent-dir path.
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        let p = b"../evil";
        raw.extend_from_slice(&(p.len() as u32).to_le_bytes());
        raw.extend_from_slice(p);
        raw.extend_from_slice(&(1u64).to_le_bytes());
        raw.push(0);
        let archive = compress(&raw, 1);
        let d = tmpdir("escape");
        assert!(unpack(&archive, &d).is_err());
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn unpack_rejects_garbage() {
        let d = tmpdir("garbage");
        assert!(unpack(b"not-an-archive", &d).is_err());
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn registry_expiry() {
        let mut reg = EnvCacheRegistry::new();
        reg.store(1, 270_000_000);
        assert_eq!(reg.lookup(1).unwrap().compressed_bytes, 270_000_000);
        assert!(reg.lookup(2).is_none());
        reg.expire(1);
        assert!(reg.lookup(1).is_none());
        // Re-store after expiry works.
        reg.store(1, 280_000_000);
        assert!(reg.lookup(1).is_some());
    }
}
