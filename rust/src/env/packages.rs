//! Runtime-installed dependency model (§4.3).
//!
//! Training jobs install part of their environment at startup rather than
//! baking it into the image, because (1) the right package version is only
//! known at runtime (GPU type, OS, region) and (2) some packages change too
//! often to justify image rebuilds. A `PackageSet` is the per-job list the
//! install script walks; its `signature` keys the environment cache and
//! invalidates it when job parameters change.

use crate::config::JobConfig;
use crate::util::cast::bytes_from_f64;
use crate::util::rng::Rng;

/// One runtime dependency.
#[derive(Clone, Debug, PartialEq)]
pub struct Package {
    pub name: String,
    pub version: String,
    /// Download size from the SCM backend.
    pub bytes: u64,
    /// CPU seconds to unpack/build/install at nominal node speed.
    pub install_cpu_s: f64,
}

/// The ordered package list a job's install script processes.
#[derive(Clone, Debug, PartialEq)]
pub struct PackageSet {
    pub packages: Vec<Package>,
    /// Environment parameters that affect resolution (GPU type, OS, ...).
    pub runtime_params: Vec<(String, String)>,
}

impl PackageSet {
    /// Deterministically synthesize the package set for a job. Sizes are
    /// lognormal with mean `env_pkg_mean_bytes` (an NCCL-sized multi-hundred
    /// MB outlier appears naturally in the tail).
    pub fn synth(job: &JobConfig, seed: u64) -> PackageSet {
        let mut rng = Rng::seeded(seed ^ 0xDE95_EED0 ^ job.env_packages as u64);
        let sigma = job.env_pkg_sigma;
        // lognormal(mu, sigma) has mean exp(mu + sigma^2/2); solve mu.
        let mu = (job.env_pkg_mean_bytes as f64).ln() - sigma * sigma / 2.0;
        let packages = (0..job.env_packages)
            .map(|i| {
                let bytes = bytes_from_f64(rng.lognormal(mu, sigma).max(50_000.0));
                // Install CPU time loosely correlates with size.
                let size_factor = (bytes as f64 / job.env_pkg_mean_bytes as f64).powf(0.35);
                let install_cpu_s =
                    (job.env_install_cpu_mean_s * size_factor * rng.lognormal(0.0, 0.35))
                        .clamp(0.3, 120.0);
                Package {
                    name: format!("pkg-{i:03}"),
                    version: format!("{}.{}.{}", rng.below(4), rng.below(20), rng.below(40)),
                    bytes,
                    install_cpu_s,
                }
            })
            .collect();
        PackageSet {
            packages,
            runtime_params: vec![
                ("gpu".to_string(), "H800".to_string()),
                ("os".to_string(), "ubuntu22".to_string()),
            ],
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.packages.iter().map(|p| p.bytes).sum()
    }

    pub fn total_install_cpu_s(&self) -> f64 {
        self.packages.iter().map(|p| p.install_cpu_s).sum()
    }

    /// Cache key: hashes every (name, version) pair and every runtime
    /// parameter. Any change — a bumped package version, a different GPU
    /// type — yields a new signature, which expires the environment cache
    /// (§4.3 "if the job parameters change, the cache is marked expired").
    pub fn signature(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |s: &str| {
            for b in s.as_bytes() {
                h ^= *b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            h ^= 0xff;
            h = h.wrapping_mul(0x100000001b3);
        };
        for p in &self.packages {
            mix(&p.name);
            mix(&p.version);
        }
        for (k, v) in &self.runtime_params {
            mix(k);
            mix(v);
        }
        h
    }

    /// A copy with one package's version bumped (for invalidation tests).
    pub fn with_bumped_version(&self, idx: usize) -> PackageSet {
        let mut c = self.clone();
        c.packages[idx].version.push_str(".post1");
        c
    }

    /// A copy resolved for a different runtime environment.
    pub fn with_param(&self, key: &str, value: &str) -> PackageSet {
        let mut c = self.clone();
        match c.runtime_params.iter_mut().find(|(k, _)| k == key) {
            Some(kv) => kv.1 = value.to_string(),
            None => c.runtime_params.push((key.to_string(), value.to_string())),
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn base() -> PackageSet {
        PackageSet::synth(&JobConfig::default(), 7)
    }

    #[test]
    fn synth_deterministic() {
        assert_eq!(base(), PackageSet::synth(&JobConfig::default(), 7));
        assert_ne!(base().signature(), PackageSet::synth(&JobConfig::default(), 8).signature());
    }

    #[test]
    fn count_and_mean_size() {
        let ps = base();
        assert_eq!(ps.packages.len(), 24);
        let mean = ps.total_bytes() as f64 / 24.0;
        // Lognormal sample mean is noisy with n=24; just sanity-band it.
        assert!((10e6..400e6).contains(&mean), "mean pkg size {mean}");
    }

    #[test]
    fn signature_changes_on_version_bump() {
        let ps = base();
        assert_ne!(ps.signature(), ps.with_bumped_version(3).signature());
    }

    #[test]
    fn signature_changes_on_runtime_param() {
        let ps = base();
        assert_ne!(ps.signature(), ps.with_param("gpu", "A100").signature());
        // Same change twice = same signature (it's a pure function).
        assert_eq!(
            ps.with_param("gpu", "A100").signature(),
            ps.with_param("gpu", "A100").signature()
        );
    }

    #[test]
    fn install_cpu_total_in_band() {
        // Baseline env setup must be able to reach the paper's 100–300 s.
        let t = base().total_install_cpu_s();
        assert!((40.0..300.0).contains(&t), "total install cpu {t}");
    }

    #[test]
    fn prop_signature_collision_free_ish() {
        prop_check(48, |g| {
            let job = JobConfig::default();
            let a = PackageSet::synth(&job, g.rng.next_u64());
            let b = PackageSet::synth(&job, g.rng.next_u64());
            if a != b {
                prop_assert!(a.signature() != b.signature());
            }
            Ok(())
        });
    }
}
