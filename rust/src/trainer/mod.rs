//! The training loop that startup exists to serve: drives the AOT train
//! step over PJRT, logs the loss curve, and saves/resumes checkpoints
//! through the striped store — the same resume path the simulator models.

use crate::ckpt::format::Checkpoint;
use crate::hdfs::local::LocalStore;
use crate::runtime::{f32_literal, i32_literal, literal_f32s, literal_scalar, Engine, ModelMeta};
use crate::util::rng::Rng;
use crate::ensure;
use crate::util::error::{Context, Result};
use std::path::Path;

/// Synthetic corpus with learnable structure: the next token follows
/// `t' = (7 t + 3) mod V` with `noise` probability of a uniform token.
/// The model must drive loss from ~ln(V) toward the noise floor.
pub struct SyntheticCorpus {
    vocab: u32,
    noise: f64,
    rng: Rng,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, noise: f64, seed: u64) -> SyntheticCorpus {
        SyntheticCorpus { vocab: vocab as u32, noise, rng: Rng::seeded(seed) }
    }

    /// One (tokens, targets) batch of shape [batch, seq].
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let n = batch * seq;
        let mut tokens = Vec::with_capacity(n);
        let mut targets = Vec::with_capacity(n);
        for _ in 0..n {
            let t = self.rng.below(self.vocab as u64) as i64;
            tokens.push(t as i32);
            let tgt = if self.rng.chance(self.noise) {
                self.rng.below(self.vocab as u64) as i64
            } else {
                (7 * t + 3) % self.vocab as i64
            };
            targets.push(tgt as i32);
        }
        (tokens, targets)
    }
}

/// A loaded model: engines + current parameters as literals.
pub struct Trainer {
    pub meta: ModelMeta,
    train: Engine,
    eval: Engine,
    params: Vec<xla::Literal>,
    pub step: u64,
    pub loss_log: Vec<(u64, f32)>,
}

impl Trainer {
    /// Load artifacts from `dir` and initialize parameters from `seed`.
    pub fn new(client: &xla::PjRtClient, dir: &Path, seed: i32) -> Result<Trainer> {
        let meta = ModelMeta::load(&dir.join("meta.json"))?;
        let train = Engine::load(client, &dir.join("train_step.hlo.txt"))?;
        let eval = Engine::load(client, &dir.join("eval.hlo.txt"))?;
        let init = Engine::load(client, &dir.join("init.hlo.txt"))?;
        let params = init.execute(&[xla::Literal::scalar(seed)])?;
        ensure!(params.len() == meta.params.len(), "init arity mismatch");
        Ok(Trainer { meta, train, eval, params, step: 0, loss_log: Vec::new() })
    }

    /// One training step; returns the loss.
    pub fn train_step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let shape = [self.meta.batch, self.meta.seq];
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 2);
        // Literals are cheap host buffers; move params in, get new ones out.
        inputs.append(&mut self.params);
        inputs.push(i32_literal(tokens, &shape)?);
        inputs.push(i32_literal(targets, &shape)?);
        let mut out = self.train.execute(&inputs)?;
        ensure!(out.len() == self.meta.params.len() + 1, "train arity mismatch");
        let loss = literal_scalar(&out[0])?;
        self.params = out.split_off(1);
        self.step += 1;
        self.loss_log.push((self.step, loss));
        Ok(loss)
    }

    /// Held-out loss without updating parameters.
    pub fn eval_loss(&self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let shape = [self.meta.batch, self.meta.seq];
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 2);
        for p in &self.params {
            // Literal has no Clone; round-trip through raw f32s.
            let data = literal_f32s(p)?;
            inputs.push(f32_literal(&data, &literal_dims(p)?)?);
        }
        inputs.push(i32_literal(tokens, &shape)?);
        inputs.push(i32_literal(targets, &shape)?);
        let out = self.eval.execute(&inputs)?;
        literal_scalar(&out[0])
    }

    /// Snapshot current parameters into a Checkpoint (real bytes).
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let mut ck = Checkpoint::new(self.step);
        for (lit, (name, shape)) in self.params.iter().zip(&self.meta.params) {
            let data = literal_f32s(lit)?;
            ck.push(name, shape.clone(), &data);
        }
        Ok(ck)
    }

    /// Save through the striped store (the §4.4 write path).
    pub fn save(&self, store: &LocalStore, name: &str, chunk: u64, width: u32) -> Result<()> {
        self.checkpoint()?.save(store, name, chunk, width)
    }

    /// Resume parameters from a checkpoint (striped parallel read when
    /// `striped`, sequential baseline otherwise).
    pub fn resume(&mut self, store: &LocalStore, name: &str, striped: bool) -> Result<()> {
        let ck = Checkpoint::load(store, name, striped)?;
        ensure!(ck.tensors.len() == self.meta.params.len(), "ckpt arity mismatch");
        let mut params = Vec::with_capacity(ck.tensors.len());
        for (name, shape) in &self.meta.params {
            let (meta, data) =
                ck.get(name).with_context(|| format!("ckpt missing {name}"))?;
            ensure!(&meta.shape == shape, "shape mismatch for {name}");
            params.push(f32_literal(data, shape)?);
        }
        self.params = params;
        self.step = ck.step;
        Ok(())
    }

    /// First f32s of the first parameter (fingerprint for tests).
    pub fn param_fingerprint(&self) -> Result<Vec<f32>> {
        Ok(literal_f32s(&self.params[0])?[..8.min(self.params[0].element_count())].to_vec())
    }
}

fn literal_dims(l: &xla::Literal) -> Result<Vec<usize>> {
    let shape = l.array_shape().map_err(|e| crate::anyhow!("{e:?}"))?;
    Ok(shape.dims().iter().map(|&d| d as usize).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_learnable_rule() {
        let mut a = SyntheticCorpus::new(512, 0.0, 1);
        let mut b = SyntheticCorpus::new(512, 0.0, 1);
        let (ta, ga) = a.batch(2, 8);
        let (tb, gb) = b.batch(2, 8);
        assert_eq!(ta, tb);
        assert_eq!(ga, gb);
        // Noise-free: targets follow the rule exactly.
        for (t, g) in ta.iter().zip(&ga) {
            assert_eq!(*g as i64, (7 * *t as i64 + 3) % 512);
        }
    }

    #[test]
    fn corpus_noise_breaks_rule_sometimes() {
        let mut c = SyntheticCorpus::new(512, 0.5, 2);
        let (t, g) = c.batch(8, 32);
        let broken = t
            .iter()
            .zip(&g)
            .filter(|(t, g)| (**g as i64) != (7 * **t as i64 + 3) % 512)
            .count();
        assert!(broken > 20, "noise should break ~half: {broken}/256");
    }

    // Full Trainer integration (init → steps → ckpt → resume) lives in
    // tests/trainer_integration.rs since it needs built artifacts.
}
