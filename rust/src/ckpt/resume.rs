//! Model Initialization stage planner (§2.2, §4.4).
//!
//! Model init = launching ranks, building parallel groups, RDMA connection
//! setup (a base cost that grows mildly with scale), plus checkpoint
//! resumption — the only part that touches remote storage, and the part
//! BootSeer's striped HDFS-FUSE accelerates.

use crate::artifact::transfer::{ProviderTier, TransferPlanner};
use crate::config::defaults as d;
use crate::config::{BootseerConfig, ClusterConfig, JobConfig};
use crate::hdfs::fuse::ReadEngine;
use crate::image::loader::staged_of;
use crate::sim::{ClusterSim, NodeHandle, TaskId};

/// Planned Model Initialization stage.
pub struct ModelInitPlan {
    /// Per-node stage completion.
    pub node_done: Vec<TaskId>,
    /// Bytes each node's full resume share holds (before any resident
    /// credit — the logical shard size).
    pub read_bytes_per_node: u64,
    /// Bytes actually read from HDFS across nodes, after subtracting
    /// per-node resident credit (delta resume).
    pub fetched_bytes: u64,
}

/// Checkpoint bytes each node must read: every DP replica loads a full
/// model copy, spread over the `pp*tp/gpus_per_node` nodes that host it.
pub fn resume_bytes_per_node(job: &JobConfig, cluster: &ClusterConfig) -> u64 {
    let nodes_per_replica =
        ((job.pp * job.tp + cluster.gpus_per_node - 1) / cluster.gpus_per_node).max(1);
    job.ckpt_bytes / u64::from(nodes_per_replica)
}

/// Resume-shard bytes still valid on a node after a rollback: the chunks
/// training did not rewrite since the resident copy
/// (`1 − CKPT_DELTA_CHANGED_FRACTION` of the shard). The one definition
/// the delta-resume producers (the replay's warm-restart cache, the
/// artifact sweep) and consumer (the shard-manifest credit) share.
pub fn retained_resume_bytes_per_node(job: &JobConfig, cluster: &ClusterConfig) -> u64 {
    let per_node = resume_bytes_per_node(job, cluster);
    (per_node as f64 * (1.0 - d::CKPT_DELTA_CHANGED_FRACTION)) as u64
}

/// Plan Model Initialization for every node.
pub fn plan_model_init(
    cs: &mut ClusterSim,
    job: &JobConfig,
    cfg: &BootseerConfig,
    deps: &[Vec<TaskId>],
    tag: u64,
) -> ModelInitPlan {
    plan_model_init_with(cs, job, cfg, deps, None, &[], tag)
}

/// [`plan_model_init`] with an optional early per-node gate for the
/// checkpoint read (`read_gates`): when set (the stage graph's Overlapped
/// modes), node `i`'s full resume share starts streaming through the
/// HDFS-FUSE client into the local page cache at `read_gates[i]` — as soon
/// as its container is up, since the FUSE mount is host-level and needs
/// nothing from the job environment — concurrent with env setup and rank
/// launch, instead of chaining strictly after launch. `None` reproduces
/// the paper-faithful chain bit-for-bit.
///
/// `prestaged[i]` (empty → none) is the resume-shard byte credit already
/// resident on node `i` — a delta resume after a same-nodes restart
/// re-reads only the chunks rewritten since the resident copy. Zero /
/// empty credit is byte-identical to the full read.
pub fn plan_model_init_with(
    cs: &mut ClusterSim,
    job: &JobConfig,
    cfg: &BootseerConfig,
    deps: &[Vec<TaskId>],
    read_gates: Option<&[TaskId]>,
    prestaged: &[u64],
    tag: u64,
) -> ModelInitPlan {
    let n = cs.nodes();
    assert!(deps.is_empty() || deps.len() == n);
    assert!(prestaged.is_empty() || prestaged.len() == n);
    if let Some(g) = read_gates {
        assert_eq!(g.len(), n);
    }
    let engine = if cfg.ckpt_striped { ReadEngine::Striped } else { ReadEngine::Sequential };
    let per_node = resume_bytes_per_node(job, &cs.cfg);
    // Resume shards stream through the HDFS-FUSE tier of the transfer
    // plane (sequential download-and-resume or BootSeer's striped engine).
    let provider =
        TransferPlanner::build(cs, "ckpt.resume", ProviderTier::HdfsStream(engine), 0, 0);
    let mut node_done = Vec::with_capacity(n);
    let mut fetched = 0u64;
    for i in 0..n {
        let h = NodeHandle::new(i);
        let gate: &[TaskId] = if deps.is_empty() { &[] } else { &deps[i] };
        let read_bytes = per_node.saturating_sub(staged_of(prestaged, i));
        fetched += read_bytes;
        // Rank launch + parallel-group construction + RDMA setup.
        let base = cs.cpu_time(h, d::MODEL_INIT_BASE_S) + d::model_init_sync_s(n);
        let launched = cs.sim.delay(base, gate, 0);
        let done = match read_gates {
            // Checkpoint resumption through HDFS-FUSE, after launch.
            None => {
                let resumed = provider.fetch_u64(cs, h, read_bytes, &[launched], 0);
                cs.sim.barrier(&[resumed], tag)
            }
            // Overlapped: the resume read streams from the early gate into
            // the page cache; the stage completes when launch AND read are
            // done (launch-side consumption of a cached file is free).
            Some(gates) => {
                let resumed = provider.fetch_u64(cs, h, read_bytes, &[gates[i]], 0);
                cs.sim.barrier(&[launched, resumed], tag)
            }
        };
        node_done.push(done);
    }
    ModelInitPlan { node_done, read_bytes_per_node: per_node, fetched_bytes: fetched }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn run_stage(gpus: u32, cfg: &BootseerConfig) -> f64 {
        let job = JobConfig::paper_moe(gpus);
        let cluster = ClusterConfig::with_nodes(job.nodes(&ClusterConfig::default()));
        let mut cs = ClusterSim::build(&cluster, 42);
        let plan = plan_model_init(&mut cs, &job, cfg, &[], 1);
        cs.sim.run();
        plan.node_done.iter().map(|&t| cs.sim.finished_at(t)).fold(0.0, f64::max)
    }

    #[test]
    fn per_node_read_bytes() {
        let job = JobConfig::paper_moe(128);
        let cluster = ClusterConfig::default();
        // PP=2 × TP=8 = 16 GPUs per replica = 2 nodes → 206.5 GB each.
        assert_eq!(resume_bytes_per_node(&job, &cluster), 206_500_000_000);
    }

    #[test]
    fn baseline_in_paper_band() {
        // §3.2: Model Initialization takes 100–200 s in the baseline.
        let t = run_stage(128, &BootseerConfig::baseline());
        assert!((100.0..220.0).contains(&t), "baseline model init {t}");
    }

    #[test]
    fn bootseer_improves_about_1_6x() {
        let base = run_stage(128, &BootseerConfig::baseline());
        let boot = run_stage(128, &BootseerConfig::bootseer());
        let ratio = base / boot;
        assert!((1.3..2.5).contains(&ratio), "model-init improvement {ratio}");
    }

    #[test]
    fn early_read_gate_overlaps_launch() {
        let job = JobConfig::paper_moe(128);
        let cluster = ClusterConfig::with_nodes(job.nodes(&ClusterConfig::default()));
        // Chained (paper): read starts after env-done (t=50) + rank launch.
        let mut cs = ClusterSim::build(&cluster, 42);
        let n = cs.nodes();
        let env = cs.sim.delay(50.0, &[], 0);
        let deps = vec![vec![env]; n];
        let plan = plan_model_init(&mut cs, &job, &BootseerConfig::baseline(), &deps, 1);
        cs.sim.run();
        let t_chain =
            plan.node_done.iter().map(|&t| cs.sim.finished_at(t)).fold(0.0, f64::max);
        // Overlapped: the read gates at t=0 (container up), launch at t=50.
        let mut cs2 = ClusterSim::build(&cluster, 42);
        let img: Vec<TaskId> = (0..n).map(|_| cs2.sim.delay(0.0, &[], 0)).collect();
        let env2 = cs2.sim.delay(50.0, &[], 0);
        let deps2 = vec![vec![env2]; n];
        let plan2 = plan_model_init_with(
            &mut cs2,
            &job,
            &BootseerConfig::baseline(),
            &deps2,
            Some(&img),
            &[],
            1,
        );
        cs2.sim.run();
        let t_ovl =
            plan2.node_done.iter().map(|&t| cs2.sim.finished_at(t)).fold(0.0, f64::max);
        assert!(t_ovl < t_chain, "overlapped {t_ovl} vs chained {t_chain}");
    }

    #[test]
    fn resident_credit_shrinks_read_and_zero_credit_is_identical() {
        let job = JobConfig::paper_moe(64);
        let cluster = ClusterConfig::with_nodes(job.nodes(&ClusterConfig::default()));
        let run = |credit: Option<u64>| {
            let mut cs = ClusterSim::build(&cluster, 42);
            let n = cs.nodes();
            let staged: Vec<u64> = match credit {
                Some(c) => vec![c; n],
                None => Vec::new(),
            };
            let plan = plan_model_init_with(
                &mut cs,
                &job,
                &BootseerConfig::bootseer(),
                &[],
                None,
                &staged,
                1,
            );
            cs.sim.run();
            let t = plan.node_done.iter().map(|&t| cs.sim.finished_at(t)).fold(0.0, f64::max);
            (t, plan.fetched_bytes, plan.read_bytes_per_node)
        };
        let (t_full, fetched_full, per_node) = run(None);
        let (t_zero, fetched_zero, _) = run(Some(0));
        assert_eq!(t_full.to_bits(), t_zero.to_bits(), "zero credit must be byte-identical");
        assert_eq!(fetched_full, fetched_zero);
        // Delta resume: 65% of the shard resident → strictly fewer bytes
        // and a strictly faster stage.
        let credit = (per_node as f64 * 0.65) as u64;
        let (t_delta, fetched_delta, _) = run(Some(credit));
        assert!(fetched_delta < fetched_full);
        assert!(t_delta < t_full, "delta {t_delta} vs full {t_full}");
        let n = job.nodes(&ClusterConfig::default()) as u64;
        assert_eq!(fetched_delta, n * (per_node - credit));
    }

    #[test]
    fn stable_across_scales() {
        // §5.3: duration does not grow much with job scale.
        for cfg in [BootseerConfig::baseline(), BootseerConfig::bootseer()] {
            let t16 = run_stage(16, &cfg);
            let t128 = run_stage(128, &cfg);
            assert!(t128 < t16 * 1.4, "{}: {t16} → {t128}", cfg.image_mode.name());
        }
    }
}
