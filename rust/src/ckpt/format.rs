//! Checkpoint format: a JSON manifest of tensors plus a raw little-endian
//! f32 payload, stored through the striped local store.
//!
//! This is the real-bytes counterpart of the §4.4 resume path: the trainer
//! saves model parameters here (striped write) and resumes by reading them
//! back (striped parallel read), so the exact code path the simulator
//! models is also exercised with real data in the e2e example.

use crate::hdfs::local::LocalStore;
use crate::util::cast::{u64_from_usize, usize_from_u64};
use crate::util::json::{self, Json};
use crate::bail;
use crate::util::error::{Context, Result};

/// Metadata of one tensor in the checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// Offset in f32 elements into the payload.
    pub offset: usize,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// An in-memory checkpoint: tensor directory + flat f32 payload.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub tensors: Vec<TensorMeta>,
    pub payload: Vec<f32>,
    /// Training step the checkpoint was taken at.
    pub step: u64,
}

impl Checkpoint {
    pub fn new(step: u64) -> Checkpoint {
        Checkpoint { tensors: Vec::new(), payload: Vec::new(), step }
    }

    /// Append a tensor; returns its index.
    pub fn push(&mut self, name: &str, shape: Vec<usize>, data: &[f32]) -> usize {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        let offset = self.payload.len();
        self.payload.extend_from_slice(data);
        self.tensors.push(TensorMeta { name: name.to_string(), shape, offset });
        self.tensors.len() - 1
    }

    pub fn get(&self, name: &str) -> Option<(&TensorMeta, &[f32])> {
        let t = self.tensors.iter().find(|t| t.name == name)?;
        Some((t, &self.payload[t.offset..t.offset + t.numel()]))
    }

    pub fn total_bytes(&self) -> u64 {
        u64_from_usize(self.payload.len() * 4)
    }

    fn manifest(&self) -> Json {
        let mut m = Json::obj();
        m.set("step", self.step);
        m.set("n_elems", self.payload.len());
        let tensors: Vec<Json> = self
            .tensors
            .iter()
            .map(|t| {
                let mut o = Json::obj();
                o.set("name", t.name.as_str())
                    .set("shape", t.shape.iter().map(|&x| x as u64).collect::<Vec<u64>>())
                    .set("offset", t.offset);
                o
            })
            .collect();
        m.set("tensors", Json::Arr(tensors));
        m
    }

    /// Serialize: manifest length (u64 LE) + manifest JSON + f32 LE payload.
    pub fn to_bytes(&self) -> Vec<u8> {
        let manifest = self.manifest().to_string();
        let mut out = Vec::with_capacity(16 + manifest.len() + self.payload.len() * 4);
        out.extend_from_slice(b"BSCKPT01");
        out.extend_from_slice(&u64_from_usize(manifest.len()).to_le_bytes());
        out.extend_from_slice(manifest.as_bytes());
        for x in &self.payload {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    /// Parse a serialized checkpoint. Returns `Err` — never panics — on
    /// truncated buffers, bad magic, or a corrupt manifest (including
    /// offset/shape values whose extents overflow or overrun the payload).
    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint> {
        if data.len() < 16 {
            bail!("truncated checkpoint header: {} bytes", data.len());
        }
        if &data[..8] != b"BSCKPT01" {
            bail!("bad checkpoint magic");
        }
        let mlen = usize_from_u64(u64::from_le_bytes(data[8..16].try_into().unwrap()));
        // `saturating_sub` keeps the bound total even for absurd lengths.
        if mlen > data.len().saturating_sub(16) {
            bail!("truncated checkpoint manifest");
        }
        let manifest = std::str::from_utf8(&data[16..16 + mlen])?;
        let m = json::parse(manifest).map_err(|e| crate::anyhow!("manifest: {e}"))?;
        let step = m.get("step").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let n_elems =
            m.get("n_elems").and_then(|v| v.as_usize()).context("manifest n_elems")?;
        let payload_bytes = n_elems.checked_mul(4).context("manifest n_elems overflow")?;
        let body = &data[16 + mlen..];
        if body.len() != payload_bytes {
            bail!("payload size mismatch: {} != {}", body.len(), payload_bytes);
        }
        let payload: Vec<f32> = body
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        let tensors = m
            .get("tensors")
            .and_then(|v| v.as_arr())
            .context("manifest tensors")?
            .iter()
            .map(|t| -> Result<TensorMeta> {
                Ok(TensorMeta {
                    name: t.get("name").and_then(|v| v.as_str()).context("name")?.to_string(),
                    shape: t
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .context("shape")?
                        .iter()
                        .map(|x| x.as_usize().context("shape dim"))
                        .collect::<Result<Vec<_>>>()?,
                    offset: t.get("offset").and_then(|v| v.as_usize()).context("offset")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // Validate tensor extents with overflow-checked arithmetic.
        for t in &tensors {
            let numel = t
                .shape
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .with_context(|| format!("tensor {} shape overflows", t.name))?;
            let end = t
                .offset
                .checked_add(numel)
                .with_context(|| format!("tensor {} extent overflows", t.name))?;
            if end > payload.len() {
                bail!("tensor {} overruns payload", t.name);
            }
        }
        Ok(Checkpoint { tensors, payload, step })
    }

    /// Save through the striped store (the BootSeer write path).
    pub fn save(&self, store: &LocalStore, name: &str, chunk_bytes: u64, width: u32) -> Result<()> {
        store.write_striped(name, &self.to_bytes(), chunk_bytes, width)?;
        Ok(())
    }

    /// Resume via striped parallel read (BootSeer) or the sequential
    /// baseline path.
    pub fn load(store: &LocalStore, name: &str, striped: bool) -> Result<Checkpoint> {
        let bytes = if striped {
            store.read_striped_parallel(name)?
        } else {
            store.read_sequential(name)?
        };
        Checkpoint::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_ckpt() -> Checkpoint {
        let mut c = Checkpoint::new(1234);
        let mut rng = Rng::seeded(1);
        let w: Vec<f32> = (0..64 * 32).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
        c.push("layer0.w", vec![64, 32], &w);
        c.push("layer0.b", vec![32], &b);
        c
    }

    #[test]
    fn bytes_roundtrip() {
        let c = sample_ckpt();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.step, 1234);
    }

    #[test]
    fn get_by_name() {
        let c = sample_ckpt();
        let (meta, data) = c.get("layer0.b").unwrap();
        assert_eq!(meta.shape, vec![32]);
        assert_eq!(data.len(), 32);
        assert!(c.get("nope").is_none());
    }

    #[test]
    fn save_load_striped_and_sequential() {
        let dir = std::env::temp_dir().join(format!("bootseer-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = LocalStore::open(&dir).unwrap();
        let c = sample_ckpt();
        c.save(&store, "model", 1024, 4).unwrap();
        assert_eq!(Checkpoint::load(&store, "model", true).unwrap(), c);
        assert_eq!(Checkpoint::load(&store, "model", false).unwrap(), c);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_corrupt() {
        assert!(Checkpoint::from_bytes(b"garbage").is_err());
        let c = sample_ckpt();
        let mut bytes = c.to_bytes();
        bytes.truncate(bytes.len() - 4); // drop one f32
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_short_buffers_at_every_length() {
        // Every truncation of a valid checkpoint must error, never panic —
        // including the sub-header lengths that used to slice blindly.
        let full = sample_ckpt().to_bytes();
        assert!(Checkpoint::from_bytes(&[]).is_err());
        for len in [1, 7, 8, 9, 15, 16, 17, full.len() / 2, full.len() - 1] {
            assert!(
                Checkpoint::from_bytes(&full[..len]).is_err(),
                "truncation to {len} bytes must be rejected"
            );
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = sample_ckpt().to_bytes();
        bytes[0] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
        // Right length, wrong magic, no panic.
        assert!(Checkpoint::from_bytes(&[0u8; 16]).is_err());
    }

    #[test]
    fn rejects_absurd_manifest_length() {
        // mlen = u64::MAX: the 16 + mlen bound must not overflow.
        let mut bytes = b"BSCKPT01".to_vec();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(b"{}");
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_overflowing_tensor_extent() {
        // A manifest whose tensor offset+numel overflows usize must error
        // cleanly instead of panicking in the extent check.
        let manifest = format!(
            "{{\"step\":1,\"n_elems\":2,\"tensors\":[{{\"name\":\"x\",\"shape\":[2],\"offset\":{}}}]}}",
            usize::MAX
        );
        let mut bytes = b"BSCKPT01".to_vec();
        bytes.extend_from_slice(&(manifest.len() as u64).to_le_bytes());
        bytes.extend_from_slice(manifest.as_bytes());
        bytes.extend_from_slice(&[0u8; 8]); // 2 f32 elems
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_non_numeric_shape() {
        let manifest =
            "{\"step\":1,\"n_elems\":1,\"tensors\":[{\"name\":\"x\",\"shape\":[\"a\"],\"offset\":0}]}";
        let mut bytes = b"BSCKPT01".to_vec();
        bytes.extend_from_slice(&(manifest.len() as u64).to_le_bytes());
        bytes.extend_from_slice(manifest.as_bytes());
        bytes.extend_from_slice(&[0u8; 4]);
        assert!(Checkpoint::from_bytes(bytes.as_slice()).is_err());
    }

    #[test]
    fn rejects_overrunning_tensor() {
        let c = sample_ckpt();
        let mut bytes = c.to_bytes();
        // Corrupt the manifest offset field by rewriting manifest.
        let mlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let manifest = String::from_utf8(bytes[16..16 + mlen].to_vec()).unwrap();
        // layer0.b sits at offset 2048; push it out of bounds (same width).
        let bad = manifest.replace("\"offset\":2048", "\"offset\":9999");
        assert_eq!(manifest.len(), bad.len(), "test setup: same length edit");
        bytes[16..16 + mlen].copy_from_slice(bad.as_bytes());
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn shape_data_mismatch_panics() {
        let mut c = Checkpoint::new(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.push("x", vec![3, 3], &[1.0; 8]);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn total_bytes() {
        let c = sample_ckpt();
        assert_eq!(c.total_bytes(), (64 * 32 + 32) * 4);
    }
}
