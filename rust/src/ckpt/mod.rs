//! Checkpoint subsystem: the on-disk format + save/resume over the striped
//! store (real bytes), and the Model Initialization stage planner (sim).

pub mod format;
pub mod resume;

pub use format::{Checkpoint, TensorMeta};
pub use resume::{
    plan_model_init, plan_model_init_with, resume_bytes_per_node,
    retained_resume_bytes_per_node, ModelInitPlan,
};
