//! Record phase of record-and-prefetch (§4.2).
//!
//! During the first run of an image, the container runtime on each worker
//! node records `(path, block offset, timestamp)` for every block it
//! faults in. The trace is uploaded to a central registry service; later
//! runs of the same image ask the registry for the image's *hot set* —
//! the union of blocks observed within the record window — and prefetch
//! exactly those before container start.

use std::collections::{BTreeMap, BTreeSet};

/// One recorded block access.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccessEvent {
    pub block: u32,
    /// Seconds since container start.
    pub t: f64,
}

/// Per-node access recorder (runs inside the container runtime).
#[derive(Clone, Debug, Default)]
pub struct AccessRecorder {
    pub events: Vec<AccessEvent>,
}

impl AccessRecorder {
    pub fn new() -> AccessRecorder {
        AccessRecorder::default()
    }

    pub fn record(&mut self, block: u32, t: f64) {
        self.events.push(AccessEvent { block, t });
    }

    /// First-access time per block: the *minimum* `t` over the block's
    /// events, never the first one encountered in vector order — recorder
    /// events arrive out of order in production (per-thread buffers flush
    /// independently), so position in `events` carries no meaning.
    pub fn first_access(&self) -> BTreeMap<u32, f64> {
        let mut first: BTreeMap<u32, f64> = BTreeMap::new();
        for e in &self.events {
            let t = first.entry(e.block).or_insert(e.t);
            if e.t < *t {
                *t = e.t;
            }
        }
        first
    }

    /// Blocks whose first access falls within `window_s` of container
    /// start, sorted by block id. Robust to out-of-order event arrival:
    /// membership depends only on each block's minimum recorded `t`.
    pub fn hot_blocks(&self, window_s: f64) -> Vec<u32> {
        self.first_access()
            .into_iter()
            .filter(|&(_, t)| t <= window_s)
            .map(|(b, _)| b)
            .collect()
    }
}

/// A hot-set record stored by the central service, merged across recorders.
#[derive(Clone, Debug, Default)]
pub struct HotSetRecord {
    /// Union of hot blocks across all reporting nodes.
    pub blocks: BTreeSet<u32>,
    /// Number of recorder reports merged in.
    pub reports: u32,
}

/// Central record registry: image digest → hot-set record (§4.2's "remote
/// service" the container runtime uploads traces to and fetches records
/// from).
#[derive(Clone, Debug, Default)]
pub struct HotSetRegistry {
    records: BTreeMap<u64, HotSetRecord>,
    pub window_s: f64,
}

impl HotSetRegistry {
    pub fn new(window_s: f64) -> HotSetRegistry {
        HotSetRegistry { records: BTreeMap::new(), window_s }
    }

    /// Upload one node's trace for `image_digest`.
    pub fn upload(&mut self, image_digest: u64, recorder: &AccessRecorder) {
        let rec = self.records.entry(image_digest).or_default();
        for b in recorder.hot_blocks(self.window_s) {
            rec.blocks.insert(b);
        }
        rec.reports += 1;
    }

    /// Directly install a hot-set record for `image_digest`. The cluster
    /// replay's `trace::SharedWorld` uses this to materialize the record an
    /// earlier (virtual-time) startup of the same image produced, without
    /// re-running its record pass; equivalent to one `upload` whose
    /// recorder saw exactly `blocks` inside the window.
    pub fn seed_record(&mut self, image_digest: u64, blocks: impl IntoIterator<Item = u32>) {
        let rec = self.records.entry(image_digest).or_default();
        for b in blocks {
            rec.blocks.insert(b);
        }
        rec.reports += 1;
    }

    /// Fetch the hot set for an image; None on first-ever use (the record
    /// run must fall back to lazy loading).
    pub fn lookup(&self, image_digest: u64) -> Option<Vec<u32>> {
        self.records.get(&image_digest).map(|r| r.blocks.iter().copied().collect())
    }

    /// Drop the record (e.g., image rebuilt under the same tag).
    pub fn invalidate(&mut self, image_digest: u64) {
        self.records.remove(&image_digest);
    }

    pub fn has_record(&self, image_digest: u64) -> bool {
        self.records.contains_key(&image_digest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn recorder_windows_accesses() {
        let mut r = AccessRecorder::new();
        r.record(10, 1.0);
        r.record(20, 50.0);
        r.record(30, 130.0); // outside a 120 s window
        r.record(10, 200.0); // re-access outside window; already hot
        assert_eq!(r.hot_blocks(120.0), vec![10, 20]);
        assert_eq!(r.hot_blocks(1000.0), vec![10, 20, 30]);
    }

    #[test]
    fn hot_blocks_robust_to_out_of_order_events() {
        // Regression: per-thread recorder buffers flush out of order, so a
        // block's earliest access can appear *after* a later re-access in
        // the event vector. Membership must follow the minimum t.
        let mut shuffled = AccessRecorder::new();
        shuffled.record(5, 200.0); // late re-access arrives first
        shuffled.record(5, 1.0); // the true first access
        shuffled.record(9, 130.0); // genuinely outside the window
        assert_eq!(shuffled.hot_blocks(120.0), vec![5]);
        assert_eq!(*shuffled.first_access().get(&5).unwrap(), 1.0);

        // Any permutation of the same events yields the same hot set.
        let events = [(10u32, 50.0), (20, 3.0), (10, 0.5), (30, 119.9), (20, 121.0)];
        let ordered = {
            let mut r = AccessRecorder::new();
            for &(b, t) in &events {
                r.record(b, t);
            }
            r.hot_blocks(120.0)
        };
        let reversed = {
            let mut r = AccessRecorder::new();
            for &(b, t) in events.iter().rev() {
                r.record(b, t);
            }
            r.hot_blocks(120.0)
        };
        assert_eq!(ordered, reversed);
        assert_eq!(ordered, vec![10, 20, 30]);
    }

    #[test]
    fn registry_merges_reports() {
        let mut reg = HotSetRegistry::new(120.0);
        let mut a = AccessRecorder::new();
        a.record(1, 0.5);
        a.record(2, 1.0);
        let mut b = AccessRecorder::new();
        b.record(2, 0.2);
        b.record(3, 2.0);
        reg.upload(99, &a);
        reg.upload(99, &b);
        assert_eq!(reg.lookup(99), Some(vec![1, 2, 3]));
        assert_eq!(reg.records.get(&99).unwrap().reports, 2);
    }

    #[test]
    fn lookup_miss_on_first_use() {
        let reg = HotSetRegistry::new(120.0);
        assert_eq!(reg.lookup(42), None);
        assert!(!reg.has_record(42));
    }

    #[test]
    fn invalidate_forces_rerecord() {
        let mut reg = HotSetRegistry::new(120.0);
        let mut r = AccessRecorder::new();
        r.record(5, 1.0);
        reg.upload(7, &r);
        assert!(reg.has_record(7));
        reg.invalidate(7);
        assert_eq!(reg.lookup(7), None);
    }

    #[test]
    fn seed_record_equivalent_to_upload() {
        let mut via_upload = HotSetRegistry::new(120.0);
        let mut rec = AccessRecorder::new();
        for (k, b) in [9u32, 3, 7, 3].into_iter().enumerate() {
            rec.record(b, k as f64 * 0.05);
        }
        via_upload.upload(5, &rec);
        let mut via_seed = HotSetRegistry::new(120.0);
        via_seed.seed_record(5, [9u32, 3, 7, 3]);
        assert_eq!(via_upload.lookup(5), via_seed.lookup(5));
        assert!(via_seed.has_record(5));
    }

    #[test]
    fn images_do_not_cross_pollinate() {
        let mut reg = HotSetRegistry::new(120.0);
        let mut r = AccessRecorder::new();
        r.record(5, 1.0);
        reg.upload(1, &r);
        assert_eq!(reg.lookup(2), None);
    }

    #[test]
    fn prop_hot_set_is_subset_and_sorted() {
        prop_check(32, |g| {
            let mut r = AccessRecorder::new();
            let n = g.usize_in(0, 200);
            for _ in 0..n {
                r.record(g.u64_in(0, 500) as u32, g.f64_in(0.0, 300.0));
            }
            let w = g.f64_in(0.0, 300.0);
            let hot = r.hot_blocks(w);
            prop_assert!(hot.windows(2).all(|p| p[0] < p[1]), "sorted+unique");
            let all: std::collections::BTreeSet<u32> =
                r.events.iter().map(|e| e.block).collect();
            prop_assert!(hot.iter().all(|b| all.contains(b)));
            // Monotone in window size.
            let hot_big = r.hot_blocks(w + 10.0);
            prop_assert!(hot.iter().all(|b| hot_big.contains(b)));
            Ok(())
        });
    }
}
