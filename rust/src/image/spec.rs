//! Container image model.
//!
//! Our platform (like the paper's, §4.2) abandons layered OCI images for a
//! *flattened, block-addressed* layout: all layers are squashed, contents
//! are split into fixed-size blocks, and blocks are content-addressed so
//! identical blocks dedupe across images. An `ImageSpec` is the metadata
//! view the simulator and the loaders work against; real block bytes only
//! exist in unit tests and the blockstore micro-bench.

use crate::util::cast::{bytes_from_f64, u32_from_u64};
use crate::util::rng::Rng;

/// A file inside the flattened image.
#[derive(Clone, Debug)]
pub struct FileEntry {
    pub path: String,
    pub bytes: u64,
    /// Index of the file's first block in the image block array.
    pub first_block: u32,
    /// Number of blocks (last one may be partial).
    pub n_blocks: u32,
}

/// Block-level metadata of a flattened image.
#[derive(Clone, Debug)]
pub struct ImageSpec {
    /// Digest identifying the image (content hash of the block digest list).
    pub digest: u64,
    pub block_bytes: u64,
    pub total_bytes: u64,
    pub files: Vec<FileEntry>,
    /// Content digest per block — equal digests dedupe.
    pub block_digests: Vec<u64>,
    /// Blocks touched during container startup, in access order. This is
    /// what the record phase captures and the prefetch phase replays.
    pub startup_access: Vec<u32>,
}

impl ImageSpec {
    pub fn n_blocks(&self) -> u32 {
        self.block_digests.len() as u32
    }

    /// Bytes of the startup-hot set.
    pub fn hot_bytes(&self) -> u64 {
        // The final block of the image may be partial; treat all accessed
        // blocks as full blocks except a possible tail block.
        let mut total = 0u64;
        for &b in &self.startup_access {
            total += self.block_len(b);
        }
        total
    }

    /// Length of block `b` (the image's last block may be partial).
    pub fn block_len(&self, b: u32) -> u64 {
        let full_blocks = self.total_bytes / self.block_bytes;
        if (b as u64) < full_blocks {
            self.block_bytes
        } else {
            self.total_bytes - full_blocks * self.block_bytes
        }
    }

    pub fn cold_bytes(&self) -> u64 {
        self.total_bytes - self.hot_bytes()
    }

    /// Generate a synthetic training image:
    /// * `total_bytes` split into lognormally-sized files (a few huge
    ///   framework/CUDA-like blobs and a long tail of small files),
    /// * a startup-hot set of ~`hot_fraction` of blocks, biased toward a
    ///   contiguous "runtime + interpreter + shared libs" region plus
    ///   scattered config files — matching Slacker's observation [15] that
    ///   startup touches a small, stable subset.
    pub fn synth(seed: u64, total_bytes: u64, block_bytes: u64, hot_fraction: f64) -> ImageSpec {
        let mut rng = Rng::seeded(seed ^ 0x1111_2222_3333_4444);
        let n_blocks = u32_from_u64((total_bytes + block_bytes - 1) / block_bytes);

        // Files: draw sizes until the image is full.
        let mut files = Vec::new();
        let mut covered = 0u64;
        let mut next_block = 0u32;
        let mut fid = 0u32;
        while covered < total_bytes {
            // Lognormal sizes, mean ~ tens of MB, heavy tail for the
            // multi-GB framework blobs.
            let raw = bytes_from_f64(rng.lognormal(16.0, 2.0)); // median ≈ 8.9 MB
            let bytes = raw.clamp(4 * 1024, 8 * 1_000_000_000).min(total_bytes - covered);
            let nb = u32_from_u64((bytes + block_bytes - 1) / block_bytes).max(1);
            files.push(FileEntry {
                path: format!("/opt/image/file{fid:06}"),
                bytes,
                first_block: next_block,
                n_blocks: nb,
            });
            covered += bytes;
            // Files are packed block-aligned in the flattened layout.
            next_block = (next_block + nb).min(n_blocks.saturating_sub(1).max(1));
            fid += 1;
        }

        // Block digests: unique per (seed, index) except a shared base-layer
        // region (first 20% of blocks) that uses seed-independent digests so
        // different images built on the same base dedupe.
        let base_region = (n_blocks as f64 * 0.20) as u32;
        let block_digests: Vec<u64> = (0..n_blocks)
            .map(|i| {
                if i < base_region {
                    0xBA5E_0000_0000_0000 ^ (i as u64)
                } else {
                    let mut h = Rng::seeded(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
                    h.next_u64()
                }
            })
            .collect();

        // Startup-hot set: contiguous runtime region + scattered extras.
        let n_hot = ((n_blocks as f64 * hot_fraction) as u32).max(1).min(n_blocks);
        let contiguous = (n_hot as f64 * 0.7) as u32;
        let runtime_start = base_region.min(n_blocks.saturating_sub(contiguous.max(1)));
        let mut startup_access: Vec<u32> = Vec::with_capacity(n_hot as usize);
        for i in 0..contiguous {
            startup_access.push(runtime_start + i);
        }
        while (startup_access.len() as u32) < n_hot {
            let b = rng.below(n_blocks as u64) as u32;
            if !startup_access.contains(&b) {
                startup_access.push(b);
            }
        }

        // Digest of the image = mix of block digests.
        let digest = block_digests
            .iter()
            .fold(0xCAFE_F00Du64, |acc, &d| acc.rotate_left(5) ^ d.wrapping_mul(0x100000001B3));

        ImageSpec { digest, block_bytes, total_bytes, files, block_digests, startup_access }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::{IMAGE_BLOCK_BYTES, PAPER_IMAGE_BYTES};
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn paper_image() -> ImageSpec {
        ImageSpec::synth(1, PAPER_IMAGE_BYTES, IMAGE_BLOCK_BYTES, 0.07)
    }

    #[test]
    fn synth_covers_total_bytes() {
        let img = paper_image();
        assert_eq!(img.total_bytes, PAPER_IMAGE_BYTES);
        let file_bytes: u64 = img.files.iter().map(|f| f.bytes).sum();
        assert_eq!(file_bytes, PAPER_IMAGE_BYTES);
        let expect_blocks = (PAPER_IMAGE_BYTES + IMAGE_BLOCK_BYTES - 1) / IMAGE_BLOCK_BYTES;
        assert_eq!(img.n_blocks() as u64, expect_blocks);
    }

    #[test]
    fn hot_set_close_to_fraction() {
        let img = paper_image();
        let frac = img.hot_bytes() as f64 / img.total_bytes as f64;
        assert!((0.05..0.09).contains(&frac), "hot fraction {frac}");
        assert_eq!(img.hot_bytes() + img.cold_bytes(), img.total_bytes);
    }

    #[test]
    fn hot_set_unique_blocks() {
        let img = paper_image();
        let mut seen = std::collections::BTreeSet::new();
        for &b in &img.startup_access {
            assert!(b < img.n_blocks());
            assert!(seen.insert(b), "duplicate hot block {b}");
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = ImageSpec::synth(5, 1_000_000_000, 4_000_000, 0.07);
        let b = ImageSpec::synth(5, 1_000_000_000, 4_000_000, 0.07);
        let c = ImageSpec::synth(6, 1_000_000_000, 4_000_000, 0.07);
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.startup_access, b.startup_access);
        assert_ne!(a.digest, c.digest);
    }

    #[test]
    fn base_layer_dedupes_across_images() {
        let a = ImageSpec::synth(7, 1_000_000_000, 4_000_000, 0.07);
        let b = ImageSpec::synth(8, 1_000_000_000, 4_000_000, 0.07);
        let shared = a
            .block_digests
            .iter()
            .filter(|d| b.block_digests.contains(d))
            .count();
        // The 20% base region is shared.
        assert!(shared as f64 >= 0.19 * a.n_blocks() as f64, "shared {shared}");
    }

    #[test]
    fn partial_tail_block() {
        let img = ImageSpec::synth(9, 10_500_000, 4_000_000, 0.5);
        assert_eq!(img.n_blocks(), 3);
        assert_eq!(img.block_len(0), 4_000_000);
        assert_eq!(img.block_len(2), 2_500_000);
    }

    #[test]
    fn prop_synth_invariants() {
        prop_check(24, |g| {
            let total = g.u64_in(10_000_000, 2_000_000_000);
            let block = 4_000_000;
            let frac = g.f64_in(0.01, 0.5);
            let img = ImageSpec::synth(g.rng.next_u64(), total, block, frac);
            prop_assert!(img.hot_bytes() <= img.total_bytes);
            prop_assert!(img.startup_access.len() as u32 <= img.n_blocks());
            prop_assert!(!img.startup_access.is_empty());
            let sum: u64 = (0..img.n_blocks()).map(|b| img.block_len(b)).sum();
            prop_assert!(sum == img.total_bytes, "block lens {sum} != {}", img.total_bytes);
            Ok(())
        });
    }
}
