//! Content-addressed block store.
//!
//! The flattened image layout manages contents at block granularity with
//! content addressing, which gives both dedup (identical blocks stored
//! once) and lazy loading (fetch by digest). This module implements the
//! store over *real bytes* — used by the real-byte integration tests, the
//! env-cache packer, and `micro_blockstore` — plus the dedup accounting the
//! simulator reads.

use crate::util::sha256::Sha256;
use std::collections::HashMap;

/// 256-bit content digest.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockDigest(pub [u8; 32]);

impl std::fmt::Debug for BlockDigest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for b in &self.0[..6] {
            write!(f, "{b:02x}")?;
        }
        write!(f, "…")
    }
}

pub fn digest_of(data: &[u8]) -> BlockDigest {
    let mut h = Sha256::new();
    h.update(data);
    BlockDigest(h.finalize())
}

/// In-memory content-addressed store with refcounts and dedup statistics.
#[derive(Default)]
pub struct BlockStore {
    blocks: HashMap<BlockDigest, (Vec<u8>, u64)>,
    /// Logical bytes put (before dedup).
    pub logical_bytes: u64,
    /// Physical bytes stored (after dedup).
    pub physical_bytes: u64,
}

impl BlockStore {
    pub fn new() -> BlockStore {
        BlockStore::default()
    }

    /// Insert a block; returns its digest. Duplicate content costs nothing.
    pub fn put(&mut self, data: &[u8]) -> BlockDigest {
        let d = digest_of(data);
        self.logical_bytes += data.len() as u64;
        match self.blocks.get_mut(&d) {
            Some((_, rc)) => *rc += 1,
            None => {
                self.physical_bytes += data.len() as u64;
                self.blocks.insert(d, (data.to_vec(), 1));
            }
        }
        d
    }

    pub fn get(&self, d: &BlockDigest) -> Option<&[u8]> {
        self.blocks.get(d).map(|(v, _)| v.as_slice())
    }

    pub fn contains(&self, d: &BlockDigest) -> bool {
        self.blocks.contains_key(d)
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// logical/physical — 1.0 means no dedup, 2.0 means half the bytes.
    pub fn dedup_ratio(&self) -> f64 {
        if self.physical_bytes == 0 {
            1.0
        } else {
            self.logical_bytes as f64 / self.physical_bytes as f64
        }
    }

    /// Split `data` into `block_bytes` chunks, store each, return digests.
    pub fn put_chunked(&mut self, data: &[u8], block_bytes: usize) -> Vec<BlockDigest> {
        assert!(block_bytes > 0);
        data.chunks(block_bytes).map(|c| self.put(c)).collect()
    }

    /// Reassemble chunked content; None if any block is missing.
    pub fn get_chunked(&self, digests: &[BlockDigest]) -> Option<Vec<u8>> {
        let mut out = Vec::new();
        for d in digests {
            out.extend_from_slice(self.get(d)?);
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn put_get_roundtrip() {
        let mut s = BlockStore::new();
        let d = s.put(b"hello world");
        assert_eq!(s.get(&d), Some(b"hello world".as_slice()));
        assert!(s.contains(&d));
        assert_eq!(s.n_blocks(), 1);
    }

    #[test]
    fn dedup_identical_blocks() {
        let mut s = BlockStore::new();
        let a = s.put(b"same-content");
        let b = s.put(b"same-content");
        assert_eq!(a, b);
        assert_eq!(s.n_blocks(), 1);
        assert_eq!(s.physical_bytes, 12);
        assert_eq!(s.logical_bytes, 24);
        assert!((s.dedup_ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn digest_differs_on_content() {
        assert_ne!(digest_of(b"a"), digest_of(b"b"));
        assert_eq!(digest_of(b"a"), digest_of(b"a"));
    }

    #[test]
    fn chunked_roundtrip() {
        let mut s = BlockStore::new();
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let ds = s.put_chunked(&data, 1024);
        assert_eq!(ds.len(), 10); // ceil(10000/1024)
        assert_eq!(s.get_chunked(&ds).unwrap(), data);
    }

    #[test]
    fn chunked_dedups_repeats() {
        let mut s = BlockStore::new();
        // 8 identical 1 KiB chunks.
        let data = vec![7u8; 8 * 1024];
        let ds = s.put_chunked(&data, 1024);
        assert_eq!(ds.len(), 8);
        assert_eq!(s.n_blocks(), 1);
        assert!(s.dedup_ratio() > 7.9);
    }

    #[test]
    fn missing_block_is_none() {
        let s = BlockStore::new();
        assert_eq!(s.get(&digest_of(b"nope")), None);
        assert!(s.get_chunked(&[digest_of(b"nope")]).is_none());
    }

    #[test]
    fn prop_chunk_roundtrip_any_size() {
        prop_check(32, |g| {
            let n = g.usize_in(0, 5000);
            let data = g.bytes(n);
            let block = g.usize_in(1, 600);
            let mut s = BlockStore::new();
            let ds = s.put_chunked(&data, block);
            let back = s.get_chunked(&ds).unwrap();
            prop_assert!(back == data);
            prop_assert!(s.physical_bytes <= s.logical_bytes);
            Ok(())
        });
    }
}
