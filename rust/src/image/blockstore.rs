//! Content-addressed block store.
//!
//! The flattened image layout manages contents at block granularity with
//! content addressing, which gives both dedup (identical blocks stored
//! once) and lazy loading (fetch by digest). This module implements the
//! store over *real bytes* — used by the real-byte integration tests, the
//! env-cache packer, and `micro_blockstore` — plus the dedup accounting the
//! simulator reads.

use crate::util::cast::u64_from_usize;
use crate::util::sha256::Sha256;
use std::collections::HashMap;

/// 256-bit content digest.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockDigest(pub [u8; 32]);

impl std::fmt::Debug for BlockDigest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for b in &self.0[..6] {
            write!(f, "{b:02x}")?;
        }
        write!(f, "…")
    }
}

pub fn digest_of(data: &[u8]) -> BlockDigest {
    let mut h = Sha256::new();
    h.update(data);
    BlockDigest(h.finalize())
}

/// In-memory content-addressed store with refcounts and dedup statistics.
#[derive(Default)]
pub struct BlockStore {
    // detlint::allow(hash-container, "keyed get/insert/remove/len only; iteration order is never observed, and the real-byte store is off the replay path")
    blocks: HashMap<BlockDigest, (Vec<u8>, u64)>,
    /// Logical bytes put (before dedup).
    pub logical_bytes: u64,
    /// Physical bytes stored (after dedup).
    pub physical_bytes: u64,
}

impl BlockStore {
    pub fn new() -> BlockStore {
        BlockStore::default()
    }

    /// Insert a block; returns its digest. Duplicate content costs nothing.
    pub fn put(&mut self, data: &[u8]) -> BlockDigest {
        let d = digest_of(data);
        self.logical_bytes += u64_from_usize(data.len());
        match self.blocks.get_mut(&d) {
            Some((_, rc)) => *rc += 1,
            None => {
                self.physical_bytes += u64_from_usize(data.len());
                self.blocks.insert(d, (data.to_vec(), 1));
            }
        }
        d
    }

    /// Drop one reference to the block; frees the bytes when the last
    /// reference goes. Returns `false` if the digest is not stored.
    /// Accounting invariant (the artifact layer's dedup arithmetic relies
    /// on it): `logical_bytes` falls by the block length on every
    /// successful deref, `physical_bytes` only when the block is freed.
    pub fn remove(&mut self, d: &BlockDigest) -> bool {
        let Some((data, rc)) = self.blocks.get_mut(d) else {
            return false;
        };
        let len = u64_from_usize(data.len());
        self.logical_bytes -= len;
        if *rc > 1 {
            *rc -= 1;
        } else {
            self.blocks.remove(d);
            self.physical_bytes -= len;
        }
        true
    }

    /// Current reference count of a block (0 if absent).
    pub fn refcount(&self, d: &BlockDigest) -> u64 {
        self.blocks.get(d).map(|(_, rc)| *rc).unwrap_or(0)
    }

    pub fn get(&self, d: &BlockDigest) -> Option<&[u8]> {
        self.blocks.get(d).map(|(v, _)| v.as_slice())
    }

    pub fn contains(&self, d: &BlockDigest) -> bool {
        self.blocks.contains_key(d)
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// logical/physical — 1.0 means no dedup, 2.0 means half the bytes.
    pub fn dedup_ratio(&self) -> f64 {
        if self.physical_bytes == 0 {
            1.0
        } else {
            self.logical_bytes as f64 / self.physical_bytes as f64
        }
    }

    /// Split `data` into `block_bytes` chunks, store each, return digests.
    pub fn put_chunked(&mut self, data: &[u8], block_bytes: usize) -> Vec<BlockDigest> {
        assert!(block_bytes > 0);
        data.chunks(block_bytes).map(|c| self.put(c)).collect()
    }

    /// Reassemble chunked content; None if any block is missing.
    pub fn get_chunked(&self, digests: &[BlockDigest]) -> Option<Vec<u8>> {
        let mut out = Vec::new();
        for d in digests {
            out.extend_from_slice(self.get(d)?);
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn put_get_roundtrip() {
        let mut s = BlockStore::new();
        let d = s.put(b"hello world");
        assert_eq!(s.get(&d), Some(b"hello world".as_slice()));
        assert!(s.contains(&d));
        assert_eq!(s.n_blocks(), 1);
    }

    #[test]
    fn dedup_identical_blocks() {
        let mut s = BlockStore::new();
        let a = s.put(b"same-content");
        let b = s.put(b"same-content");
        assert_eq!(a, b);
        assert_eq!(s.n_blocks(), 1);
        assert_eq!(s.physical_bytes, 12);
        assert_eq!(s.logical_bytes, 24);
        assert!((s.dedup_ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn digest_differs_on_content() {
        assert_ne!(digest_of(b"a"), digest_of(b"b"));
        assert_eq!(digest_of(b"a"), digest_of(b"a"));
    }

    #[test]
    fn chunked_roundtrip() {
        let mut s = BlockStore::new();
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let ds = s.put_chunked(&data, 1024);
        assert_eq!(ds.len(), 10); // ceil(10000/1024)
        assert_eq!(s.get_chunked(&ds).unwrap(), data);
    }

    #[test]
    fn chunked_dedups_repeats() {
        let mut s = BlockStore::new();
        // 8 identical 1 KiB chunks.
        let data = vec![7u8; 8 * 1024];
        let ds = s.put_chunked(&data, 1024);
        assert_eq!(ds.len(), 8);
        assert_eq!(s.n_blocks(), 1);
        assert!(s.dedup_ratio() > 7.9);
    }

    #[test]
    fn remove_pins_refcount_and_physical_accounting() {
        let mut s = BlockStore::new();
        let a = s.put(b"shared-block"); // 12 bytes, rc=1
        let _ = s.put(b"shared-block"); // rc=2
        let b = s.put(b"loner"); // 5 bytes, rc=1
        assert_eq!(s.refcount(&a), 2);
        assert_eq!((s.logical_bytes, s.physical_bytes), (29, 17));

        // Deref the shared block: logical falls, physical stays (one
        // reference remains), content still readable.
        assert!(s.remove(&a));
        assert_eq!(s.refcount(&a), 1);
        assert_eq!((s.logical_bytes, s.physical_bytes), (17, 17));
        assert_eq!(s.get(&a), Some(b"shared-block".as_slice()));

        // Last deref frees the bytes.
        assert!(s.remove(&a));
        assert_eq!(s.refcount(&a), 0);
        assert_eq!(s.get(&a), None);
        assert_eq!((s.logical_bytes, s.physical_bytes), (5, 5));
        assert_eq!(s.n_blocks(), 1);

        // Removing an absent digest is a no-op.
        assert!(!s.remove(&a));
        assert_eq!((s.logical_bytes, s.physical_bytes), (5, 5));

        // Re-putting freed content starts a fresh refcount.
        let a2 = s.put(b"shared-block");
        assert_eq!(a2, a);
        assert_eq!(s.refcount(&a2), 1);
        assert!((s.dedup_ratio() - 1.0).abs() < 1e-12);
        assert!(s.remove(&b));
        assert_eq!(s.n_blocks(), 1);
    }

    #[test]
    fn prop_put_remove_roundtrip_restores_accounting() {
        prop_check(24, |g| {
            let mut s = BlockStore::new();
            let n = g.usize_in(1, 40);
            let mut digests = Vec::new();
            for _ in 0..n {
                // Small alphabet forces dedup collisions.
                let len = g.usize_in(1, 64);
                let byte = g.u64_in(0, 3) as u8;
                digests.push(s.put(&vec![byte; len]));
            }
            prop_assert!(s.physical_bytes <= s.logical_bytes);
            for d in &digests {
                prop_assert!(s.remove(d));
            }
            prop_assert!(s.logical_bytes == 0, "logical {}", s.logical_bytes);
            prop_assert!(s.physical_bytes == 0, "physical {}", s.physical_bytes);
            prop_assert!(s.n_blocks() == 0);
            Ok(())
        });
    }

    #[test]
    fn missing_block_is_none() {
        let s = BlockStore::new();
        assert_eq!(s.get(&digest_of(b"nope")), None);
        assert!(s.get_chunked(&[digest_of(b"nope")]).is_none());
    }

    #[test]
    fn prop_chunk_roundtrip_any_size() {
        prop_check(32, |g| {
            let n = g.usize_in(0, 5000);
            let data = g.bytes(n);
            let block = g.usize_in(1, 600);
            let mut s = BlockStore::new();
            let ds = s.put_chunked(&data, block);
            let back = s.get_chunked(&ds).unwrap();
            prop_assert!(back == data);
            prop_assert!(s.physical_bytes <= s.logical_bytes);
            Ok(())
        });
    }
}
