//! Container image subsystem (§4.2): flattened block-addressed images,
//! content-addressed storage, access-trace recording, hot-set prefetch,
//! and the three loading engines the evaluation compares.

pub mod access;
pub mod blockstore;
pub mod loader;
pub mod p2p;
pub mod spec;

pub use access::{AccessRecorder, HotSetRegistry};
pub use blockstore::{digest_of, BlockDigest, BlockStore};
pub use loader::{plan_image_load, plan_image_load_with, ImageLoadPlan};
pub use p2p::Swarm;
pub use spec::ImageSpec;
