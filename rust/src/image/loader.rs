//! Image-loading stage planners: given a cluster sim and an image, lay down
//! the task DAG for one of three engines (§4.2):
//!
//! * `OciFull` — the pre-lazy-loading strawman: every node downloads every
//!   byte from the registry before the container starts.
//! * `Lazy` — the paper's baseline: container starts against a block-level
//!   lazy mount; startup faults in the hot set on demand. Each miss pays a
//!   FUSE+RPC latency that grows with the number of concurrently-faulting
//!   nodes (shared block-service IOPS), which is why this engine degrades
//!   with scale.
//! * `RecordPrefetch` — BootSeer: the hot set (from the central
//!   `HotSetRegistry`) is bulk-prefetched peer-to-peer before container
//!   start; cold blocks stream in the background without gating the stage.
//!
//! Planners return one completion `TaskId` per node (stage end), plus the
//! background-streaming ids so tests can assert they don't gate the stage.
//! All bulk bytes move through the unified transfer plane
//! ([`crate::artifact::transfer`]): each engine picks a [`ProviderTier`]
//! (registry swarm, cache swarm, plain cache/registry egress) instead of
//! hand-building its own pools and flow paths.

use crate::artifact::transfer::{ProviderTier, TransferPlanner};
use crate::config::defaults as d;
use crate::config::{BootseerConfig, ImageMode};
use crate::image::access::HotSetRegistry;
use crate::image::spec::ImageSpec;
use crate::sim::{ClusterSim, NodeHandle, TaskId};
use crate::util::cast::u32_from_usize;

/// Result of planning the image-loading stage.
pub struct ImageLoadPlan {
    /// Per-node: task that marks "image stage done, container running".
    pub node_done: Vec<TaskId>,
    /// Background cold-block streaming tasks (BootSeer only) — run after
    /// stage completion and must not gate it.
    pub background: Vec<TaskId>,
    /// Bytes each node pulled before container start (for reporting).
    pub foreground_bytes_per_node: u64,
    /// Total foreground bytes the stage actually fetched over the network,
    /// summed across nodes — i.e. after subtracting prestaged/resident
    /// credit. Background cold-tail streaming is not included (it never
    /// gates the stage).
    pub fetched_bytes: u64,
}

/// Plan the image loading stage for every node of a job.
///
/// `deps[n]` (if provided) gates node n's first task (e.g. allocation done).
/// `tag` is attached to every node-done task.
pub fn plan_image_load(
    cs: &mut ClusterSim,
    img: &ImageSpec,
    cfg: &BootseerConfig,
    registry: &HotSetRegistry,
    deps: &[Vec<TaskId>],
    tag: u64,
) -> ImageLoadPlan {
    plan_image_load_with(cs, img, cfg, registry, deps, &[], tag)
}

/// [`plan_image_load`] with per-node bytes already staged by speculative
/// prefetch during the Allocation phase (`prestaged`, empty → none): staged
/// bytes are subtracted from the foreground fetch on each node.
pub fn plan_image_load_with(
    cs: &mut ClusterSim,
    img: &ImageSpec,
    cfg: &BootseerConfig,
    registry: &HotSetRegistry,
    deps: &[Vec<TaskId>],
    prestaged: &[u64],
    tag: u64,
) -> ImageLoadPlan {
    assert!(deps.is_empty() || deps.len() == cs.nodes());
    assert!(prestaged.is_empty() || prestaged.len() == cs.nodes());
    match cfg.image_mode {
        ImageMode::OciFull => plan_oci_full(cs, img, cfg, deps, prestaged, tag),
        ImageMode::Lazy => plan_lazy(cs, img, deps, prestaged, tag),
        ImageMode::RecordPrefetch => {
            // First-ever use of the image: no hot-set record exists yet, so
            // BootSeer falls back to lazy loading (the record run).
            if registry.has_record(img.digest) {
                plan_prefetch(cs, img, cfg, registry, deps, prestaged, tag)
            } else {
                plan_lazy(cs, img, deps, prestaged, tag)
            }
        }
    }
}

/// Node `i`'s gating dependencies (empty `deps` means no gates).
fn dep_of<'a>(deps: &'a [Vec<TaskId>], i: usize) -> &'a [TaskId] {
    if deps.is_empty() {
        &[]
    } else {
        &deps[i]
    }
}

/// Bytes already staged on node `i` (empty or short `prestaged` means
/// none). Also used by `env::installer` — one definition of the
/// empty-means-none convention.
pub(crate) fn staged_of(prestaged: &[u64], i: usize) -> u64 {
    prestaged.get(i).copied().unwrap_or(0)
}

fn plan_oci_full(
    cs: &mut ClusterSim,
    img: &ImageSpec,
    cfg: &BootseerConfig,
    deps: &[Vec<TaskId>],
    prestaged: &[u64],
    tag: u64,
) -> ImageLoadPlan {
    let n = cs.nodes();
    let mut node_done = Vec::with_capacity(n);
    let mut fetched = 0u64;
    // One download per node crosses the pool; scoped so the pool's slot is
    // recycled once the last node's pull completes.
    let tier = if cfg.p2p { ProviderTier::RegistrySwarm } else { ProviderTier::Registry };
    let provider = TransferPlanner::build(cs, "img.swarm", tier, n as u32, n as u32);
    for i in 0..n {
        let h = NodeHandle::new(i);
        let gate = dep_of(deps, i);
        let bytes = img.total_bytes.saturating_sub(staged_of(prestaged, i));
        fetched += bytes;
        let dl = provider.fetch(cs, h, bytes as f64, gate, 0);
        // Layered-OCI decompress + unpack: CPU-bound, ~180 MB/s per node
        // (always over the full image; staged bytes still need unpacking).
        let unpack = cs
            .sim
            .delay(cs.cpu_time(h, img.total_bytes as f64 / d::OCI_UNPACK_BPS), &[dl], 0);
        let start = cs.sim.delay(cs.cpu_time(h, d::CONTAINER_START_S), &[unpack], tag);
        node_done.push(start);
    }
    ImageLoadPlan {
        node_done,
        background: Vec::new(),
        foreground_bytes_per_node: img.total_bytes,
        fetched_bytes: fetched,
    }
}

fn plan_lazy(
    cs: &mut ClusterSim,
    img: &ImageSpec,
    deps: &[Vec<TaskId>],
    prestaged: &[u64],
    tag: u64,
) -> ImageLoadPlan {
    let n = cs.nodes();
    let hot_blocks = img.startup_access.len() as u32;
    let hot_total = img.hot_bytes();
    let hot_bytes = hot_total as f64;
    let batches = ((hot_blocks + d::LAZY_MISS_BATCH_BLOCKS - 1) / d::LAZY_MISS_BATCH_BLOCKS).max(1);
    let blocks_per_batch = hot_blocks as f64 / batches as f64;
    let bytes_per_batch = hot_bytes / batches as f64;
    // Shared block-service IOPS queueing: per-miss latency grows with the
    // number of concurrently-faulting nodes, saturating once the (scaled-
    // out) block cache's instance count catches up.
    let contention = 1.0 + d::LAZY_CONTENTION_PENALTY * ((n as f64 - 1.0).min(31.0));
    let mut node_done = Vec::with_capacity(n);
    let mut fetched = 0u64;
    // On-demand misses are served by the cluster block cache.
    let provider = TransferPlanner::build(cs, "img.lazy", ProviderTier::ClusterCache, 0, 0);
    for i in 0..n {
        // Staged bytes are already local, so that fraction of the startup
        // reads never faults (a multiply by exactly 1.0 when nothing is
        // staged, keeping the unstaged path bit-identical).
        let frac = if hot_bytes > 0.0 {
            (hot_bytes - staged_of(prestaged, i) as f64).max(0.0) / hot_bytes
        } else {
            1.0
        };
        fetched += hot_total.saturating_sub(staged_of(prestaged, i));
        // Container starts immediately against the lazy mount...
        let h = NodeHandle::new(i);
        let start = cs.sim.delay(cs.cpu_time(h, d::CONTAINER_START_S), dep_of(deps, i), 0);
        // ...then faults in the hot set: `batches` sequential miss bursts.
        let mut prev = start;
        for _ in 0..batches {
            let miss_lat =
                cs.cpu_time(h, d::LAZY_MISS_LATENCY_S) * blocks_per_batch * contention * frac;
            let lat = cs.sim.delay(miss_lat, &[prev], 0);
            prev = provider.fetch(cs, h, bytes_per_batch * frac, &[lat], 0);
        }
        // Stage ends when startup reads are all served.
        node_done.push(cs.sim.barrier(&[prev], tag));
    }
    ImageLoadPlan {
        node_done,
        background: Vec::new(),
        foreground_bytes_per_node: hot_total,
        fetched_bytes: fetched,
    }
}

fn plan_prefetch(
    cs: &mut ClusterSim,
    img: &ImageSpec,
    cfg: &BootseerConfig,
    registry: &HotSetRegistry,
    deps: &[Vec<TaskId>],
    prestaged: &[u64],
    tag: u64,
) -> ImageLoadPlan {
    let n = cs.nodes();
    let hot: Vec<u32> = registry.lookup(img.digest).expect("record exists");
    let hot_bytes: u64 = hot.iter().map(|&b| img.block_len(b)).sum();
    let cold_bytes = img.total_bytes - hot_bytes;
    // Hot set is distributed peer-to-peer (or straight from the cache).
    // Every node runs one foreground prefetch and, when cold bytes exist,
    // one background stream — the pool's exact flow count, after which its
    // slot is recycled.
    let swarm_uses = u32_from_usize(n) + if cold_bytes > 0 { u32_from_usize(n) } else { 0 };
    let tier = if cfg.p2p { ProviderTier::CacheSwarm } else { ProviderTier::ClusterCache };
    let provider =
        TransferPlanner::build(cs, "img.prefetch.swarm", tier, u32_from_usize(n), swarm_uses);
    let mut node_done = Vec::with_capacity(n);
    let mut background = Vec::with_capacity(n);
    let mut fetched = 0u64;
    for i in 0..n {
        let h = NodeHandle::new(i);
        let gate = dep_of(deps, i);
        let fg_bytes = hot_bytes.saturating_sub(staged_of(prestaged, i));
        fetched += fg_bytes;
        let prefetch = provider.fetch(cs, h, fg_bytes as f64, gate, 0);
        let start = cs.sim.delay(cs.cpu_time(h, d::CONTAINER_START_S), &[prefetch], tag);
        node_done.push(start);
        // Cold blocks stream in the background after container start. The
        // thread count bounds per-node background rate: 8 threads ≈ 8
        // concurrent range-reads; we model the aggregate as one flow whose
        // rate the fair-share engine bounds via pool + NIC. It must NOT
        // gate `node_done`.
        if cold_bytes > 0 {
            background.push(provider.fetch(cs, h, cold_bytes as f64, &[start], 0));
        }
    }
    ImageLoadPlan {
        node_done,
        background,
        foreground_bytes_per_node: hot_bytes,
        fetched_bytes: fetched,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BootseerConfig, ClusterConfig};
    use crate::image::access::AccessRecorder;

    fn setup(nodes: u32) -> (ClusterSim, ImageSpec, HotSetRegistry) {
        let cs = ClusterSim::build(&ClusterConfig::with_nodes(nodes), 42);
        let img = ImageSpec::synth(1, d::PAPER_IMAGE_BYTES, d::IMAGE_BLOCK_BYTES, 0.07);
        let mut reg = HotSetRegistry::new(d::PAPER_RECORD_WINDOW_S);
        // Pretend a prior run recorded the true startup access set.
        let mut rec = AccessRecorder::new();
        for (k, &b) in img.startup_access.iter().enumerate() {
            rec.record(b, k as f64 * 0.05);
        }
        reg.upload(img.digest, &rec);
        (cs, img, reg)
    }

    /// Run a plan to completion; return (stage_end_max, per-node times).
    fn run_stage(cs: &mut ClusterSim, plan: &ImageLoadPlan) -> (f64, Vec<f64>) {
        cs.sim.run();
        let times: Vec<f64> =
            plan.node_done.iter().map(|&t| cs.sim.finished_at(t)).collect();
        (times.iter().copied().fold(0.0, f64::max), times)
    }

    #[test]
    fn lazy_baseline_in_paper_band_at_16_gpus() {
        // 16 GPUs = 2 nodes: paper says lazy image stage is 20–40 s.
        let (mut cs, img, reg) = setup(2);
        let plan =
            plan_image_load(&mut cs, &img, &BootseerConfig::baseline(), &reg, &[], 1);
        let (t, _) = run_stage(&mut cs, &plan);
        assert!((15.0..60.0).contains(&t), "lazy stage at 2 nodes: {t}");
    }

    #[test]
    fn prefetch_beats_lazy_4x_to_10x() {
        for nodes in [2u32, 16] {
            let (mut cs, img, reg) = setup(nodes);
            let plan =
                plan_image_load(&mut cs, &img, &BootseerConfig::baseline(), &reg, &[], 1);
            let (lazy_t, _) = run_stage(&mut cs, &plan);

            let (mut cs2, img2, reg2) = setup(nodes);
            let plan2 =
                plan_image_load(&mut cs2, &img2, &BootseerConfig::bootseer(), &reg2, &[], 1);
            let (boot_t, _) = run_stage(&mut cs2, &plan2);
            let ratio = lazy_t / boot_t;
            assert!(
                (2.0..20.0).contains(&ratio),
                "nodes={nodes} lazy={lazy_t} boot={boot_t} ratio={ratio}"
            );
        }
    }

    #[test]
    fn lazy_degrades_with_scale_prefetch_flat() {
        let lazy_at = |nodes: u32| {
            let (mut cs, img, reg) = setup(nodes);
            let plan =
                plan_image_load(&mut cs, &img, &BootseerConfig::baseline(), &reg, &[], 1);
            run_stage(&mut cs, &plan).0
        };
        let boot_at = |nodes: u32| {
            let (mut cs, img, reg) = setup(nodes);
            let plan =
                plan_image_load(&mut cs, &img, &BootseerConfig::bootseer(), &reg, &[], 1);
            run_stage(&mut cs, &plan).0
        };
        assert!(lazy_at(16) > lazy_at(2) * 1.5, "lazy should degrade with scale");
        let (b2, b16) = (boot_at(2), boot_at(16));
        assert!(b16 < b2 * 1.6, "bootseer should stay ~flat: {b2} vs {b16}");
    }

    #[test]
    fn first_use_falls_back_to_lazy() {
        let (mut cs, img, _) = setup(2);
        let empty_reg = HotSetRegistry::new(d::PAPER_RECORD_WINDOW_S);
        let plan =
            plan_image_load(&mut cs, &img, &BootseerConfig::bootseer(), &empty_reg, &[], 1);
        // Fallback means no background streaming tasks.
        assert!(plan.background.is_empty());
        assert_eq!(plan.foreground_bytes_per_node, img.hot_bytes());
    }

    #[test]
    fn background_does_not_gate_stage() {
        let (mut cs, img, reg) = setup(4);
        let plan =
            plan_image_load(&mut cs, &img, &BootseerConfig::bootseer(), &reg, &[], 1);
        assert_eq!(plan.background.len(), 4);
        let (stage_end, _) = run_stage(&mut cs, &plan);
        for &bg in &plan.background {
            assert!(cs.sim.finished_at(bg) >= stage_end);
        }
        // Whole image eventually lands on every node.
        let total_fg_bg = plan.foreground_bytes_per_node
            + (img.total_bytes - plan.foreground_bytes_per_node);
        assert_eq!(total_fg_bg, img.total_bytes);
    }

    #[test]
    fn oci_full_much_slower_than_lazy() {
        let (mut cs, img, reg) = setup(4);
        let plan =
            plan_image_load(&mut cs, &img, &BootseerConfig::oci_strawman(), &reg, &[], 1);
        let (oci_t, _) = run_stage(&mut cs, &plan);
        let (mut cs2, img2, reg2) = setup(4);
        let plan2 =
            plan_image_load(&mut cs2, &img2, &BootseerConfig::baseline(), &reg2, &[], 1);
        let (lazy_t, _) = run_stage(&mut cs2, &plan2);
        // §4.2: block-level lazy loading achieves "up to 10x" over OCI.
        assert!(oci_t > lazy_t * 3.0, "oci {oci_t} vs lazy {lazy_t}");
        assert!(oci_t < lazy_t * 20.0, "oci {oci_t} vs lazy {lazy_t}");
        assert_eq!(plan.foreground_bytes_per_node, img.total_bytes);
    }

    #[test]
    fn prestaged_bytes_shrink_foreground() {
        // Speculative staging: half the hot set already local → the stage's
        // own fetch shrinks, for the prefetch and the lazy engines alike.
        for cfg in [BootseerConfig::bootseer(), BootseerConfig::baseline()] {
            let (mut cs, img, reg) = setup(2);
            let plan = plan_image_load(&mut cs, &img, &cfg, &reg, &[], 1);
            let (t_full, _) = run_stage(&mut cs, &plan);

            let (mut cs2, img2, reg2) = setup(2);
            let staged = vec![img2.hot_bytes() / 2; 2];
            let plan2 =
                plan_image_load_with(&mut cs2, &img2, &cfg, &reg2, &[], &staged, 1);
            let (t_half, _) = run_stage(&mut cs2, &plan2);
            assert!(t_half < t_full, "{}: {t_half} vs {t_full}", cfg.image_mode.name());
        }
    }

    #[test]
    fn fetched_bytes_counts_foreground_after_credit() {
        let (mut cs, img, reg) = setup(4);
        let plan = plan_image_load(&mut cs, &img, &BootseerConfig::bootseer(), &reg, &[], 1);
        assert_eq!(plan.fetched_bytes, 4 * img.hot_bytes());
        // Prestaged credit shrinks the fetch, per node.
        let (mut cs2, img2, reg2) = setup(4);
        let staged = vec![img2.hot_bytes() / 2; 4];
        let plan2 = plan_image_load_with(
            &mut cs2,
            &img2,
            &BootseerConfig::bootseer(),
            &reg2,
            &[],
            &staged,
            1,
        );
        assert_eq!(plan2.fetched_bytes, 4 * (img2.hot_bytes() - img2.hot_bytes() / 2));
        // The lazy engine accounts the same way.
        let (mut cs3, img3, reg3) = setup(2);
        let staged3 = vec![img3.hot_bytes(); 2];
        let plan3 = plan_image_load_with(
            &mut cs3,
            &img3,
            &BootseerConfig::baseline(),
            &reg3,
            &[],
            &staged3,
            1,
        );
        assert_eq!(plan3.fetched_bytes, 0);
    }

    #[test]
    fn empty_prestage_is_identical() {
        let (mut cs, img, reg) = setup(4);
        let plan = plan_image_load(&mut cs, &img, &BootseerConfig::bootseer(), &reg, &[], 1);
        let (t_a, times_a) = run_stage(&mut cs, &plan);
        let (mut cs2, img2, reg2) = setup(4);
        let plan2 = plan_image_load_with(
            &mut cs2,
            &img2,
            &BootseerConfig::bootseer(),
            &reg2,
            &[],
            &[0, 0, 0, 0],
            1,
        );
        let (t_b, times_b) = run_stage(&mut cs2, &plan2);
        assert_eq!(t_a.to_bits(), t_b.to_bits());
        for (a, b) in times_a.iter().zip(&times_b) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn deps_gate_stage_start() {
        let (mut cs, img, reg) = setup(2);
        let gate = cs.sim.delay(100.0, &[], 0);
        let deps = vec![vec![gate], vec![gate]];
        let plan =
            plan_image_load(&mut cs, &img, &BootseerConfig::bootseer(), &reg, &deps, 1);
        let (t, times) = run_stage(&mut cs, &plan);
        assert!(t > 100.0);
        assert!(times.iter().all(|&t| t > 100.0));
    }
}
