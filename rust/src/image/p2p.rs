//! Peer-to-peer block distribution model (§4.2).
//!
//! When N nodes pull the same bytes concurrently, BootSeer serves blocks
//! peer-to-peer so the origin (registry / cluster cache) ships roughly one
//! copy and peers exchange the rest. We model the swarm fluidly: a shared
//! *pool* resource whose capacity is the steady-state aggregate service
//! rate of a swarm —
//!
//! `pool = origin_egress + N * nic_up / 2`
//!
//! (each peer can dedicate ~half its NIC to uploads while downloading), and
//! each node's download flows through `[pool, own NIC]`. This reproduces
//! the two regimes that matter: small swarms are origin-bound, large swarms
//! are NIC-bound — i.e. per-node time stays ~flat as the job scales, which
//! is exactly the behaviour §5.3 reports for BootSeer's image stage.

use crate::sim::engine::{Capacity, FluidSim, ResourceId, TaskId};

/// A P2P distribution group for one content set (image hot set, env cache).
pub struct Swarm {
    pub pool: ResourceId,
    pub n_peers: u32,
    pub origin_bps: f64,
    pub nic_bps: f64,
    /// The steady-state pool capacity `build()` registered. The analytic
    /// lower bound reads this same field, so the model and its bound can
    /// never drift apart (they used to be two hand-synced copies of the
    /// formula).
    pub pool_bps: f64,
}

impl Swarm {
    /// Register the swarm pool resource on `sim`.
    pub fn build(
        sim: &mut FluidSim,
        name: &str,
        origin_bps: f64,
        n_peers: u32,
        nic_bps: f64,
    ) -> Swarm {
        let pool_bps = Self::pool_capacity(origin_bps, n_peers, nic_bps);
        let pool = sim.add_resource(name, Capacity::Fixed(pool_bps));
        Swarm { pool, n_peers, origin_bps, nic_bps, pool_bps }
    }

    /// [`Self::build`] with a *scoped* pool: the resource retires (and its
    /// slot recycles) after exactly `uses` downloads have completed
    /// through it. Planners know their download count up front, so their
    /// per-plan pools no longer accrete in the resource table.
    pub fn build_scoped(
        sim: &mut FluidSim,
        name: &str,
        origin_bps: f64,
        n_peers: u32,
        nic_bps: f64,
        uses: u32,
    ) -> Swarm {
        let pool_bps = Self::pool_capacity(origin_bps, n_peers, nic_bps);
        let pool = sim.add_resource_scoped(name, Capacity::Fixed(pool_bps), uses);
        Swarm { pool, n_peers, origin_bps, nic_bps, pool_bps }
    }

    /// The steady-state aggregate service rate of the swarm — computed in
    /// exactly one place.
    fn pool_capacity(origin_bps: f64, n_peers: u32, nic_bps: f64) -> f64 {
        origin_bps + n_peers as f64 * nic_bps / 2.0
    }

    /// One node's download of `bytes` through the swarm.
    pub fn download(
        &self,
        sim: &mut FluidSim,
        bytes: f64,
        node_nic: ResourceId,
        deps: &[TaskId],
        tag: u64,
    ) -> TaskId {
        sim.flow(bytes, vec![self.pool, node_nic], deps, tag)
    }

    /// Analytic lower bound on swarm completion (for tests): every node
    /// needs `bytes`, aggregate capacity is the pool the sim actually
    /// enforces ([`Self::pool_bps`]), per-node cap is the NIC.
    pub fn lower_bound_s(&self, bytes: f64) -> f64 {
        (bytes / self.nic_bps).max(self.n_peers as f64 * bytes / self.pool_bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sim::engine::Capacity;
    use crate::util::prop::{close, prop_check};

    /// Build a sim with n nodes of `nic` bps and run a swarm download.
    fn run_swarm(n: u32, nic: f64, origin: f64, bytes: f64) -> f64 {
        let mut sim = FluidSim::new();
        let nics: Vec<ResourceId> =
            (0..n).map(|i| sim.add_resource(&format!("nic{i}"), Capacity::Fixed(nic))).collect();
        let swarm = Swarm::build(&mut sim, "swarm", origin, n, nic);
        for (i, &nr) in nics.iter().enumerate() {
            swarm.download(&mut sim, bytes, nr, &[], i as u64);
        }
        sim.run();
        sim.now()
    }

    #[test]
    fn small_swarm_origin_bound() {
        // 2 peers, slow origin: pool = 10 + 2*100/2 = 110, NICs 100 each →
        // each gets 55 B/s (pool-bound).
        let t = run_swarm(2, 100.0, 10.0, 550.0);
        assert!(close(t, 10.0, 1e-9), "t={t}");
    }

    #[test]
    fn large_swarm_nic_bound() {
        // Many peers: per-node rate ≈ nic/2: pool = 10 + 64*100/2 = 3210
        // over 64 flows = 50.156 B/s each (NIC no longer the constraint).
        let t = run_swarm(64, 100.0, 10.0, 502.0);
        assert!(close(t, 502.0 / (3210.0 / 64.0), 1e-9), "t={t}");
    }

    #[test]
    fn scaling_is_flat() {
        // The BootSeer property: per-node download time roughly constant in
        // swarm size (within 2x across 4 → 256 peers).
        let t4 = run_swarm(4, 100.0, 1000.0, 1000.0);
        let t256 = run_swarm(256, 100.0, 1000.0, 1000.0);
        assert!(t256 < t4 * 2.0, "t4={t4} t256={t256}");
    }

    #[test]
    fn lower_bound_matches_built_pool() {
        // The bound must read the exact capacity build() registered on the
        // sim — one formula, one place.
        let mut sim = FluidSim::new();
        let sw = Swarm::build(&mut sim, "s", 123.0, 17, 456.0);
        let registered = match sim.capacity(sw.pool) {
            Capacity::Fixed(c) => *c,
            _ => panic!("swarm pool must be Fixed"),
        };
        assert_eq!(registered.to_bits(), sw.pool_bps.to_bits());
        assert_eq!(
            sw.lower_bound_s(1000.0).to_bits(),
            (1000.0f64 / 456.0).max(17.0 * 1000.0 / sw.pool_bps).to_bits()
        );
        // Scoped build registers the same capacity.
        let sw2 = Swarm::build_scoped(&mut sim, "s2", 123.0, 17, 456.0, 17);
        let registered2 = match sim.capacity(sw2.pool) {
            Capacity::Fixed(c) => *c,
            _ => panic!("swarm pool must be Fixed"),
        };
        assert_eq!(registered2.to_bits(), sw.pool_bps.to_bits());
    }

    #[test]
    fn scoped_pool_retires_after_declared_downloads() {
        let mut sim = FluidSim::new();
        let nics: Vec<ResourceId> =
            (0..4).map(|i| sim.add_resource(&format!("nic{i}"), Capacity::Fixed(100.0))).collect();
        let sw = Swarm::build_scoped(&mut sim, "swarm", 50.0, 4, 100.0, 4);
        for (i, &nic) in nics.iter().enumerate() {
            sw.download(&mut sim, 500.0, nic, &[], i as u64);
        }
        sim.run();
        let slots = sim.resource_slots();
        // The pool slot is free again: a fresh resource reuses it.
        let fresh = sim.add_resource("fresh", Capacity::Fixed(1.0));
        assert_eq!(fresh.0, sw.pool.0);
        assert_eq!(sim.resource_slots(), slots);
    }

    #[test]
    fn lower_bound_holds() {
        prop_check(20, |g| {
            let n = g.usize_in(1, 64) as u32;
            let nic = g.f64_in(10.0, 1000.0);
            let origin = g.f64_in(10.0, 1000.0);
            let bytes = g.f64_in(100.0, 10_000.0);
            let t = run_swarm(n, nic, origin, bytes);
            let mut sim = FluidSim::new();
            let sw = Swarm::build(&mut sim, "x", origin, n, nic);
            prop_assert!(
                t >= sw.lower_bound_s(bytes) - 1e-6,
                "t={} lb={}",
                t,
                sw.lower_bound_s(bytes)
            );
            Ok(())
        });
    }
}
