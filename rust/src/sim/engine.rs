//! Fluid-flow discrete-event simulator.
//!
//! This is the substrate every cluster-scale experiment runs on. The model:
//!
//! * **Resources** are capacity-constrained pipes (a node NIC, the registry's
//!   aggregate egress, the SCM backend, an HDFS DataNode group, a local
//!   disk). Capacity can be *fixed* or *throttled* (effective capacity
//!   degrades once concurrency exceeds a threshold — the §3.4 SCM rate-limit
//!   collapse).
//! * **Tasks** are either `Delay` (pure time: CPU work, health checks,
//!   container start) or `Flow` (move N bytes across a set of resources; the
//!   flow's rate is its max-min fair share across every resource it
//!   touches).
//! * Tasks declare dependencies; the engine runs the resulting DAG, sharing
//!   bandwidth among concurrently-active flows by progressive filling
//!   (water-filling max-min fairness), recomputing allocations whenever the
//!   active set changes.
//!
//! The engine yields one completion at a time so callers can inject new
//! tasks mid-simulation (lazy-loading misses, SCM retries, barrier fan-out).
//! Everything is deterministic: ties are broken by task id.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// f64 ordered for the delay heap via `total_cmp` (delays are always
/// finite and non-negative, so the total order agrees with the numeric
/// order). All four comparison traits are derived from the same total
/// order to keep them consistent.
struct OrdF64(f64);
impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Index of a resource registered with the simulator.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub usize);

/// Index of a task.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// Capacity policy of a resource.
#[derive(Clone, Debug)]
pub enum Capacity {
    /// Fixed aggregate capacity in bytes/s.
    Fixed(f64),
    /// Rate-limited service: full capacity up to `threshold` concurrent
    /// flows, past which effective capacity shrinks as
    /// `base / (1 + penalty * (n - threshold))` — the throughput *collapse*
    /// (not just saturation) seen when >1,000 nodes hammer an SCM backend.
    Throttled { base: f64, threshold: u32, penalty: f64 },
}

impl Capacity {
    fn effective(&self, n_flows: usize) -> f64 {
        match *self {
            Capacity::Fixed(c) => c,
            Capacity::Throttled { base, threshold, penalty } => {
                if n_flows as u32 <= threshold {
                    base
                } else {
                    base / (1.0 + penalty * (n_flows as u32 - threshold) as f64)
                }
            }
        }
    }
}

#[derive(Clone, Debug)]
struct Resource {
    cap: Capacity,
    /// Active flows currently crossing this resource.
    active: Vec<TaskId>,
    #[allow(dead_code)]
    name: String,
}

/// What a task does once its dependencies are satisfied.
#[derive(Clone, Debug)]
pub enum Work {
    /// Fixed wall-clock duration in seconds (CPU, disk seek, barrier glue).
    Delay(f64),
    /// Transfer `bytes` across all of `path`; rate = max-min fair share.
    Flow { bytes: f64, path: Vec<ResourceId> },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TaskState {
    /// Waiting on `deps_left` dependencies.
    Blocked,
    /// Running (delay ticking or flow transferring).
    Active,
    Done,
}

#[derive(Clone, Debug)]
struct Task {
    work: Work,
    state: TaskState,
    deps_left: usize,
    /// Tasks to notify on completion.
    dependents: Vec<TaskId>,
    /// For Delay: absolute completion time. For Flow: bytes remaining.
    remaining: f64,
    /// Current fair-share rate (flows only).
    rate: f64,
    /// Opaque caller tag for dispatch on completion.
    pub tag: u64,
    /// Completion timestamp (set when done).
    finished_at: f64,
}

/// A completion event handed back to the caller.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub task: TaskId,
    pub time: f64,
    pub tag: u64,
}

/// The simulator.
pub struct FluidSim {
    now: f64,
    resources: Vec<Resource>,
    tasks: Vec<Task>,
    /// Active flow task ids (subset of tasks).
    active_flows: Vec<TaskId>,
    /// Pending delay completions (min-heap by absolute time; entries are
    /// never invalidated — delays cannot be cancelled).
    delay_heap: BinaryHeap<Reverse<(OrdF64, TaskId)>>,
    rates_dirty: bool,
    /// Statistics: total bytes moved per resource.
    bytes_through: Vec<f64>,
    // Reusable scratch for recompute_rates (perf: avoid per-event allocs).
    scr_rem_cap: Vec<f64>,
    scr_unset_on: Vec<u32>,
    scr_touched: Vec<usize>,
}

impl FluidSim {
    pub fn new() -> FluidSim {
        FluidSim {
            now: 0.0,
            resources: Vec::new(),
            tasks: Vec::new(),
            active_flows: Vec::new(),
            delay_heap: BinaryHeap::new(),
            rates_dirty: false,
            bytes_through: Vec::new(),
            scr_rem_cap: Vec::new(),
            scr_unset_on: Vec::new(),
            scr_touched: Vec::new(),
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Register a resource; returns its id.
    pub fn add_resource(&mut self, name: &str, cap: Capacity) -> ResourceId {
        self.resources.push(Resource { cap, active: Vec::new(), name: name.to_string() });
        self.bytes_through.push(0.0);
        ResourceId(self.resources.len() - 1)
    }

    /// Number of flows currently crossing `r` (pipelines use this to model
    /// admission-time rejection under overload).
    pub fn concurrency(&self, r: ResourceId) -> usize {
        self.resources[r.0].active.len()
    }

    /// Total bytes that have crossed `r` so far.
    pub fn bytes_through(&self, r: ResourceId) -> f64 {
        self.bytes_through[r.0]
    }

    /// Add a task with dependencies. `tag` is returned in its Completion.
    pub fn add_task(&mut self, work: Work, deps: &[TaskId], tag: u64) -> TaskId {
        let id = TaskId(self.tasks.len());
        let mut deps_left = 0;
        for &d in deps {
            debug_assert!(d.0 < self.tasks.len(), "dependency on unknown task");
            if self.tasks[d.0].state != TaskState::Done {
                self.tasks[d.0].dependents.push(id);
                deps_left += 1;
            }
        }
        let remaining = match &work {
            Work::Delay(d) => {
                assert!(*d >= 0.0 && d.is_finite(), "bad delay {d}");
                *d
            }
            Work::Flow { bytes, path } => {
                assert!(*bytes >= 0.0 && bytes.is_finite(), "bad flow bytes {bytes}");
                assert!(!path.is_empty(), "flow with empty path");
                *bytes
            }
        };
        self.tasks.push(Task {
            work,
            state: TaskState::Blocked,
            deps_left,
            dependents: Vec::new(),
            remaining,
            rate: 0.0,
            tag,
            finished_at: f64::NAN,
        });
        if deps_left == 0 {
            self.activate(id);
        }
        id
    }

    /// Convenience: delay task.
    pub fn delay(&mut self, seconds: f64, deps: &[TaskId], tag: u64) -> TaskId {
        self.add_task(Work::Delay(seconds), deps, tag)
    }

    /// Convenience: flow task.
    pub fn flow(&mut self, bytes: f64, path: Vec<ResourceId>, deps: &[TaskId], tag: u64) -> TaskId {
        self.add_task(Work::Flow { bytes, path }, deps, tag)
    }

    /// Barrier: completes when all deps complete (zero-duration delay).
    pub fn barrier(&mut self, deps: &[TaskId], tag: u64) -> TaskId {
        self.add_task(Work::Delay(0.0), deps, tag)
    }

    fn activate(&mut self, id: TaskId) {
        let task = &mut self.tasks[id.0];
        debug_assert_eq!(task.state, TaskState::Blocked);
        task.state = TaskState::Active;
        match &task.work {
            Work::Delay(_) => {
                // remaining already holds the duration; convert to absolute.
                task.remaining += self.now;
                let t = task.remaining;
                self.delay_heap.push(Reverse((OrdF64(t), id)));
            }
            Work::Flow { path, .. } => {
                let path = path.clone();
                for r in path {
                    self.resources[r.0].active.push(id);
                }
                self.active_flows.push(id);
                self.rates_dirty = true;
            }
        }
    }

    /// Max-min fair-share allocation by progressive filling.
    ///
    /// Hot path (§Perf): dense per-resource scratch vectors reused across
    /// calls — no hashing, no per-round allocation. Complexity is
    /// O(rounds x touched_resources + total path length).
    fn recompute_rates(&mut self) {
        self.rates_dirty = false;
        let nf = self.active_flows.len();
        if nf == 0 {
            return;
        }
        let nr = self.resources.len();
        // Scratch: grow on demand, reset only touched entries at the end.
        self.scr_rem_cap.resize(nr, 0.0);
        self.scr_unset_on.resize(nr, 0);
        self.scr_touched.clear();
        for (ri, r) in self.resources.iter().enumerate() {
            if !r.active.is_empty() {
                self.scr_rem_cap[ri] = r.cap.effective(r.active.len());
                self.scr_unset_on[ri] = r.active.len() as u32;
                self.scr_touched.push(ri);
            }
        }
        // Mark all active flows unset (rate = NAN sentinel).
        for &t in &self.active_flows {
            self.tasks[t.0].rate = f64::NAN;
        }
        let mut unset = nf;
        while unset > 0 {
            // Bottleneck = min fair share among touched resources that
            // still carry unset flows (ties: lowest id, for determinism).
            let mut best: Option<(usize, f64)> = None;
            for &ri in &self.scr_touched {
                let n = self.scr_unset_on[ri];
                if n == 0 {
                    continue;
                }
                let fair = self.scr_rem_cap[ri] / n as f64;
                match best {
                    Some((bri, bfair)) => {
                        if fair < bfair || (fair == bfair && ri < bri) {
                            best = Some((ri, fair));
                        }
                    }
                    None => best = Some((ri, fair)),
                }
            }
            let Some((bottleneck, fair)) = best else { break };
            // Fix every unset flow crossing the bottleneck at `fair`.
            let mut fi = 0;
            while fi < self.resources[bottleneck].active.len() {
                let t = self.resources[bottleneck].active[fi];
                fi += 1;
                if !self.tasks[t.0].rate.is_nan() {
                    continue;
                }
                self.tasks[t.0].rate = fair;
                unset -= 1;
                // Subtract this flow's rate from every resource it crosses.
                let task_ptr = t.0;
                if let Work::Flow { path, .. } = &self.tasks[task_ptr].work {
                    for r in path {
                        let ri = r.0;
                        self.scr_rem_cap[ri] = (self.scr_rem_cap[ri] - fair).max(0.0);
                        self.scr_unset_on[ri] -= 1;
                    }
                }
            }
            self.scr_unset_on[bottleneck] = 0;
        }
        // Clear scratch for the touched entries (cheap partial reset) and
        // zero any still-unset flows (starved).
        for &ri in &self.scr_touched {
            self.scr_rem_cap[ri] = 0.0;
            self.scr_unset_on[ri] = 0;
        }
        for &t in &self.active_flows {
            if self.tasks[t.0].rate.is_nan() {
                self.tasks[t.0].rate = 0.0;
            }
        }
    }

    /// Advance to the next completion and return it, or `None` when idle.
    pub fn step(&mut self) -> Option<Completion> {
        if self.rates_dirty {
            self.recompute_rates();
        }
        // Earliest completion among delays and flows.
        let mut best: Option<(f64, TaskId)> =
            self.delay_heap.peek().map(|Reverse((t, id))| (t.0, *id));
        for &id in &self.active_flows {
            let task = &self.tasks[id.0];
            let t = if task.rate > 0.0 {
                self.now + task.remaining / task.rate
            } else if task.remaining <= 0.0 {
                self.now
            } else {
                f64::INFINITY // starved flow; cannot finish until rates change
            };
            let better = match best {
                None => true,
                Some((bt, bid)) => t < bt || (t == bt && id < bid),
            };
            if better {
                best = Some((t, id));
            }
        }
        let (time, id) = best?;
        assert!(
            time.is_finite(),
            "deadlock: active flow starved with no other progress possible"
        );
        let dt = time - self.now;
        debug_assert!(dt >= -1e-9, "time went backwards: {dt}");
        let dt = dt.max(0.0);
        // Progress all active flows by dt.
        if dt > 0.0 {
            for &fid in &self.active_flows {
                let rate = self.tasks[fid.0].rate;
                let moved = rate * dt;
                self.tasks[fid.0].remaining = (self.tasks[fid.0].remaining - moved).max(0.0);
                if let Work::Flow { path, .. } = &self.tasks[fid.0].work {
                    for r in path.clone() {
                        self.bytes_through[r.0] += moved;
                    }
                }
            }
        }
        self.now = time;
        self.complete(id);
        Some(Completion { task: id, time: self.now, tag: self.tasks[id.0].tag })
    }

    fn complete(&mut self, id: TaskId) {
        let is_flow = matches!(self.tasks[id.0].work, Work::Flow { .. });
        self.tasks[id.0].state = TaskState::Done;
        self.tasks[id.0].finished_at = self.now;
        if is_flow {
            self.active_flows.retain(|&t| t != id);
            if let Work::Flow { path, .. } = self.tasks[id.0].work.clone() {
                for r in path {
                    self.resources[r.0].active.retain(|&t| t != id);
                }
            }
            self.rates_dirty = true;
        } else {
            // Must be the heap top (completions come out in time order).
            let popped = self.delay_heap.pop().expect("delay heap empty");
            debug_assert_eq!(popped.0 .1, id);
        }
        let dependents = std::mem::take(&mut self.tasks[id.0].dependents);
        for dep in dependents {
            let t = &mut self.tasks[dep.0];
            t.deps_left -= 1;
            if t.deps_left == 0 && t.state == TaskState::Blocked {
                self.activate(dep);
            }
        }
    }

    /// Run everything to quiescence; returns all completions in order.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while let Some(c) = self.step() {
            out.push(c);
        }
        out
    }

    /// Completion time of a finished task.
    pub fn finished_at(&self, id: TaskId) -> f64 {
        let t = &self.tasks[id.0];
        assert_eq!(t.state, TaskState::Done, "task not finished");
        t.finished_at
    }

    /// True if the task has completed.
    pub fn is_done(&self, id: TaskId) -> bool {
        self.tasks[id.0].state == TaskState::Done
    }

    /// Number of tasks registered (for capacity planning in benches).
    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }
}

impl Default for FluidSim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{close, prop_check};

    #[test]
    fn single_flow_bandwidth_limited() {
        let mut sim = FluidSim::new();
        let nic = sim.add_resource("nic", Capacity::Fixed(100.0));
        let f = sim.flow(1000.0, vec![nic], &[], 0);
        sim.run();
        assert!(close(sim.finished_at(f), 10.0, 1e-9));
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("link", Capacity::Fixed(100.0));
        let a = sim.flow(500.0, vec![link], &[], 1);
        let b = sim.flow(500.0, vec![link], &[], 2);
        sim.run();
        // Equal shares: both finish at t=10 (50 B/s each).
        assert!(close(sim.finished_at(a), 10.0, 1e-9));
        assert!(close(sim.finished_at(b), 10.0, 1e-9));
    }

    #[test]
    fn short_flow_releases_bandwidth() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("link", Capacity::Fixed(100.0));
        let a = sim.flow(100.0, vec![link], &[], 1); // finishes at t=2 (50 B/s)
        let b = sim.flow(900.0, vec![link], &[], 2);
        sim.run();
        assert!(close(sim.finished_at(a), 2.0, 1e-9));
        // b: 100 bytes by t=2, then 800 at 100 B/s → t=10.
        assert!(close(sim.finished_at(b), 10.0, 1e-9));
    }

    #[test]
    fn bottleneck_is_min_across_path() {
        let mut sim = FluidSim::new();
        let fast = sim.add_resource("fast", Capacity::Fixed(1000.0));
        let slow = sim.add_resource("slow", Capacity::Fixed(10.0));
        let f = sim.flow(100.0, vec![fast, slow], &[], 0);
        sim.run();
        assert!(close(sim.finished_at(f), 10.0, 1e-9));
    }

    #[test]
    fn max_min_not_just_equal_split() {
        // Two flows share a 100 B/s service; one is also limited by a
        // 20 B/s NIC. Max-min: constrained flow gets 20, other gets 80.
        let mut sim = FluidSim::new();
        let svc = sim.add_resource("svc", Capacity::Fixed(100.0));
        let nic = sim.add_resource("nic", Capacity::Fixed(20.0));
        let slow = sim.flow(20.0, vec![svc, nic], &[], 1); // 1s at rate 20
        let fast = sim.flow(80.0, vec![svc], &[], 2); // 1s at rate 80
        sim.run();
        assert!(close(sim.finished_at(slow), 1.0, 1e-9));
        assert!(close(sim.finished_at(fast), 1.0, 1e-9));
    }

    #[test]
    fn delays_and_deps() {
        let mut sim = FluidSim::new();
        let a = sim.delay(5.0, &[], 1);
        let b = sim.delay(3.0, &[a], 2);
        let link = sim.add_resource("l", Capacity::Fixed(10.0));
        let c = sim.flow(20.0, vec![link], &[b], 3);
        sim.run();
        assert!(close(sim.finished_at(a), 5.0, 1e-9));
        assert!(close(sim.finished_at(b), 8.0, 1e-9));
        assert!(close(sim.finished_at(c), 10.0, 1e-9));
    }

    #[test]
    fn barrier_waits_for_all() {
        let mut sim = FluidSim::new();
        let a = sim.delay(1.0, &[], 0);
        let b = sim.delay(7.0, &[], 0);
        let c = sim.delay(3.0, &[], 0);
        let bar = sim.barrier(&[a, b, c], 9);
        sim.run();
        assert!(close(sim.finished_at(bar), 7.0, 1e-9));
    }

    #[test]
    fn dep_on_done_task_is_satisfied() {
        let mut sim = FluidSim::new();
        let a = sim.delay(1.0, &[], 0);
        sim.run();
        let b = sim.delay(1.0, &[a], 0);
        sim.run();
        assert!(close(sim.finished_at(b), 2.0, 1e-9));
    }

    #[test]
    fn throttled_capacity_collapses() {
        let cap = Capacity::Throttled { base: 100.0, threshold: 4, penalty: 0.5 };
        assert_eq!(cap.effective(4), 100.0);
        assert!(cap.effective(8) < 100.0 / 2.0); // 100/(1+0.5*4)=33.3
        assert!(close(cap.effective(8), 100.0 / 3.0, 1e-9));
    }

    #[test]
    fn throttled_service_slower_in_aggregate() {
        // 10 flows of 100 bytes through a throttled service (threshold 4):
        // finishing takes longer than untrottled 100 B/s would predict.
        let mut run = |cap: Capacity| {
            let mut sim = FluidSim::new();
            let svc = sim.add_resource("svc", cap);
            for i in 0..10 {
                sim.flow(100.0, vec![svc], &[], i);
            }
            sim.run();
            sim.now()
        };
        let fixed = run(Capacity::Fixed(100.0));
        let throttled =
            run(Capacity::Throttled { base: 100.0, threshold: 4, penalty: 0.5 });
        assert!(close(fixed, 10.0, 1e-9));
        assert!(throttled > 15.0, "throttled {throttled}");
    }

    #[test]
    fn injection_mid_run() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("l", Capacity::Fixed(10.0));
        sim.flow(100.0, vec![link], &[], 1);
        let c = sim.step().unwrap();
        assert_eq!(c.tag, 1);
        // Inject a new flow after the first finished.
        let f2 = sim.flow(50.0, vec![link], &[], 2);
        sim.run();
        assert!(close(sim.finished_at(f2), 15.0, 1e-9));
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("l", Capacity::Fixed(10.0));
        let f = sim.flow(0.0, vec![link], &[], 0);
        sim.run();
        assert!(close(sim.finished_at(f), 0.0, 1e-12));
    }

    #[test]
    fn bytes_accounting() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("l", Capacity::Fixed(10.0));
        sim.flow(30.0, vec![link], &[], 0);
        sim.flow(70.0, vec![link], &[], 1);
        sim.run();
        assert!(close(sim.bytes_through(link), 100.0, 1e-6));
    }

    // ---- property tests ----

    #[test]
    fn prop_conservation_and_capacity() {
        prop_check(60, |g| {
            let mut sim = FluidSim::new();
            let cap = g.f64_in(10.0, 1000.0);
            let link = sim.add_resource("l", Capacity::Fixed(cap));
            let n = g.usize_in(1, 20);
            let mut total = 0.0;
            for i in 0..n {
                let bytes = g.f64_in(1.0, 5000.0);
                total += bytes;
                sim.flow(bytes, vec![link], &[], i as u64);
            }
            sim.run();
            // Conservation: all bytes crossed the link.
            prop_assert!(close(sim.bytes_through(link), total, 1e-6));
            // Capacity: makespan >= total/cap (can't beat the pipe).
            prop_assert!(
                sim.now() >= total / cap - 1e-6,
                "makespan {} < {}",
                sim.now(),
                total / cap
            );
            Ok(())
        });
    }

    #[test]
    fn prop_equal_flows_finish_together() {
        prop_check(40, |g| {
            let mut sim = FluidSim::new();
            let link = sim.add_resource("l", Capacity::Fixed(g.f64_in(10.0, 100.0)));
            let n = g.usize_in(2, 16);
            let bytes = g.f64_in(10.0, 1000.0);
            let ids: Vec<TaskId> =
                (0..n).map(|i| sim.flow(bytes, vec![link], &[], i as u64)).collect();
            sim.run();
            let t0 = sim.finished_at(ids[0]);
            for &id in &ids[1..] {
                prop_assert!(close(sim.finished_at(id), t0, 1e-9));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dag_ordering_respected() {
        prop_check(40, |g| {
            let mut sim = FluidSim::new();
            let link = sim.add_resource("l", Capacity::Fixed(100.0));
            // Random chain of tasks; each must finish no earlier than its dep.
            let n = g.usize_in(2, 24);
            let mut prev: Option<TaskId> = None;
            let mut ids = Vec::new();
            for i in 0..n {
                let deps: Vec<TaskId> = prev.into_iter().collect();
                let id = if g.bool() {
                    sim.delay(g.f64_in(0.0, 5.0), &deps, i as u64)
                } else {
                    sim.flow(g.f64_in(1.0, 200.0), vec![link], &deps, i as u64)
                };
                ids.push(id);
                prev = Some(id);
            }
            sim.run();
            for w in ids.windows(2) {
                prop_assert!(sim.finished_at(w[1]) >= sim.finished_at(w[0]) - 1e-9);
            }
            Ok(())
        });
    }

    #[test]
    fn prop_more_bandwidth_never_slower() {
        prop_check(30, |g| {
            let n = g.usize_in(2, 12);
            let sizes: Vec<f64> = (0..n).map(|_| g.f64_in(10.0, 1000.0)).collect();
            let cap = g.f64_in(10.0, 100.0);
            let mk = |c: f64, sizes: &[f64]| {
                let mut sim = FluidSim::new();
                let link = sim.add_resource("l", Capacity::Fixed(c));
                for (i, &b) in sizes.iter().enumerate() {
                    sim.flow(b, vec![link], &[], i as u64);
                }
                sim.run();
                sim.now()
            };
            let slow = mk(cap, &sizes);
            let fast = mk(cap * 2.0, &sizes);
            prop_assert!(fast <= slow + 1e-9, "fast {fast} slow {slow}");
            Ok(())
        });
    }
}
