//! Fluid-flow discrete-event simulator.
//!
//! This is the substrate every cluster-scale experiment runs on. The model:
//!
//! * **Resources** are capacity-constrained pipes (a node NIC, the registry's
//!   aggregate egress, the SCM backend, an HDFS DataNode group, a local
//!   disk). Capacity can be *fixed* or *throttled* (effective capacity
//!   degrades once concurrency exceeds a threshold — the §3.4 SCM rate-limit
//!   collapse).
//! * **Tasks** are either `Delay` (pure time: CPU work, health checks,
//!   container start) or `Flow` (move N bytes across a set of resources; the
//!   flow's rate is its max-min fair share across every resource it
//!   touches).
//! * Tasks declare dependencies; the engine runs the resulting DAG, sharing
//!   bandwidth among concurrently-active flows by progressive filling
//!   (water-filling max-min fairness), recomputing allocations whenever the
//!   active set changes.
//!
//! The engine yields one completion at a time so callers can inject new
//! tasks mid-simulation (lazy-loading misses, SCM retries, barrier fan-out).
//! Everything is deterministic: ties are broken by task id.
//!
//! # Performance model (see `docs/sim_engine.md`)
//!
//! Per-event cost is bounded by the *active* set, never by the totals:
//!
//! * Flow completions are selected from a min-heap of completion deadlines
//!   with lazy invalidation: a deadline is computed once when a flow's rate
//!   is assigned and stays valid until that rate changes (a per-task epoch
//!   counter, bumped on rate recompute, invalidates superseded heap
//!   entries). Delay selection was already a heap. A pure-delay event is
//!   O(log n); nothing touches the other flows.
//! * Flows progress *lazily*: `remaining` is materialized only when a
//!   flow's rate changes (and finally at completion), not on every event.
//!   The old engine walked every active flow on every event to advance it.
//! * `recompute_rates` is component-local: progressive filling decomposes
//!   exactly over connected components of the flow↔resource graph, so a
//!   completion re-fills only the component reachable from the resources
//!   whose membership changed — with values identical to a global fill.
//! * Membership updates are swap-remove via per-task position indices
//!   (`active_flows`, each resource's active list, the active-resource
//!   set), O(path) per completion instead of O(active) `retain`s.
//! * Short-lived resources (per-read HDFS streams, per-plan swarm pools)
//!   are *scoped*: [`FluidSim::add_resource_scoped`] auto-retires them
//!   after a declared number of flow completions, and retired slots are
//!   recycled through a free list — the live resource table is O(active),
//!   not O(everything ever created).
//!
//! The pre-refactor engine is preserved verbatim as
//! [`crate::sim::reference::ReferenceSim`]; `sim::golden` drives both
//! engines through identical workloads to pin schedule equivalence, and
//! `micro_simnet` benchmarks the speedup against it.
//!
//! # Accounting
//!
//! `bytes_through` is settled when a flow's rate changes and when it
//! completes; every settlement is clamped to the flow's remaining bytes
//! and the completion credits the whole uncredited tail (the old engine
//! credited `rate * dt` even past the flow's remaining bytes,
//! overcounting). A flow settled only at completion credits its byte
//! count bit-exactly; one that settled at intermediate rate changes
//! credits it to within an ulp per settlement (the telescoped subtraction
//! rounds), which is what `prop_conservation_and_capacity` pins. Between
//! rate changes the counter lags the fluid position of in-flight flows by
//! design.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// f64 ordered for the event heaps via `total_cmp` (event times are always
/// finite and non-negative, so the total order agrees with the numeric
/// order). All four comparison traits are derived from the same total
/// order to keep them consistent.
#[derive(Clone, Copy)]
struct OrdF64(f64);
impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Index of a resource registered with the simulator.
///
/// With scoped/retired resources, ids are *recycled*: once a resource is
/// retired its id may be handed out again by a later `add_resource`. A
/// retired id must not be used afterwards (activation checks this).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub usize);

/// Index of a task.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// Capacity policy of a resource.
#[derive(Clone, Debug)]
pub enum Capacity {
    /// Fixed aggregate capacity in bytes/s.
    Fixed(f64),
    /// Rate-limited service: full capacity up to `threshold` concurrent
    /// flows, past which effective capacity shrinks as
    /// `base / (1 + penalty * (n - threshold))` — the throughput *collapse*
    /// (not just saturation) seen when >1,000 nodes hammer an SCM backend.
    Throttled { base: f64, threshold: u32, penalty: f64 },
}

impl Capacity {
    pub(crate) fn effective(&self, n_flows: usize) -> f64 {
        match *self {
            Capacity::Fixed(c) => c,
            Capacity::Throttled { base, threshold, penalty } => {
                if n_flows as u32 <= threshold {
                    base
                } else {
                    base / (1.0 + penalty * (n_flows as u32 - threshold) as f64)
                }
            }
        }
    }
}

/// Sentinel for "not a member of the dense set".
const NOT_ACTIVE: usize = usize::MAX;

#[derive(Clone, Debug)]
struct Resource {
    cap: Capacity,
    /// Active flows currently crossing this resource.
    active: Vec<TaskId>,
    /// Position in `FluidSim::active_resources` (NOT_ACTIVE when idle).
    active_pos: usize,
    /// `Some(n)`: scoped — auto-retire after `n` more flow completions.
    uses_left: Option<u32>,
    retired: bool,
    /// Queued in `dirty_res` for the next rate recompute.
    dirty: bool,
    #[allow(dead_code)]
    name: String,
}

/// What a task does once its dependencies are satisfied.
#[derive(Clone, Debug)]
pub enum Work {
    /// Fixed wall-clock duration in seconds (CPU, disk seek, barrier glue).
    Delay(f64),
    /// Transfer `bytes` across all of `path`; rate = max-min fair share.
    Flow { bytes: f64, path: Vec<ResourceId> },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TaskState {
    /// Waiting on `deps_left` dependencies.
    Blocked,
    /// Running (delay ticking or flow transferring).
    Active,
    Done,
}

#[derive(Clone, Debug)]
struct Task {
    is_flow: bool,
    /// Resources a flow crosses (empty for delays). Stored directly on the
    /// task — not behind the `Work` enum — so the hot loops (BFS, fill
    /// subtraction, materialization) iterate it without per-element enum
    /// matching.
    path: Vec<ResourceId>,
    state: TaskState,
    deps_left: usize,
    /// Tasks to notify on completion.
    dependents: Vec<TaskId>,
    /// For Delay: absolute completion time. For Flow: bytes remaining as of
    /// `anchor` (materialized lazily — see the module docs).
    remaining: f64,
    /// Current fair-share rate (flows only).
    rate: f64,
    /// Simulation time at which `remaining` was last materialized.
    anchor: f64,
    /// Epoch of this flow's live entry in the completion heap (0 = none).
    heap_epoch: u64,
    /// Position in `active_flows` while active (flows only).
    active_pos: usize,
    /// Position of this flow in each path resource's `active` list,
    /// parallel to `path` (flows only).
    res_pos: Vec<u32>,
    /// Opaque caller tag for dispatch on completion.
    tag: u64,
    /// Completion timestamp (set when done).
    finished_at: f64,
}

/// A completion event handed back to the caller.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub task: TaskId,
    pub time: f64,
    pub tag: u64,
}

/// The simulator.
pub struct FluidSim {
    now: f64,
    resources: Vec<Resource>,
    /// Retired resource slots available for reuse (LIFO).
    free_slots: Vec<usize>,
    tasks: Vec<Task>,
    /// Active flow task ids (dense set; swap-removed via `Task::active_pos`).
    active_flows: Vec<TaskId>,
    /// Resources with at least one active flow (dense set; swap-removed via
    /// `Resource::active_pos`).
    active_resources: Vec<usize>,
    /// Pending delay completions (min-heap by absolute time; entries are
    /// never invalidated — delays cannot be cancelled).
    delay_heap: BinaryHeap<Reverse<(OrdF64, TaskId)>>,
    /// Flow-completion deadlines `(deadline, id, epoch)` with lazy
    /// invalidation: an entry is live iff its epoch matches the task's
    /// `heap_epoch`.
    flow_heap: BinaryHeap<Reverse<(OrdF64, TaskId, u64)>>,
    /// Bumped on every rate recompute; stamps fresh heap entries.
    rate_epoch: u64,
    rates_dirty: bool,
    /// Resources whose active membership changed since the last recompute —
    /// the BFS seeds of the next component-local fill.
    dirty_res: Vec<usize>,
    /// Statistics: total bytes moved per resource (see module docs for the
    /// settlement discipline). Reset to zero when a retired slot is reused.
    bytes_through: Vec<f64>,
    // Reusable scratch (perf: avoid per-event allocs).
    scr_rem_cap: Vec<f64>,
    scr_unset_on: Vec<u32>,
    scr_comp_res: Vec<usize>,
    scr_comp_flows: Vec<TaskId>,
    scr_old_rate: Vec<f64>,
    /// BFS visit stamps (epoch-tagged so they never need clearing).
    res_seen: Vec<u64>,
    task_seen: Vec<u64>,
    bfs_epoch: u64,
}

impl FluidSim {
    pub fn new() -> FluidSim {
        FluidSim {
            now: 0.0,
            resources: Vec::new(),
            free_slots: Vec::new(),
            tasks: Vec::new(),
            active_flows: Vec::new(),
            active_resources: Vec::new(),
            delay_heap: BinaryHeap::new(),
            flow_heap: BinaryHeap::new(),
            rate_epoch: 0,
            rates_dirty: false,
            dirty_res: Vec::new(),
            bytes_through: Vec::new(),
            scr_rem_cap: Vec::new(),
            scr_unset_on: Vec::new(),
            scr_comp_res: Vec::new(),
            scr_comp_flows: Vec::new(),
            scr_old_rate: Vec::new(),
            res_seen: Vec::new(),
            task_seen: Vec::new(),
            bfs_epoch: 0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Register a resource; returns its id (possibly a recycled slot).
    pub fn add_resource(&mut self, name: &str, cap: Capacity) -> ResourceId {
        self.add_resource_inner(name, cap, None)
    }

    /// Register a *scoped* resource: after exactly `uses` flow completions
    /// have crossed it, it is retired automatically and its slot recycled.
    /// The declared count must cover every flow (present or future) whose
    /// path includes it — a scoped resource still carrying flows when its
    /// uses run out is a caller bug and panics.
    pub fn add_resource_scoped(&mut self, name: &str, cap: Capacity, uses: u32) -> ResourceId {
        assert!(uses > 0, "scoped resource with zero uses");
        self.add_resource_inner(name, cap, Some(uses))
    }

    fn add_resource_inner(&mut self, name: &str, cap: Capacity, uses: Option<u32>) -> ResourceId {
        if let Some(slot) = self.free_slots.pop() {
            let r = &mut self.resources[slot];
            debug_assert!(r.retired && r.active.is_empty());
            r.cap = cap;
            r.active_pos = NOT_ACTIVE;
            r.uses_left = uses;
            r.retired = false;
            // `dirty` is deliberately left as-is: it tracks membership in
            // `dirty_res`, which may still hold this slot from before
            // retirement.
            r.name.clear();
            r.name.push_str(name);
            self.bytes_through[slot] = 0.0;
            return ResourceId(slot);
        }
        self.resources.push(Resource {
            cap,
            active: Vec::new(),
            active_pos: NOT_ACTIVE,
            uses_left: uses,
            retired: false,
            dirty: false,
            name: name.to_string(),
        });
        self.bytes_through.push(0.0);
        self.res_seen.push(0);
        ResourceId(self.resources.len() - 1)
    }

    /// Explicitly retire a resource, recycling its slot. The resource must
    /// be idle and no live or future flow may reference its id afterwards.
    pub fn retire_resource(&mut self, r: ResourceId) {
        let res = &mut self.resources[r.0];
        assert!(!res.retired, "resource retired twice");
        assert!(res.active.is_empty(), "retiring a resource with active flows");
        res.retired = true;
        res.uses_left = None;
        self.free_slots.push(r.0);
    }

    /// Number of live (non-retired) resource slots plus free-listed ones —
    /// i.e. the size of the resource table. Scoped retirement keeps this
    /// O(active) in long-running simulations.
    pub fn resource_slots(&self) -> usize {
        self.resources.len()
    }

    /// Capacity policy of a resource (tests and planners introspect this).
    pub fn capacity(&self, r: ResourceId) -> &Capacity {
        &self.resources[r.0].cap
    }

    /// Number of flows currently crossing `r` (pipelines use this to model
    /// admission-time rejection under overload).
    pub fn concurrency(&self, r: ResourceId) -> usize {
        self.resources[r.0].active.len()
    }

    /// Total bytes that have crossed `r` so far. Settled at rate changes
    /// and (exactly) at flow completions; between rate changes the counter
    /// lags in-flight flows.
    pub fn bytes_through(&self, r: ResourceId) -> f64 {
        self.bytes_through[r.0]
    }

    fn mark_dirty(&mut self, ri: usize) {
        let r = &mut self.resources[ri];
        if !r.dirty {
            r.dirty = true;
            self.dirty_res.push(ri);
        }
    }

    /// Add a task with dependencies. `tag` is returned in its Completion.
    pub fn add_task(&mut self, work: Work, deps: &[TaskId], tag: u64) -> TaskId {
        let id = TaskId(self.tasks.len());
        let mut deps_left = 0;
        for &d in deps {
            debug_assert!(d.0 < self.tasks.len(), "dependency on unknown task");
            if self.tasks[d.0].state != TaskState::Done {
                self.tasks[d.0].dependents.push(id);
                deps_left += 1;
            }
        }
        let (is_flow, path, remaining) = match work {
            Work::Delay(d) => {
                assert!(d >= 0.0 && d.is_finite(), "bad delay {d}");
                (false, Vec::new(), d)
            }
            Work::Flow { bytes, path } => {
                assert!(bytes >= 0.0 && bytes.is_finite(), "bad flow bytes {bytes}");
                assert!(!path.is_empty(), "flow with empty path");
                // Hard error in every build profile: the swap-remove
                // position indices assume each resource appears once, and a
                // violation would otherwise surface as a confusing panic
                // deep inside complete(). Paths are short (≤ a handful), so
                // the pairwise scan is cheaper than a sort.
                for i in 1..path.len() {
                    for j in 0..i {
                        assert!(
                            path[i] != path[j],
                            "flow path lists resource {} twice",
                            path[i].0
                        );
                    }
                }
                (true, path, bytes)
            }
        };
        let res_pos = vec![0u32; path.len()];
        self.tasks.push(Task {
            is_flow,
            path,
            state: TaskState::Blocked,
            deps_left,
            dependents: Vec::new(),
            remaining,
            rate: 0.0,
            anchor: 0.0,
            heap_epoch: 0,
            active_pos: NOT_ACTIVE,
            res_pos,
            tag,
            finished_at: f64::NAN,
        });
        self.task_seen.push(0);
        if deps_left == 0 {
            self.activate(id);
        }
        id
    }

    /// Convenience: delay task.
    pub fn delay(&mut self, seconds: f64, deps: &[TaskId], tag: u64) -> TaskId {
        self.add_task(Work::Delay(seconds), deps, tag)
    }

    /// Convenience: flow task.
    pub fn flow(&mut self, bytes: f64, path: Vec<ResourceId>, deps: &[TaskId], tag: u64) -> TaskId {
        self.add_task(Work::Flow { bytes, path }, deps, tag)
    }

    /// Barrier: completes when all deps complete (zero-duration delay).
    pub fn barrier(&mut self, deps: &[TaskId], tag: u64) -> TaskId {
        self.add_task(Work::Delay(0.0), deps, tag)
    }

    fn activate(&mut self, id: TaskId) {
        debug_assert_eq!(self.tasks[id.0].state, TaskState::Blocked);
        self.tasks[id.0].state = TaskState::Active;
        if !self.tasks[id.0].is_flow {
            // remaining already holds the duration; convert to absolute.
            let task = &mut self.tasks[id.0];
            task.remaining += self.now;
            let t = task.remaining;
            self.delay_heap.push(Reverse((OrdF64(t), id)));
            return;
        }
        // `res_pos` is pulled out so it can be written while the task's
        // path is borrowed (both live on the task).
        let mut res_pos = std::mem::take(&mut self.tasks[id.0].res_pos);
        for (k, r) in self.tasks[id.0].path.iter().enumerate() {
            let ri = r.0;
            assert!(!self.resources[ri].retired, "flow through a retired resource");
            if self.resources[ri].active.is_empty() {
                self.resources[ri].active_pos = self.active_resources.len();
                self.active_resources.push(ri);
            }
            res_pos[k] = self.resources[ri].active.len() as u32;
            self.resources[ri].active.push(id);
            // mark_dirty, inlined (the path borrow pins `self.tasks`).
            if !self.resources[ri].dirty {
                self.resources[ri].dirty = true;
                self.dirty_res.push(ri);
            }
        }
        let pos = self.active_flows.len();
        self.active_flows.push(id);
        let now = self.now;
        let task = &mut self.tasks[id.0];
        task.res_pos = res_pos;
        task.active_pos = pos;
        task.anchor = now;
        task.rate = 0.0;
        task.heap_epoch = 0;
        self.rates_dirty = true;
    }

    /// Max-min fair-share allocation by progressive filling, restricted to
    /// the connected component(s) reachable from resources whose membership
    /// changed since the last recompute.
    ///
    /// Water-filling decomposes exactly over connected components of the
    /// flow↔resource graph: fair shares in one component never read state
    /// from another, so re-filling only the dirty component produces rates
    /// bit-identical to a global fill — flows outside it keep their rates
    /// and their heap deadlines stay live (§Perf: this is what bounds
    /// per-event cost by the coupled set instead of everything active).
    fn recompute_rates(&mut self) {
        self.rates_dirty = false;
        self.rate_epoch += 1;
        if self.active_flows.is_empty() {
            for &ri in &self.dirty_res {
                self.resources[ri].dirty = false;
            }
            self.dirty_res.clear();
            self.flow_heap.clear();
            return;
        }

        // ---- BFS the dirty component over the bipartite graph ----
        self.bfs_epoch += 1;
        let be = self.bfs_epoch;
        self.scr_comp_res.clear();
        self.scr_comp_flows.clear();
        for &ri in &self.dirty_res {
            self.resources[ri].dirty = false;
            if !self.resources[ri].active.is_empty() && self.res_seen[ri] != be {
                self.res_seen[ri] = be;
                self.scr_comp_res.push(ri);
            }
        }
        self.dirty_res.clear();
        let mut qi = 0;
        while qi < self.scr_comp_res.len() {
            let ri = self.scr_comp_res[qi];
            qi += 1;
            let mut fi = 0;
            while fi < self.resources[ri].active.len() {
                let tid = self.resources[ri].active[fi];
                fi += 1;
                if self.task_seen[tid.0] == be {
                    continue;
                }
                self.task_seen[tid.0] = be;
                self.scr_comp_flows.push(tid);
                for r2 in &self.tasks[tid.0].path {
                    if self.res_seen[r2.0] != be {
                        self.res_seen[r2.0] = be;
                        self.scr_comp_res.push(r2.0);
                    }
                }
            }
        }
        if self.scr_comp_flows.is_empty() {
            return;
        }

        // ---- Seed scratch for the component ----
        let nr = self.resources.len();
        self.scr_rem_cap.resize(nr, 0.0);
        self.scr_unset_on.resize(nr, 0);
        for &ri in &self.scr_comp_res {
            let r = &self.resources[ri];
            self.scr_rem_cap[ri] = r.cap.effective(r.active.len());
            self.scr_unset_on[ri] = r.active.len() as u32;
        }
        let ncf = self.scr_comp_flows.len();
        self.scr_old_rate.resize(ncf, 0.0);
        for i in 0..ncf {
            let tid = self.scr_comp_flows[i];
            self.scr_old_rate[i] = self.tasks[tid.0].rate;
            self.tasks[tid.0].rate = f64::NAN;
        }

        // ---- Progressive filling over the component ----
        let mut unset = ncf;
        while unset > 0 {
            // Bottleneck = min fair share among component resources that
            // still carry unset flows (ties: lowest id, for determinism).
            let mut best: Option<(usize, f64)> = None;
            for &ri in &self.scr_comp_res {
                let n = self.scr_unset_on[ri];
                if n == 0 {
                    continue;
                }
                let fair = self.scr_rem_cap[ri] / n as f64;
                match best {
                    Some((bri, bfair)) => {
                        if fair < bfair || (fair == bfair && ri < bri) {
                            best = Some((ri, fair));
                        }
                    }
                    None => best = Some((ri, fair)),
                }
            }
            let Some((bottleneck, fair)) = best else { break };
            // Fix every unset flow crossing the bottleneck at `fair`.
            let mut fi = 0;
            while fi < self.resources[bottleneck].active.len() {
                let t = self.resources[bottleneck].active[fi];
                fi += 1;
                if !self.tasks[t.0].rate.is_nan() {
                    continue;
                }
                self.tasks[t.0].rate = fair;
                unset -= 1;
                // Subtract this flow's rate from every resource it crosses.
                for r in &self.tasks[t.0].path {
                    self.scr_rem_cap[r.0] = (self.scr_rem_cap[r.0] - fair).max(0.0);
                    self.scr_unset_on[r.0] -= 1;
                }
            }
            self.scr_unset_on[bottleneck] = 0;
        }

        // ---- Deadline maintenance (lazy invalidation) ----
        // Only flows whose rate actually changed materialize progression and
        // get a fresh heap entry; everyone else's entry stays live.
        let epoch = self.rate_epoch;
        for i in 0..ncf {
            let tid = self.scr_comp_flows[i];
            if self.tasks[tid.0].rate.is_nan() {
                self.tasks[tid.0].rate = 0.0; // starved
            }
            let new_rate = self.tasks[tid.0].rate;
            let old_rate = self.scr_old_rate[i];
            let changed =
                self.tasks[tid.0].heap_epoch == 0 || new_rate.to_bits() != old_rate.to_bits();
            if !changed {
                continue;
            }
            self.materialize(tid, old_rate);
            let remaining = self.tasks[tid.0].remaining;
            if remaining <= 0.0 {
                self.tasks[tid.0].heap_epoch = epoch;
                self.flow_heap.push(Reverse((OrdF64(self.now), tid, epoch)));
            } else if new_rate > 0.0 {
                self.tasks[tid.0].heap_epoch = epoch;
                let deadline = self.now + remaining / new_rate;
                self.flow_heap.push(Reverse((OrdF64(deadline), tid, epoch)));
            } else {
                // Starved: no deadline until rates change.
                self.tasks[tid.0].heap_epoch = 0;
            }
        }

        // Stale entries are discarded lazily on pop; compact if they ever
        // dominate the heap (bounds memory on churn-heavy runs).
        if self.flow_heap.len() > 2 * self.active_flows.len() + 1024 {
            let heap = std::mem::take(&mut self.flow_heap);
            let tasks = &self.tasks;
            let entries: Vec<_> = heap
                .into_vec()
                .into_iter()
                .filter(|Reverse((_, id, ep))| {
                    tasks[id.0].state == TaskState::Active && tasks[id.0].heap_epoch == *ep
                })
                .collect();
            self.flow_heap = BinaryHeap::from(entries);
        }
    }

    /// Advance a flow's `remaining` (and the byte counters of its path)
    /// from its anchor to `now` under `rate`, clamped to the bytes it
    /// actually had left — never overcounts past the flow's size.
    fn materialize(&mut self, tid: TaskId, rate: f64) {
        let now = self.now;
        let moved = {
            let task = &mut self.tasks[tid.0];
            if !(rate > 0.0 && now > task.anchor && task.remaining > 0.0) {
                task.anchor = now;
                return;
            }
            let moved = (rate * (now - task.anchor)).min(task.remaining);
            task.remaining = (task.remaining - moved).max(0.0);
            task.anchor = now;
            moved
        };
        for r in &self.tasks[tid.0].path {
            self.bytes_through[r.0] += moved;
        }
    }

    /// Advance to the next completion and return it, or `None` when idle.
    pub fn step(&mut self) -> Option<Completion> {
        if self.rates_dirty {
            self.recompute_rates();
        }
        // Scrub invalidated entries off the flow-heap top.
        let flow_top = loop {
            match self.flow_heap.peek() {
                None => break None,
                Some(&Reverse((OrdF64(t), id, ep))) => {
                    let task = &self.tasks[id.0];
                    if task.state == TaskState::Active && task.heap_epoch == ep {
                        break Some((t, id));
                    }
                }
            }
            self.flow_heap.pop();
        };
        let delay_top = self.delay_heap.peek().map(|Reverse((t, id))| (t.0, *id));
        // Earliest completion across both heaps; ties by task id.
        let (time, id, is_flow) = match (flow_top, delay_top) {
            (None, None) => {
                assert!(
                    self.active_flows.is_empty(),
                    "deadlock: active flow starved with no other progress possible"
                );
                return None;
            }
            (Some((ft, fid)), None) => (ft, fid, true),
            (None, Some((dt, did))) => (dt, did, false),
            (Some((ft, fid)), Some((dt, did))) => {
                if ft < dt || (ft == dt && fid < did) {
                    (ft, fid, true)
                } else {
                    (dt, did, false)
                }
            }
        };
        debug_assert!(time - self.now >= -1e-9, "time went backwards: {}", time - self.now);
        if is_flow {
            self.flow_heap.pop();
        } else {
            self.delay_heap.pop();
        }
        self.now = time;
        self.complete(id);
        Some(Completion { task: id, time: self.now, tag: self.tasks[id.0].tag })
    }

    fn complete(&mut self, id: TaskId) {
        let is_flow = self.tasks[id.0].is_flow;
        self.tasks[id.0].state = TaskState::Done;
        self.tasks[id.0].finished_at = self.now;
        if is_flow {
            // Final settlement: whatever was not yet credited moves now —
            // in total a finished flow credits its byte count, bit-exactly
            // when this is its only settlement, to within an ulp per
            // intermediate rate-change settlement otherwise.
            // Path and positions are pulled out because the removal loop
            // retargets *other* tasks' position indices (same `tasks` vec).
            let path = std::mem::take(&mut self.tasks[id.0].path);
            let res_pos = std::mem::take(&mut self.tasks[id.0].res_pos);
            let rem = self.tasks[id.0].remaining;
            self.tasks[id.0].remaining = 0.0;
            for (k, r) in path.iter().enumerate() {
                let ri = r.0;
                self.bytes_through[ri] += rem;
                self.mark_dirty(ri);
                // Swap-remove this flow from the resource's active list,
                // retargeting the moved flow's position index.
                let pos = res_pos[k] as usize;
                debug_assert_eq!(self.resources[ri].active[pos], id);
                let last = self.resources[ri].active.len() - 1;
                self.resources[ri].active.swap_remove(pos);
                if pos < self.resources[ri].active.len() {
                    let moved = self.resources[ri].active[pos];
                    let m = &self.tasks[moved.0];
                    let mut hit = None;
                    for (mk, mr) in m.path.iter().enumerate() {
                        if mr.0 == ri && m.res_pos[mk] as usize == last {
                            hit = Some(mk);
                            break;
                        }
                    }
                    let mk = hit.expect("moved flow must reference this resource");
                    self.tasks[moved.0].res_pos[mk] = pos as u32;
                }
                if self.resources[ri].active.is_empty() {
                    // Drop from the dense active-resource set.
                    let ap = self.resources[ri].active_pos;
                    debug_assert_eq!(self.active_resources[ap], ri);
                    self.active_resources.swap_remove(ap);
                    if ap < self.active_resources.len() {
                        self.resources[self.active_resources[ap]].active_pos = ap;
                    }
                    self.resources[ri].active_pos = NOT_ACTIVE;
                }
                // Scoped resources retire once their declared flow count
                // has crossed them.
                if let Some(uses) = &mut self.resources[ri].uses_left {
                    *uses -= 1;
                    if *uses == 0 {
                        assert!(
                            self.resources[ri].active.is_empty(),
                            "scoped resource exhausted its uses while still carrying flows"
                        );
                        self.resources[ri].retired = true;
                        self.resources[ri].uses_left = None;
                        self.free_slots.push(ri);
                    }
                }
            }
            // Restore the (now settled) path for introspection.
            self.tasks[id.0].path = path;
            // Swap-remove from the dense active-flow set.
            let pos = self.tasks[id.0].active_pos;
            debug_assert_eq!(self.active_flows[pos], id);
            self.active_flows.swap_remove(pos);
            if pos < self.active_flows.len() {
                self.tasks[self.active_flows[pos].0].active_pos = pos;
            }
            self.tasks[id.0].active_pos = NOT_ACTIVE;
            self.rates_dirty = true;
        }
        let dependents = std::mem::take(&mut self.tasks[id.0].dependents);
        for dep in dependents {
            let t = &mut self.tasks[dep.0];
            t.deps_left -= 1;
            if t.deps_left == 0 && t.state == TaskState::Blocked {
                self.activate(dep);
            }
        }
    }

    /// Run everything to quiescence; returns all completions in order.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while let Some(c) = self.step() {
            out.push(c);
        }
        out
    }

    /// Completion time of a finished task.
    pub fn finished_at(&self, id: TaskId) -> f64 {
        let t = &self.tasks[id.0];
        assert_eq!(t.state, TaskState::Done, "task not finished");
        t.finished_at
    }

    /// True if the task has completed.
    pub fn is_done(&self, id: TaskId) -> bool {
        self.tasks[id.0].state == TaskState::Done
    }

    /// Number of tasks registered (for capacity planning in benches).
    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }
}

impl Default for FluidSim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{close, close_ulps, prop_check};

    #[test]
    fn single_flow_bandwidth_limited() {
        let mut sim = FluidSim::new();
        let nic = sim.add_resource("nic", Capacity::Fixed(100.0));
        let f = sim.flow(1000.0, vec![nic], &[], 0);
        sim.run();
        assert!(close(sim.finished_at(f), 10.0, 1e-9));
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("link", Capacity::Fixed(100.0));
        let a = sim.flow(500.0, vec![link], &[], 1);
        let b = sim.flow(500.0, vec![link], &[], 2);
        sim.run();
        // Equal shares: both finish at t=10 (50 B/s each).
        assert!(close(sim.finished_at(a), 10.0, 1e-9));
        assert!(close(sim.finished_at(b), 10.0, 1e-9));
    }

    #[test]
    fn short_flow_releases_bandwidth() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("link", Capacity::Fixed(100.0));
        let a = sim.flow(100.0, vec![link], &[], 1); // finishes at t=2 (50 B/s)
        let b = sim.flow(900.0, vec![link], &[], 2);
        sim.run();
        assert!(close(sim.finished_at(a), 2.0, 1e-9));
        // b: 100 bytes by t=2, then 800 at 100 B/s → t=10.
        assert!(close(sim.finished_at(b), 10.0, 1e-9));
    }

    #[test]
    fn bottleneck_is_min_across_path() {
        let mut sim = FluidSim::new();
        let fast = sim.add_resource("fast", Capacity::Fixed(1000.0));
        let slow = sim.add_resource("slow", Capacity::Fixed(10.0));
        let f = sim.flow(100.0, vec![fast, slow], &[], 0);
        sim.run();
        assert!(close(sim.finished_at(f), 10.0, 1e-9));
    }

    #[test]
    fn max_min_not_just_equal_split() {
        // Two flows share a 100 B/s service; one is also limited by a
        // 20 B/s NIC. Max-min: constrained flow gets 20, other gets 80.
        let mut sim = FluidSim::new();
        let svc = sim.add_resource("svc", Capacity::Fixed(100.0));
        let nic = sim.add_resource("nic", Capacity::Fixed(20.0));
        let slow = sim.flow(20.0, vec![svc, nic], &[], 1); // 1s at rate 20
        let fast = sim.flow(80.0, vec![svc], &[], 2); // 1s at rate 80
        sim.run();
        assert!(close(sim.finished_at(slow), 1.0, 1e-9));
        assert!(close(sim.finished_at(fast), 1.0, 1e-9));
    }

    #[test]
    fn delays_and_deps() {
        let mut sim = FluidSim::new();
        let a = sim.delay(5.0, &[], 1);
        let b = sim.delay(3.0, &[a], 2);
        let link = sim.add_resource("l", Capacity::Fixed(10.0));
        let c = sim.flow(20.0, vec![link], &[b], 3);
        sim.run();
        assert!(close(sim.finished_at(a), 5.0, 1e-9));
        assert!(close(sim.finished_at(b), 8.0, 1e-9));
        assert!(close(sim.finished_at(c), 10.0, 1e-9));
    }

    #[test]
    fn barrier_waits_for_all() {
        let mut sim = FluidSim::new();
        let a = sim.delay(1.0, &[], 0);
        let b = sim.delay(7.0, &[], 0);
        let c = sim.delay(3.0, &[], 0);
        let bar = sim.barrier(&[a, b, c], 9);
        sim.run();
        assert!(close(sim.finished_at(bar), 7.0, 1e-9));
    }

    #[test]
    fn dep_on_done_task_is_satisfied() {
        let mut sim = FluidSim::new();
        let a = sim.delay(1.0, &[], 0);
        sim.run();
        let b = sim.delay(1.0, &[a], 0);
        sim.run();
        assert!(close(sim.finished_at(b), 2.0, 1e-9));
    }

    #[test]
    fn throttled_capacity_collapses() {
        let cap = Capacity::Throttled { base: 100.0, threshold: 4, penalty: 0.5 };
        assert_eq!(cap.effective(4), 100.0);
        assert!(cap.effective(8) < 100.0 / 2.0); // 100/(1+0.5*4)=33.3
        assert!(close(cap.effective(8), 100.0 / 3.0, 1e-9));
    }

    #[test]
    fn throttled_service_slower_in_aggregate() {
        // 10 flows of 100 bytes through a throttled service (threshold 4):
        // finishing takes longer than untrottled 100 B/s would predict.
        let mut run = |cap: Capacity| {
            let mut sim = FluidSim::new();
            let svc = sim.add_resource("svc", cap);
            for i in 0..10 {
                sim.flow(100.0, vec![svc], &[], i);
            }
            sim.run();
            sim.now()
        };
        let fixed = run(Capacity::Fixed(100.0));
        let throttled =
            run(Capacity::Throttled { base: 100.0, threshold: 4, penalty: 0.5 });
        assert!(close(fixed, 10.0, 1e-9));
        assert!(throttled > 15.0, "throttled {throttled}");
    }

    #[test]
    fn injection_mid_run() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("l", Capacity::Fixed(10.0));
        sim.flow(100.0, vec![link], &[], 1);
        let c = sim.step().unwrap();
        assert_eq!(c.tag, 1);
        // Inject a new flow after the first finished.
        let f2 = sim.flow(50.0, vec![link], &[], 2);
        sim.run();
        assert!(close(sim.finished_at(f2), 15.0, 1e-9));
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("l", Capacity::Fixed(10.0));
        let f = sim.flow(0.0, vec![link], &[], 0);
        sim.run();
        assert!(close(sim.finished_at(f), 0.0, 1e-12));
    }

    #[test]
    fn zero_byte_flow_completes_even_when_starved() {
        // A zero-capacity pipe starves real flows but a zero-byte flow has
        // nothing to move and must still complete.
        let mut sim = FluidSim::new();
        let dead = sim.add_resource("dead", Capacity::Fixed(0.0));
        let f = sim.flow(0.0, vec![dead], &[], 0);
        sim.run();
        assert_eq!(sim.finished_at(f), 0.0);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn starved_flow_without_progress_is_a_deadlock() {
        let mut sim = FluidSim::new();
        let dead = sim.add_resource("dead", Capacity::Fixed(0.0));
        sim.flow(10.0, vec![dead], &[], 0);
        sim.run();
    }

    #[test]
    fn bytes_accounting() {
        let mut sim = FluidSim::new();
        let link = sim.add_resource("l", Capacity::Fixed(10.0));
        sim.flow(30.0, vec![link], &[], 0);
        sim.flow(70.0, vec![link], &[], 1);
        sim.run();
        assert!(close(sim.bytes_through(link), 100.0, 1e-6));
    }

    #[test]
    fn completed_flow_credits_exactly_its_bytes() {
        // Regression for the pre-refactor overcount: `rate * dt` was
        // credited to every path resource even past the flow's remaining
        // bytes. A lone completed flow must credit exactly its size.
        // (Bit-exactness holds here because nothing changes the flow's
        // rate mid-transfer — the interleaved delays never trigger a
        // recompute, so completion is its only settlement. A workload
        // with intermediate settlements is ulp-close instead; see
        // prop_conservation_and_capacity.)
        let mut sim = FluidSim::new();
        let a = sim.add_resource("a", Capacity::Fixed(7.0));
        let b = sim.add_resource("b", Capacity::Fixed(13.0));
        let f = sim.flow(123.456, vec![a, b], &[], 0);
        // Interleave unrelated delays so the flow crosses several events.
        sim.delay(3.0, &[], 1);
        sim.delay(9.0, &[], 2);
        sim.run();
        assert!(sim.is_done(f));
        assert_eq!(sim.bytes_through(a).to_bits(), 123.456f64.to_bits());
        assert_eq!(sim.bytes_through(b).to_bits(), 123.456f64.to_bits());
    }

    // ---- scoped resources / free list ----

    #[test]
    fn scoped_resource_retires_and_slot_recycles() {
        let mut sim = FluidSim::new();
        let nic = sim.add_resource("nic", Capacity::Fixed(1e9));
        let mut prev: Vec<TaskId> = Vec::new();
        for i in 0..200u64 {
            let st = sim.add_resource_scoped("st", Capacity::Fixed(1e9), 1);
            prev = vec![sim.flow(1e6, vec![st, nic], &prev, i)];
            sim.run();
        }
        // One persistent NIC + at most one live stream slot at a time.
        assert!(sim.resource_slots() <= 3, "slots grew: {}", sim.resource_slots());
    }

    #[test]
    fn scoped_resource_with_multiple_uses() {
        let mut sim = FluidSim::new();
        let pool = sim.add_resource_scoped("pool", Capacity::Fixed(100.0), 2);
        let a = sim.flow(100.0, vec![pool], &[], 1);
        let b = sim.flow(100.0, vec![pool], &[a], 2);
        sim.run();
        assert!(sim.is_done(b));
        // Both uses consumed → the slot is recyclable.
        let again = sim.add_resource("fresh", Capacity::Fixed(1.0));
        assert_eq!(again.0, pool.0, "retired slot should be recycled");
        assert_eq!(sim.bytes_through(again), 0.0, "recycled slot stats reset");
    }

    #[test]
    fn explicit_retire_recycles_slot() {
        let mut sim = FluidSim::new();
        let tmp = sim.add_resource("tmp", Capacity::Fixed(5.0));
        let f = sim.flow(10.0, vec![tmp], &[], 0);
        sim.run();
        assert!(sim.is_done(f));
        sim.retire_resource(tmp);
        let next = sim.add_resource("next", Capacity::Fixed(9.0));
        assert_eq!(next.0, tmp.0);
        match sim.capacity(next) {
            Capacity::Fixed(c) => assert_eq!(*c, 9.0),
            _ => panic!("wrong capacity"),
        }
    }

    #[test]
    #[should_panic(expected = "active flows")]
    fn retiring_busy_resource_panics() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource("busy", Capacity::Fixed(5.0));
        sim.flow(10.0, vec![r], &[], 0);
        sim.retire_resource(r);
    }

    #[test]
    fn retirement_mid_run_keeps_schedule_sane() {
        // Streams retire while unrelated flows are still moving; the
        // remaining traffic must be unaffected.
        let mut sim = FluidSim::new();
        let nic = sim.add_resource("nic", Capacity::Fixed(100.0));
        let long = sim.flow(1000.0, vec![nic], &[], 1);
        let st = sim.add_resource_scoped("st", Capacity::Fixed(1000.0), 1);
        let short = sim.flow(50.0, vec![st, nic], &[], 2);
        sim.run();
        assert!(sim.finished_at(short) < sim.finished_at(long));
        // 50 B each at t=1 → long has 950 left at 100 B/s → 10.5 s total.
        assert!(close(sim.finished_at(long), 10.5, 1e-9));
    }

    // ---- property tests ----

    #[test]
    fn prop_conservation_and_capacity() {
        prop_check(60, |g| {
            let mut sim = FluidSim::new();
            let cap = g.f64_in(10.0, 1000.0);
            let link = sim.add_resource("l", Capacity::Fixed(cap));
            let n = g.usize_in(1, 20);
            let mut total = 0.0;
            for i in 0..n {
                let bytes = g.f64_in(1.0, 5000.0);
                total += bytes;
                sim.flow(bytes, vec![link], &[], i as u64);
            }
            sim.run();
            // Conservation: all bytes crossed the link, to within rounding
            // of the per-flow settlements (a few ulps — the completion
            // credit is exact per flow; see `completed_flow_credits_...`).
            prop_assert!(
                close_ulps(sim.bytes_through(link), total, 256),
                "bytes_through {} vs {}",
                sim.bytes_through(link),
                total
            );
            // Capacity: makespan >= total/cap (can't beat the pipe).
            prop_assert!(
                sim.now() >= total / cap - 1e-6,
                "makespan {} < {}",
                sim.now(),
                total / cap
            );
            Ok(())
        });
    }

    #[test]
    fn prop_equal_flows_finish_together() {
        prop_check(40, |g| {
            let mut sim = FluidSim::new();
            let link = sim.add_resource("l", Capacity::Fixed(g.f64_in(10.0, 100.0)));
            let n = g.usize_in(2, 16);
            let bytes = g.f64_in(10.0, 1000.0);
            let ids: Vec<TaskId> =
                (0..n).map(|i| sim.flow(bytes, vec![link], &[], i as u64)).collect();
            sim.run();
            let t0 = sim.finished_at(ids[0]);
            for &id in &ids[1..] {
                prop_assert!(close(sim.finished_at(id), t0, 1e-9));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dag_ordering_respected() {
        prop_check(40, |g| {
            let mut sim = FluidSim::new();
            let link = sim.add_resource("l", Capacity::Fixed(100.0));
            // Random chain of tasks; each must finish no earlier than its dep.
            let n = g.usize_in(2, 24);
            let mut prev: Option<TaskId> = None;
            let mut ids = Vec::new();
            for i in 0..n {
                let deps: Vec<TaskId> = prev.into_iter().collect();
                let id = if g.bool() {
                    sim.delay(g.f64_in(0.0, 5.0), &deps, i as u64)
                } else {
                    sim.flow(g.f64_in(1.0, 200.0), vec![link], &deps, i as u64)
                };
                ids.push(id);
                prev = Some(id);
            }
            sim.run();
            for w in ids.windows(2) {
                prop_assert!(sim.finished_at(w[1]) >= sim.finished_at(w[0]) - 1e-9);
            }
            Ok(())
        });
    }

    #[test]
    fn prop_more_bandwidth_never_slower() {
        prop_check(30, |g| {
            let n = g.usize_in(2, 12);
            let sizes: Vec<f64> = (0..n).map(|_| g.f64_in(10.0, 1000.0)).collect();
            let cap = g.f64_in(10.0, 100.0);
            let mk = |c: f64, sizes: &[f64]| {
                let mut sim = FluidSim::new();
                let link = sim.add_resource("l", Capacity::Fixed(c));
                for (i, &b) in sizes.iter().enumerate() {
                    sim.flow(b, vec![link], &[], i as u64);
                }
                sim.run();
                sim.now()
            };
            let slow = mk(cap, &sizes);
            let fast = mk(cap * 2.0, &sizes);
            prop_assert!(fast <= slow + 1e-9, "fast {fast} slow {slow}");
            Ok(())
        });
    }

    #[test]
    fn prop_scoped_streams_never_grow_the_table() {
        // The replay shape: every read allocates a fresh stream; retirement
        // must keep the table bounded by the *concurrent* stream count.
        prop_check(10, |g| {
            let mut sim = FluidSim::new();
            let nic = sim.add_resource("nic", Capacity::Fixed(1e9));
            let rounds = g.usize_in(5, 40);
            let width = g.usize_in(1, 6);
            let mut prev: Vec<TaskId> = Vec::new();
            for round in 0..rounds {
                let gate = sim.barrier(&prev, 0);
                prev = (0..width)
                    .map(|s| {
                        let st =
                            sim.add_resource_scoped("st", Capacity::Fixed(2e8), 1);
                        sim.flow(
                            g.f64_in(1e5, 1e7),
                            vec![st, nic],
                            &[gate],
                            (round * 10 + s) as u64,
                        )
                    })
                    .collect();
                sim.run();
            }
            prop_assert!(
                sim.resource_slots() <= 1 + width + 1,
                "slots {} for width {}",
                sim.resource_slots(),
                width
            );
            Ok(())
        });
    }
}
