//! The pre-refactor fluid engine, preserved verbatim as a *golden
//! reference*.
//!
//! [`ReferenceSim`] is the engine exactly as it stood before the
//! O(active)-bounded rewrite of [`crate::sim::engine::FluidSim`]: linear
//! scan over `active_flows` for event selection, per-step progression of
//! every active flow, `retain()` membership removal, and a
//! `recompute_rates` that iterates every resource ever created. It also
//! intentionally preserves the pre-refactor `bytes_through` accounting
//! (crediting `rate * dt` unclamped — the overcount the new engine fixes),
//! because its role is to reproduce the *old* behaviour, bugs and all.
//!
//! Two things keep it around:
//!
//! * `sim::golden` drives it and the new engine through identical
//!   workloads and pins schedule equivalence (bit-exact where the
//!   workload's fp history coincides, order-identical and ulp-close
//!   everywhere — see `docs/sim_engine.md` §Equivalence).
//! * `micro_simnet` benchmarks the new engine's churn-case speedup
//!   against it, and the recorded ratio is regression-gated through
//!   `BENCH_simnet.json`.
//!
//! Do not "fix" or optimize this file; it is a measurement baseline.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sim::engine::{Capacity, Completion, ResourceId, TaskId, Work};

/// f64 ordered for the delay heap via `total_cmp` (see `engine::OrdF64`).
struct OrdF64(f64);
impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[derive(Clone, Debug)]
struct Resource {
    cap: Capacity,
    active: Vec<TaskId>,
    #[allow(dead_code)]
    name: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TaskState {
    Blocked,
    Active,
    Done,
}

#[derive(Clone, Debug)]
struct Task {
    work: Work,
    state: TaskState,
    deps_left: usize,
    dependents: Vec<TaskId>,
    remaining: f64,
    rate: f64,
    tag: u64,
    finished_at: f64,
}

/// The pre-refactor simulator (see module docs).
pub struct ReferenceSim {
    now: f64,
    resources: Vec<Resource>,
    tasks: Vec<Task>,
    active_flows: Vec<TaskId>,
    delay_heap: BinaryHeap<Reverse<(OrdF64, TaskId)>>,
    rates_dirty: bool,
    bytes_through: Vec<f64>,
    scr_rem_cap: Vec<f64>,
    scr_unset_on: Vec<u32>,
    scr_touched: Vec<usize>,
}

impl ReferenceSim {
    pub fn new() -> ReferenceSim {
        ReferenceSim {
            now: 0.0,
            resources: Vec::new(),
            tasks: Vec::new(),
            active_flows: Vec::new(),
            delay_heap: BinaryHeap::new(),
            rates_dirty: false,
            bytes_through: Vec::new(),
            scr_rem_cap: Vec::new(),
            scr_unset_on: Vec::new(),
            scr_touched: Vec::new(),
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn add_resource(&mut self, name: &str, cap: Capacity) -> ResourceId {
        self.resources.push(Resource { cap, active: Vec::new(), name: name.to_string() });
        self.bytes_through.push(0.0);
        ResourceId(self.resources.len() - 1)
    }

    pub fn bytes_through(&self, r: ResourceId) -> f64 {
        self.bytes_through[r.0]
    }

    pub fn add_task(&mut self, work: Work, deps: &[TaskId], tag: u64) -> TaskId {
        let id = TaskId(self.tasks.len());
        let mut deps_left = 0;
        for &d in deps {
            debug_assert!(d.0 < self.tasks.len(), "dependency on unknown task");
            if self.tasks[d.0].state != TaskState::Done {
                self.tasks[d.0].dependents.push(id);
                deps_left += 1;
            }
        }
        let remaining = match &work {
            Work::Delay(d) => {
                assert!(*d >= 0.0 && d.is_finite(), "bad delay {d}");
                *d
            }
            Work::Flow { bytes, path } => {
                assert!(*bytes >= 0.0 && bytes.is_finite(), "bad flow bytes {bytes}");
                assert!(!path.is_empty(), "flow with empty path");
                *bytes
            }
        };
        self.tasks.push(Task {
            work,
            state: TaskState::Blocked,
            deps_left,
            dependents: Vec::new(),
            remaining,
            rate: 0.0,
            tag,
            finished_at: f64::NAN,
        });
        if deps_left == 0 {
            self.activate(id);
        }
        id
    }

    pub fn delay(&mut self, seconds: f64, deps: &[TaskId], tag: u64) -> TaskId {
        self.add_task(Work::Delay(seconds), deps, tag)
    }

    pub fn flow(
        &mut self,
        bytes: f64,
        path: Vec<ResourceId>,
        deps: &[TaskId],
        tag: u64,
    ) -> TaskId {
        self.add_task(Work::Flow { bytes, path }, deps, tag)
    }

    pub fn barrier(&mut self, deps: &[TaskId], tag: u64) -> TaskId {
        self.add_task(Work::Delay(0.0), deps, tag)
    }

    fn activate(&mut self, id: TaskId) {
        let task = &mut self.tasks[id.0];
        debug_assert_eq!(task.state, TaskState::Blocked);
        task.state = TaskState::Active;
        match &task.work {
            Work::Delay(_) => {
                task.remaining += self.now;
                let t = task.remaining;
                self.delay_heap.push(Reverse((OrdF64(t), id)));
            }
            Work::Flow { path, .. } => {
                let path = path.clone();
                for r in path {
                    self.resources[r.0].active.push(id);
                }
                self.active_flows.push(id);
                self.rates_dirty = true;
            }
        }
    }

    fn recompute_rates(&mut self) {
        self.rates_dirty = false;
        let nf = self.active_flows.len();
        if nf == 0 {
            return;
        }
        let nr = self.resources.len();
        self.scr_rem_cap.resize(nr, 0.0);
        self.scr_unset_on.resize(nr, 0);
        self.scr_touched.clear();
        for (ri, r) in self.resources.iter().enumerate() {
            if !r.active.is_empty() {
                self.scr_rem_cap[ri] = r.cap.effective(r.active.len());
                self.scr_unset_on[ri] = r.active.len() as u32;
                self.scr_touched.push(ri);
            }
        }
        for &t in &self.active_flows {
            self.tasks[t.0].rate = f64::NAN;
        }
        let mut unset = nf;
        while unset > 0 {
            let mut best: Option<(usize, f64)> = None;
            for &ri in &self.scr_touched {
                let n = self.scr_unset_on[ri];
                if n == 0 {
                    continue;
                }
                let fair = self.scr_rem_cap[ri] / n as f64;
                match best {
                    Some((bri, bfair)) => {
                        if fair < bfair || (fair == bfair && ri < bri) {
                            best = Some((ri, fair));
                        }
                    }
                    None => best = Some((ri, fair)),
                }
            }
            let Some((bottleneck, fair)) = best else { break };
            let mut fi = 0;
            while fi < self.resources[bottleneck].active.len() {
                let t = self.resources[bottleneck].active[fi];
                fi += 1;
                if !self.tasks[t.0].rate.is_nan() {
                    continue;
                }
                self.tasks[t.0].rate = fair;
                unset -= 1;
                let task_ptr = t.0;
                if let Work::Flow { path, .. } = &self.tasks[task_ptr].work {
                    for r in path {
                        let ri = r.0;
                        self.scr_rem_cap[ri] = (self.scr_rem_cap[ri] - fair).max(0.0);
                        self.scr_unset_on[ri] -= 1;
                    }
                }
            }
            self.scr_unset_on[bottleneck] = 0;
        }
        for &ri in &self.scr_touched {
            self.scr_rem_cap[ri] = 0.0;
            self.scr_unset_on[ri] = 0;
        }
        for &t in &self.active_flows {
            if self.tasks[t.0].rate.is_nan() {
                self.tasks[t.0].rate = 0.0;
            }
        }
    }

    pub fn step(&mut self) -> Option<Completion> {
        if self.rates_dirty {
            self.recompute_rates();
        }
        let mut best: Option<(f64, TaskId)> =
            self.delay_heap.peek().map(|Reverse((t, id))| (t.0, *id));
        for &id in &self.active_flows {
            let task = &self.tasks[id.0];
            let t = if task.rate > 0.0 {
                self.now + task.remaining / task.rate
            } else if task.remaining <= 0.0 {
                self.now
            } else {
                f64::INFINITY
            };
            let better = match best {
                None => true,
                Some((bt, bid)) => t < bt || (t == bt && id < bid),
            };
            if better {
                best = Some((t, id));
            }
        }
        let (time, id) = best?;
        assert!(
            time.is_finite(),
            "deadlock: active flow starved with no other progress possible"
        );
        let dt = time - self.now;
        debug_assert!(dt >= -1e-9, "time went backwards: {dt}");
        let dt = dt.max(0.0);
        if dt > 0.0 {
            for &fid in &self.active_flows {
                let rate = self.tasks[fid.0].rate;
                let moved = rate * dt;
                self.tasks[fid.0].remaining = (self.tasks[fid.0].remaining - moved).max(0.0);
                if let Work::Flow { path, .. } = &self.tasks[fid.0].work {
                    for r in path.clone() {
                        self.bytes_through[r.0] += moved;
                    }
                }
            }
        }
        self.now = time;
        self.complete(id);
        Some(Completion { task: id, time: self.now, tag: self.tasks[id.0].tag })
    }

    fn complete(&mut self, id: TaskId) {
        let is_flow = matches!(self.tasks[id.0].work, Work::Flow { .. });
        self.tasks[id.0].state = TaskState::Done;
        self.tasks[id.0].finished_at = self.now;
        if is_flow {
            self.active_flows.retain(|&t| t != id);
            if let Work::Flow { path, .. } = self.tasks[id.0].work.clone() {
                for r in path {
                    self.resources[r.0].active.retain(|&t| t != id);
                }
            }
            self.rates_dirty = true;
        } else {
            let popped = self.delay_heap.pop().expect("delay heap empty");
            debug_assert_eq!(popped.0 .1, id);
        }
        let dependents = std::mem::take(&mut self.tasks[id.0].dependents);
        for dep in dependents {
            let t = &mut self.tasks[dep.0];
            t.deps_left -= 1;
            if t.deps_left == 0 && t.state == TaskState::Blocked {
                self.activate(dep);
            }
        }
    }

    pub fn run(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while let Some(c) = self.step() {
            out.push(c);
        }
        out
    }

    pub fn finished_at(&self, id: TaskId) -> f64 {
        let t = &self.tasks[id.0];
        assert_eq!(t.state, TaskState::Done, "task not finished");
        t.finished_at
    }

    pub fn is_done(&self, id: TaskId) -> bool {
        self.tasks[id.0].state == TaskState::Done
    }

    /// Total resource slots — grows without bound in the reference engine
    /// (it has no retire/free-list API); golden tests contrast this with
    /// the new engine's bounded table.
    pub fn resource_slots(&self) -> usize {
        self.resources.len()
    }
}

impl Default for ReferenceSim {
    fn default() -> Self {
        Self::new()
    }
}
