//! Discrete-event simulation substrate: the fluid-flow engine
//! (`engine`) and the cluster resource layout built on it (`cluster`).

pub mod cluster;
pub mod engine;

pub use cluster::ClusterSim;
pub use engine::{Capacity, Completion, FluidSim, ResourceId, TaskId, Work};
