//! Discrete-event simulation substrate: the fluid-flow engine
//! (`engine`), the pre-refactor engine kept as a golden reference
//! (`reference`), the cross-engine golden workloads (`golden`), and the
//! cluster resource layout built on the engine (`cluster`).

pub mod cluster;
pub mod engine;
pub mod golden;
pub mod reference;

pub use cluster::{ClusterSim, NodeHandle, PathBetween, RackId, SpineId, Topology};
pub use engine::{Capacity, Completion, FluidSim, ResourceId, TaskId, Work};
