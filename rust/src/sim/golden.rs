//! Golden-schedule workloads: identical task graphs driven through the new
//! [`FluidSim`] and the pre-refactor [`ReferenceSim`].
//!
//! The engine refactor must not change what the simulator *computes* —
//! every schedule the repo has ever produced (startup figures, the
//! cluster-week replay) has to come out the same. Since the two engines
//! share no code, the strongest pin available is to drive both through
//! the same deterministic workloads and compare the full
//! `(task, finished_at, tag)` completion streams:
//!
//! * **Order** must be identical, event for event (same task, same tag,
//!   same position). This pins the schedule *structure* exactly.
//! * **Times** must be bit-identical wherever the two engines perform the
//!   same float operations — which is every workload whose flows all
//!   re-rate at every event (`throttled_churn`, `equal_ties`). Where the
//!   old engine's per-step progression touches a flow across events that
//!   don't change its rate, its fp history differs from the lazy engine's
//!   by design, and times agree to a few ulps instead (bounded here at
//!   [`MAX_SCHEDULE_ULPS`]; measured ≤ 8 across all seeds). See
//!   `docs/sim_engine.md` §Equivalence for why bit-exactness across that
//!   boundary is unattainable at O(log n) per event.
//!
//! The workloads deliberately mirror the shapes the pipelines compile:
//! shared services + per-node NICs, throttled backends, striped reads over
//! retiring per-read stream resources, global barriers, equal-flow ties,
//! and mid-run injection. `churn` is the 20k-flow/2k-resource scale case
//! `micro_simnet` benchmarks both engines on.

use crate::sim::engine::{Capacity, Completion, FluidSim, ResourceId, TaskId};
use crate::sim::reference::ReferenceSim;

/// Largest acceptable ulp distance between the engines' completion times
/// on the golden workloads (measured maximum is 8; see module docs).
pub const MAX_SCHEDULE_ULPS: u64 = 64;

/// The surface both engines expose, so one workload definition drives
/// either. The reference engine has no retirement — scoped adds degrade to
/// plain adds there, which is exactly the pre-refactor behaviour.
pub trait SimApi {
    fn add_resource(&mut self, name: &str, cap: Capacity) -> ResourceId;
    fn add_resource_scoped(&mut self, name: &str, cap: Capacity, uses: u32) -> ResourceId;
    fn delay(&mut self, seconds: f64, deps: &[TaskId], tag: u64) -> TaskId;
    fn flow(&mut self, bytes: f64, path: Vec<ResourceId>, deps: &[TaskId], tag: u64) -> TaskId;
    fn barrier(&mut self, deps: &[TaskId], tag: u64) -> TaskId;
    fn step(&mut self) -> Option<Completion>;
    fn run(&mut self) -> Vec<Completion>;
    fn now(&self) -> f64;
    fn finished_at(&self, id: TaskId) -> f64;
}

impl SimApi for FluidSim {
    fn add_resource(&mut self, name: &str, cap: Capacity) -> ResourceId {
        FluidSim::add_resource(self, name, cap)
    }
    fn add_resource_scoped(&mut self, name: &str, cap: Capacity, uses: u32) -> ResourceId {
        FluidSim::add_resource_scoped(self, name, cap, uses)
    }
    fn delay(&mut self, seconds: f64, deps: &[TaskId], tag: u64) -> TaskId {
        FluidSim::delay(self, seconds, deps, tag)
    }
    fn flow(&mut self, bytes: f64, path: Vec<ResourceId>, deps: &[TaskId], tag: u64) -> TaskId {
        FluidSim::flow(self, bytes, path, deps, tag)
    }
    fn barrier(&mut self, deps: &[TaskId], tag: u64) -> TaskId {
        FluidSim::barrier(self, deps, tag)
    }
    fn step(&mut self) -> Option<Completion> {
        FluidSim::step(self)
    }
    fn run(&mut self) -> Vec<Completion> {
        FluidSim::run(self)
    }
    fn now(&self) -> f64 {
        FluidSim::now(self)
    }
    fn finished_at(&self, id: TaskId) -> f64 {
        FluidSim::finished_at(self, id)
    }
}

impl SimApi for ReferenceSim {
    fn add_resource(&mut self, name: &str, cap: Capacity) -> ResourceId {
        ReferenceSim::add_resource(self, name, cap)
    }
    fn add_resource_scoped(&mut self, name: &str, cap: Capacity, _uses: u32) -> ResourceId {
        // Pre-refactor engine: no scoping, the slot lives forever.
        ReferenceSim::add_resource(self, name, cap)
    }
    fn delay(&mut self, seconds: f64, deps: &[TaskId], tag: u64) -> TaskId {
        ReferenceSim::delay(self, seconds, deps, tag)
    }
    fn flow(&mut self, bytes: f64, path: Vec<ResourceId>, deps: &[TaskId], tag: u64) -> TaskId {
        ReferenceSim::flow(self, bytes, path, deps, tag)
    }
    fn barrier(&mut self, deps: &[TaskId], tag: u64) -> TaskId {
        ReferenceSim::barrier(self, deps, tag)
    }
    fn step(&mut self) -> Option<Completion> {
        ReferenceSim::step(self)
    }
    fn run(&mut self) -> Vec<Completion> {
        ReferenceSim::run(self)
    }
    fn now(&self) -> f64 {
        ReferenceSim::now(self)
    }
    fn finished_at(&self, id: TaskId) -> f64 {
        ReferenceSim::finished_at(self, id)
    }
}

/// SplitMix64 — self-contained so the workloads depend on nothing but the
/// engine under test. (Validated against an out-of-tree twin of both
/// engines; keep in sync if you port these workloads.)
pub struct MiniRng {
    state: u64,
}

impl MiniRng {
    pub fn new(seed: u64) -> MiniRng {
        MiniRng { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn below(&mut self, n: u64) -> u64 {
        (((self.next_u64() as u128) * n as u128) >> 64) as u64
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }
}

/// Shared service + per-node NICs; per-node chains delay→flow→delay→flow,
/// plus standalone delays ticking while flows are active (the spans where
/// the engines' fp histories legitimately diverge by ulps).
pub fn fanout_shared(sim: &mut dyn SimApi, seed: u64) -> TaskId {
    let mut rng = MiniRng::new(seed);
    let link = sim.add_resource("link", Capacity::Fixed(1.0e9));
    let n = 40usize;
    let nics: Vec<ResourceId> =
        (0..n).map(|i| sim.add_resource(&format!("nic{i}"), Capacity::Fixed(3.0e8))).collect();
    let mut ends = Vec::with_capacity(n);
    for &nic in &nics {
        let d0 = sim.delay(rng.range_f64(0.1, 2.0), &[], 0);
        let f0 = sim.flow(rng.range_f64(1e6, 5e8), vec![link, nic], &[d0], 0);
        let d1 = sim.delay(rng.range_f64(0.05, 1.0), &[f0], 0);
        let f1 = sim.flow(rng.range_f64(1e6, 2e8), vec![link, nic], &[d1], 0);
        ends.push(f1);
    }
    for k in 0..25u64 {
        sim.delay(rng.range_f64(0.0, 4.0), &[], 1000 + k);
    }
    sim.barrier(&ends, 9999)
}

/// Waves of flows through a throttled backend, each wave gated on the
/// last — fully coupled, so the engines share an fp history bit for bit.
pub fn throttled_churn(sim: &mut dyn SimApi, seed: u64) -> TaskId {
    let mut rng = MiniRng::new(seed);
    let svc = sim.add_resource(
        "svc",
        Capacity::Throttled { base: 2.0e9, threshold: 8, penalty: 0.3 },
    );
    let sink = sim.add_resource("sink", Capacity::Fixed(5.0e9));
    let mut prev: Vec<TaskId> = Vec::new();
    for wave in 0..6u64 {
        let deps: Vec<TaskId> = if prev.is_empty() {
            Vec::new()
        } else {
            vec![sim.barrier(&prev, 0)]
        };
        prev = Vec::new();
        let count = rng.below(20) + 4;
        for i in 0..count {
            let d = sim.delay(rng.range_f64(0.0, 0.5), &deps, 0);
            let f = sim.flow(rng.range_f64(1e5, 8e7), vec![svc, sink], &[d], wave * 100 + i);
            prev.push(f);
        }
    }
    sim.barrier(&prev, 9999)
}

/// Striped-read shape: per-flow scoped stream resources + shared DataNode
/// groups and NICs, two rounds so retired stream slots get reused mid-run.
pub fn streams_retire(sim: &mut dyn SimApi, seed: u64) -> TaskId {
    let mut rng = MiniRng::new(seed);
    let n_groups = 6usize;
    let groups: Vec<ResourceId> = (0..n_groups)
        .map(|g| sim.add_resource(&format!("g{g}"), Capacity::Fixed(3.75e9)))
        .collect();
    let n_nodes = 12usize;
    let nics: Vec<ResourceId> = (0..n_nodes)
        .map(|i| sim.add_resource(&format!("n{i}"), Capacity::Fixed(3.125e9)))
        .collect();
    let mut reads = Vec::with_capacity(n_nodes);
    for node in 0..n_nodes {
        let nn = sim.delay(0.004 * 4.0, &[], 0);
        let mut parts = Vec::with_capacity(4);
        for s in 0..4usize {
            let st = sim.add_resource_scoped("st", Capacity::Fixed(1.6e9), 1);
            let b = rng.range_f64(1e8, 2e9);
            parts.push(sim.flow(b, vec![st, groups[(node + s) % n_groups], nics[node]], &[nn], 0));
        }
        reads.push(sim.barrier(&parts, node as u64));
    }
    let bar = sim.barrier(&reads, 0);
    let mut reads2 = Vec::with_capacity(n_nodes);
    for node in 0..n_nodes {
        let mut parts = Vec::with_capacity(3);
        for s in 0..3usize {
            let st = sim.add_resource_scoped("st2", Capacity::Fixed(1.6e9), 1);
            let b = rng.range_f64(5e7, 9e8);
            parts
                .push(sim.flow(b, vec![st, groups[(node + s) % n_groups], nics[node]], &[bar], 0));
        }
        reads2.push(sim.barrier(&parts, 100 + node as u64));
    }
    sim.barrier(&reads2, 9999)
}

/// Exact equal-fair ties: identical flows through one link, two waves.
pub fn equal_ties(sim: &mut dyn SimApi, _seed: u64) -> TaskId {
    let link = sim.add_resource("link", Capacity::Fixed(1.0e8));
    let ids: Vec<TaskId> = (0..32u64).map(|i| sim.flow(5.0e7, vec![link], &[], i)).collect();
    let bar = sim.barrier(&ids, 9999);
    let ids2: Vec<TaskId> =
        (0..16u64).map(|i| sim.flow(2.5e7, vec![link], &[bar], 100 + i)).collect();
    sim.barrier(&ids2, 10000)
}

/// Step-driven mid-run injection: every tag-1 completion injects a fresh
/// flow over a new scoped stream — the lazy-miss / retry shape. Returns
/// the completion stream directly (the run is the driver).
pub fn injection(sim: &mut dyn SimApi, seed: u64) -> Vec<Completion> {
    let mut rng = MiniRng::new(seed);
    let pool = sim.add_resource("pool", Capacity::Fixed(8.0e9));
    let nics: Vec<ResourceId> = (0..8)
        .map(|i| sim.add_resource(&format!("inic{i}"), Capacity::Fixed(2.0e9)))
        .collect();
    for &nic in &nics {
        sim.flow(rng.range_f64(1e8, 1e9), vec![pool, nic], &[], 1);
    }
    let mut out = Vec::new();
    let mut budget = 60u32;
    while let Some(c) = sim.step() {
        out.push(c);
        if c.tag == 1 && budget > 0 {
            budget -= 1;
            let node = rng.below(8) as usize;
            let st = sim.add_resource_scoped("ist", Capacity::Fixed(1.5e9), 1);
            let tag = if budget > 10 { 1 } else { 2 };
            sim.flow(rng.range_f64(5e6, 4e8), vec![pool, st, nics[node]], &[], tag);
        }
    }
    out
}

/// Tag marking a churn wave's completion barrier (`+ wave index`).
const CHURN_WAVE_TAG: u64 = 7_000_000;

/// Inject one churn wave: per node, admit-delay → `width` striped
/// downloads over fresh scoped streams + shared group + NIC → CPU delay →
/// node-local disk staging flow → SCM package pull.
#[allow(clippy::too_many_arguments)]
fn churn_wave(
    sim: &mut dyn SimApi,
    rng: &mut MiniRng,
    w: usize,
    width: usize,
    groups: &[ResourceId],
    nics: &[ResourceId],
    disks: &[ResourceId],
    scm: ResourceId,
) -> TaskId {
    let nodes = nics.len();
    let n_groups = groups.len();
    let mut pkgs = Vec::with_capacity(nodes);
    for i in 0..nodes {
        let admit = sim.delay(rng.range_f64(0.05, 0.4), &[], 0);
        // All of a chain's streams read one group — the stripe-file set of
        // one physical file lands group-local, so reader clusters stay
        // per-group instead of coupling the whole fleet.
        let group = groups[(w * 7 + i) % n_groups];
        let mut parts = Vec::with_capacity(width);
        for _s in 0..width {
            let st = sim.add_resource_scoped("st", Capacity::Fixed(1.6e9), 1);
            parts.push(sim.flow(
                rng.range_f64(5e7, 2e9),
                vec![st, group, nics[i]],
                &[admit],
                0,
            ));
        }
        let dl = sim.barrier(&parts, 0);
        let cpu = sim.delay(rng.range_f64(0.1, 2.0), &[dl], 0);
        let stage = sim.flow(rng.range_f64(5e7, 1e9), vec![disks[i]], &[cpu], 0);
        let pkg = sim.flow(rng.range_f64(1e6, 6e7), vec![scm, nics[i]], &[stage], 0);
        pkgs.push(pkg);
    }
    sim.barrier(&pkgs, CHURN_WAVE_TAG + w as u64)
}

/// The scale case (`micro_simnet`): waves of per-node chains, each wave
/// *injected mid-run* when the previous wave's barrier completes — the
/// replay's actual shape, with per-read stream resources retiring as their
/// flow finishes and their slots recycled by the next wave. Peak
/// concurrency ≈ `nodes × width` flows; the live resource table stays
/// ~`2·nodes + groups + nodes×width` in the new engine while the
/// reference engine's table grows by `nodes × width` per wave forever.
/// Returns the full completion stream (step-driven).
pub fn churn(
    sim: &mut dyn SimApi,
    seed: u64,
    nodes: usize,
    waves: usize,
    width: usize,
) -> Vec<Completion> {
    let mut rng = MiniRng::new(seed);
    let n_groups = 64usize;
    let groups: Vec<ResourceId> = (0..n_groups)
        .map(|g| sim.add_resource(&format!("g{g}"), Capacity::Fixed(3.75e9)))
        .collect();
    let nics: Vec<ResourceId> = (0..nodes)
        .map(|i| sim.add_resource(&format!("nic{i}"), Capacity::Fixed(3.125e9)))
        .collect();
    let disks: Vec<ResourceId> = (0..nodes)
        .map(|i| sim.add_resource(&format!("d{i}"), Capacity::Fixed(4.0e9)))
        .collect();
    let scm = sim.add_resource(
        "scm",
        Capacity::Throttled { base: 25e9, threshold: 96, penalty: 0.003 },
    );
    churn_wave(sim, &mut rng, 0, width, &groups, &nics, &disks, scm);
    let mut out = Vec::new();
    while let Some(c) = sim.step() {
        if c.tag >= CHURN_WAVE_TAG {
            let w = (c.tag - CHURN_WAVE_TAG) as usize;
            if w + 1 < waves {
                churn_wave(sim, &mut rng, w + 1, width, &groups, &nics, &disks, scm);
            }
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::ulps_between;

    fn batch(
        build: fn(&mut dyn SimApi, u64) -> TaskId,
        seed: u64,
    ) -> (Vec<Completion>, Vec<Completion>) {
        let mut old = ReferenceSim::new();
        build(&mut old, seed);
        let cold = ReferenceSim::run(&mut old);
        let mut new = FluidSim::new();
        build(&mut new, seed);
        let cnew = FluidSim::run(&mut new);
        (cold, cnew)
    }

    /// Order identical event-for-event; times within MAX_SCHEDULE_ULPS.
    fn assert_equivalent(name: &str, cold: &[Completion], cnew: &[Completion]) {
        assert_eq!(cold.len(), cnew.len(), "{name}: event count");
        for (i, (a, b)) in cold.iter().zip(cnew).enumerate() {
            assert_eq!(a.task, b.task, "{name}: task order diverged at event {i}");
            assert_eq!(a.tag, b.tag, "{name}: tag diverged at event {i}");
            let u = ulps_between(a.time, b.time);
            assert!(
                u <= MAX_SCHEDULE_ULPS,
                "{name}: time diverged {} ulps at event {i}: {} vs {}",
                u,
                a.time,
                b.time
            );
        }
    }

    /// Bit-exact: the stricter pin, for fully-coupled workloads.
    fn assert_bit_identical(name: &str, cold: &[Completion], cnew: &[Completion]) {
        assert_eq!(cold.len(), cnew.len(), "{name}: event count");
        for (i, (a, b)) in cold.iter().zip(cnew).enumerate() {
            assert_eq!(a.task, b.task, "{name}: task at {i}");
            assert_eq!(a.tag, b.tag, "{name}: tag at {i}");
            assert_eq!(
                a.time.to_bits(),
                b.time.to_bits(),
                "{name}: time bits at event {i}: {} vs {}",
                a.time,
                b.time
            );
        }
    }

    #[test]
    fn golden_fanout_shared_schedules_match() {
        for seed in [1u64, 2, 7, 42] {
            let (cold, cnew) = batch(fanout_shared, seed);
            assert_equivalent(&format!("fanout_shared/{seed}"), &cold, &cnew);
        }
    }

    #[test]
    fn golden_throttled_churn_is_bit_identical() {
        for seed in [1u64, 2, 7, 42] {
            let (cold, cnew) = batch(throttled_churn, seed);
            assert_bit_identical(&format!("throttled_churn/{seed}"), &cold, &cnew);
        }
    }

    #[test]
    fn golden_streams_retire_schedules_match() {
        for seed in [1u64, 2, 7, 42] {
            let (cold, cnew) = batch(streams_retire, seed);
            assert_equivalent(&format!("streams_retire/{seed}"), &cold, &cnew);
        }
    }

    #[test]
    fn golden_equal_ties_is_bit_identical() {
        for seed in [1u64, 7] {
            let (cold, cnew) = batch(equal_ties, seed);
            assert_bit_identical(&format!("equal_ties/{seed}"), &cold, &cnew);
        }
    }

    #[test]
    fn golden_injection_schedules_match() {
        for seed in [1u64, 7] {
            let mut old = ReferenceSim::new();
            let cold = injection(&mut old, seed);
            let mut new = FluidSim::new();
            let cnew = injection(&mut new, seed);
            assert_equivalent(&format!("injection/{seed}"), &cold, &cnew);
        }
    }

    #[test]
    fn golden_churn_schedules_match_and_table_stays_bounded() {
        let (nodes, waves, width) = (120, 4, 2);
        let mut old = ReferenceSim::new();
        let cold = churn(&mut old, 42, nodes, waves, width);
        let mut new = FluidSim::new();
        let cnew = churn(&mut new, 42, nodes, waves, width);
        assert_equivalent("churn", &cold, &cnew);
        // Retirement + slot recycling keep the new engine's table bounded
        // by the *concurrent* stream count; the reference engine accretes
        // one slot per stream forever.
        let base = 64 + 2 * nodes + 1;
        assert!(
            new.resource_slots() <= base + nodes * width,
            "new table grew: {} vs base {base} + {} streams",
            new.resource_slots(),
            nodes * width
        );
        assert_eq!(old.resource_slots(), base + nodes * width * waves);
    }

}
