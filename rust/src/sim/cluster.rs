//! Cluster resource layout over the fluid engine.
//!
//! Instantiates the star topology the paper's startup traffic flows over:
//! every worker node has a frontend NIC and a local disk; shared services
//! (container registry, cluster block cache, SCM/package backend, HDFS
//! DataNode groups) have aggregate egress capacities. Per-node heterogeneity
//! (the straggler source) is a sampled slowdown multiplier applied to CPU
//! work on that node.

use crate::config::ClusterConfig;
use crate::sim::engine::{Capacity, FluidSim, ResourceId};
use crate::util::rng::{Rng, TailedSlowdown};

/// Identifies a worker node within a job's allocation.
pub type NodeIdx = usize;

/// The simulated cluster: a FluidSim plus the resource ids of every pipe.
pub struct ClusterSim {
    pub sim: FluidSim,
    pub cfg: ClusterConfig,
    /// Per-node NIC (shared by ingress + egress; startup traffic is
    /// overwhelmingly ingress so a single pipe is adequate).
    pub node_nic: Vec<ResourceId>,
    /// Per-node local disk (block staging, cache restore, ckpt materialize).
    pub node_disk: Vec<ResourceId>,
    /// Container registry aggregate egress.
    pub registry: ResourceId,
    /// Cluster-level block cache egress.
    pub cache: ResourceId,
    /// SCM / package backend (throttled).
    pub scm: ResourceId,
    /// HDFS DataNode group egress pipes.
    pub hdfs_groups: Vec<ResourceId>,
    /// Per-node CPU slowdown multipliers (>= 0.7; heavy right tail).
    pub slowdown: Vec<f64>,
    /// RNG stream for pipeline-level randomness (retries, placement).
    pub rng: Rng,
}

impl ClusterSim {
    /// Build a cluster of `cfg.nodes` nodes; `seed` fixes all sampled
    /// heterogeneity.
    pub fn build(cfg: &ClusterConfig, seed: u64) -> ClusterSim {
        let mut sim = FluidSim::new();
        let mut rng = Rng::seeded(seed);
        let slow_model = TailedSlowdown {
            tail_prob: cfg.straggler_tail_prob,
            body_std: cfg.straggler_body_std,
            tail_scale: 1.5,
            tail_alpha: cfg.straggler_tail_alpha,
            cap: cfg.straggler_cap,
        };
        let mut node_nic = Vec::with_capacity(cfg.nodes as usize);
        let mut node_disk = Vec::with_capacity(cfg.nodes as usize);
        let mut slowdown = Vec::with_capacity(cfg.nodes as usize);
        for i in 0..cfg.nodes {
            node_nic.push(
                sim.add_resource(&format!("node{i}.nic"), Capacity::Fixed(cfg.node_nic_bps)),
            );
            node_disk.push(sim.add_resource(
                &format!("node{i}.disk"),
                Capacity::Fixed(cfg.node_disk_write_bps),
            ));
            slowdown.push(slow_model.sample(&mut rng));
        }
        let registry =
            sim.add_resource("registry", Capacity::Fixed(cfg.registry_egress_bps));
        let cache = sim.add_resource("cache", Capacity::Fixed(cfg.cluster_cache_egress_bps));
        let scm = sim.add_resource(
            "scm",
            Capacity::Throttled {
                base: cfg.scm_egress_bps,
                threshold: cfg.scm_throttle_concurrency,
                penalty: cfg.scm_throttle_penalty,
            },
        );
        // DataNodes are grouped by replication group; a striped read fans
        // out over many groups, a classic contiguous read hits few.
        let n_groups = (cfg.hdfs_datanodes / cfg.hdfs_replication).max(1);
        let hdfs_groups = (0..n_groups)
            .map(|g| {
                sim.add_resource(
                    &format!("hdfs.group{g}"),
                    Capacity::Fixed(
                        cfg.hdfs_datanode_egress_bps * cfg.hdfs_replication as f64,
                    ),
                )
            })
            .collect();
        ClusterSim {
            sim,
            cfg: cfg.clone(),
            node_nic,
            node_disk,
            registry,
            cache,
            scm,
            hdfs_groups,
            slowdown,
            rng,
        }
    }

    pub fn nodes(&self) -> usize {
        self.node_nic.len()
    }

    /// The DataNode group node `i`'s single-stream HDFS traffic lands on
    /// (round-robin by node — one definition shared by the FUSE planner,
    /// the env-cache restore and the speculative stager, so they can never
    /// disagree about placement).
    pub fn hdfs_group_of(&self, node: NodeIdx) -> ResourceId {
        self.hdfs_groups[node % self.hdfs_groups.len()]
    }

    /// CPU time for `nominal` seconds of work on `node` (slowdown applied).
    pub fn cpu_time(&self, node: NodeIdx, nominal: f64) -> f64 {
        nominal * self.slowdown[node]
    }

    /// Aggregate HDFS egress capacity (all groups).
    pub fn hdfs_total_bps(&self) -> f64 {
        self.hdfs_groups.len() as f64
            * self.cfg.hdfs_datanode_egress_bps
            * self.cfg.hdfs_replication as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn build_creates_all_resources() {
        let cfg = ClusterConfig::with_nodes(4);
        let c = ClusterSim::build(&cfg, 1);
        assert_eq!(c.nodes(), 4);
        assert_eq!(c.node_nic.len(), 4);
        assert_eq!(c.node_disk.len(), 4);
        assert_eq!(c.slowdown.len(), 4);
        assert!(!c.hdfs_groups.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ClusterConfig::with_nodes(64);
        let a = ClusterSim::build(&cfg, 42);
        let b = ClusterSim::build(&cfg, 42);
        assert_eq!(a.slowdown, b.slowdown);
        let c = ClusterSim::build(&cfg, 43);
        assert_ne!(a.slowdown, c.slowdown);
    }

    #[test]
    fn slowdowns_mostly_near_one() {
        let cfg = ClusterConfig::with_nodes(1000);
        let c = ClusterSim::build(&cfg, 7);
        let near = c.slowdown.iter().filter(|&&s| (0.8..1.3).contains(&s)).count();
        assert!(near as f64 / 1000.0 > 0.95);
        assert!(c.slowdown.iter().all(|&s| s >= 0.7));
    }

    #[test]
    fn cpu_time_scales_with_slowdown() {
        let cfg = ClusterConfig::with_nodes(2);
        let c = ClusterSim::build(&cfg, 11);
        assert!((c.cpu_time(0, 10.0) - 10.0 * c.slowdown[0]).abs() < 1e-12);
    }

    #[test]
    fn hdfs_groups_partition_datanodes() {
        let cfg = ClusterConfig::with_nodes(2);
        let c = ClusterSim::build(&cfg, 1);
        assert_eq!(
            c.hdfs_groups.len(),
            (cfg.hdfs_datanodes / cfg.hdfs_replication) as usize
        );
    }

    #[test]
    fn prop_large_clusters_build_fast_and_sane() {
        prop_check(10, |g| {
            let nodes = g.usize_in(1, 1500) as u32;
            let cfg = ClusterConfig::with_nodes(nodes);
            let c = ClusterSim::build(&cfg, g.rng.next_u64());
            prop_assert!(c.nodes() == nodes as usize);
            prop_assert!(c.slowdown.iter().all(|&s| s > 0.0 && s <= cfg.straggler_cap));
            Ok(())
        });
    }
}
