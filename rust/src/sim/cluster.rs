//! Cluster resource layout over the fluid engine.
//!
//! Instantiates the topology the paper's startup traffic flows over. The
//! default is the flat star of the original model: every worker node has a
//! frontend NIC and a local disk; shared services (container registry,
//! cluster block cache, SCM/package backend, HDFS DataNode groups) have
//! aggregate egress capacities. With `ClusterConfig::racks > 1` the star
//! becomes a node → rack → spine tree: each rack gets a ToR uplink pipe and
//! the racks share one (possibly oversubscribed) spine-core pipe, and
//! service traffic to a node traverses both (`ClusterSim::tier_path`). The
//! flat default creates **zero** topology resources, so every pre-topology
//! figure and golden stays byte-identical.
//!
//! Per-node heterogeneity (the straggler source) is a sampled slowdown
//! multiplier applied to CPU work on that node.
//!
//! The query surface is typed: [`NodeHandle`] identifies a node,
//! [`Topology`] answers rack/spine membership and [`PathBetween`] relation
//! queries, and the accessors (`nic`, `disk`, `cpu_time`, `hdfs_group_of`,
//! `tier_path`) take handles — no subsystem reconstructs rack membership by
//! index arithmetic.

use crate::config::ClusterConfig;
use crate::sim::engine::{Capacity, FluidSim, ResourceId};
use crate::util::rng::{Rng, TailedSlowdown};

/// Identifies a worker node within a job's allocation by position.
///
/// Superseded by the typed [`NodeHandle`] API; kept as a documented alias
/// for the low-level planners (`hdfs::fuse`) that index the per-node
/// resource vectors directly.
pub type NodeIdx = usize;

/// Typed handle to a worker node within a job's allocation.
///
/// A thin newtype over the node's position: cheap to copy, and the only
/// currency the cluster accessors accept, so rack/spine membership always
/// comes from [`Topology`] rather than ad-hoc index arithmetic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NodeHandle(usize);

impl NodeHandle {
    /// Handle to the node at position `i` in the allocation.
    pub fn new(i: usize) -> NodeHandle {
        NodeHandle(i)
    }

    /// The node's position (index into the per-node resource vectors).
    pub fn index(self) -> usize {
        self.0
    }
}

/// Identifies a rack (ToR domain) within the topology tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RackId(pub u32);

/// Identifies a spine block within the topology tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SpineId(pub u32);

/// Network relation between two nodes in the node → rack → spine tree:
/// which shared tiers a transfer between them must traverse.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathBetween {
    /// Same node: loopback, no shared fabric.
    SameNode,
    /// Same rack: traffic stays under one ToR.
    SameRack,
    /// Different racks under the same spine block: both rack uplinks.
    SameSpine,
    /// Different spine blocks: both rack uplinks plus the spine core.
    CrossSpine,
}

/// The node → rack → spine tree: per-node rack membership plus the tier
/// shape. Built from a [`ClusterConfig`] (contiguous rack blocks) or from
/// an explicit per-node placement (a fragmented allocation handed back by
/// the gang scheduler).
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    /// Rack of each node, by node position.
    rack_of: Vec<u32>,
    racks: u32,
    spines: u32,
    /// Contiguous racks per spine block.
    racks_per_spine: u32,
}

impl Topology {
    /// Default placement for `cfg`: nodes fill racks in contiguous blocks
    /// of `ceil(nodes / racks)`.
    pub fn of(cfg: &ClusterConfig) -> Topology {
        let racks = cfg.racks.max(1);
        let rack_size = ((cfg.nodes + racks - 1) / racks).max(1);
        let rack_of = (0..cfg.nodes).map(|i| (i / rack_size).min(racks - 1)).collect();
        Topology::from_rack_of(rack_of, racks, cfg.spines.max(1))
    }

    /// Explicit placement: `placement[i]` is the rack of node `i` (values
    /// clamp into `0..cfg.racks`). Used by the replay to rebuild a job's
    /// cluster view over the allocation the gang scheduler actually chose.
    pub fn placed(cfg: &ClusterConfig, placement: &[u32]) -> Topology {
        let racks = cfg.racks.max(1);
        let rack_of = placement.iter().map(|&r| r.min(racks - 1)).collect();
        Topology::from_rack_of(rack_of, racks, cfg.spines.max(1))
    }

    fn from_rack_of(rack_of: Vec<u32>, racks: u32, spines: u32) -> Topology {
        let spines = spines.min(racks).max(1);
        let racks_per_spine = ((racks + spines - 1) / spines).max(1);
        Topology { rack_of, racks, spines, racks_per_spine }
    }

    /// Is this the flat star (single rack)? Flat topologies add no tree
    /// resources and are byte-identical to the pre-topology model.
    pub fn is_flat(&self) -> bool {
        self.racks <= 1
    }

    /// Rack count of the tree.
    pub fn racks(&self) -> u32 {
        self.racks
    }

    /// Spine-block count of the tree.
    pub fn spines(&self) -> u32 {
        self.spines
    }

    /// The rack node `n` lives in.
    pub fn rack_of(&self, n: NodeHandle) -> RackId {
        RackId(self.rack_of[n.index()])
    }

    /// The spine block node `n`'s rack hangs off.
    pub fn spine_of(&self, n: NodeHandle) -> SpineId {
        SpineId(self.rack_of[n.index()] / self.racks_per_spine)
    }

    /// Network relation between two nodes (which shared tiers a transfer
    /// between them traverses).
    pub fn path_between(&self, a: NodeHandle, b: NodeHandle) -> PathBetween {
        if a == b {
            PathBetween::SameNode
        } else if self.rack_of(a) == self.rack_of(b) {
            PathBetween::SameRack
        } else if self.spine_of(a) == self.spine_of(b) {
            PathBetween::SameSpine
        } else {
            PathBetween::CrossSpine
        }
    }

    /// Hop distance of [`path_between`](Self::path_between): 0 loopback,
    /// 1 in-rack, 2 rack-to-rack under one spine, 3 across spine blocks.
    pub fn distance(&self, a: NodeHandle, b: NodeHandle) -> u32 {
        match self.path_between(a, b) {
            PathBetween::SameNode => 0,
            PathBetween::SameRack => 1,
            PathBetween::SameSpine => 2,
            PathBetween::CrossSpine => 3,
        }
    }

    /// How many *other* nodes of the allocation share node `n`'s rack —
    /// the swarm peers reachable without crossing the ToR uplink.
    pub fn in_rack_peers(&self, n: NodeHandle) -> usize {
        let r = self.rack_of[n.index()];
        self.rack_of.iter().filter(|&&x| x == r).count().saturating_sub(1)
    }
}

/// The simulated cluster: a FluidSim plus the resource ids of every pipe.
pub struct ClusterSim {
    pub sim: FluidSim,
    pub cfg: ClusterConfig,
    /// Per-node NIC (shared by ingress + egress; startup traffic is
    /// overwhelmingly ingress so a single pipe is adequate).
    pub node_nic: Vec<ResourceId>,
    /// Per-node local disk (block staging, cache restore, ckpt materialize).
    pub node_disk: Vec<ResourceId>,
    /// Container registry aggregate egress.
    pub registry: ResourceId,
    /// Cluster-level block cache egress.
    pub cache: ResourceId,
    /// SCM / package backend (throttled).
    pub scm: ResourceId,
    /// HDFS DataNode group egress pipes.
    pub hdfs_groups: Vec<ResourceId>,
    /// Per-node CPU slowdown multipliers (>= 0.7; heavy right tail).
    pub slowdown: Vec<f64>,
    /// RNG stream for pipeline-level randomness (retries, placement).
    pub rng: Rng,
    /// The node → rack → spine tree this allocation is placed over.
    pub topo: Topology,
    /// Per-rack ToR uplink pipes; empty on a flat topology.
    pub rack_up: Vec<ResourceId>,
    /// Spine-core pipe shared by cross-rack traffic; `None` when flat.
    pub spine_core: Option<ResourceId>,
}

impl ClusterSim {
    /// Build a cluster of `cfg.nodes` nodes with the default contiguous
    /// rack placement; `seed` fixes all sampled heterogeneity.
    pub fn build(cfg: &ClusterConfig, seed: u64) -> ClusterSim {
        ClusterSim::build_placed(cfg, seed, None)
    }

    /// Build a cluster over an explicit per-node rack `placement` (the
    /// allocation the gang scheduler chose); `None` is the contiguous
    /// default. The placement changes only topology pipes and membership —
    /// node resources, service pipes and sampled slowdowns are identical
    /// for a given `(cfg, seed)` regardless of placement.
    pub fn build_placed(cfg: &ClusterConfig, seed: u64, placement: Option<&[u32]>) -> ClusterSim {
        let mut sim = FluidSim::new();
        let mut rng = Rng::seeded(seed);
        let slow_model = TailedSlowdown {
            tail_prob: cfg.straggler_tail_prob,
            body_std: cfg.straggler_body_std,
            tail_scale: 1.5,
            tail_alpha: cfg.straggler_tail_alpha,
            cap: cfg.straggler_cap,
        };
        let mut node_nic = Vec::with_capacity(cfg.nodes as usize);
        let mut node_disk = Vec::with_capacity(cfg.nodes as usize);
        let mut slowdown = Vec::with_capacity(cfg.nodes as usize);
        for i in 0..cfg.nodes {
            node_nic.push(
                sim.add_resource(&format!("node{i}.nic"), Capacity::Fixed(cfg.node_nic_bps)),
            );
            node_disk.push(sim.add_resource(
                &format!("node{i}.disk"),
                Capacity::Fixed(cfg.node_disk_write_bps),
            ));
            slowdown.push(slow_model.sample(&mut rng));
        }
        let registry =
            sim.add_resource("registry", Capacity::Fixed(cfg.registry_egress_bps));
        let cache = sim.add_resource("cache", Capacity::Fixed(cfg.cluster_cache_egress_bps));
        let scm = sim.add_resource(
            "scm",
            Capacity::Throttled {
                base: cfg.scm_egress_bps,
                threshold: cfg.scm_throttle_concurrency,
                penalty: cfg.scm_throttle_penalty,
            },
        );
        // DataNodes are grouped by replication group; a striped read fans
        // out over many groups, a classic contiguous read hits few.
        let n_groups = (cfg.hdfs_datanodes / cfg.hdfs_replication).max(1);
        let hdfs_groups = (0..n_groups)
            .map(|g| {
                sim.add_resource(
                    &format!("hdfs.group{g}"),
                    Capacity::Fixed(
                        cfg.hdfs_datanode_egress_bps * cfg.hdfs_replication as f64,
                    ),
                )
            })
            .collect();
        // Topology pipes come last so the flat default (which creates
        // none) leaves every pre-existing ResourceId — and therefore the
        // deterministic bottleneck tie-break — untouched.
        let topo = match placement {
            Some(p) => Topology::placed(cfg, p),
            None => Topology::of(cfg),
        };
        let mut rack_up = Vec::new();
        let mut spine_core = None;
        if !topo.is_flat() {
            let rack_size = ((cfg.nodes + topo.racks() - 1) / topo.racks()).max(1);
            let uplink_bps = if cfg.rack_uplink_bps > 0.0 {
                cfg.rack_uplink_bps
            } else {
                // Auto: a non-blocking ToR for a full rack of nodes.
                rack_size as f64 * cfg.node_nic_bps
            };
            for r in 0..topo.racks() {
                rack_up
                    .push(sim.add_resource(&format!("rack{r}.up"), Capacity::Fixed(uplink_bps)));
            }
            let core_bps = if cfg.spine_core_bps > 0.0 {
                cfg.spine_core_bps
            } else {
                topo.racks() as f64 * uplink_bps / cfg.spine_oversub.max(1.0)
            };
            spine_core = Some(sim.add_resource("spine.core", Capacity::Fixed(core_bps)));
        }
        ClusterSim {
            sim,
            cfg: cfg.clone(),
            node_nic,
            node_disk,
            registry,
            cache,
            scm,
            hdfs_groups,
            slowdown,
            rng,
            topo,
            rack_up,
            spine_core,
        }
    }

    /// Node count of the allocation.
    pub fn nodes(&self) -> usize {
        self.node_nic.len()
    }

    /// Typed handle to node `i` (position in the allocation).
    pub fn node(&self, i: usize) -> NodeHandle {
        debug_assert!(i < self.nodes(), "node {i} out of range");
        NodeHandle::new(i)
    }

    /// Handles to every node of the allocation, in position order.
    pub fn handles(&self) -> Vec<NodeHandle> {
        (0..self.nodes()).map(NodeHandle::new).collect()
    }

    /// Node `n`'s frontend NIC pipe.
    pub fn nic(&self, n: NodeHandle) -> ResourceId {
        self.node_nic[n.index()]
    }

    /// Node `n`'s local-disk pipe.
    pub fn disk(&self, n: NodeHandle) -> ResourceId {
        self.node_disk[n.index()]
    }

    /// The tree tiers a transfer between node `n` and the shared services
    /// (registry, cluster cache, SCM, HDFS — all outside the racks)
    /// traverses: the spine core plus `n`'s rack uplink. Empty on a flat
    /// topology, so appending it to a flow path is a no-op there.
    pub fn tier_path(&self, n: NodeHandle) -> Vec<ResourceId> {
        match self.spine_core {
            Some(core) => vec![core, self.rack_up[self.topo.rack_of(n).0 as usize]],
            None => Vec::new(),
        }
    }

    /// The DataNode group node `n`'s single-stream HDFS traffic lands on
    /// (round-robin by node — one definition shared by the FUSE planner,
    /// the env-cache restore and the speculative stager, so they can never
    /// disagree about placement).
    pub fn hdfs_group_of(&self, n: NodeHandle) -> ResourceId {
        self.hdfs_groups[n.index() % self.hdfs_groups.len()]
    }

    /// CPU time for `nominal` seconds of work on node `n` (slowdown
    /// applied).
    pub fn cpu_time(&self, n: NodeHandle, nominal: f64) -> f64 {
        nominal * self.slowdown[n.index()]
    }

    /// Aggregate HDFS egress capacity (all groups).
    pub fn hdfs_total_bps(&self) -> f64 {
        self.hdfs_groups.len() as f64
            * self.cfg.hdfs_datanode_egress_bps
            * self.cfg.hdfs_replication as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn build_creates_all_resources() {
        let cfg = ClusterConfig::with_nodes(4);
        let c = ClusterSim::build(&cfg, 1);
        assert_eq!(c.nodes(), 4);
        assert_eq!(c.node_nic.len(), 4);
        assert_eq!(c.node_disk.len(), 4);
        assert_eq!(c.slowdown.len(), 4);
        assert!(!c.hdfs_groups.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ClusterConfig::with_nodes(64);
        let a = ClusterSim::build(&cfg, 42);
        let b = ClusterSim::build(&cfg, 42);
        assert_eq!(a.slowdown, b.slowdown);
        let c = ClusterSim::build(&cfg, 43);
        assert_ne!(a.slowdown, c.slowdown);
    }

    #[test]
    fn slowdowns_mostly_near_one() {
        let cfg = ClusterConfig::with_nodes(1000);
        let c = ClusterSim::build(&cfg, 7);
        let near = c.slowdown.iter().filter(|&&s| (0.8..1.3).contains(&s)).count();
        assert!(near as f64 / 1000.0 > 0.95);
        assert!(c.slowdown.iter().all(|&s| s >= 0.7));
    }

    #[test]
    fn cpu_time_scales_with_slowdown() {
        let cfg = ClusterConfig::with_nodes(2);
        let c = ClusterSim::build(&cfg, 11);
        assert!((c.cpu_time(c.node(0), 10.0) - 10.0 * c.slowdown[0]).abs() < 1e-12);
    }

    #[test]
    fn hdfs_groups_partition_datanodes() {
        let cfg = ClusterConfig::with_nodes(2);
        let c = ClusterSim::build(&cfg, 1);
        assert_eq!(
            c.hdfs_groups.len(),
            (cfg.hdfs_datanodes / cfg.hdfs_replication) as usize
        );
    }

    #[test]
    fn prop_large_clusters_build_fast_and_sane() {
        prop_check(10, |g| {
            let nodes = g.usize_in(1, 1500) as u32;
            let cfg = ClusterConfig::with_nodes(nodes);
            let c = ClusterSim::build(&cfg, g.rng.next_u64());
            prop_assert!(c.nodes() == nodes as usize);
            prop_assert!(c.slowdown.iter().all(|&s| s > 0.0 && s <= cfg.straggler_cap));
            Ok(())
        });
    }

    #[test]
    fn flat_topology_creates_no_tree_resources() {
        // The flat default must leave the resource table — and therefore
        // every ResourceId and bottleneck tie-break — exactly as before
        // the topology layer existed.
        let cfg = ClusterConfig::with_nodes(8);
        let flat = ClusterSim::build(&cfg, 5);
        assert!(flat.topo.is_flat());
        assert!(flat.rack_up.is_empty());
        assert!(flat.spine_core.is_none());
        assert!(flat.tier_path(flat.node(3)).is_empty());
        let one_rack = ClusterConfig { racks: 1, spines: 1, ..cfg.clone() };
        let explicit = ClusterSim::build(&one_rack, 5);
        assert_eq!(flat.sim.resource_slots(), explicit.sim.resource_slots());
        assert_eq!(flat.slowdown, explicit.slowdown);
    }

    #[test]
    fn tree_membership_and_path_relations() {
        let cfg = ClusterConfig { racks: 4, spines: 2, ..ClusterConfig::with_nodes(8) };
        let c = ClusterSim::build(&cfg, 1);
        assert!(!c.topo.is_flat());
        assert_eq!(c.rack_up.len(), 4);
        assert!(c.spine_core.is_some());
        // Contiguous blocks of 2: nodes 0-1 rack 0, 2-3 rack 1, ...
        assert_eq!(c.topo.rack_of(c.node(0)), RackId(0));
        assert_eq!(c.topo.rack_of(c.node(3)), RackId(1));
        assert_eq!(c.topo.rack_of(c.node(7)), RackId(3));
        assert_eq!(c.topo.spine_of(c.node(0)), SpineId(0));
        assert_eq!(c.topo.spine_of(c.node(7)), SpineId(1));
        assert_eq!(c.topo.path_between(c.node(0), c.node(0)), PathBetween::SameNode);
        assert_eq!(c.topo.path_between(c.node(0), c.node(1)), PathBetween::SameRack);
        assert_eq!(c.topo.path_between(c.node(0), c.node(2)), PathBetween::SameSpine);
        assert_eq!(c.topo.path_between(c.node(0), c.node(7)), PathBetween::CrossSpine);
        assert_eq!(c.topo.distance(c.node(0), c.node(7)), 3);
        assert_eq!(c.topo.in_rack_peers(c.node(0)), 1);
        // tier_path lists the core then the node's own rack uplink.
        let path = c.tier_path(c.node(5));
        assert_eq!(path, vec![c.spine_core.unwrap(), c.rack_up[2]]);
    }

    #[test]
    fn placed_topology_overrides_contiguous_blocks() {
        let cfg = ClusterConfig { racks: 2, ..ClusterConfig::with_nodes(4) };
        // Striped placement: alternate racks instead of contiguous halves.
        let c = ClusterSim::build_placed(&cfg, 9, Some(&[0, 1, 0, 1]));
        assert_eq!(c.topo.rack_of(c.node(1)), RackId(1));
        assert_eq!(c.topo.rack_of(c.node(2)), RackId(0));
        assert_eq!(c.topo.in_rack_peers(c.node(0)), 1);
        // Placement never perturbs sampled heterogeneity.
        let default = ClusterSim::build(&cfg, 9);
        assert_eq!(c.slowdown, default.slowdown);
        // Out-of-range racks clamp instead of panicking.
        let clamped = Topology::placed(&cfg, &[0, 99]);
        assert_eq!(clamped.rack_of(NodeHandle::new(1)), RackId(1));
    }

    #[test]
    fn cross_spine_flow_respects_oversubscription_exactly() {
        // Auto-sized core = racks x uplink / oversub. With the NIC and
        // uplinks non-binding, a single service flow must finish in
        // exactly bytes / core_bps.
        let cfg = ClusterConfig {
            racks: 4,
            spines: 2,
            node_nic_bps: 1.0e15,
            rack_uplink_bps: 1.0e12,
            spine_oversub: 8.0,
            ..ClusterConfig::with_nodes(8)
        };
        let mut c = ClusterSim::build(&cfg, 1);
        let core_bps = 4.0 * 1.0e12 / 8.0;
        match c.sim.capacity(c.spine_core.unwrap()) {
            Capacity::Fixed(b) => assert_eq!(*b, core_bps),
            other => panic!("spine core must be a fixed pipe, got {other:?}"),
        }
        let n = c.node(0);
        let mut path = vec![c.nic(n)];
        path.extend(c.tier_path(n));
        let t = c.sim.flow(8.0e12, path, &[], 0);
        c.sim.run();
        assert_eq!(c.sim.finished_at(t), 8.0e12 / core_bps);
    }
}
